//! Splittable xoshiro256** PRNG.
//!
//! The offline registry has no `rand` crate, so every stochastic
//! component (partition restarts, random walks, SBM sampling, parameter
//! init, baseline samplers) draws from this deterministic generator.
//! Each component takes an explicit seed so whole experiments replay
//! bit-for-bit.

/// xoshiro256** generator (Blackman & Vigna). Passes BigCrush; more
/// than adequate for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator from a single `u64` via splitmix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (used to hand each worker
    /// thread / restart its own stream without sharing state).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        // 24 high bits -> mantissa-exact uniform in [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = (1.0 - self.gen_f64()) as f32; // avoid ln(0)
        let u2 = self.gen_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly choose one element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// Sample an index from unnormalised non-negative weights.
    /// Falls back to uniform if the weights sum to zero.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_range(weights.len());
        }
        let mut target = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Reservoir-sample `k` distinct indices out of `0..n`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let mut reservoir: Vec<usize> = (0..k).collect();
        for i in k..n {
            let j = self.gen_range(i + 1);
            if j < k {
                reservoir[j] = i;
            }
        }
        reservoir
    }
}

/// Zipfian sampler over ranks `0..n`: rank `k` carries probability
/// mass proportional to `1/(k+1)^s`. `s = 0` degenerates to uniform;
/// larger `s` concentrates the mass on the first ranks — the standard
/// model for query-popularity skew. Setup is O(n) (one cumulative
/// table), each draw O(log n) by binary search, and draws are fully
/// determined by the driving [`Rng`] stream.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the cumulative table for `n` ranks with skew `s ≥ 0`.
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf support must be non-empty");
        assert!(s >= 0.0 && s.is_finite(), "Zipf skew must be finite and non-negative");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one rank in `0..len()`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        // first rank whose cumulative mass exceeds the uniform draw
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for bound in [1usize, 2, 3, 7, 100, 12345] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_support() {
        let mut r = Rng::seed_from_u64(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f32_unit_interval() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1000 {
            let x = r.gen_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::seed_from_u64(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_prefers_heavy() {
        let mut r = Rng::seed_from_u64(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(19);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut r = Rng::seed_from_u64(21);
        let z = Zipf::new(64, 1.1);
        assert_eq!(z.len(), 64);
        let mut counts = [0usize; 64];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[31], "mass must fall with rank");
        let head: usize = counts[..8].iter().sum();
        assert!(head * 2 > 20_000, "s=1.1 concentrates over half the mass in the head");
        // s = 0 degenerates to uniform: the same head gets ~1/8
        let z0 = Zipf::new(64, 0.0);
        let mut c0 = [0usize; 64];
        for _ in 0..20_000 {
            c0[z0.sample(&mut r)] += 1;
        }
        let head0: usize = c0[..8].iter().sum();
        assert!(head0 < 5_000, "uniform head got {head0}/20000");
    }

    #[test]
    fn zipf_deterministic_for_stream() {
        let z = Zipf::new(10, 0.9);
        let mut a = Rng::seed_from_u64(9);
        let mut b = Rng::seed_from_u64(9);
        for _ in 0..200 {
            assert_eq!(z.sample(&mut a), z.sample(&mut b));
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Rng::seed_from_u64(23);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
