//! Dataset persistence + real-data loader.
//!
//! Two formats:
//!
//! * **GADB** — a simple self-describing text format for saving and
//!   reloading any [`Dataset`] (so generated corpora can be pinned and
//!   shared, and so experiments replay byte-identical inputs).
//! * **Planetoid text** — the classic `*.content` / `*.cites` pair of
//!   the real Cora/Citeseer releases. This image is offline, but a
//!   user with the files gets the real data through the same [`Dataset`]
//!   type.

use super::{Dataset, Split};
use crate::graph::GraphBuilder;
use crate::tensor::Matrix;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

/// Serialise to the GADB text format.
pub fn to_gadb(ds: &Dataset) -> String {
    let n = ds.num_nodes();
    let f = ds.feature_dim();
    let mut s = String::new();
    let _ = writeln!(s, "GADB 1");
    let _ = writeln!(s, "name {}", ds.name);
    let _ = writeln!(s, "nodes {n} features {f} classes {}", ds.num_classes);
    for v in 0..n {
        let fold = if ds.split.train[v] {
            't'
        } else if ds.split.val[v] {
            'v'
        } else {
            's'
        };
        let _ = write!(s, "n {} {}", ds.labels[v], fold);
        // sparse feature encoding: index:value pairs
        for (d, &x) in ds.features.row(v).iter().enumerate() {
            if x != 0.0 {
                let _ = write!(s, " {d}:{x}");
            }
        }
        s.push('\n');
    }
    for (u, v) in ds.graph.edges() {
        let _ = writeln!(s, "e {u} {v}");
    }
    s
}

/// Parse the GADB text format.
pub fn from_gadb(text: &str) -> Result<Dataset> {
    let mut lines = text.lines();
    let magic = lines.next().ok_or_else(|| anyhow!("empty file"))?;
    if magic.trim() != "GADB 1" {
        return Err(anyhow!("bad magic '{magic}'"));
    }
    let name = lines
        .next()
        .and_then(|l| l.strip_prefix("name "))
        .ok_or_else(|| anyhow!("missing name"))?
        .to_string();
    let header = lines.next().ok_or_else(|| anyhow!("missing header"))?;
    let fields: Vec<&str> = header.split_whitespace().collect();
    if fields.len() != 6 || fields[0] != "nodes" {
        return Err(anyhow!("bad header '{header}'"));
    }
    let n: usize = fields[1].parse().context("nodes")?;
    let f: usize = fields[3].parse().context("features")?;
    let classes: usize = fields[5].parse().context("classes")?;

    let mut features = Matrix::zeros(n, f);
    let mut labels = vec![0u32; n];
    let mut split = Split { train: vec![false; n], val: vec![false; n], test: vec![false; n] };
    let mut builder = GraphBuilder::new(n);
    let mut node_cursor = 0usize;

    for (lineno, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next() {
            Some("n") => {
                let v = node_cursor;
                if v >= n {
                    return Err(anyhow!("too many node lines"));
                }
                labels[v] = it.next().ok_or_else(|| anyhow!("line {lineno}: label"))?.parse()?;
                match it.next() {
                    Some("t") => split.train[v] = true,
                    Some("v") => split.val[v] = true,
                    Some("s") => split.test[v] = true,
                    other => return Err(anyhow!("line {lineno}: bad fold {other:?}")),
                }
                for pair in it {
                    let (d, x) = pair
                        .split_once(':')
                        .ok_or_else(|| anyhow!("line {lineno}: bad pair '{pair}'"))?;
                    features[(v, d.parse::<usize>()?)] = x.parse::<f32>()?;
                }
                node_cursor += 1;
            }
            Some("e") => {
                let u: u32 = it.next().ok_or_else(|| anyhow!("line {lineno}: u"))?.parse()?;
                let v: u32 = it.next().ok_or_else(|| anyhow!("line {lineno}: v"))?.parse()?;
                builder.edge(u, v);
            }
            other => return Err(anyhow!("line {lineno}: unknown record {other:?}")),
        }
    }
    if node_cursor != n {
        return Err(anyhow!("expected {n} node lines, got {node_cursor}"));
    }
    Ok(Dataset { name, graph: builder.build(), features, labels, num_classes: classes, split })
}

/// Save to a file.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_gadb(ds))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_gadb(&text)
}

/// Load the classic Planetoid text release: `<stem>.content` with
/// `id feat... label` rows and `<stem>.cites` with `citing cited`
/// rows. Splits follow the paper's Table-1 fractions via seed 0.
pub fn load_planetoid(stem: impl AsRef<Path>, train_frac: f64, val_frac: f64) -> Result<Dataset> {
    let stem = stem.as_ref();
    let content = std::fs::read_to_string(stem.with_extension("content"))
        .with_context(|| format!("{}.content", stem.display()))?;
    let cites = std::fs::read_to_string(stem.with_extension("cites"))
        .with_context(|| format!("{}.cites", stem.display()))?;

    let mut ids: HashMap<String, u32> = HashMap::new();
    let mut rows: Vec<(Vec<f32>, String)> = Vec::new();
    for line in content.lines() {
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() < 3 {
            continue;
        }
        let id = fields[0].to_string();
        let label = fields[fields.len() - 1].to_string();
        let feats: Vec<f32> = fields[1..fields.len() - 1]
            .iter()
            .map(|x| x.parse::<f32>().unwrap_or(0.0))
            .collect();
        ids.insert(id, rows.len() as u32);
        rows.push((feats, label));
    }
    if rows.is_empty() {
        return Err(anyhow!("no content rows"));
    }
    let f = rows[0].0.len();
    let n = rows.len();

    // labels -> dense class ids (sorted for determinism)
    let mut class_names: Vec<String> = rows.iter().map(|(_, l)| l.clone()).collect();
    class_names.sort();
    class_names.dedup();
    let class_of: HashMap<&str, u32> = class_names
        .iter()
        .enumerate()
        .map(|(i, name)| (name.as_str(), i as u32))
        .collect();

    let mut features = Matrix::zeros(n, f);
    let mut labels = vec![0u32; n];
    for (v, (feats, label)) in rows.iter().enumerate() {
        features.row_mut(v).copy_from_slice(feats);
        labels[v] = class_of[label.as_str()];
    }

    let mut builder = GraphBuilder::new(n);
    for line in cites.lines() {
        let mut it = line.split_whitespace();
        if let (Some(a), Some(b)) = (it.next(), it.next()) {
            if let (Some(&u), Some(&v)) = (ids.get(a), ids.get(b)) {
                if u != v {
                    builder.edge(u, v);
                }
            }
        }
    }

    let mut rng = crate::rng::Rng::seed_from_u64(0);
    let split = Split::random(n, train_frac, val_frac, &mut rng);
    Ok(Dataset {
        name: stem.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        graph: builder.build(),
        features,
        labels,
        num_classes: class_names.len(),
        split,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    #[test]
    fn gadb_roundtrip_exact() {
        let ds = SyntheticSpec::tiny().generate(3);
        let text = to_gadb(&ds);
        let back = from_gadb(&text).unwrap();
        back.validate().unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.graph, ds.graph);
        assert_eq!(back.features, ds.features);
        assert_eq!(back.split.train, ds.split.train);
    }

    #[test]
    fn gadb_rejects_garbage() {
        assert!(from_gadb("").is_err());
        assert!(from_gadb("GADB 2\n").is_err());
        assert!(from_gadb("GADB 1\nname x\nnodes 1 features 1 classes 1\nz 0\n").is_err());
    }

    #[test]
    fn planetoid_parser_on_fixture() {
        let dir = std::env::temp_dir().join("gad_planetoid_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("mini.content"),
            "p1 1 0 1 ai\np2 0 1 0 db\np3 1 1 0 ai\n",
        )
        .unwrap();
        std::fs::write(dir.join("mini.cites"), "p1 p2\np2 p3\npX p1\n").unwrap();
        let ds = load_planetoid(dir.join("mini"), 0.67, 0.0).unwrap();
        assert_eq!(ds.num_nodes(), 3);
        assert_eq!(ds.feature_dim(), 3);
        assert_eq!(ds.num_classes, 2);
        assert_eq!(ds.graph.num_edges(), 2); // pX unknown -> dropped
        ds.validate().unwrap();
    }

    #[test]
    fn save_load_file() {
        let ds = SyntheticSpec::tiny().generate(4);
        let path = std::env::temp_dir().join("gad_io_test.gadb");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.graph.num_edges(), ds.graph.num_edges());
        std::fs::remove_file(&path).ok();
    }
}
