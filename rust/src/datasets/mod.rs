//! Datasets: Table-1-shaped synthetic graphs + loaders.
//!
//! The evaluation datasets of the paper (Cora, Pubmed, Flickr, Reddit)
//! are fetched by PyTorch-Geometric at runtime in the original; this
//! image is offline, so `SyntheticSpec` generates label-correlated
//! stochastic block models with the same node/edge/label/feature-dim
//! statistics (Reddit and Flickr scale-reduced — see the constants
//! below and DESIGN.md §Substitutions). Homophily + degree
//! heterogeneity are tuned so GCN-family methods actually learn and so
//! degree-based samplers (GraphSAINT) are meaningfully non-uniform.

mod features;
pub mod io;
mod split;
mod synthetic;

pub use split::Split;
pub use synthetic::SyntheticSpec;

use crate::graph::Csr;
use crate::tensor::Matrix;

/// A node-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    /// `n x f` node features.
    pub features: Matrix,
    /// Class label per node.
    pub labels: Vec<u32>,
    pub num_classes: usize,
    pub split: Split,
}

impl Dataset {
    /// Feature dimensionality.
    pub fn feature_dim(&self) -> usize {
        self.features.cols
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }

    /// One markdown row of Table-1 statistics.
    pub fn stats_row(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {}/{}/{} (%) |",
            self.name,
            self.num_nodes(),
            self.graph.num_edges(),
            self.num_classes,
            self.feature_dim(),
            (100.0 * self.split.train_fraction()).round() as u32,
            (100.0 * self.split.val_fraction()).round() as u32,
            (100.0 * self.split.test_fraction()).round() as u32,
        )
    }

    /// Bytes of features + adjacency (memory accounting baseline).
    pub fn nbytes(&self) -> usize {
        self.features.nbytes() + self.graph.nbytes() + self.labels.len() * 4
    }

    /// Sanity checks used by tests and the CLI `stats` command.
    pub fn validate(&self) -> Result<(), String> {
        self.graph.validate()?;
        if self.features.rows != self.num_nodes() {
            return Err("feature rows != nodes".into());
        }
        if self.labels.len() != self.num_nodes() {
            return Err("labels != nodes".into());
        }
        if self.labels.iter().any(|&l| l as usize >= self.num_classes) {
            return Err("label out of range".into());
        }
        self.split.validate(self.num_nodes())
    }

    /// The four paper datasets, generated at the default scales.
    pub fn paper_suite(seed: u64) -> Vec<Dataset> {
        vec![
            SyntheticSpec::cora_like().generate(seed),
            SyntheticSpec::pubmed_like().generate(seed + 1),
            SyntheticSpec::flickr_like().generate(seed + 2),
            SyntheticSpec::reddit_like().generate(seed + 3),
        ]
    }

    /// Like [`Dataset::by_name`] with a size scale factor (fast modes).
    pub fn by_name_scaled(name: &str, seed: u64, scale: f64) -> Option<Dataset> {
        Self::spec_by_name(name).map(|s| s.scale(scale).generate(seed))
    }

    /// Look a dataset up by name (`cora|pubmed|flickr|reddit|tiny`).
    pub fn by_name(name: &str, seed: u64) -> Option<Dataset> {
        Self::spec_by_name(name).map(|s| s.generate(seed))
    }

    /// The spec behind a dataset name.
    pub fn spec_by_name(name: &str) -> Option<SyntheticSpec> {
        let spec = match name {
            "cora" => SyntheticSpec::cora_like(),
            "pubmed" => SyntheticSpec::pubmed_like(),
            "flickr" | "flicker" => SyntheticSpec::flickr_like(),
            "reddit" => SyntheticSpec::reddit_like(),
            "tiny" => SyntheticSpec::tiny(),
            _ => return None,
        };
        Some(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_known_and_unknown() {
        assert!(Dataset::by_name("tiny", 1).is_some());
        assert!(Dataset::by_name("cora", 1).is_some());
        assert!(Dataset::by_name("imaginary", 1).is_none());
    }

    #[test]
    fn tiny_validates() {
        let d = Dataset::by_name("tiny", 2).unwrap();
        d.validate().unwrap();
    }
}
