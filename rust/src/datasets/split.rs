//! Train/validation/test node splits.

use crate::rng::Rng;

/// Boolean masks over nodes; exactly one of the three is set per node.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<bool>,
    pub val: Vec<bool>,
    pub test: Vec<bool>,
}

impl Split {
    /// Random split with the given fractions (train + val + test must
    /// be ≈ 1; test takes the remainder).
    pub fn random(n: usize, train_frac: f64, val_frac: f64, rng: &mut Rng) -> Split {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let n_train = (n as f64 * train_frac).round() as usize;
        let n_val = (n as f64 * val_frac).round() as usize;
        let mut s = Split {
            train: vec![false; n],
            val: vec![false; n],
            test: vec![false; n],
        };
        for (i, &v) in order.iter().enumerate() {
            if i < n_train {
                s.train[v] = true;
            } else if i < n_train + n_val {
                s.val[v] = true;
            } else {
                s.test[v] = true;
            }
        }
        s
    }

    pub fn train_fraction(&self) -> f64 {
        self.count(&self.train) as f64 / self.train.len() as f64
    }
    pub fn val_fraction(&self) -> f64 {
        self.count(&self.val) as f64 / self.val.len() as f64
    }
    pub fn test_fraction(&self) -> f64 {
        self.count(&self.test) as f64 / self.test.len() as f64
    }

    fn count(&self, m: &[bool]) -> usize {
        m.iter().filter(|&&x| x).count()
    }

    /// Every node in exactly one fold.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.train.len() != n || self.val.len() != n || self.test.len() != n {
            return Err("split length mismatch".into());
        }
        for i in 0..n {
            let c = self.train[i] as u8 + self.val[i] as u8 + self.test[i] as u8;
            if c != 1 {
                return Err(format!("node {i} in {c} folds"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_and_partition() {
        let mut rng = Rng::seed_from_u64(1);
        let s = Split::random(1000, 0.7, 0.2, &mut rng);
        s.validate(1000).unwrap();
        assert!((s.train_fraction() - 0.7).abs() < 0.01);
        assert!((s.val_fraction() - 0.2).abs() < 0.01);
        assert!((s.test_fraction() - 0.1).abs() < 0.01);
    }

    #[test]
    fn degenerate_all_train() {
        let mut rng = Rng::seed_from_u64(2);
        let s = Split::random(10, 1.0, 0.0, &mut rng);
        s.validate(10).unwrap();
        assert_eq!(s.train_fraction(), 1.0);
    }
}
