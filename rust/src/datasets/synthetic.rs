//! Degree-heterogeneous, label-correlated stochastic block models with
//! Table-1 statistics.

use super::features::class_features;
use super::{Dataset, Split};
use crate::graph::GraphBuilder;
use crate::rng::Rng;

/// Specification of a synthetic dataset (see the `*_like`
/// constructors for the paper's four datasets).
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: &'static str,
    pub nodes: usize,
    /// Target undirected edge count (achieved approximately; duplicates
    /// are dropped).
    pub edges: usize,
    pub classes: usize,
    pub feature_dim: usize,
    /// Fraction of edges that stay within a class (homophily).
    pub homophily: f64,
    /// Average micro-community size. Real citation/social graphs are
    /// locally clustered; intra-class edges attach within the node's
    /// community with probability `locality`, giving partitioners real
    /// structure to find (low edge cuts, like METIS on real Cora).
    pub community_size: usize,
    /// Probability an intra-class edge stays inside the community.
    pub locality: f64,
    /// Pareto shape for node activity (smaller = heavier tail). The
    /// degree distribution follows this activity weighting.
    pub activity_alpha: f64,
    pub train_frac: f64,
    pub val_frac: f64,
    /// Signal dims per class / noise / background for features.
    pub active_per_class: usize,
    pub feature_noise: f32,
    pub feature_background: f64,
}

impl SyntheticSpec {
    /// Cora: 2 708 nodes / 5 429 edges / 7 labels / 1 433 dims,
    /// 45/18/37 split (Table 1), full scale.
    pub fn cora_like() -> Self {
        SyntheticSpec {
            name: "cora",
            nodes: 2_708,
            edges: 5_429,
            classes: 7,
            feature_dim: 1_433,
            homophily: 0.81, // measured homophily of real Cora
            activity_alpha: 1.6,
            community_size: 36,
            locality: 0.94,
            train_frac: 0.45,
            val_frac: 0.18,
            active_per_class: 64,
            feature_noise: 0.9,
            feature_background: 0.02,
        }
    }

    /// Pubmed: 19 717 / 44 324 / 3 / 500, 92/03/05 split, full scale.
    pub fn pubmed_like() -> Self {
        SyntheticSpec {
            name: "pubmed",
            nodes: 19_717,
            edges: 44_324,
            classes: 3,
            feature_dim: 500,
            homophily: 0.80,
            activity_alpha: 1.6,
            community_size: 50,
            locality: 0.94,
            train_frac: 0.92,
            val_frac: 0.03,
            active_per_class: 48,
            feature_noise: 0.9,
            feature_background: 0.02,
        }
    }

    /// Flickr: paper 89 250 / 899 756 / 7 / 500, 50/25/25 split.
    /// Scale-reduced 10x (nodes and edges) for the CPU testbed;
    /// density is preserved (see DESIGN.md §Substitutions).
    pub fn flickr_like() -> Self {
        SyntheticSpec {
            name: "flickr",
            nodes: 8_925,
            edges: 89_976,
            classes: 7,
            feature_dim: 500,
            homophily: 0.60, // Flickr is less homophilous; GCN accuracies are low
            activity_alpha: 1.5,
            community_size: 60,
            locality: 0.88,
            train_frac: 0.50,
            val_frac: 0.25,
            active_per_class: 24,
            feature_noise: 1.2,
            feature_background: 0.04,
        }
    }

    /// Reddit: paper 231 443 / 11 606 919 / 41 / 602, 70/20/10 split.
    /// Scale-reduced 20x for the CPU testbed.
    pub fn reddit_like() -> Self {
        SyntheticSpec {
            name: "reddit",
            nodes: 11_572,
            edges: 580_346,
            classes: 41,
            feature_dim: 602,
            homophily: 0.78,
            activity_alpha: 1.4,
            community_size: 80,
            locality: 0.9,
            train_frac: 0.70,
            val_frac: 0.20,
            active_per_class: 32,
            feature_noise: 0.9,
            feature_background: 0.025,
        }
    }

    /// Small fixture for unit tests: 400 nodes, 4 classes.
    pub fn tiny() -> Self {
        SyntheticSpec {
            name: "tiny",
            nodes: 400,
            edges: 1_200,
            classes: 4,
            feature_dim: 32,
            homophily: 0.85,
            activity_alpha: 1.6,
            community_size: 25,
            locality: 0.9,
            train_frac: 0.60,
            val_frac: 0.20,
            active_per_class: 8,
            feature_noise: 0.45,
            feature_background: 0.02,
        }
    }

    /// Scale node/edge counts by `f` (used by `--fast` experiment
    /// modes); statistics other than size are preserved.
    pub fn scale(mut self, f: f64) -> Self {
        self.nodes = ((self.nodes as f64 * f) as usize).max(64);
        self.edges = ((self.edges as f64 * f) as usize).max(128);
        self
    }

    /// Generate the dataset deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5EED_5EED);
        let n = self.nodes;
        let k = self.classes;

        // round-robin labels => balanced classes, then shuffled so class
        // blocks are not contiguous in id space (partitioners must work
        // for it).
        let mut labels: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        rng.shuffle(&mut labels);

        // nodes of each class
        let mut class_nodes: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (v, &c) in labels.iter().enumerate() {
            class_nodes[c as usize].push(v as u32);
        }

        // micro-communities inside each class: chunk the class node
        // list; edges preferentially stay inside the chunk (locality)
        let mut community_of: Vec<u32> = vec![0; n];
        let mut communities: Vec<Vec<u32>> = Vec::new();
        let mut community_class: Vec<u32> = Vec::new();
        for (c, nodes) in class_nodes.iter().enumerate() {
            for chunk in nodes.chunks(self.community_size.max(2)) {
                let cid = communities.len() as u32;
                for &v in chunk {
                    community_of[v as usize] = cid;
                }
                communities.push(chunk.to_vec());
                community_class.push(c as u32);
            }
        }

        // partner communities: cross-community edges concentrate on a
        // few partners (real graphs stay locally clustered even across
        // community borders — related subfields cite each other), which
        // keeps 2-hop candidate sets small and walk mass concentrated
        let n_comm = communities.len();
        let same_class_comms: Vec<Vec<u32>> = (0..k)
            .map(|c| {
                (0..n_comm as u32)
                    .filter(|&cid| community_class[cid as usize] == c as u32)
                    .collect()
            })
            .collect();
        // partners are *nearby* in community-id space, so the
        // community-level graph is itself locally clustered (not an
        // expander) and a good partitioner can find low cuts, like
        // METIS does on real citation graphs
        let near = |cid: usize, rng: &mut Rng| -> u32 {
            let off = 1 + rng.gen_range(3);
            let p = if rng.gen_bool(0.5) { cid + off } else { cid + n_comm - off };
            (p % n_comm) as u32
        };
        let mut partners_same: Vec<Vec<u32>> = Vec::with_capacity(n_comm);
        let mut partners_any: Vec<Vec<u32>> = Vec::with_capacity(n_comm);
        for cid in 0..n_comm {
            let same = &same_class_comms[community_class[cid] as usize];
            // same-class partner: the neighbouring chunks of this class
            let my_rank = same.iter().position(|&c| c == cid as u32).unwrap_or(0);
            let mut ps: Vec<u32> = (0..2)
                .map(|_| {
                    let off = 1 + rng.gen_range(2);
                    let r = if rng.gen_bool(0.5) { my_rank + off } else { my_rank + same.len() - off };
                    same[r % same.len()]
                })
                .collect();
            ps.retain(|&p| p != cid as u32);
            if ps.is_empty() {
                ps.push(same[(my_rank + 1) % same.len()]);
            }
            partners_same.push(ps);
            let pa: Vec<u32> = (0..3).map(|_| near(cid, &mut rng)).collect();
            partners_any.push(pa);
        }

        // heavy-tailed activity -> degree heterogeneity. Pareto via
        // inverse CDF; cumulative weights per class for O(log n) draws.
        let activity: Vec<f64> = (0..n)
            .map(|_| (1.0 - rng.gen_f64()).powf(-1.0 / self.activity_alpha))
            .collect();
        let community_cumsums: Vec<Vec<f64>> = communities
            .iter()
            .map(|nodes| {
                let mut acc = 0.0;
                nodes
                    .iter()
                    .map(|&v| {
                        acc += activity[v as usize];
                        acc
                    })
                    .collect()
            })
            .collect();
        let class_cumsums: Vec<Vec<f64>> = class_nodes
            .iter()
            .map(|nodes| {
                let mut acc = 0.0;
                nodes
                    .iter()
                    .map(|&v| {
                        acc += activity[v as usize];
                        acc
                    })
                    .collect()
            })
            .collect();
        let total_cumsum: Vec<f64> = {
            let mut acc = 0.0;
            (0..n)
                .map(|v| {
                    acc += activity[v];
                    acc
                })
                .collect()
        };

        let draw = |cum: &[f64], rng: &mut Rng| -> usize {
            let t = rng.gen_f64() * cum.last().copied().unwrap_or(1.0);
            cum.partition_point(|&c| c < t).min(cum.len() - 1)
        };

        // sample edges; oversample 25% to compensate dedup losses
        let target = self.edges + self.edges / 4;
        let mut builder = GraphBuilder::new(n);
        for _ in 0..target {
            let u = draw(&total_cumsum, &mut rng) as u32;
            let ucid = community_of[u as usize] as usize;
            let v = if rng.gen_bool(self.homophily) {
                if rng.gen_bool(self.locality) {
                    // intra-community endpoint (local clustering)
                    communities[ucid][draw(&community_cumsums[ucid], &mut rng)]
                } else {
                    // intra-class: a same-class partner community
                    let p = *rng.choose(&partners_same[ucid]) as usize;
                    communities[p][draw(&community_cumsums[p], &mut rng)]
                }
            } else if rng.gen_bool(0.8) {
                // cross-class edges mostly land in partner communities
                let p = *rng.choose(&partners_any[ucid]) as usize;
                communities[p][draw(&community_cumsums[p], &mut rng)]
            } else {
                // long-range random edge
                draw(&total_cumsum, &mut rng) as u32
            };
            if u != v {
                builder.edge(u, v);
            }
        }
        // connect isolated nodes so every node participates in training
        let mut graph = builder.build();
        let isolated: Vec<u32> = (0..n)
            .filter(|&v| graph.degree(v) == 0)
            .map(|v| v as u32)
            .collect();
        if !isolated.is_empty() {
            let mut b2 = GraphBuilder::new(n);
            for (u, v) in graph.edges() {
                b2.edge(u, v);
            }
            for &v in &isolated {
                // attach to a same-class hub
                let c = labels[v as usize] as usize;
                let u = class_nodes[c][draw(&class_cumsums[c], &mut rng)];
                b2.edge(v, if u == v { (v + 1) % n as u32 } else { u });
            }
            graph = b2.build();
        }

        let features = class_features(
            &labels,
            k,
            self.feature_dim,
            self.active_per_class,
            self.feature_noise,
            self.feature_background,
            &mut rng,
        );
        let split = Split::random(n, self.train_frac, self.val_frac, &mut rng);

        Dataset {
            name: self.name.to_string(),
            graph,
            features,
            labels,
            num_classes: k,
            split,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_stats_near_spec() {
        let spec = SyntheticSpec::tiny();
        let d = spec.generate(1);
        d.validate().unwrap();
        assert_eq!(d.num_nodes(), spec.nodes);
        let e = d.graph.num_edges() as f64;
        assert!(
            (e - spec.edges as f64).abs() / spec.edges as f64 <= 0.25,
            "edges {e} vs target {}",
            spec.edges
        );
    }

    #[test]
    fn homophily_is_high() {
        let d = SyntheticSpec::tiny().generate(2);
        let intra = d
            .graph
            .edges()
            .filter(|&(u, v)| d.labels[u as usize] == d.labels[v as usize])
            .count() as f64;
        let total = d.graph.num_edges() as f64;
        assert!(intra / total > 0.6, "homophily {}", intra / total);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticSpec::tiny().generate(7);
        let b = SyntheticSpec::tiny().generate(7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn no_isolated_nodes() {
        let d = SyntheticSpec::tiny().generate(3);
        assert!((0..d.num_nodes()).all(|v| d.graph.degree(v) > 0));
    }

    #[test]
    fn degree_distribution_heavy_tailed() {
        let d = SyntheticSpec::tiny().generate(4);
        let mut degs: Vec<usize> = (0..d.num_nodes()).map(|v| d.graph.degree(v)).collect();
        degs.sort_unstable();
        let max = *degs.last().unwrap() as f64;
        let median = degs[degs.len() / 2] as f64;
        assert!(max > 3.0 * median, "max {max} median {median}");
    }
}
