//! Label-correlated feature generation.
//!
//! Each class gets a random centroid over a small subset of active
//! dimensions (word-vector-like sparsity); node features are
//! `centroid + noise`, truncated at zero and sparsified, mimicking the
//! tf-idf / bag-of-words inputs of the citation datasets.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Fraction of a class's signal dims each node expresses.
pub const PER_NODE_FRAC: f64 = 0.12;

/// Generate `n x dim` features for `labels` over `num_classes` classes.
///
/// `active_per_class` — how many dimensions carry the class signal;
/// `noise` — std of the additive Gaussian noise on active dims;
/// `background` — probability of a small random activation elsewhere.
///
/// Each node expresses only [`PER_NODE_FRAC`] of its class's signal
/// dims (a paper cites few of its field's keywords): single-node
/// features are ambiguous and neighbourhood aggregation is what
/// disambiguates — the regime where the paper's partition-information-
/// loss effects (Table 4) actually appear.
pub fn class_features(
    labels: &[u32],
    num_classes: usize,
    dim: usize,
    active_per_class: usize,
    noise: f32,
    background: f64,
    rng: &mut Rng,
) -> Matrix {
    let active = active_per_class.min(dim).max(1);
    // centroids: per class, `active` dims drawn from a shared pool 3x
    // the per-class count, so classes overlap in vocabulary (real
    // bag-of-words classes share most common words)
    let pool = (active * 3).min(dim);
    let mut centroid_dims: Vec<Vec<usize>> = Vec::with_capacity(num_classes);
    let mut centroid_vals: Vec<Vec<f32>> = Vec::with_capacity(num_classes);
    for _ in 0..num_classes {
        let dims: Vec<usize> = rng.sample_indices(pool, active);
        let vals = (0..active).map(|_| 0.5 + rng.gen_f32()).collect();
        centroid_dims.push(dims);
        centroid_vals.push(vals);
    }

    let n = labels.len();
    let per_node = ((active as f64 * PER_NODE_FRAC) as usize).max(1);
    let mut x = Matrix::zeros(n, dim);
    for (i, &lab) in labels.iter().enumerate() {
        let row = x.row_mut(i);
        let dims = &centroid_dims[lab as usize];
        let vals = &centroid_vals[lab as usize];
        // sparse per-node expression of the class signal
        for j in rng.sample_indices(active, per_node) {
            let f = vals[j] + noise * rng.gen_normal();
            if f > 0.0 {
                row[dims[j]] = f;
            }
        }
        // sparse background activations (off-class words)
        if background > 0.0 {
            let expected = (dim as f64 * background).max(1.0) as usize;
            for _ in 0..expected {
                let d = rng.gen_range(dim);
                row[d] += 0.25 * rng.gen_f32();
            }
        }
    }
    // row-normalize (standard GCN preprocessing)
    for i in 0..n {
        let row = x.row_mut(i);
        let s: f32 = row.iter().sum();
        if s > 0.0 {
            let inv = 1.0 / s;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_normalized_and_nonneg() {
        let mut rng = Rng::seed_from_u64(1);
        let labels: Vec<u32> = (0..50).map(|i| (i % 3) as u32).collect();
        let x = class_features(&labels, 3, 64, 8, 0.1, 0.02, &mut rng);
        for i in 0..50 {
            let row = x.row(i);
            assert!(row.iter().all(|&v| v >= 0.0));
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4 || s == 0.0);
        }
    }

    #[test]
    fn same_class_closer_than_cross_class_on_average() {
        let mut rng = Rng::seed_from_u64(2);
        let labels: Vec<u32> = (0..60).map(|i| (i % 2) as u32).collect();
        let x = class_features(&labels, 2, 128, 16, 0.05, 0.0, &mut rng);
        let dist = |a: usize, b: usize| -> f32 {
            x.row(a)
                .iter()
                .zip(x.row(b))
                .map(|(p, q)| (p - q) * (p - q))
                .sum::<f32>()
        };
        // per-node sparse expression makes single pairs noisy; the
        // class structure must still hold in the mean
        let (mut intra, mut inter, mut ni, mut nx) = (0.0f32, 0.0f32, 0, 0);
        for a in 0..30 {
            for b in (a + 1)..30 {
                if labels[a] == labels[b] {
                    intra += dist(a, b);
                    ni += 1;
                } else {
                    inter += dist(a, b);
                    nx += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f32, inter / nx as f32);
        assert!(intra < inter, "mean intra {intra} >= mean inter {inter}");
    }
}
