//! Minimal dense f32 tensor substrate for the native compute backend.
//!
//! The offline registry ships no ndarray/BLAS, so the [`NativeBackend`]
//! (the pure-rust GCN oracle/fallback) runs on this module: a row-major
//! [`Matrix`] with a blocked, multi-threaded GEMM and the elementwise /
//! reduction ops a GCN needs. The XLA path does *not* use this — it is
//! the second implementation the HLO numerics are cross-checked against.
//!
//! [`NativeBackend`]: crate::backend::NativeBackend

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{
    add_assign, addmm, cross_entropy_masked, gemm, gemm_into, gemm_reference,
    gemm_reference_into, gemm_ta, gemm_ta_reference, gemm_tb, gemm_tb_reference, leaky_relu,
    relu, relu_grad_inplace, scale, set_intra_threads, softmax_rows, spmm_csr,
    spmm_csr_reference,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                let av = a[(i, k)];
                for j in 0..b.cols {
                    c[(i, j)] += av * b[(k, j)];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64), (100, 7, 129)] {
            let a = Matrix::rand_uniform(m, k, &mut rng);
            let b = Matrix::rand_uniform(k, n, &mut rng);
            let c = gemm(&a, &b);
            let r = naive_gemm(&a, &b);
            assert!(c.allclose(&r, 1e-4), "gemm mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_ta_is_at_b() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Matrix::rand_uniform(13, 6, &mut rng); // a: k x m -> aT: m x k
        let b = Matrix::rand_uniform(13, 9, &mut rng);
        let c = gemm_ta(&a, &b);
        let r = naive_gemm(&a.transpose(), &b);
        assert!(c.allclose(&r, 1e-4));
    }

    #[test]
    fn gemm_tb_is_a_bt() {
        let mut rng = Rng::seed_from_u64(3);
        let a = Matrix::rand_uniform(8, 11, &mut rng);
        let b = Matrix::rand_uniform(5, 11, &mut rng);
        let c = gemm_tb(&a, &b);
        let r = naive_gemm(&a, &b.transpose());
        assert!(c.allclose(&r, 1e-4));
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut rng = Rng::seed_from_u64(4);
        let mut m = Matrix::rand_uniform(10, 7, &mut rng);
        scale(&mut m, 5.0);
        let s = softmax_rows(&m);
        for i in 0..s.rows {
            let sum: f32 = (0..s.cols).map(|j| s[(i, j)]).sum();
            assert!((sum - 1.0).abs() < 1e-5);
            for j in 0..s.cols {
                assert!(s[(i, j)] >= 0.0);
            }
        }
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let mut rng = Rng::seed_from_u64(5);
        let m = Matrix::rand_uniform(4, 6, &mut rng);
        let mut shifted = m.clone();
        for v in shifted.data_mut() {
            *v += 100.0;
        }
        assert!(softmax_rows(&m).allclose(&softmax_rows(&shifted), 1e-5));
    }

    #[test]
    fn relu_zeroes_negatives() {
        let mut m = Matrix::zeros(1, 4);
        m.data_mut().copy_from_slice(&[-1.0, 0.0, 2.0, -0.5]);
        relu(&mut m);
        assert_eq!(m.data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        // uniform predictions over C classes -> loss = ln C
        let m = Matrix::zeros(3, 4);
        let probs = softmax_rows(&m);
        let labels = vec![0u32, 1, 2];
        let mask = vec![true, true, true];
        let (loss, _grad) = cross_entropy_masked(&probs, &labels, &mask);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_mask_excludes_rows() {
        let mut probs = Matrix::zeros(2, 2);
        probs.data_mut().copy_from_slice(&[0.9, 0.1, 0.1, 0.9]);
        let labels = vec![0u32, 0]; // second row is wrong...
        let mask = vec![true, false]; // ...but masked out
        let (loss, grad) = cross_entropy_masked(&probs, &labels, &mask);
        assert!((loss - (-(0.9f32).ln())).abs() < 1e-5);
        // masked row contributes zero gradient
        assert_eq!(grad[(1, 0)], 0.0);
        assert_eq!(grad[(1, 1)], 0.0);
    }
}
