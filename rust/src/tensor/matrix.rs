//! Row-major dense f32 matrix.

use crate::rng::Rng;
use std::ops::{Index, IndexMut};

/// A dense, row-major `rows x cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing row-major buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Uniform entries in [-0.5, 0.5).
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gen_f32() - 0.5).collect();
        Matrix { rows, cols, data }
    }

    /// Glorot/Xavier-uniform init: U(-s, s) with s = sqrt(6/(fan_in+fan_out)).
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let s = (6.0 / (rows + cols) as f32).sqrt();
        let data = (0..rows * cols).map(|_| (rng.gen_f32() * 2.0 - 1.0) * s).collect();
        Matrix { rows, cols, data }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the underlying row-major buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Append one row (serving's elastic node insertion grows the
    /// feature matrix in place). Length must match `cols`.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Copy `self` into the top-left corner of a larger zero matrix.
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad_to must grow");
        let mut p = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            p.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        p
    }

    /// Take the top-left `rows x cols` block.
    pub fn crop(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows <= self.rows && cols <= self.cols, "crop must shrink");
        let mut c = Matrix::zeros(rows, cols);
        for i in 0..rows {
            c.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        c
    }

    /// Max |a-b| across entries (shapes must match).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Elementwise closeness with combined abs/rel tolerance.
    pub fn allclose(&self, other: &Matrix, tol: f32) -> bool {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= tol + tol * a.abs().max(b.abs()))
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Per-row argmax (ties -> first).
    pub fn argmax_rows(&self) -> Vec<u32> {
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                let mut best = 0usize;
                for j in 1..row.len() {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect()
    }

    /// Bytes held by the value buffer (memory accounting).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_crop_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let m = Matrix::rand_uniform(5, 3, &mut rng);
        let p = m.pad_to(8, 4);
        assert_eq!(p.rows, 8);
        assert_eq!(p[(7, 3)], 0.0);
        assert_eq!(p.crop(5, 3), m);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed_from_u64(2);
        let m = Matrix::rand_uniform(4, 7, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn glorot_within_bound() {
        let mut rng = Rng::seed_from_u64(3);
        let m = Matrix::glorot(100, 50, &mut rng);
        let s = (6.0f32 / 150.0).sqrt();
        assert!(m.data().iter().all(|x| x.abs() <= s));
    }

    #[test]
    fn argmax_rows_ties_first() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 3.0, 3.0, 0.0, -1.0, -2.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }
}
