//! GEMM and GCN-specific ops over [`Matrix`].
//!
//! The GEMM packs B into register-friendly column panels and runs a
//! register-blocked micro-kernel over cache-sized blocks, parallelised
//! with scoped `std::thread`s over row panels — the hot path of the
//! native backend. See EXPERIMENTS.md §Perf for the blocking
//! parameters' before/after and README "Raw-speed kernels" for the
//! packing scheme.
//!
//! **Determinism contract.** Every kernel here is bit-identical to its
//! retained `*_reference` twin: an optimisation may re-tile loops, pack
//! operands, hoist accumulators into registers, or split work across
//! threads, but the per-output-element k-accumulation order stays a
//! single ascending serial chain with unchanged zero-skip behaviour.
//! Rust never contracts `c + a * b` into a fused multiply-add on its
//! own, and f32 copies/spills round-trip exactly, so "same chain" means
//! "same bits". `tests/prop_tensor.rs` pins each pair bit-for-bit over
//! random ragged shapes.

use super::Matrix;

/// Row-panel block height (rows of A/C per cache block).
const MC: usize = 64;
/// K-blocking depth (one packed B panel covers KC rows of B).
const KC: usize = 256;
/// Register-block width: columns of C accumulated in registers per
/// micro-kernel call. 8 f32 lanes = two SSE / one AVX vector.
const NR: usize = 8;
/// Register-block height: rows of C per micro-kernel call.
const MR: usize = 4;
/// Problems smaller than this many MACs stay single-threaded.
const PAR_THRESHOLD: usize = 1 << 21;

thread_local! {
    /// Intra-op thread budget of the *calling* thread. The coordinator
    /// divides the machine between workers (one "device" per worker,
    /// like the paper's one-GPU-per-processor testbed); 0 = use all
    /// cores (single-worker / bench mode).
    ///
    /// Thread-local on purpose: this used to be a process-global
    /// atomic, and concurrent `train_gad` runs (cargo's parallel test
    /// threads) overwrote each other's per-worker budget, making
    /// wall-clock-sensitive assertions flaky. Each worker thread now
    /// sets its own budget at spawn (see `WorkerPlan::intra_threads`),
    /// so concurrent runs cannot interfere.
    static INTRA_THREADS: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Set the per-op thread budget for ops issued from the current thread
/// (0 = all cores). Worker threads call this with `cores / workers` so
/// wall-clock scaling with workers reflects a real multi-device
/// deployment.
pub fn set_intra_threads(n: usize) {
    INTRA_THREADS.with(|c| c.set(n));
}

/// Number of worker threads to use for a problem of `flops` MACs.
fn thread_count(flops: usize) -> usize {
    if flops < PAR_THRESHOLD {
        return 1;
    }
    let cap = match INTRA_THREADS.with(|c| c.get()) {
        // unset: size from the process-wide budget (total minus what
        // standing pools — trainer workers, serve pools — hold), with
        // the historical ceiling of 8 panels
        0 => crate::threads::available().min(8),
        n => n,
    };
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(cap)
}

/// `C = A * B`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_into(a, b, &mut c);
    c
}

/// `C += A * B` into an existing output (used by the trainer to reuse
/// allocations across steps).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let nthreads = thread_count(m * k * n);
    if nthreads <= 1 {
        gemm_panel_packed(a.data(), b.data(), c.data_mut(), 0, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(nthreads);
    let a_data = a.data();
    let b_data = b.data();
    // Split C into disjoint row panels; each thread owns one.
    let mut panels: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                gemm_panel_packed(a_data, b_data, panel, row0, rows, k, n);
            });
        }
    });
}

/// `C = A * B` through the seed-era unpacked kernel — the oracle the
/// packed path is property-tested against bit-for-bit, and the fig16
/// bench's "old" column. (The issue plan kept this `#[cfg(test)]`, but
/// the bench target is a separate crate and needs the baseline too, so
/// it stays public; nothing on a hot path calls it.)
pub fn gemm_reference(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_reference_into(a, b, &mut c);
    c
}

/// `C += A * B` through the seed-era unpacked kernel (same row-panel
/// threading, unpacked inner loops). See [`gemm_reference`].
pub fn gemm_reference_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let nthreads = thread_count(m * k * n);
    if nthreads <= 1 {
        gemm_panel(a.data(), b.data(), c.data_mut(), 0, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(nthreads);
    let a_data = a.data();
    let b_data = b.data();
    let mut panels: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                gemm_panel(a_data, b_data, panel, row0, rows, k, n);
            });
        }
    });
}

/// Seed-era single-threaded blocked-but-unpacked kernel over a row
/// panel `[row0, row0+rows)`. Retained as the bit-identity oracle.
fn gemm_panel(a: &[f32], b: &[f32], c_panel: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for ib in (0..rows).step_by(MC) {
        let ie = (ib + MC).min(rows);
        for kb in (0..k).step_by(KC) {
            let ke = (kb + KC).min(k);
            for i in ib..ie {
                let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
                let crow = &mut c_panel[i * n..i * n + n];
                for kk in kb..ke {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue; // feature matrices are sparse-ish
                    }
                    let brow = &b[kk * n..kk * n + n];
                    // autovectorises: contiguous fused multiply-add
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// Packed register-blocked kernel over a row panel `[row0, row0+rows)`.
///
/// Per KC-deep slice of B the panel packs the slice once into
/// contiguous KC×NR column panels (tail panel zero-padded), then runs
/// the MR×NR micro-kernel over MC-row blocks of A, so the inner loop
/// reads one sequential 8-KiB panel instead of striding full rows of
/// B. Bit-identity vs [`gemm_panel`]: element `(i, j)` still
/// accumulates `a[i][kk] * b[kk][j]` over the *same* ascending `kk`
/// sequence with the *same* `a == 0.0` skips — packing moves bytes,
/// never the chain; the zero-padded tail lanes are computed but never
/// written back.
fn gemm_panel_packed(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    if rows == 0 || n == 0 || k == 0 {
        return;
    }
    let npanels = n.div_ceil(NR);
    let mut packed = vec![0.0f32; npanels * NR * KC.min(k)];
    for kb in (0..k).step_by(KC) {
        let ke = (kb + KC).min(k);
        let kcb = ke - kb;
        pack_b(b, kb, ke, n, &mut packed);
        for ib in (0..rows).step_by(MC) {
            let ie = (ib + MC).min(rows);
            for jp in 0..npanels {
                let j0 = jp * NR;
                let jw = NR.min(n - j0);
                let panel = &packed[jp * kcb * NR..(jp + 1) * kcb * NR];
                let mut i = ib;
                while i < ie {
                    let rb = MR.min(ie - i);
                    micro_kernel(a, k, row0 + i, i, rb, panel, kb, kcb, c_panel, n, j0, jw);
                    i += rb;
                }
            }
        }
    }
}

/// Pack `B[kb..ke, :]` into column panels of width NR:
/// `packed[(jp * kcb + kk) * NR + jr] = B[kb + kk, jp * NR + jr]`,
/// with the ragged tail panel zero-padded so the micro-kernel never
/// branches on column width.
fn pack_b(b: &[f32], kb: usize, ke: usize, n: usize, packed: &mut [f32]) {
    let kcb = ke - kb;
    let npanels = n.div_ceil(NR);
    for jp in 0..npanels {
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let dst_panel = &mut packed[jp * kcb * NR..(jp + 1) * kcb * NR];
        for kk in 0..kcb {
            let src = &b[(kb + kk) * n + j0..(kb + kk) * n + j0 + jw];
            let dst = &mut dst_panel[kk * NR..kk * NR + NR];
            dst[..jw].copy_from_slice(src);
            for pad in dst[jw..].iter_mut() {
                *pad = 0.0;
            }
        }
    }
}

/// MR×NR micro-kernel: accumulate `rb ≤ MR` rows of A against one
/// packed column panel into register accumulators, spilling to C once
/// per (kb, block) instead of once per k step. `jw ≤ NR` masks the
/// ragged column tail on the way in and out; the padded lanes compute
/// on zeros and are discarded.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    a: &[f32],
    k: usize,
    arow0: usize,
    i0: usize,
    rb: usize,
    panel: &[f32],
    kb: usize,
    kcb: usize,
    c_panel: &mut [f32],
    n: usize,
    j0: usize,
    jw: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate().take(rb) {
        let crow = &c_panel[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
        accr[..jw].copy_from_slice(crow);
    }
    for kk in 0..kcb {
        let brow = &panel[kk * NR..kk * NR + NR];
        for (r, accr) in acc.iter_mut().enumerate().take(rb) {
            let av = a[(arow0 + r) * k + kb + kk];
            if av == 0.0 {
                continue; // same skip, same chain, as the oracle
            }
            // unrolled: NR independent lanes, one vector FMA-shaped op
            for jr in 0..NR {
                accr[jr] += av * brow[jr];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(rb) {
        let crow = &mut c_panel[(i0 + r) * n + j0..(i0 + r) * n + j0 + jw];
        crow.copy_from_slice(&accr[..jw]);
    }
}

/// `C = A^T * B` (A is `k x m`, result `m x n`). Used for weight grads.
/// Parallelised over row panels of C (= column ranges of A) through the
/// same budget as [`gemm`]; each panel replays the reference kernel's
/// ascending-k accumulation, so any width is bit-identical to
/// [`gemm_ta_reference`].
pub fn gemm_ta(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "gemm_ta shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let nthreads = thread_count(m * k * n);
    if nthreads <= 1 {
        gemm_ta_panel(a.data(), b.data(), c.data_mut(), 0, m, m, k, n);
        return c;
    }
    let rows_per = m.div_ceil(nthreads);
    let a_data = a.data();
    let b_data = b.data();
    let mut panels: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let col0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                gemm_ta_panel(a_data, b_data, panel, col0, rows, m, k, n);
            });
        }
    });
    c
}

/// One row panel of `C = AᵀB`: C rows `[col0, col0+rows)` are A's
/// columns of the same range. Outer loop stays ascending over k (the
/// per-element chain), the panel split only confines which C rows this
/// thread touches — blocking C into cache while B streams.
fn gemm_ta_panel(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    col0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    for kk in 0..k {
        let arow = &a[kk * m..kk * m + m];
        let brow = &b[kk * n..kk * n + n];
        for i in 0..rows {
            let av = arow[col0 + i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c_panel[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Seed-era single-threaded `C = AᵀB` — the bit-identity oracle for
/// [`gemm_ta`] and the fig16 "old" column.
pub fn gemm_ta_reference(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "gemm_ta shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // Accumulate row-by-row of A/B: C += a_row^T b_row. Sequential over k,
    // contiguous over n — cache friendly without materialising A^T.
    let cd = c.data_mut();
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = A * B^T` (B is `n x k`). Used for input grads. Parallelised
/// over row panels of C/A through the same budget as [`gemm`]; each
/// output element is one serial ascending-k dot product (no zero skip,
/// matching the reference exactly — adding one would change ±0.0/NaN
/// propagation), NR of them accumulated side by side for ILP.
pub fn gemm_tb(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "gemm_tb shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    let nthreads = thread_count(m * k * n);
    if nthreads <= 1 {
        gemm_tb_panel(a.data(), b.data(), c.data_mut(), 0, m, k, n);
        return c;
    }
    let rows_per = m.div_ceil(nthreads);
    let a_data = a.data();
    let b_data = b.data();
    let mut panels: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                gemm_tb_panel(a_data, b_data, panel, row0, rows, k, n);
            });
        }
    });
    c
}

/// One row panel of `C = ABᵀ`: NR dot products run side by side so
/// `a[i][kk]` loads once per kk instead of once per (j, kk); each
/// product is still its own serial ascending-k chain, so bits match
/// [`gemm_tb_reference`]'s one-at-a-time loop.
fn gemm_tb_panel(a: &[f32], b: &[f32], c_panel: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
        let crow = &mut c_panel[i * n..i * n + n];
        let mut j0 = 0;
        while j0 < n {
            let jw = NR.min(n - j0);
            let mut acc = [0.0f32; NR];
            for (kk, &av) in arow.iter().enumerate() {
                for jr in 0..jw {
                    acc[jr] += av * b[(j0 + jr) * k + kk];
                }
            }
            crow[j0..j0 + jw].copy_from_slice(&acc[..jw]);
            j0 += jw;
        }
    }
}

/// Seed-era single-threaded `C = ABᵀ` — the bit-identity oracle for
/// [`gemm_tb`] and the fig16 "old" column.
pub fn gemm_tb_reference(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "gemm_tb shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = &b.data()[j * k..j * k + k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
    c
}

/// `C = alpha * A * B + beta * C0` convenience.
pub fn addmm(a: &Matrix, b: &Matrix, c0: &Matrix, alpha: f32, beta: f32) -> Matrix {
    let mut c = gemm(a, b);
    assert_eq!((c.rows, c.cols), (c0.rows, c0.cols));
    for (x, y) in c.data_mut().iter_mut().zip(c0.data()) {
        *x = alpha * *x + beta * *y;
    }
    c
}

/// Sparse (CSR) times dense: `out = S * D` where S is given by
/// `(offsets, targets, values)` with `offsets.len() == out.rows + 1`.
/// This is the aggregation `Â·H` of the GCN layer on the native path.
///
/// Work splits across threads by **cumulative nnz**, not row count:
/// `offsets` is already the prefix-nnz array, so each thread's row
/// range is picked by binary search at `t · nnz / threads` — a skewed
/// degree distribution (one hub row with half the edges) no longer
/// serialises behind the thread that drew the hub. Per-row
/// accumulation order is untouched, so any split is bit-identical to
/// [`spmm_csr_reference`].
pub fn spmm_csr(
    offsets: &[usize],
    targets: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_rows: usize,
) -> Matrix {
    assert_eq!(offsets.len(), out_rows + 1);
    let n = dense.cols;
    let mut out = Matrix::zeros(out_rows, n);
    let nnz = targets.len();
    let nthreads = thread_count(nnz * n * 4).min(out_rows.max(1));
    if nthreads <= 1 {
        spmm_rows(offsets, targets, values, dense, out.data_mut(), 0, out_rows);
        return out;
    }
    // nnz-balanced row boundaries: bounds[t] = first row whose prefix
    // nnz reaches t/nthreads of the total (monotone by construction)
    let mut bounds = Vec::with_capacity(nthreads + 1);
    bounds.push(0usize);
    for t in 1..nthreads {
        let goal = t * nnz / nthreads;
        let r = offsets.partition_point(|&o| o < goal).min(out_rows);
        bounds.push(r.max(*bounds.last().expect("bounds is non-empty")));
    }
    bounds.push(out_rows);
    let mut panels: Vec<(usize, &mut [f32])> = Vec::with_capacity(nthreads);
    let mut rest = out.data_mut();
    for t in 0..nthreads {
        let (head, tail) = rest.split_at_mut((bounds[t + 1] - bounds[t]) * n);
        panels.push((bounds[t], head));
        rest = tail;
    }
    std::thread::scope(|s| {
        for (row0, panel) in panels.iter_mut() {
            let row0 = *row0;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                spmm_rows(offsets, targets, values, dense, panel, row0, rows);
            });
        }
    });
    out
}

/// Seed-era `spmm_csr` splitting by row count — the load-balance
/// baseline the nnz split is property-tested against (identical bits,
/// different wall-clock under degree skew) and the fig16 "old" column.
pub fn spmm_csr_reference(
    offsets: &[usize],
    targets: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_rows: usize,
) -> Matrix {
    assert_eq!(offsets.len(), out_rows + 1);
    let n = dense.cols;
    let mut out = Matrix::zeros(out_rows, n);
    let nthreads = thread_count(targets.len() * n * 4);
    if nthreads <= 1 {
        spmm_rows(offsets, targets, values, dense, out.data_mut(), 0, out_rows);
        return out;
    }
    let rows_per = out_rows.div_ceil(nthreads);
    let mut panels: Vec<&mut [f32]> = out.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                spmm_rows(offsets, targets, values, dense, panel, row0, rows);
            });
        }
    });
    out
}

fn spmm_rows(
    offsets: &[usize],
    targets: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_panel: &mut [f32],
    row0: usize,
    rows: usize,
) {
    let n = dense.cols;
    for i in 0..rows {
        let g = row0 + i;
        let orow = &mut out_panel[i * n..i * n + n];
        for e in offsets[g]..offsets[g + 1] {
            let j = targets[e] as usize;
            let w = values[e];
            let drow = dense.row(j);
            for c in 0..n {
                orow[c] += w * drow[c];
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(m: &mut Matrix) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place LeakyReLU with slope `alpha`.
pub fn leaky_relu(m: &mut Matrix, alpha: f32) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v *= alpha;
        }
    }
}

/// Zero entries of `grad` where the forward pre-activation was <= 0.
pub fn relu_grad_inplace(grad: &mut Matrix, pre_activation: &Matrix) {
    assert_eq!((grad.rows, grad.cols), (pre_activation.rows, pre_activation.cols));
    for (g, z) in grad.data_mut().iter_mut().zip(pre_activation.data()) {
        if *z <= 0.0 {
            *g = 0.0;
        }
    }
}

/// In-place scalar multiply.
pub fn scale(m: &mut Matrix, alpha: f32) {
    for v in m.data_mut() {
        *v *= alpha;
    }
}

/// `dst += src`.
pub fn add_assign(dst: &mut Matrix, src: &Matrix) {
    assert_eq!((dst.rows, dst.cols), (src.rows, src.cols));
    for (d, s) in dst.data_mut().iter_mut().zip(src.data()) {
        *d += s;
    }
}

/// Numerically-stable row softmax.
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Masked mean cross-entropy over softmax probabilities.
///
/// Returns `(loss, dL/dlogits)` where the gradient is the usual
/// `(p - onehot(y)) / n_masked` for masked rows, zero elsewhere — i.e.
/// the gradient w.r.t. the *logits* that produced `probs`.
pub fn cross_entropy_masked(probs: &Matrix, labels: &[u32], mask: &[bool]) -> (f32, Matrix) {
    assert_eq!(probs.rows, labels.len());
    assert_eq!(probs.rows, mask.len());
    let n_masked = mask.iter().filter(|&&m| m).count().max(1);
    let scale = 1.0 / n_masked as f32;
    let mut grad = Matrix::zeros(probs.rows, probs.cols);
    let mut loss = 0.0f32;
    for i in 0..probs.rows {
        if !mask[i] {
            continue;
        }
        let y = labels[i] as usize;
        let p = probs[(i, y)].max(1e-12);
        loss -= p.ln();
        let grow = grad.row_mut(i);
        grow.copy_from_slice(probs.row(i));
        grow[y] -= 1.0;
        for g in grow.iter_mut() {
            *g *= scale;
        }
    }
    (loss * scale, grad)
}
