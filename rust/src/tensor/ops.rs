//! GEMM and GCN-specific ops over [`Matrix`].
//!
//! The GEMM is cache-blocked and (for large problems) parallelised with
//! scoped `std::thread`s over row panels — the hot path of the native
//! backend. See EXPERIMENTS.md §Perf for the blocking parameters'
//! before/after.

use super::Matrix;

/// Row-panel block height for the threaded GEMM.
const MC: usize = 64;
/// K-blocking depth.
const KC: usize = 256;
/// Problems smaller than this many MACs stay single-threaded.
const PAR_THRESHOLD: usize = 1 << 21;

thread_local! {
    /// Intra-op thread budget of the *calling* thread. The coordinator
    /// divides the machine between workers (one "device" per worker,
    /// like the paper's one-GPU-per-processor testbed); 0 = use all
    /// cores (single-worker / bench mode).
    ///
    /// Thread-local on purpose: this used to be a process-global
    /// atomic, and concurrent `train_gad` runs (cargo's parallel test
    /// threads) overwrote each other's per-worker budget, making
    /// wall-clock-sensitive assertions flaky. Each worker thread now
    /// sets its own budget at spawn (see `WorkerPlan::intra_threads`),
    /// so concurrent runs cannot interfere.
    static INTRA_THREADS: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Set the per-op thread budget for ops issued from the current thread
/// (0 = all cores). Worker threads call this with `cores / workers` so
/// wall-clock scaling with workers reflects a real multi-device
/// deployment.
pub fn set_intra_threads(n: usize) {
    INTRA_THREADS.with(|c| c.set(n));
}

/// Number of worker threads to use for a problem of `flops` MACs.
fn thread_count(flops: usize) -> usize {
    if flops < PAR_THRESHOLD {
        return 1;
    }
    let cap = match INTRA_THREADS.with(|c| c.get()) {
        // unset: size from the process-wide budget (total minus what
        // standing pools — trainer workers, serve pools — hold), with
        // the historical ceiling of 8 panels
        0 => crate::threads::available().min(8),
        n => n,
    };
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(cap)
}

/// `C = A * B`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_into(a, b, &mut c);
    c
}

/// `C += A * B` into an existing output (used by the trainer to reuse
/// allocations across steps).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let nthreads = thread_count(m * k * n);
    if nthreads <= 1 {
        gemm_panel(a.data(), b.data(), c.data_mut(), 0, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(nthreads);
    let a_data = a.data();
    let b_data = b.data();
    // Split C into disjoint row panels; each thread owns one.
    let mut panels: Vec<&mut [f32]> = c.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                gemm_panel(a_data, b_data, panel, row0, rows, k, n);
            });
        }
    });
}

/// Single-threaded blocked kernel over a row panel `[row0, row0+rows)`.
/// `c_panel` is the panel's slice of C (row-major, `rows * n`).
fn gemm_panel(a: &[f32], b: &[f32], c_panel: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for ib in (0..rows).step_by(MC) {
        let ie = (ib + MC).min(rows);
        for kb in (0..k).step_by(KC) {
            let ke = (kb + KC).min(k);
            for i in ib..ie {
                let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
                let crow = &mut c_panel[i * n..i * n + n];
                for kk in kb..ke {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue; // feature matrices are sparse-ish
                    }
                    let brow = &b[kk * n..kk * n + n];
                    // autovectorises: contiguous fused multiply-add
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// `C = A^T * B` (A is `k x m`, result `m x n`). Used for weight grads.
pub fn gemm_ta(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "gemm_ta shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // Accumulate row-by-row of A/B: C += a_row^T b_row. Sequential over k,
    // contiguous over n — cache friendly without materialising A^T.
    let cd = c.data_mut();
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C = A * B^T` (B is `n x k`). Used for input grads.
pub fn gemm_tb(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "gemm_tb shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            let brow = &b.data()[j * k..j * k + k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
    c
}

/// `C = alpha * A * B + beta * C0` convenience.
pub fn addmm(a: &Matrix, b: &Matrix, c0: &Matrix, alpha: f32, beta: f32) -> Matrix {
    let mut c = gemm(a, b);
    assert_eq!((c.rows, c.cols), (c0.rows, c0.cols));
    for (x, y) in c.data_mut().iter_mut().zip(c0.data()) {
        *x = alpha * *x + beta * *y;
    }
    c
}

/// Sparse (CSR) times dense: `out = S * D` where S is given by
/// `(offsets, targets, values)` with `offsets.len() == out.rows + 1`.
/// This is the aggregation `Â·H` of the GCN layer on the native path.
pub fn spmm_csr(
    offsets: &[usize],
    targets: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_rows: usize,
) -> Matrix {
    assert_eq!(offsets.len(), out_rows + 1);
    let n = dense.cols;
    let mut out = Matrix::zeros(out_rows, n);
    let nthreads = thread_count(targets.len() * n * 4);
    if nthreads <= 1 {
        spmm_rows(offsets, targets, values, dense, out.data_mut(), 0, out_rows);
        return out;
    }
    let rows_per = out_rows.div_ceil(nthreads);
    let mut panels: Vec<&mut [f32]> = out.data_mut().chunks_mut(rows_per * n).collect();
    std::thread::scope(|s| {
        for (t, panel) in panels.iter_mut().enumerate() {
            let row0 = t * rows_per;
            let rows = panel.len() / n;
            let panel: &mut [f32] = panel;
            s.spawn(move || {
                spmm_rows(offsets, targets, values, dense, panel, row0, rows);
            });
        }
    });
    out
}

fn spmm_rows(
    offsets: &[usize],
    targets: &[u32],
    values: &[f32],
    dense: &Matrix,
    out_panel: &mut [f32],
    row0: usize,
    rows: usize,
) {
    let n = dense.cols;
    for i in 0..rows {
        let g = row0 + i;
        let orow = &mut out_panel[i * n..i * n + n];
        for e in offsets[g]..offsets[g + 1] {
            let j = targets[e] as usize;
            let w = values[e];
            let drow = dense.row(j);
            for c in 0..n {
                orow[c] += w * drow[c];
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(m: &mut Matrix) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place LeakyReLU with slope `alpha`.
pub fn leaky_relu(m: &mut Matrix, alpha: f32) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v *= alpha;
        }
    }
}

/// Zero entries of `grad` where the forward pre-activation was <= 0.
pub fn relu_grad_inplace(grad: &mut Matrix, pre_activation: &Matrix) {
    assert_eq!((grad.rows, grad.cols), (pre_activation.rows, pre_activation.cols));
    for (g, z) in grad.data_mut().iter_mut().zip(pre_activation.data()) {
        if *z <= 0.0 {
            *g = 0.0;
        }
    }
}

/// In-place scalar multiply.
pub fn scale(m: &mut Matrix, alpha: f32) {
    for v in m.data_mut() {
        *v *= alpha;
    }
}

/// `dst += src`.
pub fn add_assign(dst: &mut Matrix, src: &Matrix) {
    assert_eq!((dst.rows, dst.cols), (src.rows, src.cols));
    for (d, s) in dst.data_mut().iter_mut().zip(src.data()) {
        *d += s;
    }
}

/// Numerically-stable row softmax.
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Masked mean cross-entropy over softmax probabilities.
///
/// Returns `(loss, dL/dlogits)` where the gradient is the usual
/// `(p - onehot(y)) / n_masked` for masked rows, zero elsewhere — i.e.
/// the gradient w.r.t. the *logits* that produced `probs`.
pub fn cross_entropy_masked(probs: &Matrix, labels: &[u32], mask: &[bool]) -> (f32, Matrix) {
    assert_eq!(probs.rows, labels.len());
    assert_eq!(probs.rows, mask.len());
    let n_masked = mask.iter().filter(|&&m| m).count().max(1);
    let scale = 1.0 / n_masked as f32;
    let mut grad = Matrix::zeros(probs.rows, probs.cols);
    let mut loss = 0.0f32;
    for i in 0..probs.rows {
        if !mask[i] {
            continue;
        }
        let y = labels[i] as usize;
        let p = probs[(i, y)].max(1e-12);
        loss -= p.ln();
        let grow = grad.row_mut(i);
        grow.copy_from_slice(probs.row(i));
        grow[y] -= 1.0;
        for g in grow.iter_mut() {
            *g *= scale;
        }
    }
    (loss * scale, grad)
}
