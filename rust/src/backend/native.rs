//! Pure-rust GCN forward/backward — the reference implementation the
//! XLA artifacts are cross-checked against, and the engine for large
//! parameter sweeps (no per-shape compilation).
//!
//! Forward (Eq. 7/8):  `H_0 = X`, `Z_l = Â H_{l-1} W_l`,
//! `H_l = relu(Z_l)` for hidden layers, `P = softmax(Z_L)`.
//! Loss: masked mean cross-entropy (Eq. 9, softmax form).
//! Backward: standard reverse-mode through the chain, exploiting
//! `Â^T = Â` (symmetric normalization).

use super::Backend;
use crate::model::{Batch, GcnParams, StepOutput};
use crate::tensor::{
    cross_entropy_masked, gemm, gemm_ta, gemm_tb, relu, relu_grad_inplace, softmax_rows, Matrix,
};
use anyhow::Result;

/// See module docs.
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }

    /// Forward pass keeping intermediates for the backward.
    /// Returns (pre-activations Z_l, aggregated inputs AH_{l-1}, probs).
    fn forward(
        &self,
        batch: &Batch,
        params: &GcnParams,
    ) -> (Vec<Matrix>, Vec<Matrix>, Matrix) {
        let layers = params.layers();
        let mut zs: Vec<Matrix> = Vec::with_capacity(layers);
        let mut ahs: Vec<Matrix> = Vec::with_capacity(layers);
        let mut h = batch.features.clone();
        for (l, w) in params.ws.iter().enumerate() {
            let ah = batch.adj.spmm(&h);
            let mut z = gemm(&ah, w);
            ahs.push(ah);
            if l + 1 < layers {
                let pre = z.clone();
                relu(&mut z);
                zs.push(pre);
                h = z;
            } else {
                zs.push(z.clone());
                h = z;
            }
        }
        let probs = softmax_rows(&h);
        (zs, ahs, probs)
    }
}

impl Backend for NativeBackend {
    fn train_step(&mut self, batch: &Batch, params: &GcnParams) -> Result<StepOutput> {
        let layers = params.layers();
        let (zs, ahs, probs) = self.forward(batch, params);
        let (loss, mut dz) = cross_entropy_masked(&probs, &batch.labels, &batch.loss_mask);

        let mut grads: Vec<Matrix> = vec![Matrix::zeros(0, 0); layers];
        // walk layers backwards; dz holds dL/dZ_l
        for l in (0..layers).rev() {
            grads[l] = gemm_ta(&ahs[l], &dz); // dW_l = (Â H_{l-1})^T dZ_l
            if l > 0 {
                // dH_{l-1} = Â^T dZ_l W_l^T = Â (dZ_l W_l^T)
                let dh = batch.adj.spmm(&gemm_tb(&dz, &params.ws[l]));
                dz = dh;
                relu_grad_inplace(&mut dz, &zs[l - 1]);
            }
        }
        Ok(StepOutput { loss, grads })
    }

    fn predict(&mut self, batch: &Batch, params: &GcnParams) -> Result<Vec<u32>> {
        let (_, _, probs) = self.forward(batch, params);
        Ok(probs.argmax_rows())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::model::NormAdj;
    use crate::rng::Rng;

    fn toy_batch() -> Batch {
        let g = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (2, 3)])
            .build();
        let mut rng = Rng::seed_from_u64(42);
        let mut features = Matrix::rand_uniform(6, 8, &mut rng);
        // separate the two triangles in feature space
        for i in 0..3 {
            features[(i, 0)] += 2.0;
        }
        for i in 3..6 {
            features[(i, 1)] += 2.0;
        }
        Batch {
            id: 1,
            adj: NormAdj::from_csr(&g),
            features,
            labels: vec![0, 0, 0, 1, 1, 1],
            loss_mask: vec![true; 6],
            val_mask: vec![false; 6],
            test_mask: vec![false; 6],
            num_classes: 2,
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let batch = toy_batch();
        let mut rng = Rng::seed_from_u64(7);
        let params = GcnParams::init(8, 5, 2, 2, &mut rng);
        let mut be = NativeBackend::new();
        let out = be.train_step(&batch, &params).unwrap();

        let eps = 1e-3f32;
        let mut checked = 0;
        for l in 0..params.layers() {
            for idx in [0usize, 3, 7] {
                if idx >= params.ws[l].data().len() {
                    continue;
                }
                let mut plus = params.clone();
                plus.ws[l].data_mut()[idx] += eps;
                let mut minus = params.clone();
                minus.ws[l].data_mut()[idx] -= eps;
                let lp = be.train_step(&batch, &plus).unwrap().loss;
                let lm = be.train_step(&batch, &minus).unwrap().loss;
                let fd = (lp - lm) / (2.0 * eps);
                let an = out.grads[l].data()[idx];
                assert!(
                    (fd - an).abs() < 1e-2 + 0.05 * fd.abs().max(an.abs()),
                    "layer {l} idx {idx}: fd {fd} vs analytic {an}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 4);
    }

    #[test]
    fn training_reduces_loss_and_fits_toy() {
        let batch = toy_batch();
        let mut rng = Rng::seed_from_u64(3);
        let mut params = GcnParams::init(8, 8, 2, 2, &mut rng);
        let mut be = NativeBackend::new();
        use crate::model::{Adam, Optimizer};
        let mut opt = Adam::new(0.05);
        let first = be.train_step(&batch, &params).unwrap().loss;
        let mut last = first;
        for _ in 0..150 {
            let out = be.train_step(&batch, &params).unwrap();
            last = out.loss;
            opt.step(&mut params, &out.grads);
        }
        assert!(last < 0.3 * first, "loss {first} -> {last}");
        let preds = be.predict(&batch, &params).unwrap();
        let correct = preds
            .iter()
            .zip(&batch.labels)
            .filter(|(p, l)| p == l)
            .count();
        assert!(correct >= 5, "only {correct}/6 correct");
    }

    #[test]
    fn masked_nodes_do_not_affect_gradient() {
        // flipping the label of a masked-out node must not change grads
        let mut batch = toy_batch();
        batch.loss_mask[5] = false;
        let mut rng = Rng::seed_from_u64(4);
        let params = GcnParams::init(8, 5, 2, 2, &mut rng);
        let mut be = NativeBackend::new();
        let g1 = be.train_step(&batch, &params).unwrap();
        batch.labels[5] = 0; // flip masked node's label
        let g2 = be.train_step(&batch, &params).unwrap();
        assert_eq!(g1.loss, g2.loss);
        for (a, b) in g1.grads.iter().zip(&g2.grads) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
    }

    #[test]
    fn works_for_one_and_three_layers() {
        let batch = toy_batch();
        let mut rng = Rng::seed_from_u64(5);
        let mut be = NativeBackend::new();
        for layers in [1usize, 3] {
            let params = GcnParams::init(8, 6, 2, layers, &mut rng);
            let out = be.train_step(&batch, &params).unwrap();
            assert!(out.loss.is_finite());
            assert_eq!(out.grads.len(), layers);
            for (g, w) in out.grads.iter().zip(&params.ws) {
                assert_eq!((g.rows, g.cols), (w.rows, w.cols));
            }
        }
    }
}
