//! Compute backends: the pluggable engine that turns a [`Batch`] +
//! [`GcnParams`] into loss/gradients (train) or predictions (eval).
//!
//! * [`NativeBackend`] — pure-rust fwd/bwd on the in-repo tensor lib;
//!   works for any shape; the numerical oracle.
//! * [`XlaBackend`] — executes the AOT artifacts produced by
//!   `python/compile/aot.py` (L2 JAX model wrapping the L1 Pallas
//!   kernel) through PJRT; the production hot path. Shape-static, so
//!   batches are padded to the nearest compiled bucket.

mod native;
mod xla_backend;

pub use native::NativeBackend;
pub use xla_backend::XlaBackend;

use crate::model::{Batch, GcnParams, StepOutput};
use anyhow::Result;

/// Which backend the run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl std::str::FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => Err(format!("unknown backend '{other}' (native|xla)")),
        }
    }
}

/// A compute engine for GCN training steps.
///
/// Deliberately NOT `Send`: the xla crate's PJRT handles hold raw
/// pointers. Worker threads receive a [`BackendFactory`] and construct
/// their backend locally instead of moving one across threads.
pub trait Backend {
    /// Forward + backward: loss over `batch.loss_mask` and gradients
    /// for every weight matrix.
    fn train_step(&mut self, batch: &Batch, params: &GcnParams) -> Result<StepOutput>;

    /// Forward only: per-node predicted class.
    fn predict(&mut self, batch: &Batch, params: &GcnParams) -> Result<Vec<u32>>;

    /// Human-readable engine name for logs.
    fn name(&self) -> &'static str;
}

/// Thread-safe constructor for per-worker backends.
pub type BackendFactory = std::sync::Arc<dyn Fn() -> Result<Box<dyn Backend>> + Send + Sync>;

/// Construct a backend of the given kind. For [`BackendKind::Xla`],
/// `artifact_dir` must contain `manifest.txt` from `make artifacts`.
pub fn make_backend(kind: BackendKind, artifact_dir: &str) -> Result<Box<dyn Backend>> {
    Ok(match kind {
        BackendKind::Native => Box::new(NativeBackend::new()),
        BackendKind::Xla => Box::new(XlaBackend::new(artifact_dir)?),
    })
}

/// A [`BackendFactory`] for the given kind/dir.
pub fn backend_factory(kind: BackendKind, artifact_dir: &str) -> BackendFactory {
    let dir = artifact_dir.to_string();
    std::sync::Arc::new(move || make_backend(kind, &dir))
}
