//! XLA compute backend: pads batches to the nearest compiled shape
//! bucket and runs the AOT train/predict artifacts through [`Runtime`].

use super::Backend;
use crate::model::{Batch, GcnParams, StepOutput};
use crate::runtime::{literal_1d, literal_2d, ArtifactKind, BucketKey, Runtime};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};

/// See module docs. One instance per worker thread (PJRT handles are
/// not `Send`).
pub struct XlaBackend {
    rt: Runtime,
}

impl XlaBackend {
    /// Open the artifact directory (`make artifacts` output).
    pub fn new(artifact_dir: &str) -> Result<XlaBackend> {
        Ok(XlaBackend { rt: Runtime::new(artifact_dir)? })
    }

    /// Hidden width as the manifest encodes it (0 for 1-layer models).
    fn hidden_of(params: &GcnParams) -> usize {
        if params.layers() > 1 {
            params.ws[0].cols
        } else {
            0
        }
    }

    fn bucket(&self, kind: ArtifactKind, batch: &Batch, params: &GcnParams) -> Result<BucketKey> {
        let fdim = batch.features.cols;
        let hidden = Self::hidden_of(params);
        self.rt
            .find_bucket(kind, params.layers(), fdim, hidden, batch.num_classes, batch.len())
            .ok_or_else(|| {
                anyhow!(
                    "no {kind:?} artifact bucket for layers={} n>={} f={} h={} c={}; \
                     regenerate with `make artifacts` (see python/compile/aot.py --help)",
                    params.layers(),
                    batch.len(),
                    fdim,
                    hidden,
                    batch.num_classes
                )
            })
    }

    /// Common input marshalling: padded adj, x (+ optional y/mask).
    fn marshal(
        &self,
        batch: &Batch,
        params: &GcnParams,
        bucket_nodes: usize,
        with_labels: bool,
    ) -> Result<Vec<xla::Literal>> {
        let n = batch.len();
        let np = bucket_nodes;
        let mut inputs = Vec::with_capacity(4 + params.layers());

        let adj = batch.adj.to_dense(np);
        inputs.push(literal_2d(adj.data(), np, np)?);

        let x = batch.features.pad_to(np, batch.features.cols);
        inputs.push(literal_2d(x.data(), np, x.cols)?);

        if with_labels {
            let c = batch.num_classes;
            let mut y = Matrix::zeros(np, c);
            for i in 0..n {
                y[(i, batch.labels[i] as usize)] = 1.0;
            }
            inputs.push(literal_2d(y.data(), np, c)?);
            let mut mask = vec![0f32; np];
            for i in 0..n {
                if batch.loss_mask[i] {
                    mask[i] = 1.0;
                }
            }
            inputs.push(literal_1d(&mask));
        }

        for w in &params.ws {
            inputs.push(literal_2d(w.data(), w.rows, w.cols)?);
        }
        Ok(inputs)
    }
}

impl Backend for XlaBackend {
    fn train_step(&mut self, batch: &Batch, params: &GcnParams) -> Result<StepOutput> {
        let key = self.bucket(ArtifactKind::Train, batch, params)?;
        let inputs = self.marshal(batch, params, key.nodes, true)?;
        let outs = self.rt.execute(&key, &inputs)?;
        if outs.len() != 1 + params.layers() {
            return Err(anyhow!("train artifact returned {} outputs, want {}", outs.len(), 1 + params.layers()));
        }
        let loss = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss fetch: {e:?}"))?[0];
        let mut grads = Vec::with_capacity(params.layers());
        for (i, w) in params.ws.iter().enumerate() {
            let data = outs[i + 1]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("grad {i} fetch: {e:?}"))?;
            grads.push(Matrix::from_vec(w.rows, w.cols, data));
        }
        Ok(StepOutput { loss, grads })
    }

    fn predict(&mut self, batch: &Batch, params: &GcnParams) -> Result<Vec<u32>> {
        let key = self.bucket(ArtifactKind::Predict, batch, params)?;
        let inputs = self.marshal(batch, params, key.nodes, false)?;
        let outs = self.rt.execute(&key, &inputs)?;
        let logits = outs
            .first()
            .ok_or_else(|| anyhow!("predict artifact returned no outputs"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits fetch: {e:?}"))?;
        let full = Matrix::from_vec(key.nodes, batch.num_classes, logits);
        Ok(full.crop(batch.len(), batch.num_classes).argmax_rows())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
