//! Learning-rate schedules. The paper trains at a fixed η = 0.001;
//! production training wants warmup + decay, so the trainer accepts a
//! schedule and the ablation harness compares them.

/// η as a function of the epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// The paper's setting: η constant.
    Constant,
    /// Multiply by `gamma` every `every` epochs.
    Step { every: usize, gamma: f32 },
    /// Cosine decay from η to `floor * η` over `total` epochs.
    Cosine { total: usize, floor: f32 },
    /// Linear warmup over `epochs` then constant.
    Warmup { epochs: usize },
}

impl LrSchedule {
    /// The multiplier applied to the base learning rate at `epoch`.
    pub fn factor(&self, epoch: usize) -> f32 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::Step { every, gamma } => {
                gamma.powi((epoch / every.max(1)) as i32)
            }
            LrSchedule::Cosine { total, floor } => {
                let t = (epoch as f32 / total.max(1) as f32).min(1.0);
                floor + (1.0 - floor) * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
            }
            LrSchedule::Warmup { epochs } => {
                if epochs == 0 || epoch >= epochs {
                    1.0
                } else {
                    (epoch + 1) as f32 / epochs as f32
                }
            }
        }
    }
}

impl std::str::FromStr for LrSchedule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "constant" => Ok(LrSchedule::Constant),
            "step" => Ok(LrSchedule::Step { every: 30, gamma: 0.5 }),
            "cosine" => Ok(LrSchedule::Cosine { total: 100, floor: 0.01 }),
            "warmup" => Ok(LrSchedule::Warmup { epochs: 5 }),
            other => Err(format!("unknown schedule '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        for e in [0, 10, 1000] {
            assert_eq!(LrSchedule::Constant.factor(e), 1.0);
        }
    }

    #[test]
    fn step_halves() {
        let s = LrSchedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(0), 1.0);
        assert_eq!(s.factor(9), 1.0);
        assert_eq!(s.factor(10), 0.5);
        assert_eq!(s.factor(25), 0.25);
    }

    #[test]
    fn cosine_monotone_decreasing_to_floor() {
        let s = LrSchedule::Cosine { total: 50, floor: 0.1 };
        let mut prev = s.factor(0);
        assert!((prev - 1.0).abs() < 1e-6);
        for e in 1..=50 {
            let f = s.factor(e);
            assert!(f <= prev + 1e-6, "not monotone at {e}");
            prev = f;
        }
        assert!((s.factor(50) - 0.1).abs() < 1e-5);
        assert!((s.factor(500) - 0.1).abs() < 1e-5); // clamps past total
    }

    #[test]
    fn warmup_ramps_then_flat() {
        let s = LrSchedule::Warmup { epochs: 4 };
        assert_eq!(s.factor(0), 0.25);
        assert_eq!(s.factor(1), 0.5);
        assert_eq!(s.factor(3), 1.0);
        assert_eq!(s.factor(10), 1.0);
    }

    #[test]
    fn parse() {
        assert_eq!("constant".parse::<LrSchedule>().unwrap(), LrSchedule::Constant);
        assert!("nope".parse::<LrSchedule>().is_err());
    }
}
