//! Parameter checkpointing: save/restore a trained model so serving
//! and resumed training don't retrain from scratch. Plain text format
//! (offline image has no serde); exact f32 round-trip via bit patterns.

use super::GcnParams;
use crate::tensor::Matrix;
use anyhow::{anyhow, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Serialise parameters. Format:
/// ```text
/// GADCKPT 1
/// layers <L>
/// w <rows> <cols> <hex bits...>
/// ```
pub fn to_text(params: &GcnParams) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "GADCKPT 1");
    let _ = writeln!(s, "layers {}", params.layers());
    for w in &params.ws {
        let _ = write!(s, "w {} {}", w.rows, w.cols);
        for v in w.data() {
            let _ = write!(s, " {:08x}", v.to_bits());
        }
        s.push('\n');
    }
    s
}

/// Parse a checkpoint produced by [`to_text`].
pub fn from_text(text: &str) -> Result<GcnParams> {
    let mut lines = text.lines();
    let magic = lines.next().ok_or_else(|| anyhow!("empty checkpoint"))?;
    if magic.trim() != "GADCKPT 1" {
        return Err(anyhow!("bad magic '{magic}'"));
    }
    let layers: usize = lines
        .next()
        .and_then(|l| l.strip_prefix("layers "))
        .ok_or_else(|| anyhow!("missing layers line"))?
        .trim()
        .parse()
        .context("layer count")?;
    let mut ws = Vec::with_capacity(layers);
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        if it.next() != Some("w") {
            return Err(anyhow!("expected weight record, got '{line}'"));
        }
        let rows: usize = it.next().ok_or_else(|| anyhow!("rows"))?.parse()?;
        let cols: usize = it.next().ok_or_else(|| anyhow!("cols"))?.parse()?;
        let data: Result<Vec<f32>> = it
            .map(|h| {
                u32::from_str_radix(h, 16)
                    .map(f32::from_bits)
                    .map_err(|e| anyhow!("bad hex '{h}': {e}"))
            })
            .collect();
        let data = data?;
        if data.len() != rows * cols {
            return Err(anyhow!("weight size mismatch: {}x{} vs {} values", rows, cols, data.len()));
        }
        ws.push(Matrix::from_vec(rows, cols, data));
    }
    if ws.len() != layers {
        return Err(anyhow!("expected {layers} weight records, got {}", ws.len()));
    }
    Ok(GcnParams { ws })
}

/// Save to a file.
pub fn save(params: &GcnParams, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_text(params))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<GcnParams> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_text(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut rng = Rng::seed_from_u64(1);
        let p = GcnParams::init(13, 7, 3, 3, &mut rng);
        let q = from_text(&to_text(&p)).unwrap();
        assert_eq!(p.layers(), q.layers());
        for (a, b) in p.ws.iter().zip(&q.ws) {
            assert_eq!(a, b, "weights must round-trip exactly");
        }
    }

    #[test]
    fn special_values_survive() {
        let p = GcnParams {
            ws: vec![Matrix::from_vec(1, 4, vec![0.0, -0.0, f32::MIN_POSITIVE, 1e30])],
        };
        let q = from_text(&to_text(&p)).unwrap();
        assert_eq!(p.ws[0].data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   q.ws[0].data().iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        assert!(from_text("").is_err());
        assert!(from_text("GADCKPT 2\nlayers 0\n").is_err());
        assert!(from_text("GADCKPT 1\nlayers 1\nw 2 2 00000000\n").is_err());
        assert!(from_text("GADCKPT 1\nlayers 2\nw 1 1 3f800000\n").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::seed_from_u64(2);
        let p = GcnParams::init(4, 4, 2, 2, &mut rng);
        let path = std::env::temp_dir().join("gad_ckpt_test.txt");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.ws, q.ws);
        std::fs::remove_file(&path).ok();
    }
}
