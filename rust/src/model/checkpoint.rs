//! Parameter checkpointing: save/restore a trained model so serving
//! and resumed training don't retrain from scratch. Plain text format
//! (offline image has no serde); exact f32 round-trip via bit patterns.

use super::GcnParams;
use crate::tensor::Matrix;
use anyhow::{anyhow, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Serialise parameters. Format (version 2):
/// ```text
/// GADCKPT 2
/// layers <L>
/// shape <feature_dim> <classes>
/// w <rows> <cols> <hex bits...>
/// ```
/// The `shape` line duplicates what the weight records imply, on
/// purpose: a truncated or bit-flipped file fails the cross-check
/// instead of loading garbage into a serving tier. Version-1 files
/// (no `shape` line) still parse.
pub fn to_text(params: &GcnParams) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "GADCKPT 2");
    let _ = writeln!(s, "layers {}", params.layers());
    let _ = writeln!(
        s,
        "shape {} {}",
        params.ws.first().map(|w| w.rows).unwrap_or(0),
        params.ws.last().map(|w| w.cols).unwrap_or(0)
    );
    for w in &params.ws {
        let _ = write!(s, "w {} {}", w.rows, w.cols);
        for v in w.data() {
            let _ = write!(s, " {:08x}", v.to_bits());
        }
        s.push('\n');
    }
    s
}

/// Parse a checkpoint produced by [`to_text`] (version 2) or by the
/// pre-serving version-1 writer.
pub fn from_text(text: &str) -> Result<GcnParams> {
    let mut lines = text.lines();
    let magic = lines.next().ok_or_else(|| anyhow!("empty checkpoint"))?;
    let version: u32 = match magic.trim() {
        "GADCKPT 1" => 1,
        "GADCKPT 2" => 2,
        other => return Err(anyhow!("bad magic '{other}'")),
    };
    let layers: usize = lines
        .next()
        .and_then(|l| l.strip_prefix("layers "))
        .ok_or_else(|| anyhow!("missing layers line"))?
        .trim()
        .parse()
        .context("layer count")?;
    if layers == 0 {
        return Err(anyhow!("checkpoint declares zero layers"));
    }
    // version 2 carries a redundant shape header to cross-check against
    let declared_shape: Option<(usize, usize)> = if version >= 2 {
        let line = lines.next().ok_or_else(|| anyhow!("truncated checkpoint: missing shape line"))?;
        let rest = line
            .strip_prefix("shape ")
            .ok_or_else(|| anyhow!("expected shape line, got '{line}'"))?;
        let mut it = rest.split_whitespace();
        let fin: usize = it.next().ok_or_else(|| anyhow!("shape: feature dim"))?.parse()?;
        let fout: usize = it.next().ok_or_else(|| anyhow!("shape: classes"))?.parse()?;
        Some((fin, fout))
    } else {
        None
    };
    let mut ws = Vec::with_capacity(layers);
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        if it.next() != Some("w") {
            return Err(anyhow!("expected weight record, got '{line}'"));
        }
        let rows: usize = it.next().ok_or_else(|| anyhow!("rows"))?.parse()?;
        let cols: usize = it.next().ok_or_else(|| anyhow!("cols"))?.parse()?;
        if rows == 0 || cols == 0 {
            return Err(anyhow!("degenerate weight shape {rows}x{cols}"));
        }
        let data: Result<Vec<f32>> = it
            .map(|h| {
                // the writer always emits 8 hex digits; a shorter token
                // is a truncated file, not a smaller number
                if h.len() != 8 {
                    return Err(anyhow!("bad hex '{h}': expected 8 digits (truncated file?)"));
                }
                u32::from_str_radix(h, 16)
                    .map(f32::from_bits)
                    .map_err(|e| anyhow!("bad hex '{h}': {e}"))
            })
            .collect();
        let data = data?;
        if data.len() != rows * cols {
            return Err(anyhow!(
                "weight size mismatch: {}x{} vs {} values (truncated file?)",
                rows,
                cols,
                data.len()
            ));
        }
        ws.push(Matrix::from_vec(rows, cols, data));
    }
    if ws.len() != layers {
        return Err(anyhow!("expected {layers} weight records, got {} (truncated file?)", ws.len()));
    }
    // the layer chain must compose: f -> h -> ... -> c
    for i in 1..ws.len() {
        if ws[i - 1].cols != ws[i].rows {
            return Err(anyhow!(
                "layer chain broken at {}: {}x{} feeds {}x{}",
                i,
                ws[i - 1].rows,
                ws[i - 1].cols,
                ws[i].rows,
                ws[i].cols
            ));
        }
    }
    if let Some((fin, fout)) = declared_shape {
        if ws[0].rows != fin || ws.last().unwrap().cols != fout {
            return Err(anyhow!(
                "shape header says {fin}->{fout} but weights are {}->{}",
                ws[0].rows,
                ws.last().unwrap().cols
            ));
        }
    }
    Ok(GcnParams { ws })
}

/// Parse + verify the checkpoint fits the deployment it is about to
/// serve: input width must match the dataset's feature dimension and
/// output width its class count. The serving tier refuses to start on
/// a mismatched model instead of emitting garbage predictions.
pub fn from_text_validated(text: &str, feature_dim: usize, num_classes: usize) -> Result<GcnParams> {
    let params = from_text(text)?;
    let fin = params.ws[0].rows;
    let fout = params.ws.last().unwrap().cols;
    if fin != feature_dim || fout != num_classes {
        return Err(anyhow!(
            "checkpoint is a {fin}->{fout} model but the deployment needs {feature_dim}->{num_classes}"
        ));
    }
    Ok(params)
}

/// Save to a file.
pub fn save(params: &GcnParams, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_text(params))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<GcnParams> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_text(&text)
}

/// Load from a file and verify the model fits the deployment (see
/// [`from_text_validated`]).
pub fn load_validated(
    path: impl AsRef<Path>,
    feature_dim: usize,
    num_classes: usize,
) -> Result<GcnParams> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_text_validated(&text, feature_dim, num_classes)
        .with_context(|| format!("loading {}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut rng = Rng::seed_from_u64(1);
        let p = GcnParams::init(13, 7, 3, 3, &mut rng);
        let q = from_text(&to_text(&p)).unwrap();
        assert_eq!(p.layers(), q.layers());
        for (a, b) in p.ws.iter().zip(&q.ws) {
            assert_eq!(a, b, "weights must round-trip exactly");
        }
    }

    #[test]
    fn special_values_survive() {
        let p = GcnParams {
            ws: vec![Matrix::from_vec(1, 4, vec![0.0, -0.0, f32::MIN_POSITIVE, 1e30])],
        };
        let q = from_text(&to_text(&p)).unwrap();
        assert_eq!(p.ws[0].data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   q.ws[0].data().iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn rejects_corrupt_checkpoints() {
        assert!(from_text("").is_err());
        assert!(from_text("GADCKPT 9\nlayers 1\n").is_err(), "unknown version");
        assert!(from_text("GADCKPT 2\nlayers 0\n").is_err(), "zero layers");
        assert!(from_text("GADCKPT 1\nlayers 1\nw 2 2 00000000\n").is_err(), "too few values");
        assert!(from_text("GADCKPT 1\nlayers 2\nw 1 1 3f800000\n").is_err(), "missing record");
        assert!(from_text("GADCKPT 1\nlayers 1\nw 1 1 zzzz\n").is_err(), "bad hex");
        assert!(from_text("GADCKPT 1\nlayers 1\nw 0 0\n").is_err(), "degenerate shape");
    }

    #[test]
    fn reads_version_1_files() {
        // a file produced by the pre-serving writer: no shape line
        let v1 = "GADCKPT 1\nlayers 1\nw 1 2 3f800000 40000000\n";
        let p = from_text(v1).unwrap();
        assert_eq!((p.ws[0].rows, p.ws[0].cols), (1, 2));
        assert_eq!(p.ws[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn truncation_is_detected() {
        let mut rng = Rng::seed_from_u64(9);
        let p = GcnParams::init(6, 4, 3, 2, &mut rng);
        let full = to_text(&p);
        // chopping anywhere shy of the end must fail, never load garbage
        for frac in [0.2, 0.5, 0.9] {
            let cut = (full.len() as f64 * frac) as usize; // ASCII format: any index splits cleanly
            assert!(from_text(&full[..cut]).is_err(), "accepted a {frac} truncation");
        }
        // the nasty window: cutting inside the very last hex token
        // keeps the token count right and the shape checks blind —
        // only the 8-digit rule catches it
        let trimmed = full.trim_end();
        for cut in 1..8 {
            assert!(
                from_text(&trimmed[..trimmed.len() - cut]).is_err(),
                "accepted a {cut}-byte tail truncation"
            );
        }
    }

    #[test]
    fn shape_header_cross_check() {
        // header says 3->2 but the weight record is 1x2
        let lying = "GADCKPT 2\nlayers 1\nshape 3 2\nw 1 2 3f800000 40000000\n";
        assert!(from_text(lying).is_err());
    }

    #[test]
    fn broken_layer_chain_rejected() {
        // 2x3 feeding 4x2 cannot compose
        let bad = "GADCKPT 2\nlayers 2\nshape 2 2\n\
                   w 2 3 00000000 00000000 00000000 00000000 00000000 00000000\n\
                   w 4 2 00000000 00000000 00000000 00000000 00000000 00000000 00000000 00000000\n";
        let err = from_text(bad).unwrap_err();
        assert!(format!("{err:#}").contains("chain"), "got: {err:#}");
    }

    #[test]
    fn validated_load_checks_deployment_dims() {
        let mut rng = Rng::seed_from_u64(10);
        let p = GcnParams::init(5, 4, 3, 2, &mut rng);
        let text = to_text(&p);
        assert!(from_text_validated(&text, 5, 3).is_ok());
        assert!(from_text_validated(&text, 6, 3).is_err(), "wrong feature dim");
        assert!(from_text_validated(&text, 5, 4).is_err(), "wrong class count");
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::seed_from_u64(2);
        let p = GcnParams::init(4, 4, 2, 2, &mut rng);
        let path = std::env::temp_dir().join("gad_ckpt_test.txt");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.ws, q.ws);
        std::fs::remove_file(&path).ok();
    }
}
