//! Symmetric-normalized adjacency `Â = D^{-1/2} (A + I) D^{-1/2}`
//! (Kipf & Welling preprocessing), stored sparse (CSR with values) for
//! the native backend and densified on demand for the XLA path.
//!
//! For the serving tier's live-update path the structure is *patchable*:
//! [`refresh_rows`](NormAdj::refresh_rows) rebuilds just the rows whose
//! adjacency or inverse-sqrt-degree factors a [`GraphDelta`] touched
//! (O(Δ · deg) instead of an O(V+E) recompute), storing them in a
//! per-row overlay that [`compact`](NormAdj::compact) periodically
//! folds back into the flat arrays.
//!
//! [`GraphDelta`]: crate::serve::GraphDelta

use crate::graph::{Csr, GraphView};
use crate::tensor::{spmm_csr, Matrix};
use std::collections::HashMap;

/// Sparse normalized adjacency with self loops.
#[derive(Clone, Debug)]
pub struct NormAdj {
    offsets: Vec<usize>,
    targets: Vec<u32>,
    values: Vec<f32>,
    /// Rows diverged from the flat arrays since the last compaction
    /// (serving-tier delta updates land here; empty on the training
    /// path).
    patched: HashMap<u32, (Vec<u32>, Vec<f32>)>,
}

impl NormAdj {
    /// `1/sqrt(deg+1)` per node of `g` (degree includes the self
    /// loop). The single source of the normalization factors: both
    /// [`from_csr`](Self::from_csr) and the serving tier (which feeds
    /// *full-graph* factors into shard-local adjacencies) use this, so
    /// the serving bit-identity contract cannot drift from the
    /// training-time formula.
    pub fn inv_sqrt_degrees<G: GraphView>(g: &G) -> Vec<f32> {
        (0..g.num_nodes())
            .map(|v| 1.0 / ((g.degree(v) + 1) as f32).sqrt())
            .collect()
    }

    /// The factor for a single node — the incremental-update form of
    /// [`inv_sqrt_degrees`](Self::inv_sqrt_degrees), used when a delta
    /// changes O(Δ) degrees and a full recompute would be wasteful.
    #[inline]
    pub fn inv_sqrt_degree(degree: usize) -> f32 {
        1.0 / ((degree + 1) as f32).sqrt()
    }

    /// Build from an unweighted symmetric CSR.
    pub fn from_csr(g: &Csr) -> NormAdj {
        let inv_sqrt = Self::inv_sqrt_degrees(g);
        Self::with_inv_sqrt(g, &inv_sqrt)
    }

    /// Build over `g` with caller-supplied per-node `1/sqrt(deg+1)`
    /// factors. The serving tier passes factors computed from *global*
    /// degrees so a shard's Â entries match the full graph's exactly
    /// wherever both endpoints keep their full neighbourhood — the key
    /// to bit-identical shard-local inference on halo-complete shards.
    pub fn with_inv_sqrt<G: GraphView>(g: &G, inv_sqrt: &[f32]) -> NormAdj {
        let n = g.num_nodes();
        assert_eq!(inv_sqrt.len(), n, "inv_sqrt/node mismatch");
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + g.degree(v) + 1; // + self loop
        }
        let mut targets = vec![0u32; offsets[n]];
        let mut values = vec![0f32; offsets[n]];
        for v in 0..n {
            let mut c = offsets[v];
            let mut self_written = false;
            for &t in g.neighbors(v) {
                // keep targets sorted: insert the self loop in order
                if !self_written && t as usize > v {
                    targets[c] = v as u32;
                    values[c] = inv_sqrt[v] * inv_sqrt[v];
                    self_written = true;
                    c += 1;
                }
                targets[c] = t;
                values[c] = inv_sqrt[v] * inv_sqrt[t as usize];
                c += 1;
            }
            if !self_written {
                targets[c] = v as u32;
                values[c] = inv_sqrt[v] * inv_sqrt[v];
            }
        }
        NormAdj { offsets, targets, values, patched: HashMap::new() }
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// One row of Â: `(targets, values)`, sorted by target, self loop
    /// included — reads through the patch overlay. The serving tier's
    /// aggregation loop uses this instead of [`raw`](Self::raw) so it
    /// keeps working mid-overlay.
    #[inline]
    pub fn row(&self, v: usize) -> (&[u32], &[f32]) {
        if let Some((t, w)) = self.patched.get(&(v as u32)) {
            (t, w)
        } else {
            let (a, b) = (self.offsets[v], self.offsets[v + 1]);
            (&self.targets[a..b], &self.values[a..b])
        }
    }

    /// Rebuild the rows in `rows` from the (post-delta) graph view and
    /// the *updated* inverse-sqrt-degree factors, placing them in the
    /// patch overlay. Callers pass exactly the affected set — the
    /// delta's endpoints plus their current neighbours (a degree change
    /// at `u` perturbs `inv_sqrt[u]`, which appears in every
    /// neighbour's row) — so the cost is O(Δ · deg), not O(V+E).
    pub fn refresh_rows<G: GraphView>(&mut self, g: &G, inv_sqrt: &[f32], rows: &[u32]) {
        assert_eq!(g.num_nodes(), self.num_nodes(), "refresh cannot resize; rebuild instead");
        for &v in rows {
            let vu = v as usize;
            let nbrs = g.neighbors(vu);
            let mut t = Vec::with_capacity(nbrs.len() + 1);
            let mut w = Vec::with_capacity(nbrs.len() + 1);
            let iv = inv_sqrt[vu];
            let mut self_written = false;
            for &x in nbrs {
                if !self_written && x > v {
                    t.push(v);
                    w.push(iv * iv);
                    self_written = true;
                }
                t.push(x);
                w.push(iv * inv_sqrt[x as usize]);
            }
            if !self_written {
                t.push(v);
                w.push(iv * iv);
            }
            self.patched.insert(v, (t, w));
        }
    }

    /// Patched-row count (compaction heuristics / tests).
    pub fn patched_rows(&self) -> usize {
        self.patched.len()
    }

    /// Fold the patch overlay back into flat arrays. O(V+E); called on
    /// the same cadence as [`DeltaCsr`](crate::graph::DeltaCsr)
    /// compaction, never per delta.
    pub fn compact(&mut self) {
        if self.patched.is_empty() {
            return;
        }
        let n = self.num_nodes();
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + self.row(v).0.len();
        }
        let mut targets = vec![0u32; offsets[n]];
        let mut values = vec![0f32; offsets[n]];
        for v in 0..n {
            let (t, w) = self.row(v);
            targets[offsets[v]..offsets[v] + t.len()].copy_from_slice(t);
            values[offsets[v]..offsets[v] + w.len()].copy_from_slice(w);
        }
        self.offsets = offsets;
        self.targets = targets;
        self.values = values;
        self.patched.clear();
    }

    /// `Â * dense` — the aggregation of one GCN layer.
    pub fn spmm(&self, dense: &Matrix) -> Matrix {
        let n = self.num_nodes();
        if self.patched.is_empty() {
            return spmm_csr(&self.offsets, &self.targets, &self.values, dense, n);
        }
        // overlay present: row-wise gather (serving-tier path; the
        // training hot loop never patches)
        let cols = dense.cols;
        let mut out = Matrix::zeros(n, cols);
        for v in 0..n {
            let (t, w) = self.row(v);
            let orow = out.row_mut(v);
            for (e, &j) in t.iter().enumerate() {
                let x = dense.row(j as usize);
                let wv = w[e];
                for c in 0..cols {
                    orow[c] += wv * x[c];
                }
            }
        }
        out
    }

    /// Densify into an `n x n` matrix (XLA path, pre-padding).
    pub fn to_dense(&self, padded: usize) -> Matrix {
        let n = self.num_nodes();
        assert!(padded >= n);
        let mut m = Matrix::zeros(padded, padded);
        for v in 0..n {
            let (t, w) = self.row(v);
            for (e, &j) in t.iter().enumerate() {
                m[(v, j as usize)] = w[e];
            }
        }
        m
    }

    /// Bytes resident.
    pub fn nbytes(&self) -> usize {
        self.offsets.len() * 8
            + self.targets.len() * 4
            + self.values.len() * 4
            + self
                .patched
                .values()
                .map(|(t, w)| t.capacity() * 4 + w.capacity() * 4 + 32)
                .sum::<usize>()
    }

    /// Raw flat parts (tests; ignores the patch overlay — call
    /// [`compact`](Self::compact) first when patches may exist).
    pub fn raw(&self) -> (&[usize], &[u32], &[f32]) {
        (&self.offsets, &self.targets, &self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn rows_include_self_loop_and_are_sorted() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let a = NormAdj::from_csr(&g);
        let (off, tgt, _) = a.raw();
        for v in 0..4 {
            let row = &tgt[off[v]..off[v + 1]];
            assert!(row.contains(&(v as u32)), "self loop missing at {v}");
            assert!(row.windows(2).all(|w| w[0] < w[1]), "row {v} unsorted");
        }
    }

    #[test]
    fn symmetric_values() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let a = NormAdj::from_csr(&g);
        let d = a.to_dense(3);
        for i in 0..3 {
            for j in 0..3 {
                assert!((d[(i, j)] - d[(j, i)]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn isolated_node_self_loop_is_one() {
        let g = GraphBuilder::new(2).edges(&[]).build();
        let a = NormAdj::from_csr(&g);
        let d = a.to_dense(2);
        assert!((d[(0, 0)] - 1.0).abs() < 1e-7);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn spmm_equals_dense_matmul() {
        use crate::rng::Rng;
        use crate::tensor::gemm;
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)])
            .build();
        let a = NormAdj::from_csr(&g);
        let mut rng = Rng::seed_from_u64(1);
        let x = Matrix::rand_uniform(5, 7, &mut rng);
        let sparse = a.spmm(&x);
        let dense = gemm(&a.to_dense(5), &x);
        assert!(sparse.allclose(&dense, 1e-5));
    }

    #[test]
    fn with_inv_sqrt_generalises_from_csr() {
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (1, 3)])
            .build();
        let local: Vec<f32> = (0..5).map(|v| 1.0 / ((g.degree(v) + 1) as f32).sqrt()).collect();
        let a = NormAdj::from_csr(&g);
        let b = NormAdj::with_inv_sqrt(&g, &local);
        let (ao, at, av) = a.raw();
        let (bo, bt, bv) = b.raw();
        assert_eq!(ao, bo);
        assert_eq!(at, bt);
        assert_eq!(
            av.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            bv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "local-degree factors must reproduce from_csr bit-for-bit"
        );
    }

    #[test]
    fn global_factors_differ_on_truncated_subgraph() {
        use crate::graph::Subgraph;
        // path 0-1-2-3: induce {0,1,2}; node 2 loses its edge to 3, so
        // induced and global degrees disagree exactly at node 2
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let sub = Subgraph::induce(&g, &[0, 1, 2]);
        let global: Vec<f32> = sub
            .global_ids
            .iter()
            .map(|&gid| 1.0 / ((g.degree(gid as usize) + 1) as f32).sqrt())
            .collect();
        let induced = NormAdj::from_csr(&sub.csr).to_dense(3);
        let exact = NormAdj::with_inv_sqrt(&sub.csr, &global).to_dense(3);
        // rows not touching node 2 agree, node 2's self loop does not
        assert!((induced[(0, 1)] - exact[(0, 1)]).abs() < 1e-7);
        assert!((induced[(2, 2)] - exact[(2, 2)]).abs() > 1e-3);
    }

    #[test]
    fn kipf_normalization_values() {
        // edge 0-1 only: Â[0][1] = 1/sqrt(2)/sqrt(2) = 0.5, diag = 0.5
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let d = NormAdj::from_csr(&g).to_dense(2);
        for (i, j, want) in [(0, 0, 0.5), (0, 1, 0.5), (1, 1, 0.5)] {
            assert!((d[(i, j)] - want).abs() < 1e-6);
        }
    }

    /// Patch a delta's affected rows and compare against a from-scratch
    /// rebuild on the mutated graph — the incremental path must be
    /// bit-identical, across spmm and after compaction.
    #[test]
    fn refresh_rows_matches_full_rebuild() {
        use crate::graph::{DeltaCsr, GraphView};
        let base = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (1, 4)])
            .build();
        let mut inv = NormAdj::inv_sqrt_degrees(&base);
        let mut adj = NormAdj::with_inv_sqrt(&base, &inv);

        let mut g = DeltaCsr::new(base);
        g.add_edge(0, 5);
        g.remove_edge(1, 4);
        // affected: endpoints {0,5,1,4} + their current neighbours
        let mut affected: Vec<u32> = vec![0, 5, 1, 4];
        for &v in &[0u32, 5, 1, 4] {
            inv[v as usize] = NormAdj::inv_sqrt_degree(GraphView::degree(&g, v as usize));
        }
        for &v in &[0u32, 5, 1, 4] {
            affected.extend_from_slice(GraphView::neighbors(&g, v as usize));
        }
        affected.sort_unstable();
        affected.dedup();
        adj.refresh_rows(&g, &inv, &affected);

        let oracle = NormAdj::with_inv_sqrt(&g, &NormAdj::inv_sqrt_degrees(&g));
        for v in 0..6 {
            let (pt, pw) = adj.row(v);
            let (ot, ow) = oracle.row(v);
            assert_eq!(pt, ot, "targets diverge at row {v}");
            assert_eq!(
                pw.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                ow.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "values diverge at row {v}"
            );
        }
        // spmm through the overlay agrees too, and compaction is lossless
        use crate::rng::Rng;
        let mut rng = Rng::seed_from_u64(9);
        let x = Matrix::rand_uniform(6, 4, &mut rng);
        let through_patch = adj.spmm(&x);
        adj.compact();
        assert_eq!(adj.patched_rows(), 0);
        let flat = adj.spmm(&x);
        assert_eq!(
            through_patch.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            flat.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let oracle_y = oracle.spmm(&x);
        assert_eq!(
            flat.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            oracle_y.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
