//! Symmetric-normalized adjacency `Â = D^{-1/2} (A + I) D^{-1/2}`
//! (Kipf & Welling preprocessing), stored sparse (CSR with values) for
//! the native backend and densified on demand for the XLA path.

use crate::graph::Csr;
use crate::tensor::{spmm_csr, Matrix};

/// Sparse normalized adjacency with self loops.
#[derive(Clone, Debug)]
pub struct NormAdj {
    offsets: Vec<usize>,
    targets: Vec<u32>,
    values: Vec<f32>,
}

impl NormAdj {
    /// `1/sqrt(deg+1)` per node of `g` (degree includes the self
    /// loop). The single source of the normalization factors: both
    /// [`from_csr`](Self::from_csr) and the serving tier (which feeds
    /// *full-graph* factors into shard-local adjacencies) use this, so
    /// the serving bit-identity contract cannot drift from the
    /// training-time formula.
    pub fn inv_sqrt_degrees(g: &Csr) -> Vec<f32> {
        (0..g.num_nodes())
            .map(|v| 1.0 / ((g.degree(v) + 1) as f32).sqrt())
            .collect()
    }

    /// Build from an unweighted symmetric CSR.
    pub fn from_csr(g: &Csr) -> NormAdj {
        let inv_sqrt = Self::inv_sqrt_degrees(g);
        Self::with_inv_sqrt(g, &inv_sqrt)
    }

    /// Build over `g` with caller-supplied per-node `1/sqrt(deg+1)`
    /// factors. The serving tier passes factors computed from *global*
    /// degrees so a shard's Â entries match the full graph's exactly
    /// wherever both endpoints keep their full neighbourhood — the key
    /// to bit-identical shard-local inference on halo-complete shards.
    pub fn with_inv_sqrt(g: &Csr, inv_sqrt: &[f32]) -> NormAdj {
        let n = g.num_nodes();
        assert_eq!(inv_sqrt.len(), n, "inv_sqrt/node mismatch");
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + g.degree(v) + 1; // + self loop
        }
        let mut targets = vec![0u32; offsets[n]];
        let mut values = vec![0f32; offsets[n]];
        for v in 0..n {
            let mut c = offsets[v];
            let mut self_written = false;
            for &t in g.neighbors(v) {
                // keep targets sorted: insert the self loop in order
                if !self_written && t as usize > v {
                    targets[c] = v as u32;
                    values[c] = inv_sqrt[v] * inv_sqrt[v];
                    self_written = true;
                    c += 1;
                }
                targets[c] = t;
                values[c] = inv_sqrt[v] * inv_sqrt[t as usize];
                c += 1;
            }
            if !self_written {
                targets[c] = v as u32;
                values[c] = inv_sqrt[v] * inv_sqrt[v];
            }
        }
        NormAdj { offsets, targets, values }
    }

    /// Node count.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// `Â * dense` — the aggregation of one GCN layer.
    pub fn spmm(&self, dense: &Matrix) -> Matrix {
        spmm_csr(&self.offsets, &self.targets, &self.values, dense, self.num_nodes())
    }

    /// Densify into an `n x n` matrix (XLA path, pre-padding).
    pub fn to_dense(&self, padded: usize) -> Matrix {
        let n = self.num_nodes();
        assert!(padded >= n);
        let mut m = Matrix::zeros(padded, padded);
        for v in 0..n {
            for e in self.offsets[v]..self.offsets[v + 1] {
                m[(v, self.targets[e] as usize)] = self.values[e];
            }
        }
        m
    }

    /// Bytes resident.
    pub fn nbytes(&self) -> usize {
        self.offsets.len() * 8 + self.targets.len() * 4 + self.values.len() * 4
    }

    /// Row sums of `D^{1/2} Â D^{1/2}` are degrees+1 — cheap invariant:
    /// every row of Â must sum to a positive value <= 1·√((d+1)) etc.
    /// We expose raw parts for tests instead.
    pub fn raw(&self) -> (&[usize], &[u32], &[f32]) {
        (&self.offsets, &self.targets, &self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn rows_include_self_loop_and_are_sorted() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let a = NormAdj::from_csr(&g);
        let (off, tgt, _) = a.raw();
        for v in 0..4 {
            let row = &tgt[off[v]..off[v + 1]];
            assert!(row.contains(&(v as u32)), "self loop missing at {v}");
            assert!(row.windows(2).all(|w| w[0] < w[1]), "row {v} unsorted");
        }
    }

    #[test]
    fn symmetric_values() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let a = NormAdj::from_csr(&g);
        let d = a.to_dense(3);
        for i in 0..3 {
            for j in 0..3 {
                assert!((d[(i, j)] - d[(j, i)]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn isolated_node_self_loop_is_one() {
        let g = GraphBuilder::new(2).edges(&[]).build();
        let a = NormAdj::from_csr(&g);
        let d = a.to_dense(2);
        assert!((d[(0, 0)] - 1.0).abs() < 1e-7);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn spmm_equals_dense_matmul() {
        use crate::rng::Rng;
        use crate::tensor::gemm;
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)])
            .build();
        let a = NormAdj::from_csr(&g);
        let mut rng = Rng::seed_from_u64(1);
        let x = Matrix::rand_uniform(5, 7, &mut rng);
        let sparse = a.spmm(&x);
        let dense = gemm(&a.to_dense(5), &x);
        assert!(sparse.allclose(&dense, 1e-5));
    }

    #[test]
    fn with_inv_sqrt_generalises_from_csr() {
        let g = GraphBuilder::new(5)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (1, 3)])
            .build();
        let local: Vec<f32> = (0..5).map(|v| 1.0 / ((g.degree(v) + 1) as f32).sqrt()).collect();
        let a = NormAdj::from_csr(&g);
        let b = NormAdj::with_inv_sqrt(&g, &local);
        let (ao, at, av) = a.raw();
        let (bo, bt, bv) = b.raw();
        assert_eq!(ao, bo);
        assert_eq!(at, bt);
        assert_eq!(
            av.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            bv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "local-degree factors must reproduce from_csr bit-for-bit"
        );
    }

    #[test]
    fn global_factors_differ_on_truncated_subgraph() {
        use crate::graph::Subgraph;
        // path 0-1-2-3: induce {0,1,2}; node 2 loses its edge to 3, so
        // induced and global degrees disagree exactly at node 2
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let sub = Subgraph::induce(&g, &[0, 1, 2]);
        let global: Vec<f32> = sub
            .global_ids
            .iter()
            .map(|&gid| 1.0 / ((g.degree(gid as usize) + 1) as f32).sqrt())
            .collect();
        let induced = NormAdj::from_csr(&sub.csr).to_dense(3);
        let exact = NormAdj::with_inv_sqrt(&sub.csr, &global).to_dense(3);
        // rows not touching node 2 agree, node 2's self loop does not
        assert!((induced[(0, 1)] - exact[(0, 1)]).abs() < 1e-7);
        assert!((induced[(2, 2)] - exact[(2, 2)]).abs() > 1e-3);
    }

    #[test]
    fn kipf_normalization_values() {
        // edge 0-1 only: Â[0][1] = 1/sqrt(2)/sqrt(2) = 0.5, diag = 0.5
        let g = GraphBuilder::new(2).edges(&[(0, 1)]).build();
        let d = NormAdj::from_csr(&g).to_dense(2);
        for (i, j, want) in [(0, 0, 0.5), (0, 1, 0.5), (1, 1, 0.5)] {
            assert!((d[(i, j)] - want).abs() < 1e-6);
        }
    }
}
