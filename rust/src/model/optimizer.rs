//! Parameter-update rules. The paper's Eq. 12/16 is plain synchronous
//! SGD on the consensus gradient; we default to Adam (the de-facto
//! optimizer behind its PyTorch baselines at lr = 0.001) and keep SGD
//! for ablations.

use super::GcnParams;
use crate::tensor::Matrix;

/// A stateful optimizer applied by every worker to the *same* consensus
/// gradient, keeping replicas in sync (updates are deterministic).
pub trait Optimizer: Send {
    /// Apply one update in place.
    fn step(&mut self, params: &mut GcnParams, grads: &[Matrix]);
    /// Clone into a boxed fresh instance with the same hyperparameters
    /// (each worker holds its own state).
    fn fresh(&self) -> Box<dyn Optimizer>;
    /// Clone *including accumulated state* (moments, step count). The
    /// async engine ships this alongside a parameter snapshot when it
    /// re-syncs a laggard, so the recovered replica's future updates
    /// stay bit-identical to every other replica's.
    fn clone_box(&self) -> Box<dyn Optimizer>;
    /// Bytes of accumulated optimizer state (zero for stateless rules).
    /// Re-sync traffic accounting adds this to the parameter bytes so
    /// the reported payload matches what a real transfer would ship.
    fn state_nbytes(&self) -> usize {
        0
    }
    /// Scale the effective learning rate relative to the base (LR
    /// schedules; gradient scaling would be a no-op under Adam).
    fn set_lr_factor(&mut self, _factor: f32) {}
}

/// Vanilla SGD: `W -= lr * g` (paper Eq. 12).
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    factor: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Sgd { lr, factor: 1.0 }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut GcnParams, grads: &[Matrix]) {
        let lr = self.lr * self.factor;
        for (w, g) in params.ws.iter_mut().zip(grads) {
            for (wv, gv) in w.data_mut().iter_mut().zip(g.data()) {
                *wv -= lr * gv;
            }
        }
    }
    fn fresh(&self) -> Box<dyn Optimizer> {
        Box::new(Sgd::new(self.lr))
    }
    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
    fn set_lr_factor(&mut self, factor: f32) {
        self.factor = factor;
    }
}

/// Adam (Kingma & Ba) with bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    factor: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            factor: 1.0,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut GcnParams, grads: &[Matrix]) {
        if self.m.is_empty() {
            self.m = grads.iter().map(|g| vec![0.0; g.data().len()]).collect();
            self.v = grads.iter().map(|g| vec![0.0; g.data().len()]).collect();
        }
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for ((w, g), (m, v)) in params
            .ws
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for i in 0..g.data().len() {
                let gv = g.data()[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gv;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gv * gv;
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                w.data_mut()[i] -= self.lr * self.factor * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
    fn fresh(&self) -> Box<dyn Optimizer> {
        Box::new(Adam::new(self.lr))
    }
    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
    fn state_nbytes(&self) -> usize {
        self.m
            .iter()
            .chain(self.v.iter())
            .map(|s| s.len() * std::mem::size_of::<f32>())
            .sum()
    }
    fn set_lr_factor(&mut self, factor: f32) {
        self.factor = factor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn quadratic_grad(p: &GcnParams) -> Vec<Matrix> {
        // grad of 0.5*||W||^2 is W: both optimizers must shrink weights
        p.ws.clone()
    }

    #[test]
    fn sgd_descends_quadratic() {
        let mut rng = Rng::seed_from_u64(1);
        let mut p = GcnParams::init(4, 4, 2, 2, &mut rng);
        let mut opt = Sgd::new(0.1);
        let before: f32 = p.ws.iter().map(|w| w.frobenius()).sum();
        for _ in 0..50 {
            let g = quadratic_grad(&p);
            opt.step(&mut p, &g);
        }
        let after: f32 = p.ws.iter().map(|w| w.frobenius()).sum();
        assert!(after < 0.1 * before, "before {before} after {after}");
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut rng = Rng::seed_from_u64(2);
        let mut p = GcnParams::init(4, 4, 2, 2, &mut rng);
        let mut opt = Adam::new(0.05);
        let before: f32 = p.ws.iter().map(|w| w.frobenius()).sum();
        for _ in 0..200 {
            let g = quadratic_grad(&p);
            opt.step(&mut p, &g);
        }
        let after: f32 = p.ws.iter().map(|w| w.frobenius()).sum();
        assert!(after < 0.2 * before, "before {before} after {after}");
    }

    #[test]
    fn clone_box_carries_adam_state() {
        let mut rng = Rng::seed_from_u64(4);
        let mut p = GcnParams::init(4, 4, 2, 2, &mut rng);
        let mut opt = Adam::new(0.01);
        // accumulate some moments, then fork
        for _ in 0..5 {
            let g = quadratic_grad(&p);
            opt.step(&mut p, &g);
        }
        let mut forked = opt.clone_box();
        let (mut a, mut b) = (p.clone(), p.clone());
        for _ in 0..5 {
            let g = quadratic_grad(&a);
            opt.step(&mut a, &g);
            forked.step(&mut b, &g);
        }
        assert_eq!(a.max_abs_diff(&b), 0.0, "cloned state must track exactly");
    }

    #[test]
    fn identical_updates_keep_replicas_synced() {
        let mut rng = Rng::seed_from_u64(3);
        let p0 = GcnParams::init(4, 4, 2, 2, &mut rng);
        let (mut a, mut b) = (p0.clone(), p0.clone());
        let mut oa = Adam::new(0.01);
        let mut ob = oa.fresh();
        for _ in 0..10 {
            let g = quadratic_grad(&a);
            oa.step(&mut a, &g);
            ob.step(&mut b, &g);
        }
        assert!(a.max_abs_diff(&b) == 0.0);
    }
}
