//! GCN model state shared by both compute backends: parameters,
//! optimizers, the normalized adjacency operator, and the batch type
//! the trainer feeds to a [`Backend`](crate::backend::Backend).

mod adjacency;
pub mod checkpoint;
mod optimizer;
mod params;
mod schedule;

pub use adjacency::NormAdj;
pub use optimizer::{Adam, Optimizer, Sgd};
pub use params::GcnParams;
pub use schedule::LrSchedule;

use crate::tensor::Matrix;

/// One training unit: an (augmented) subgraph with everything the
/// forward/backward pass needs, in local ids.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Stable identity for executable-side caching (dense adjacency,
    /// bucket choice). Unique per distinct subgraph within a run.
    pub id: u64,
    /// Symmetric-normalized adjacency with self loops.
    pub adj: NormAdj,
    /// `n x f` node features.
    pub features: Matrix,
    /// Label per node.
    pub labels: Vec<u32>,
    /// Nodes contributing to the loss (train split ∩ non-replica).
    pub loss_mask: Vec<bool>,
    /// Validation / test nodes (non-replica) for distributed eval.
    pub val_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
    pub num_classes: usize,
}

impl Batch {
    /// Node count.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True if no nodes.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Count of loss-contributing nodes.
    pub fn masked_count(&self) -> usize {
        self.loss_mask.iter().filter(|&&m| m).count()
    }

    /// Bytes resident for this batch (memory accounting).
    pub fn nbytes(&self) -> usize {
        self.features.nbytes() + self.adj.nbytes() + self.labels.len() * 5
    }

    /// Structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.len();
        if self.features.rows != n {
            return Err("features/labels mismatch".into());
        }
        if self.loss_mask.len() != n || self.val_mask.len() != n || self.test_mask.len() != n {
            return Err("mask length mismatch".into());
        }
        if self.adj.num_nodes() != n {
            return Err("adjacency size mismatch".into());
        }
        if self.labels.iter().any(|&l| l as usize >= self.num_classes) {
            return Err("label out of range".into());
        }
        Ok(())
    }
}

/// Gradients + loss returned by one backend step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    pub grads: Vec<Matrix>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn batch_validate_catches_mismatch() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let b = Batch {
            id: 0,
            adj: NormAdj::from_csr(&g),
            features: Matrix::zeros(3, 4),
            labels: vec![0, 1, 0],
            loss_mask: vec![true; 3],
            val_mask: vec![false; 3],
            test_mask: vec![false; 3],
            num_classes: 2,
        };
        b.validate().unwrap();
        let mut bad = b.clone();
        bad.labels[0] = 9;
        assert!(bad.validate().is_err());
    }
}
