//! GCN trainable parameters.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Weight matrices of an `L`-layer GCN:
/// `f -> h -> ... -> h -> c` (no biases, per the paper's Eq. 7).
#[derive(Clone, Debug)]
pub struct GcnParams {
    pub ws: Vec<Matrix>,
}

impl GcnParams {
    /// Glorot-initialised parameters for the given shape.
    pub fn init(feature_dim: usize, hidden: usize, classes: usize, layers: usize, rng: &mut Rng) -> Self {
        assert!(layers >= 1);
        let mut ws = Vec::with_capacity(layers);
        if layers == 1 {
            ws.push(Matrix::glorot(feature_dim, classes, rng));
        } else {
            ws.push(Matrix::glorot(feature_dim, hidden, rng));
            for _ in 1..layers - 1 {
                ws.push(Matrix::glorot(hidden, hidden, rng));
            }
            ws.push(Matrix::glorot(hidden, classes, rng));
        }
        GcnParams { ws }
    }

    /// Layer count.
    pub fn layers(&self) -> usize {
        self.ws.len()
    }

    /// Total scalar parameter count.
    pub fn num_params(&self) -> usize {
        self.ws.iter().map(|w| w.rows * w.cols).sum()
    }

    /// Bytes of one full gradient/parameter exchange (communication
    /// accounting for consensus rounds).
    pub fn nbytes(&self) -> usize {
        self.num_params() * std::mem::size_of::<f32>()
    }

    /// Zeroed gradients of matching shapes.
    pub fn zeros_like(&self) -> Vec<Matrix> {
        self.ws.iter().map(|w| Matrix::zeros(w.rows, w.cols)).collect()
    }

    /// Flatten all weights into one vector (runtime marshalling).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_params());
        for w in &self.ws {
            out.extend_from_slice(w.data());
        }
        out
    }

    /// Max |Δ| against another parameter set (convergence checks).
    pub fn max_abs_diff(&self, other: &GcnParams) -> f32 {
        self.ws
            .iter()
            .zip(&other.ws)
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_for_layer_counts() {
        let mut rng = Rng::seed_from_u64(1);
        for layers in 1..=4 {
            let p = GcnParams::init(10, 8, 3, layers, &mut rng);
            assert_eq!(p.layers(), layers);
            assert_eq!(p.ws[0].rows, 10);
            assert_eq!(p.ws.last().unwrap().cols, 3);
            for i in 1..layers {
                assert_eq!(p.ws[i - 1].cols, p.ws[i].rows, "chain broken at {i}");
            }
        }
    }

    #[test]
    fn num_params_and_bytes() {
        let mut rng = Rng::seed_from_u64(2);
        let p = GcnParams::init(4, 3, 2, 2, &mut rng);
        assert_eq!(p.num_params(), 4 * 3 + 3 * 2);
        assert_eq!(p.nbytes(), (4 * 3 + 3 * 2) * 4);
        assert_eq!(p.flatten().len(), p.num_params());
    }
}
