//! Tiny criterion replacement (criterion is not in the offline
//! registry): warmup + timed samples, mean/σ/min/max, markdown rows.
//! Used by every target in `rust/benches/`.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    /// criterion-style one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.mean),
            fmt_dur(self.max),
            self.samples
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: `warmup` untimed runs then `samples` timed runs.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, samples: 5, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bencher { warmup, samples, results: Vec::new() }
    }

    /// Time `f`, which must return something observable (guards against
    /// the optimizer deleting the body). Prints the summary line.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let total: Duration = times.iter().sum();
        let mean = total / times.len() as u32;
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean.as_secs_f64();
                d * d
            })
            .sum::<f64>()
            / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples: times.len(),
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *times.iter().min().unwrap(),
            max: *times.iter().max().unwrap(),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All recorded stats.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Markdown table of every result.
    pub fn markdown(&self) -> String {
        let mut s = String::from("| benchmark | mean | min | max | samples |\n|---|---|---|---|---|\n");
        for r in &self.results {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} |\n",
                r.name,
                fmt_dur(r.mean),
                fmt_dur(r.min),
                fmt_dur(r.max),
                r.samples
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(0, 3);
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.mean.as_nanos() > 0);
        assert_eq!(s.samples, 3);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn markdown_contains_rows() {
        let mut b = Bencher::new(0, 1);
        b.bench("x", || 1);
        assert!(b.markdown().contains("| x |"));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
