//! Tiny criterion replacement (criterion is not in the offline
//! registry): warmup + timed samples, mean/σ/min/max, markdown rows.
//! Used by every target in `rust/benches/`.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    /// criterion-style one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.mean),
            fmt_dur(self.max),
            self.samples
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: `warmup` untimed runs then `samples` timed runs.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, samples: 5, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bencher { warmup, samples, results: Vec::new() }
    }

    /// Time `f`, which must return something observable (guards against
    /// the optimizer deleting the body). Prints the summary line.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let total: Duration = times.iter().sum();
        let mean = total / times.len() as u32;
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean.as_secs_f64();
                d * d
            })
            .sum::<f64>()
            / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples: times.len(),
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *times.iter().min().unwrap(),
            max: *times.iter().max().unwrap(),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All recorded stats.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Markdown table of every result.
    pub fn markdown(&self) -> String {
        let mut s = String::from("| benchmark | mean | min | max | samples |\n|---|---|---|---|---|\n");
        for r in &self.results {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} |\n",
                r.name,
                fmt_dur(r.mean),
                fmt_dur(r.min),
                fmt_dur(r.max),
                r.samples
            ));
        }
        s
    }
}

// --------------------------------------------------------------------
// Fig 16 (ours): raw-speed kernel comparison, old vs new
// --------------------------------------------------------------------

/// One fig16 row: a kernel at one shape, seed-era reference vs packed/
/// balanced path, same inputs, same bits (asserted before timing).
#[derive(Clone, Debug)]
pub struct KernelRow {
    pub kernel: &'static str,
    pub shape: String,
    /// MACs × 2 for the dense kernels, `2 · nnz · n` for SpMM.
    pub flops: f64,
    pub old_s: f64,
    pub new_s: f64,
}

impl KernelRow {
    pub fn gflops_old(&self) -> f64 {
        self.flops / self.old_s / 1e9
    }

    pub fn gflops_new(&self) -> f64 {
        self.flops / self.new_s / 1e9
    }

    pub fn speedup(&self) -> f64 {
        self.old_s / self.new_s
    }
}

/// Fig 16 report: every kernel row plus md/csv/json emitters (the
/// JSON is hand-rolled — serde is not in the offline registry).
#[derive(Clone, Debug, Default)]
pub struct KernelBenchReport {
    pub rows: Vec<KernelRow>,
}

impl KernelBenchReport {
    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "## Fig 16 (ours) — raw-speed kernels, reference vs packed/balanced\n\n\
             | kernel | shape | old GFLOP/s | new GFLOP/s | speedup |\n|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {} | {:.2} | {:.2} | {:.2}x |\n",
                r.kernel,
                r.shape,
                r.gflops_old(),
                r.gflops_new(),
                r.speedup()
            ));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("kernel,shape,flops,old_s,new_s,old_gflops,new_gflops,speedup\n");
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{:.0},{:.9},{:.9},{:.3},{:.3},{:.3}\n",
                r.kernel,
                r.shape,
                r.flops,
                r.old_s,
                r.new_s,
                r.gflops_old(),
                r.gflops_new(),
                r.speedup()
            ));
        }
        s
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, r) in self.rows.iter().enumerate() {
            let sep = if i + 1 < self.rows.len() { "," } else { "" };
            s.push_str(&format!(
                "  {{\"kernel\": \"{}\", \"shape\": \"{}\", \"flops\": {:.0}, \
                 \"old_s\": {:.9}, \"new_s\": {:.9}, \"old_gflops\": {:.3}, \
                 \"new_gflops\": {:.3}, \"speedup\": {:.3}}}{}\n",
                r.kernel,
                r.shape,
                r.flops,
                r.old_s,
                r.new_s,
                r.gflops_old(),
                r.gflops_new(),
                r.speedup()
            ));
        }
        s.push_str("]\n");
        s
    }
}

/// Deterministic synthetic CSR: `rows` rows of degree `1..=deg`
/// (uniform), optionally with row 0 turned into a hub of `hub` edges —
/// the degree skew that serialises a row-count split.
fn synth_csr(
    rng: &mut crate::rng::Rng,
    rows: usize,
    deg: usize,
    hub: usize,
) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
    let mut offsets = vec![0usize];
    let mut targets = Vec::new();
    let mut values = Vec::new();
    for r in 0..rows {
        let d = if r == 0 && hub > 0 { hub } else { 1 + rng.gen_range(deg) };
        for _ in 0..d {
            targets.push(rng.gen_range(rows) as u32);
            values.push(rng.gen_f32());
        }
        offsets.push(targets.len());
    }
    (offsets, targets, values)
}

fn bits(m: &crate::tensor::Matrix) -> Vec<u32> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

/// Run the Fig 16 kernel sweep: GCN-shaped GEMM (`H·W`), the two
/// gradient transposes (`HᵀdZ`, `dZ·Wᵀ`), and SpMM (`Â·H`, uniform and
/// hub-skewed degrees), each timed through the seed-era reference
/// kernel and the packed/nnz-balanced replacement on identical inputs.
/// Every case asserts bit-identity before it is timed — the bench
/// refuses to report a speedup on answers that moved.
pub fn run_fig16_kernels(fast: bool, warmup: usize, samples: usize) -> KernelBenchReport {
    use crate::tensor::{
        gemm, gemm_reference, gemm_ta, gemm_ta_reference, gemm_tb, gemm_tb_reference, spmm_csr,
        spmm_csr_reference, Matrix,
    };

    let mut b = Bencher::new(warmup, samples);
    let mut rng = crate::rng::Rng::seed_from_u64(16);
    let mut rows: Vec<KernelRow> = Vec::new();

    // H·W and the two grad transposes share these (nodes, in, out)
    let shapes: &[(usize, usize, usize)] =
        if fast { &[(96, 180, 32), (128, 64, 48)] } else { &[(512, 1433, 128), (1024, 512, 256)] };
    for &(m, k, n) in shapes {
        let flops = 2.0 * (m * k * n) as f64;
        let shape = format!("{m}x{k}x{n}");

        let a = Matrix::rand_uniform(m, k, &mut rng);
        let w = Matrix::rand_uniform(k, n, &mut rng);
        assert_eq!(bits(&gemm(&a, &w)), bits(&gemm_reference(&a, &w)), "gemm bits moved");
        let old = b.bench(&format!("gemm {shape} reference"), || gemm_reference(&a, &w));
        let old_s = old.mean.as_secs_f64();
        let new = b.bench(&format!("gemm {shape} packed"), || gemm(&a, &w));
        rows.push(KernelRow {
            kernel: "gemm",
            shape: shape.clone(),
            flops,
            old_s,
            new_s: new.mean.as_secs_f64(),
        });

        // grad W = Hᵀ·dZ: a is k-rows × m-cols
        let at = Matrix::rand_uniform(k, m, &mut rng);
        let dz = Matrix::rand_uniform(k, n, &mut rng);
        assert_eq!(bits(&gemm_ta(&at, &dz)), bits(&gemm_ta_reference(&at, &dz)));
        let old = b.bench(&format!("gemm_ta {shape} reference"), || gemm_ta_reference(&at, &dz));
        let old_s = old.mean.as_secs_f64();
        let new = b.bench(&format!("gemm_ta {shape} panelled"), || gemm_ta(&at, &dz));
        rows.push(KernelRow {
            kernel: "gemm_ta",
            shape: shape.clone(),
            flops,
            old_s,
            new_s: new.mean.as_secs_f64(),
        });

        // grad H = dZ·Wᵀ: b is n-rows × k-cols
        let dzm = Matrix::rand_uniform(m, k, &mut rng);
        let wt = Matrix::rand_uniform(n, k, &mut rng);
        assert_eq!(bits(&gemm_tb(&dzm, &wt)), bits(&gemm_tb_reference(&dzm, &wt)));
        let old = b.bench(&format!("gemm_tb {shape} reference"), || gemm_tb_reference(&dzm, &wt));
        let old_s = old.mean.as_secs_f64();
        let new = b.bench(&format!("gemm_tb {shape} panelled"), || gemm_tb(&dzm, &wt));
        rows.push(KernelRow {
            kernel: "gemm_tb",
            shape,
            flops,
            old_s,
            new_s: new.mean.as_secs_f64(),
        });
    }

    // Â·H: uniform degrees, then one hub row holding half the edges —
    // the case a row-count split serialises behind
    let (nodes, dim) = if fast { (512usize, 32usize) } else { (4096, 128) };
    for (label, hub) in [("uniform", 0usize), ("hub-skewed", nodes / 2)] {
        let (offsets, targets, values) = synth_csr(&mut rng, nodes, 8, hub);
        let h = Matrix::rand_uniform(nodes, dim, &mut rng);
        let nnz = targets.len();
        let flops = 2.0 * (nnz * dim) as f64;
        let shape = format!("{label} n={nodes} nnz={nnz} d={dim}");
        assert_eq!(
            bits(&spmm_csr(&offsets, &targets, &values, &h, nodes)),
            bits(&spmm_csr_reference(&offsets, &targets, &values, &h, nodes)),
            "spmm bits moved"
        );
        let old = b.bench(&format!("spmm {shape} row-split"), || {
            spmm_csr_reference(&offsets, &targets, &values, &h, nodes)
        });
        let old_s = old.mean.as_secs_f64();
        let new = b.bench(&format!("spmm {shape} nnz-split"), || {
            spmm_csr(&offsets, &targets, &values, &h, nodes)
        });
        rows.push(KernelRow {
            kernel: "spmm_csr",
            shape,
            flops,
            old_s,
            new_s: new.mean.as_secs_f64(),
        });
    }

    KernelBenchReport { rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16_report_emitters_are_well_formed() {
        let rep = KernelBenchReport {
            rows: vec![KernelRow {
                kernel: "gemm",
                shape: "8x8x8".into(),
                flops: 1024.0,
                old_s: 2e-6,
                new_s: 1e-6,
            }],
        };
        assert!((rep.rows[0].speedup() - 2.0).abs() < 1e-9);
        let md = rep.to_markdown();
        assert!(md.contains("| gemm | 8x8x8 |") && md.contains("2.00x"));
        let csv = rep.to_csv();
        assert!(csv.starts_with("kernel,shape,"));
        assert_eq!(csv.lines().count(), 2);
        let json = rep.to_json();
        assert!(json.trim_start().starts_with('[') && json.trim_end().ends_with(']'));
        assert_eq!(json.matches("\"kernel\"").count(), 1);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn fig16_sweep_runs_at_test_scale() {
        // one tiny traversal of every case proves the runner's
        // bit-identity asserts hold on real kernel output
        let rep = run_fig16_kernels(true, 0, 1);
        assert_eq!(rep.rows.len(), 2 * 3 + 2);
        assert!(rep.rows.iter().all(|r| r.old_s > 0.0 && r.new_s > 0.0 && r.flops > 0.0));
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(0, 3);
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.mean.as_nanos() > 0);
        assert_eq!(s.samples, 3);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn markdown_contains_rows() {
        let mut b = Bencher::new(0, 1);
        b.bench("x", || 1);
        assert!(b.markdown().contains("| x |"));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
