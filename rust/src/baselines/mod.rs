//! The six baselines of the paper's evaluation (§4.1), implemented as
//! batch-construction policies over the same distributed trainer —
//! exactly how the paper ran them ("we implemented six state-of-the-art
//! distributed GCN training methods").
//!
//! | Method | shard | per-epoch batches |
//! |---|---|---|
//! | Distributed GCN | random partition | the full local shard |
//! | Distributed GraphSAGE | random partition | uniform neighbour-sampled root batches |
//! | Distributed ClusterGCN | multilevel partition | one cluster per round |
//! | GraphSAINT-Node | random partition | degree-prob node-sampled subgraphs |
//! | GraphSAINT-Edge | random partition | edge-sampled subgraphs |
//! | GraphSAINT-RW | random partition | random-walk subgraphs |
//! | GAD (ours) | multilevel partition + augmentation | augmented clusters, ζ-weighted consensus |

mod sampler;

pub use sampler::{sample_batch, SampledSource, SamplerKind, SamplerSpec};

use crate::augment::plain_part;
use crate::comm::feature_traffic_per_epoch;
use crate::coordinator::{
    batch_from_subgraph, train_gad, train_with_plans, BatchSource, ConsensusMode, FixedSource,
    TrainConfig, TrainReport,
};
use crate::datasets::Dataset;
use crate::partition::{edge_cut, random};
use anyhow::Result;
use std::sync::Arc;

/// All methods of Table 2 / Fig. 5 / Fig. 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Gcn,
    GraphSage,
    ClusterGcn,
    SaintNode,
    SaintEdge,
    SaintRw,
    Gad,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Gcn,
        Method::GraphSage,
        Method::ClusterGcn,
        Method::SaintNode,
        Method::SaintEdge,
        Method::SaintRw,
        Method::Gad,
    ];

    /// Paper row label.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Gcn => "Distributed GCN",
            Method::GraphSage => "Distributed GraphSAGE",
            Method::ClusterGcn => "Distributed ClusterGCN",
            Method::SaintNode => "Distributed GraphSAINT-Node",
            Method::SaintEdge => "Distributed GraphSAINT-Edge",
            Method::SaintRw => "Distributed GraphSAINT-RW",
            Method::Gad => "GAD",
        }
    }
}

impl std::str::FromStr for Method {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "gcn" => Ok(Method::Gcn),
            "sage" | "graphsage" => Ok(Method::GraphSage),
            "clustergcn" | "cluster" => Ok(Method::ClusterGcn),
            "saint-node" => Ok(Method::SaintNode),
            "saint-edge" => Ok(Method::SaintEdge),
            "saint-rw" => Ok(Method::SaintRw),
            "gad" => Ok(Method::Gad),
            other => Err(format!("unknown method '{other}'")),
        }
    }
}

/// Train `method` on `dataset` with the shared config. `batch_size` is
/// the sampler minibatch size `b` (paper: 300, 1500 for pubmed).
pub fn train_method(
    dataset: &Dataset,
    method: Method,
    cfg: &TrainConfig,
    batch_size: usize,
) -> Result<TrainReport> {
    match method {
        Method::Gad => train_gad(dataset, cfg),
        Method::ClusterGcn => {
            // our partitioner's clusters, no augmentation, plain consensus
            let mut c = cfg.clone();
            c.augment = false;
            c.consensus = ConsensusMode::Plain;
            train_gad(dataset, &c)
        }
        Method::Gcn => train_full_shards(dataset, cfg),
        Method::GraphSage | Method::SaintNode | Method::SaintEdge | Method::SaintRw => {
            train_sampled(dataset, method, cfg, batch_size)
        }
    }
}

/// Distributed GCN: random shards, every epoch = one full-shard batch,
/// plain consensus.
fn train_full_shards(dataset: &Dataset, cfg: &TrainConfig) -> Result<TrainReport> {
    let assignment = random::random_partition(dataset.num_nodes(), cfg.workers, cfg.seed);
    let cut = edge_cut(&dataset.graph, &assignment);

    let mut sources: Vec<Box<dyn BatchSource>> = Vec::new();
    let mut traffic = 0u64;
    for w in 0..cfg.workers as u32 {
        let part = plain_part(&dataset.graph, &assignment, w);
        traffic += feature_traffic_per_epoch(
            &dataset.graph,
            &assignment,
            w,
            &[],
            cfg.layers,
            dataset.feature_dim(),
        );
        let batch = batch_from_subgraph(dataset, &part, w as u64);
        sources.push(Box::new(FixedSource::new(vec![batch], vec![1.0])));
    }
    let mut c = cfg.clone();
    c.consensus = ConsensusMode::Plain;
    train_with_plans(dataset, sources, traffic, cut, 0, &c)
}

/// Sampling methods: random shards; each worker draws
/// `ceil(|shard|/b)` sampled subgraph batches per epoch.
fn train_sampled(
    dataset: &Dataset,
    method: Method,
    cfg: &TrainConfig,
    batch_size: usize,
) -> Result<TrainReport> {
    let assignment = random::random_partition(dataset.num_nodes(), cfg.workers, cfg.seed);
    let cut = edge_cut(&dataset.graph, &assignment);
    let dataset_arc = Arc::new(dataset.clone());

    let kind = match method {
        Method::GraphSage => SamplerKind::Sage { fanout: 10 },
        Method::SaintNode => SamplerKind::SaintNode,
        Method::SaintEdge => SamplerKind::SaintEdge,
        Method::SaintRw => SamplerKind::SaintRw { walk_len: cfg.layers },
        _ => unreachable!(),
    };

    let mut sources: Vec<Box<dyn BatchSource>> = Vec::new();
    let mut traffic = 0u64;
    for w in 0..cfg.workers as u32 {
        let shard: Vec<u32> = (0..dataset.num_nodes() as u32)
            .filter(|&v| assignment[v as usize] == w)
            .collect();
        // samplers restrict to local shards (Jiang et al. §1-style
        // locality), so remote traffic is the shard's 1-hop candidates
        // touched by sampled batches; we charge the full-shard candidate
        // traffic scaled by the sampled fraction per epoch.
        let full = feature_traffic_per_epoch(
            &dataset.graph,
            &assignment,
            w,
            &[],
            cfg.layers,
            dataset.feature_dim(),
        );
        let frac = (batch_size as f64 / shard.len().max(1) as f64).min(1.0);
        let batches = shard.len().div_ceil(batch_size.max(1)).max(1);
        traffic += (full as f64 * frac * batches as f64) as u64;

        let spec = SamplerSpec {
            kind,
            batch_size,
            batches_per_epoch: batches,
            seed: cfg.seed ^ (0xBA5E + w as u64),
        };
        sources.push(Box::new(SampledSource::new(dataset_arc.clone(), shard, spec)));
    }
    let mut c = cfg.clone();
    c.consensus = ConsensusMode::Plain;
    train_with_plans(dataset, sources, traffic, cut, 0, &c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    fn cfg() -> TrainConfig {
        TrainConfig {
            partitions: 4,
            workers: 2,
            layers: 2,
            hidden: 24,
            lr: 0.02,
            epochs: 12,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn every_method_trains_tiny() {
        let ds = SyntheticSpec::tiny().generate(9);
        for m in Method::ALL {
            let r = train_method(&ds, m, &cfg(), 100).unwrap();
            assert!(
                r.test_accuracy > 0.25,
                "{} acc {}",
                m.label(),
                r.test_accuracy
            );
        }
    }

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("gcn", Method::Gcn),
            ("sage", Method::GraphSage),
            ("clustergcn", Method::ClusterGcn),
            ("saint-node", Method::SaintNode),
            ("saint-edge", Method::SaintEdge),
            ("saint-rw", Method::SaintRw),
            ("gad", Method::Gad),
        ] {
            assert_eq!(s.parse::<Method>().unwrap(), m);
        }
        assert!("nope".parse::<Method>().is_err());
    }
}
