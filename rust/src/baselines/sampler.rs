//! Minibatch samplers for the baseline methods: GraphSAGE uniform
//! neighbour expansion and the three GraphSAINT strategies
//! (node / edge / random-walk). Each draw induces a subgraph over the
//! sampled nodes and builds a training [`Batch`] from it.

use crate::coordinator::{batch_from_subgraph, BatchSource};
use crate::datasets::Dataset;
use crate::graph::Subgraph;
use crate::model::Batch;
use crate::rng::Rng;
use std::sync::Arc;

/// Which sampling rule to apply.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplerKind {
    /// GraphSAGE: uniform roots + `fanout` neighbours per hop.
    Sage { fanout: usize },
    /// GraphSAINT node sampler: roots drawn with prob ∝ degree.
    SaintNode,
    /// GraphSAINT edge sampler: edges drawn with prob ∝ 1/du + 1/dv.
    SaintEdge,
    /// GraphSAINT random-walk sampler: uniform roots + walks.
    SaintRw { walk_len: usize },
    /// Jiang & Rumi (2021): communication-efficient sampling — local
    /// nodes get sampling weight 1, remote-adjacent boundary nodes get
    /// `remote_weight < 1`, shrinking the expected cross-processor
    /// traffic (related-work baseline, used by the ablation harness).
    LocalityAware { remote_weight: f64 },
}

/// Per-worker sampler parameters.
#[derive(Clone, Copy, Debug)]
pub struct SamplerSpec {
    pub kind: SamplerKind,
    /// Target nodes per batch (paper's `b`).
    pub batch_size: usize,
    pub batches_per_epoch: usize,
    pub seed: u64,
}

/// Draw one sampled batch from `shard` (node ids restricted to the
/// worker's shard — locality-aware sampling).
pub fn sample_batch(dataset: &Dataset, shard: &[u32], spec: &SamplerSpec, rng: &mut Rng, id: u64) -> Batch {
    let nodes = match spec.kind {
        SamplerKind::Sage { fanout } => sample_sage(dataset, shard, spec.batch_size, fanout, rng),
        SamplerKind::SaintNode => sample_saint_node(dataset, shard, spec.batch_size, rng),
        SamplerKind::SaintEdge => sample_saint_edge(dataset, shard, spec.batch_size, rng),
        SamplerKind::SaintRw { walk_len } => {
            sample_saint_rw(dataset, shard, spec.batch_size, walk_len, rng)
        }
        SamplerKind::LocalityAware { remote_weight } => {
            sample_locality_aware(dataset, shard, spec.batch_size, remote_weight, rng)
        }
    };
    let sub = Subgraph::induce(&dataset.graph, &nodes);
    // wrap in an AugmentedSubgraph-shaped view: no replicas
    let aug = crate::augment::AugmentedSubgraph {
        part: 0,
        is_replica: vec![false; sub.len()],
        sub,
        candidate_importance: Vec::new(),
        replicas: Vec::new(),
        walks_used: 0,
    };
    batch_from_subgraph(dataset, &aug, id)
}

fn shard_set(shard: &[u32]) -> std::collections::HashSet<u32> {
    shard.iter().copied().collect()
}

/// GraphSAGE: uniform roots; expand each hop with ≤ `fanout` uniform
/// neighbours (within the shard); union of all hops is the batch.
fn sample_sage(dataset: &Dataset, shard: &[u32], b: usize, fanout: usize, rng: &mut Rng) -> Vec<u32> {
    let local = shard_set(shard);
    let n_roots = b.min(shard.len()).max(1);
    let mut nodes: Vec<u32> = rng
        .sample_indices(shard.len(), n_roots)
        .into_iter()
        .map(|i| shard[i])
        .collect();
    let mut frontier = nodes.clone();
    let mut seen: std::collections::HashSet<u32> = nodes.iter().copied().collect();
    // 2 hops of expansion (standard SAGE depth)
    for _ in 0..2 {
        let mut next = Vec::new();
        for &v in &frontier {
            let nbrs = dataset.graph.neighbors(v as usize);
            let take = fanout.min(nbrs.len());
            for i in rng.sample_indices(nbrs.len(), take) {
                let t = nbrs[i];
                if local.contains(&t) && seen.insert(t) {
                    next.push(t);
                    nodes.push(t);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    nodes
}

/// GraphSAINT node sampler: `b` draws with replacement, prob ∝ degree.
fn sample_saint_node(dataset: &Dataset, shard: &[u32], b: usize, rng: &mut Rng) -> Vec<u32> {
    // cumulative degree weights over the shard
    let mut cum: Vec<f64> = Vec::with_capacity(shard.len());
    let mut acc = 0.0;
    for &v in shard {
        acc += dataset.graph.degree(v as usize) as f64 + 1.0;
        cum.push(acc);
    }
    let mut out = Vec::with_capacity(b);
    for _ in 0..b {
        let t = rng.gen_f64() * acc;
        let i = cum.partition_point(|&c| c < t).min(shard.len() - 1);
        out.push(shard[i]);
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// GraphSAINT edge sampler: pick ~b/2 shard-internal edges with prob
/// ∝ 1/du + 1/dv; batch = endpoint union.
fn sample_saint_edge(dataset: &Dataset, shard: &[u32], b: usize, rng: &mut Rng) -> Vec<u32> {
    let local = shard_set(shard);
    let edges: Vec<(u32, u32)> = shard
        .iter()
        .flat_map(|&u| {
            dataset
                .graph
                .neighbors(u as usize)
                .iter()
                .filter(move |&&v| u < v)
                .filter(|&&v| local.contains(&v))
                .map(move |&v| (u, v))
        })
        .collect();
    if edges.is_empty() {
        return shard.iter().take(b.max(2)).copied().collect();
    }
    let weights: Vec<f64> = edges
        .iter()
        .map(|&(u, v)| {
            1.0 / dataset.graph.degree(u as usize).max(1) as f64
                + 1.0 / dataset.graph.degree(v as usize).max(1) as f64
        })
        .collect();
    let mut out = Vec::with_capacity(b);
    for _ in 0..(b / 2).max(1) {
        let (u, v) = edges[rng.choose_weighted(&weights)];
        out.push(u);
        out.push(v);
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// GraphSAINT RW sampler: `b / (walk_len+1)` uniform roots, one walk
/// each (within the shard where possible).
fn sample_saint_rw(dataset: &Dataset, shard: &[u32], b: usize, walk_len: usize, rng: &mut Rng) -> Vec<u32> {
    let local = shard_set(shard);
    let n_roots = (b / (walk_len + 1)).max(1).min(shard.len());
    let mut out: Vec<u32> = Vec::with_capacity(b);
    for i in rng.sample_indices(shard.len(), n_roots) {
        let mut cur = shard[i];
        out.push(cur);
        for _ in 0..walk_len {
            let nbrs: Vec<u32> = dataset
                .graph
                .neighbors(cur as usize)
                .iter()
                .copied()
                .filter(|t| local.contains(t))
                .collect();
            if nbrs.is_empty() {
                break;
            }
            cur = nbrs[rng.gen_range(nbrs.len())];
            out.push(cur);
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Jiang et al. locality-aware sampling: down-weight nodes whose
/// neighbourhood leaves the shard (they would trigger remote fetches).
fn sample_locality_aware(
    dataset: &Dataset,
    shard: &[u32],
    b: usize,
    remote_weight: f64,
    rng: &mut Rng,
) -> Vec<u32> {
    let local = shard_set(shard);
    let weights: Vec<f64> = shard
        .iter()
        .map(|&v| {
            let has_remote = dataset
                .graph
                .neighbors(v as usize)
                .iter()
                .any(|t| !local.contains(t));
            if has_remote {
                remote_weight
            } else {
                1.0
            }
        })
        .collect();
    let mut out = Vec::with_capacity(b);
    for _ in 0..b.min(shard.len() * 2) {
        out.push(shard[rng.choose_weighted(&weights)]);
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// [`BatchSource`] drawing fresh sampled batches each epoch
/// (deterministic in `(epoch, round, seed)` so eval reuses epoch 0).
pub struct SampledSource {
    dataset: Arc<Dataset>,
    shard: Vec<u32>,
    spec: SamplerSpec,
}

impl SampledSource {
    pub fn new(dataset: Arc<Dataset>, shard: Vec<u32>, spec: SamplerSpec) -> Self {
        SampledSource { dataset, shard, spec }
    }
}

impl BatchSource for SampledSource {
    fn batches_per_epoch(&self) -> usize {
        self.spec.batches_per_epoch
    }

    fn batch(&mut self, epoch: usize, round: usize) -> Option<(Arc<Batch>, f64)> {
        if round >= self.spec.batches_per_epoch || self.shard.is_empty() {
            return None;
        }
        // key randomness on (seed, epoch, round) for replayability
        let mut rng = Rng::seed_from_u64(
            self.spec.seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (round as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        let id = (epoch as u64) << 32 | round as u64;
        let batch = sample_batch(&self.dataset, &self.shard, &self.spec, &mut rng, id);
        Some((Arc::new(batch), 1.0))
    }

    fn resident_bytes(&self) -> usize {
        // the worker holds its shard's features + adjacency resident
        let f = self.dataset.feature_dim() * 4;
        self.shard.len() * (f + 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    fn fixture() -> (Arc<Dataset>, Vec<u32>) {
        let d = Arc::new(SyntheticSpec::tiny().generate(2));
        let shard: Vec<u32> = (0..d.num_nodes() as u32).filter(|v| v % 2 == 0).collect();
        (d, shard)
    }

    #[test]
    fn all_samplers_produce_valid_batches() {
        let (d, shard) = fixture();
        for kind in [
            SamplerKind::Sage { fanout: 5 },
            SamplerKind::SaintNode,
            SamplerKind::SaintEdge,
            SamplerKind::SaintRw { walk_len: 2 },
        ] {
            let spec = SamplerSpec { kind, batch_size: 60, batches_per_epoch: 2, seed: 1 };
            let mut rng = Rng::seed_from_u64(1);
            let b = sample_batch(&d, &shard, &spec, &mut rng, 0);
            b.validate().unwrap();
            assert!(!b.is_empty(), "{kind:?} empty batch");
            assert!(b.len() <= 3 * 60 + 60, "{kind:?} oversize {}", b.len());
        }
    }

    #[test]
    fn saint_node_prefers_high_degree() {
        let (d, _) = fixture();
        let shard: Vec<u32> = (0..d.num_nodes() as u32).collect();
        let mut rng = Rng::seed_from_u64(4);
        let mut picked = vec![0usize; d.num_nodes()];
        for _ in 0..200 {
            for v in sample_saint_node(&d, &shard, 30, &mut rng) {
                picked[v as usize] += 1;
            }
        }
        // correlation: mean degree of picked nodes > global mean degree
        let deg = |v: usize| d.graph.degree(v) as f64;
        let total_picks: usize = picked.iter().sum();
        let mean_picked: f64 =
            (0..d.num_nodes()).map(|v| deg(v) * picked[v] as f64).sum::<f64>() / total_picks as f64;
        let mean_all: f64 = (0..d.num_nodes()).map(deg).sum::<f64>() / d.num_nodes() as f64;
        assert!(mean_picked > mean_all, "picked {mean_picked} vs all {mean_all}");
    }

    #[test]
    fn sampled_source_is_replayable() {
        let (d, shard) = fixture();
        let spec = SamplerSpec {
            kind: SamplerKind::SaintRw { walk_len: 2 },
            batch_size: 40,
            batches_per_epoch: 3,
            seed: 7,
        };
        let mut s1 = SampledSource::new(d.clone(), shard.clone(), spec);
        let mut s2 = SampledSource::new(d, shard, spec);
        let (a, _) = s1.batch(5, 1).unwrap();
        let (b, _) = s2.batch(5, 1).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.len(), b.len());
        assert!(s1.batch(0, 3).is_none());
    }

    #[test]
    fn rw_sampler_respects_shard() {
        let (d, shard) = fixture();
        let local: std::collections::HashSet<u32> = shard.iter().copied().collect();
        let mut rng = Rng::seed_from_u64(9);
        let nodes = sample_saint_rw(&d, &shard, 50, 3, &mut rng);
        assert!(nodes.iter().all(|v| local.contains(v)));
    }
}
