//! Global consensus: gradient aggregation across workers.
//!
//! Plain (Definition 4, Eq. 11): `∇W = (1/n) Σ ∇W_i`.
//! Weighted (Eq. 15): `∇Ŵ = Σ ζ_i ∇W_i / Σ ζ_i` — subgraphs with lower
//! variance (higher ζ) steer the update.

use crate::tensor::Matrix;

/// True if every gradient entry is finite. The async engine rejects a
/// contribution that fails this (a diverged replica, or a corrupted
/// message in a real deployment) by zeroing its weight instead of
/// poisoning the consensus.
pub fn grads_finite(grads: &[Matrix]) -> bool {
    grads.iter().all(|m| m.data().iter().all(|v| v.is_finite()))
}

/// Aggregate per-worker gradients with the given weights (pass all-1s
/// for plain consensus). Workers that contributed nothing this round
/// are passed with weight 0. Panics on shape mismatch; returns zeros if
/// every weight is 0 (idle round).
pub fn aggregate_gradients(grads: &[Vec<Matrix>], weights: &[f64]) -> Vec<Matrix> {
    assert_eq!(grads.len(), weights.len());
    assert!(!grads.is_empty());
    let shapes: Vec<(usize, usize)> = grads[0].iter().map(|m| (m.rows, m.cols)).collect();
    let total: f64 = weights.iter().sum();
    let mut out: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
    if total <= 0.0 {
        return out;
    }
    for (g, &w) in grads.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        assert_eq!(g.len(), out.len(), "gradient layer count mismatch");
        let scale = (w / total) as f32;
        for (acc, m) in out.iter_mut().zip(g) {
            assert_eq!((m.rows, m.cols), (acc.rows, acc.cols), "gradient shape mismatch");
            for (a, v) in acc.data_mut().iter_mut().zip(m.data()) {
                *a += scale * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(v: f32) -> Vec<Matrix> {
        vec![Matrix::from_vec(1, 2, vec![v, 2.0 * v])]
    }

    #[test]
    fn plain_is_mean() {
        let gs = vec![grad(1.0), grad(3.0)];
        let agg = aggregate_gradients(&gs, &[1.0, 1.0]);
        assert_eq!(agg[0].data(), &[2.0, 4.0]);
    }

    #[test]
    fn weighted_matches_eq15() {
        let gs = vec![grad(1.0), grad(3.0)];
        // ζ = (3, 1): ∇Ŵ = (3*1 + 1*3)/4 = 1.5
        let agg = aggregate_gradients(&gs, &[3.0, 1.0]);
        assert!((agg[0].data()[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_worker_ignored() {
        let gs = vec![grad(1.0), grad(100.0)];
        let agg = aggregate_gradients(&gs, &[1.0, 0.0]);
        assert_eq!(agg[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn all_zero_weights_give_zero_grad() {
        let gs = vec![grad(1.0)];
        let agg = aggregate_gradients(&gs, &[0.0]);
        assert_eq!(agg[0].data(), &[0.0, 0.0]);
    }

    #[test]
    fn ragged_participation_across_rounds() {
        // the async path feeds rounds where whole workers are absent
        // (weight 0): the present subset must renormalise among itself,
        // round by round, independent of who was absent before
        let gs = vec![grad(1.0), grad(3.0), grad(5.0)];
        let round1 = aggregate_gradients(&gs, &[1.0, 1.0, 0.0]); // worker 2 absent
        assert_eq!(round1[0].data(), &[2.0, 4.0]);
        let round2 = aggregate_gradients(&gs, &[0.0, 1.0, 1.0]); // worker 0 absent
        assert_eq!(round2[0].data(), &[4.0, 8.0]);
        let round3 = aggregate_gradients(&gs, &[0.0, 0.0, 2.0]); // only worker 2
        assert_eq!(round3[0].data(), &[5.0, 10.0]);
    }

    #[test]
    fn single_survivor_quorum_is_identity() {
        // quorum of one: the sole contribution passes through unscaled
        // whatever its weight magnitude
        let gs = vec![grad(7.0)];
        let agg = aggregate_gradients(&gs, &[0.3]);
        assert_eq!(agg[0].data(), &[7.0, 14.0]);
    }

    #[test]
    fn non_finite_grads_detected_and_excludable() {
        let nan = vec![Matrix::from_vec(1, 2, vec![f32::NAN, 1.0])];
        let inf = vec![Matrix::from_vec(1, 2, vec![1.0, f32::INFINITY])];
        let ok = grad(2.0);
        assert!(!grads_finite(&nan));
        assert!(!grads_finite(&inf));
        assert!(grads_finite(&ok));
        // rejection via zero weight keeps the aggregate finite
        let gs = vec![nan, ok];
        let agg = aggregate_gradients(&gs, &[0.0, 1.0]);
        assert!(grads_finite(&agg));
        assert_eq!(agg[0].data(), &[2.0, 4.0]);
    }

    #[test]
    fn invariant_under_weight_scaling() {
        let gs = vec![grad(1.0), grad(2.0), grad(5.0)];
        let a = aggregate_gradients(&gs, &[1.0, 2.0, 3.0]);
        let b = aggregate_gradients(&gs, &[10.0, 20.0, 30.0]);
        assert!(a[0].allclose(&b[0], 1e-6));
    }
}
