//! Bounded-staleness asynchronous round engine.
//!
//! The synchronous loop (Algorithm 2) stalls every processor on the
//! slowest one — exactly the failure mode [`FaultPlan`]'s straggler
//! injection demonstrates. This engine decouples per-worker progress
//! from global synchronization:
//!
//! * workers push gradient contributions as soon as a step finishes;
//! * the leader applies a consensus update whenever a **quorum** of
//!   contributions has arrived, discounting each one by its subgraph
//!   quality *and* its age: `weight_i = ζ_i · λ^staleness_i`, where
//!   staleness is the number of consensus versions applied since the
//!   contribution's replica snapshot (`param_version` rides with every
//!   step result);
//! * a contribution older than the hard staleness bound `s` is dropped
//!   and the laggard **re-synced** — it pulls a fresh replica
//!   (parameters + optimizer state + version) from the leader's shadow
//!   copy, so its future updates stay bit-identical to every other
//!   replica's. Re-sync traffic is accounted separately from gradient
//!   traffic in the [`CommLedger`];
//! * membership is **elastic**: a worker crashed by [`FaultPlan`]
//!   leaves the quorum, and one recovered via [`Fault::Recover`]
//!   rejoins with a fresh replica pull instead of killing the run.
//!
//! **Equivalence guarantee** (enforced by `tests/integration_async.rs`):
//! with `staleness: 0, quorum: 0 (= all alive), lambda: 1.0` the engine
//! degenerates to lock-step rounds and reproduces the synchronous
//! trainer bit-for-bit given the same seed — contributions are applied
//! in worker-id order, with the same weights, the same loss summation
//! order and the same communication accounting. That equivalence is
//! what makes switching engines safe.
//!
//! [`FaultPlan`]: super::FaultPlan
//! [`Fault::Recover`]: super::Fault::Recover
//! [`CommLedger`]: crate::comm::CommLedger

use super::config::AsyncConfig;
use super::consensus::{aggregate_gradients, grads_finite};
use super::trainer::{collect, LoopState, Wiring};
use super::worker::{WorkerCommand, WorkerResult};
use crate::metrics::AccuracyMeter;
use crate::model::{GcnParams, Optimizer};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};

/// One buffered worker contribution awaiting consensus.
struct Contribution {
    worker: usize,
    /// Replica version the gradient was computed at.
    version: u64,
    /// `None` when the worker idled that step.
    grads: Option<Vec<Matrix>>,
    loss: f32,
    zeta: f64,
}

/// Ship the leader's shadow replica (params + optimizer state +
/// version) to `worker` and account the transfer.
fn resync_worker(
    w: &Wiring<'_>,
    st: &mut LoopState,
    worker: usize,
    shadow: &GcnParams,
    shadow_opt: &dyn Optimizer,
    version: u64,
) -> Result<()> {
    let _span = crate::span!("train.resync", worker = worker, version = version);
    w.send(
        worker,
        WorkerCommand::LoadParams {
            params: shadow.clone(),
            optimizer: shadow_opt.clone_box(),
            version,
        },
    )?;
    if w.workers() > 1 {
        // the payload is the parameters plus the optimizer's moments
        w.ledger.record_resync((shadow.nbytes() + shadow_opt.state_nbytes()) as u64);
    }
    st.resyncs += 1;
    Ok(())
}

/// Shared admission path for a step result, used by the round loop and
/// the epoch-edge drain. Either buffers the contribution or — when the
/// gradient is non-finite (poisoned replica) or past the staleness
/// bound — drops it and re-syncs the worker. Returns `true` when the
/// worker was re-synced (its contribution was consumed without
/// buffering, so the caller may owe it a fresh step).
#[allow(clippy::too_many_arguments)]
fn admit_contribution(
    w: &Wiring<'_>,
    st: &mut LoopState,
    pending: &mut Vec<Contribution>,
    shadow: &GcnParams,
    shadow_opt: &dyn Optimizer,
    version: u64,
    bound: u64,
    worker: usize,
    grads: Option<Vec<Matrix>>,
    loss: f32,
    zeta: f64,
    param_version: u64,
) -> Result<bool> {
    // divergence guard: a non-finite gradient means the replica itself
    // may already be poisoned (NaN params stay NaN through every later
    // update), so don't just reject the gradient — restore the replica
    let poisoned = matches!(&grads, Some(g) if !grads_finite(g));
    let staleness = version.saturating_sub(param_version);
    if poisoned || staleness > bound {
        resync_worker(w, st, worker, shadow, shadow_opt, version)?;
        return Ok(true);
    }
    pending.push(Contribution { worker, version: param_version, grads, loss, zeta });
    Ok(false)
}

/// Batch cursor: in the strict sync-equivalent regime workers walk
/// their shard exactly like the synchronous loop (idling past its
/// end); otherwise they cycle so a straggler always has useful work.
fn round_for(strict: bool, worker_rounds: &[usize], worker: usize, step_idx: usize) -> usize {
    let n = worker_rounds[worker];
    if strict || n == 0 {
        step_idx
    } else {
        step_idx % n
    }
}

pub(super) fn run_async_epochs(
    w: &Wiring<'_>,
    st: &mut LoopState,
    acfg: AsyncConfig,
) -> Result<()> {
    let cfg = w.cfg;
    let workers = w.workers();
    let bound = acfg.staleness as u64;

    // Leader shadow replica: initialized and updated exactly like every
    // worker replica (same params, same optimizer via the shared
    // `make_optimizer` constructor, same consensus stream), so a
    // re-synced laggard rejoins in perfect step, moments included.
    let mut shadow = w.params0.clone();
    let mut shadow_opt: Box<dyn Optimizer> = (w.make_optimizer)();
    let mut version: u64 = 0;
    let mut prev_active: Vec<bool> = vec![true; workers];
    // contributions carried between applies (and across epoch edges)
    let mut pending: Vec<Contribution> = Vec::new();

    for epoch in 0..cfg.epochs {
        let _espan = crate::span!("train.epoch", epoch = epoch);
        st.epochs_run = epoch + 1;

        // elastic membership for this epoch
        let active: Vec<bool> = (0..workers).map(|i| cfg.faults.active(i, epoch)).collect();
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active == 0 {
            return Err(anyhow!("all workers inactive at epoch {epoch}"));
        }
        // buffered work from workers that just left the quorum is void
        pending.retain(|p| active[p.worker]);
        // rejoining workers pull a fresh replica before stepping again
        for i in 0..workers {
            if active[i] && !prev_active[i] {
                resync_worker(w, st, i, &shadow, shadow_opt.as_ref(), version)?;
            }
        }
        prev_active.copy_from_slice(&active);

        let quorum = if acfg.quorum == 0 { n_active } else { acfg.quorum.min(n_active) };
        // the degenerate config that must reproduce the sync engine
        let strict = acfg.staleness == 0 && quorum == n_active;

        let lr_factor = cfg.schedule.factor(epoch);
        shadow_opt.set_lr_factor(lr_factor);
        for i in 0..workers {
            if active[i] {
                w.send(i, WorkerCommand::SetLr { factor: lr_factor })?;
            }
        }

        let mut loss_sum = 0.0f64;
        let mut loss_count = 0usize;
        let mut steps_sent = vec![0usize; workers];
        let mut outstanding = vec![false; workers];
        let mut rounds_done = 0usize;

        let send_step = |i: usize,
                         steps_sent: &mut Vec<usize>,
                         outstanding: &mut Vec<bool>|
         -> Result<()> {
            let round = round_for(strict, w.worker_rounds, i, steps_sent[i]);
            let delay_ms = cfg.faults.straggle_ms(i, epoch).unwrap_or(0);
            w.send(i, WorkerCommand::Step { epoch, round, delay_ms })?;
            steps_sent[i] += 1;
            outstanding[i] = true;
            Ok(())
        };

        // kick off one step per active worker
        for i in 0..workers {
            if active[i] {
                send_step(i, &mut steps_sent, &mut outstanding)?;
            }
        }

        while rounds_done < w.rounds_per_epoch {
            match w.result_rx.recv() {
                Err(_) => return Err(anyhow!("worker channel closed early")),
                Ok(WorkerResult::Error { worker, message }) => {
                    return Err(anyhow!("worker {worker}: {message}"));
                }
                // no Eval/FetchParams is in flight during the round loop
                Ok(WorkerResult::Eval { .. }) | Ok(WorkerResult::Params { .. }) => {}
                Ok(WorkerResult::Step { worker, grads, loss, zeta, param_version, .. }) => {
                    outstanding[worker] = false;
                    if active[worker]
                        && admit_contribution(
                            w,
                            st,
                            &mut pending,
                            &shadow,
                            shadow_opt.as_ref(),
                            version,
                            bound,
                            worker,
                            grads,
                            loss,
                            zeta,
                            param_version,
                        )?
                    {
                        // dropped + re-synced: hand the laggard new work
                        send_step(worker, &mut steps_sent, &mut outstanding)?;
                    }
                }
            }

            // apply a consensus update once a quorum is buffered (or, as
            // a liveness backstop, when nothing is left in flight)
            let any_outstanding = (0..workers).any(|i| active[i] && outstanding[i]);
            if pending.len() < quorum && (any_outstanding || pending.is_empty()) {
                continue;
            }

            let _rspan =
                crate::span!("train.async_round", round = rounds_done, version = version);
            // deterministic float order: worker id, then version
            pending.sort_by_key(|p| (p.worker, p.version));
            let contributors = std::mem::take(&mut pending);
            let mut grads_vec: Vec<Vec<Matrix>> = Vec::with_capacity(contributors.len());
            let mut weights: Vec<f64> = Vec::with_capacity(contributors.len());
            for p in contributors {
                if let Some(g) = p.grads {
                    let staleness = version.saturating_sub(p.version) as usize;
                    st.max_staleness_applied = st.max_staleness_applied.max(staleness);
                    let base = if acfg.zeta_weighted && p.zeta > 0.0 { p.zeta } else { 1.0 };
                    weights.push(base * acfg.lambda.powi(staleness as i32));
                    loss_sum += p.loss as f64;
                    loss_count += 1;
                    grads_vec.push(g);
                }
            }
            if !grads_vec.is_empty() {
                let consensus = aggregate_gradients(&grads_vec, &weights);
                // same accounting rule as the sync engine: every
                // contributor uploads, every contributor downloads
                if workers > 1 {
                    w.ledger.record_gradient(grads_vec.len() as u64 * w.grad_bytes_per_sync);
                }
                shadow_opt.step(&mut shadow, &consensus);
                version += 1;
                for i in 0..workers {
                    if active[i] {
                        w.send(i, WorkerCommand::Update { grads: consensus.clone() })?;
                    }
                }
            }
            rounds_done += 1;
            if rounds_done < w.rounds_per_epoch {
                for i in 0..workers {
                    if active[i] && !outstanding[i] {
                        send_step(i, &mut steps_sent, &mut outstanding)?;
                    }
                }
            }
        }

        // drain in-flight steps so Eval observes a quiescent replica
        // set; late arrivals are buffered for the next epoch (where
        // they are applied discounted, or evicted by the bound)
        while (0..workers).any(|i| active[i] && outstanding[i]) {
            match w.result_rx.recv() {
                Err(_) => return Err(anyhow!("worker channel closed early")),
                Ok(WorkerResult::Error { worker, message }) => {
                    return Err(anyhow!("worker {worker}: {message}"));
                }
                Ok(WorkerResult::Eval { .. }) | Ok(WorkerResult::Params { .. }) => {}
                Ok(WorkerResult::Step { worker, grads, loss, zeta, param_version, .. }) => {
                    outstanding[worker] = false;
                    if active[worker] {
                        // buffered contributions carry into the next
                        // epoch (applied discounted there); re-synced
                        // workers get no new step — the epoch is over
                        admit_contribution(
                            w,
                            st,
                            &mut pending,
                            &shadow,
                            shadow_opt.as_ref(),
                            version,
                            bound,
                            worker,
                            grads,
                            loss,
                            zeta,
                            param_version,
                        )?;
                    }
                }
            }
        }

        w.ledger.record_feature(w.feature_traffic_per_epoch_bytes);

        // distributed eval, identical to the sync engine
        for i in 0..workers {
            if active[i] {
                w.send(i, WorkerCommand::Eval)?;
            }
        }
        let mut test_meter = AccuracyMeter::default();
        let mut val_meter = AccuracyMeter::default();
        let mut train_meter = AccuracyMeter::default();
        for r in collect(w.result_rx, n_active)? {
            if let WorkerResult::Eval { train, val, test, .. } = r {
                train_meter.merge(train);
                val_meter.merge(val);
                test_meter.merge(test);
            }
        }
        st.final_train = train_meter;
        st.final_val = val_meter;
        st.final_test = test_meter;

        let mean_loss = if loss_count > 0 { (loss_sum / loss_count as f64) as f32 } else { 0.0 };
        let converged = st.recorder.record(epoch, mean_loss, test_meter.value());
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            eprintln!(
                "epoch {epoch:4}  loss {mean_loss:.4}  test_acc {:.4}  v{version}  resyncs {}",
                test_meter.value(),
                st.resyncs
            );
        }
        if converged && cfg.stop_on_converge {
            break;
        }
    }
    Ok(())
}
