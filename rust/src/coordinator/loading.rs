//! Subgraph loading (paper §3.2.3): deal augmented subgraphs to
//! processors so node counts stay balanced — iterate subgraphs
//! (largest first) and hand each to the currently least-loaded worker.

/// `sizes[i]` = node count of subgraph `i`; returns, per worker, the
/// list of subgraph indices it owns.
pub fn allocate_subgraphs(sizes: &[usize], workers: usize) -> Vec<Vec<usize>> {
    assert!(workers > 0);
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    // largest-first (LPT) gives the classic 4/3-approx of makespan
    order.sort_by_key(|&i| std::cmp::Reverse(sizes[i]));
    let mut load = vec![0usize; workers];
    let mut owned: Vec<Vec<usize>> = vec![Vec::new(); workers];
    for i in order {
        let w = (0..workers).min_by_key(|&w| (load[w], w)).unwrap();
        load[w] += sizes[i];
        owned[w].push(i);
    }
    // deterministic round order within each worker
    for o in &mut owned {
        o.sort_unstable();
    }
    owned
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_subgraphs_once() {
        let sizes = [10, 20, 30, 40, 50];
        let alloc = allocate_subgraphs(&sizes, 2);
        let mut all: Vec<usize> = alloc.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn balances_loads() {
        let sizes = [50, 40, 30, 20, 10];
        let alloc = allocate_subgraphs(&sizes, 2);
        let load = |w: &Vec<usize>| w.iter().map(|&i| sizes[i]).sum::<usize>();
        let (a, b) = (load(&alloc[0]), load(&alloc[1]));
        assert!((a as i64 - b as i64).abs() <= 10, "loads {a} vs {b}");
    }

    #[test]
    fn more_workers_than_subgraphs() {
        let alloc = allocate_subgraphs(&[5, 5], 4);
        let used: usize = alloc.iter().filter(|w| !w.is_empty()).count();
        assert_eq!(used, 2);
    }

    #[test]
    fn single_worker_gets_everything() {
        let alloc = allocate_subgraphs(&[1, 2, 3], 1);
        assert_eq!(alloc[0], vec![0, 1, 2]);
    }
}
