//! The distributed training loop driver: the full GAD pipeline, the
//! synchronous round engine (Algorithm 2), and the shared scaffolding
//! (worker spawn/teardown, reporting) that the bounded-staleness
//! [`async_engine`](super::async_engine) plugs into.

use super::async_engine;
use super::config::{ConsensusMode, TrainConfig};
use super::consensus::aggregate_gradients;
use super::loading::allocate_subgraphs;
use super::worker::{worker_main, BatchSource, FixedSource, WorkerCommand, WorkerPlan, WorkerResult};
use crate::augment::{augment_all, plain_part, AugmentConfig, AugmentedSubgraph};
use crate::backend::backend_factory;
use crate::comm::{weighted_feature_traffic_per_epoch, CommLedger, CommStats};
use crate::graph::boundary_nodes;
use crate::datasets::Dataset;
use crate::metrics::{AccuracyMeter, CurveRecorder};
use crate::model::{Adam, Batch, GcnParams, NormAdj, Optimizer};
use crate::partition::{partition, PartitionConfig};
use crate::rng::Rng;
use crate::tensor::Matrix;
use crate::variance::{zeta, ZetaConfig};
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::time::Instant;

/// Outcome of a training run — everything the experiment harness needs
/// to print a paper table/figure row.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub test_accuracy: f32,
    pub val_accuracy: f32,
    pub train_accuracy: f32,
    pub epochs_run: usize,
    pub wall_seconds: f64,
    /// Seconds until the loss plateaued (Fig. 6's quantity).
    pub time_to_converge: f64,
    pub converged_epoch: Option<usize>,
    /// `(epoch, seconds, loss, test_accuracy)` per epoch.
    pub curve: Vec<crate::metrics::CurvePoint>,
    pub comm: CommStats,
    /// Estimated network seconds under the configured [`Topology`]
    /// (what a real interconnect would add to `wall_seconds`).
    ///
    /// [`Topology`]: crate::comm::Topology
    pub network_time_est_sec: f64,
    /// Resident graph-state bytes per worker (+ one replica of params).
    pub memory_per_worker: Vec<usize>,
    pub edge_cut: usize,
    pub replicas_total: usize,
    pub workers: usize,
    /// Largest staleness (in consensus versions) of any gradient the
    /// run actually applied. Always 0 for the synchronous engine; the
    /// async engine guarantees it never exceeds the configured bound.
    pub max_staleness_applied: usize,
    /// Replica re-syncs performed (async engine: staleness-bound
    /// evictions plus elastic rejoins).
    pub resyncs: u64,
    /// The trained parameters, harvested from the freshest replica
    /// (highest consensus version) after the last epoch — what
    /// [`model::checkpoint`](crate::model::checkpoint) saves and the
    /// serving tier ([`crate::serve`]) loads. `None` only if every
    /// worker died before the harvest.
    pub final_params: Option<GcnParams>,
}

impl TrainReport {
    /// Mean allocated memory per worker in MB.
    pub fn memory_mb_per_worker(&self) -> f64 {
        if self.memory_per_worker.is_empty() {
            return 0.0;
        }
        let sum: usize = self.memory_per_worker.iter().sum();
        sum as f64 / self.memory_per_worker.len() as f64 / 1e6
    }
}

/// Build the [`Batch`] for one augmented subgraph.
pub fn batch_from_subgraph(dataset: &Dataset, aug: &AugmentedSubgraph, id: u64) -> Batch {
    let n = aug.sub.len();
    let f = dataset.feature_dim();
    let mut features = Matrix::zeros(n, f);
    let mut labels = vec![0u32; n];
    let mut loss_mask = vec![false; n];
    let mut val_mask = vec![false; n];
    let mut test_mask = vec![false; n];
    for (local, &global) in aug.sub.global_ids.iter().enumerate() {
        let g = global as usize;
        features.row_mut(local).copy_from_slice(dataset.features.row(g));
        labels[local] = dataset.labels[g];
        if !aug.is_replica[local] {
            loss_mask[local] = dataset.split.train[g];
            val_mask[local] = dataset.split.val[g];
            test_mask[local] = dataset.split.test[g];
        }
    }
    Batch {
        id,
        adj: NormAdj::from_csr(&aug.sub.csr),
        features,
        labels,
        loss_mask,
        val_mask,
        test_mask,
        num_classes: dataset.num_classes,
    }
}

/// ζ(g') for a built batch (degree probabilities from the local
/// adjacency, Euclidean distances from the local features).
pub fn batch_zeta(batch: &Batch, aug: &AugmentedSubgraph, seed: u64) -> f64 {
    zeta(
        &aug.sub.csr,
        Some(&batch.features),
        &ZetaConfig { seed, ..Default::default() },
    )
}

/// Full GAD pipeline: partition → (optionally) augment → load → train
/// with (optionally ζ-weighted) global consensus.
pub fn train_gad(dataset: &Dataset, cfg: &TrainConfig) -> Result<TrainReport> {
    let part = partition(
        &dataset.graph,
        &PartitionConfig { k: cfg.partitions, seed: cfg.seed, ..Default::default() },
    );

    // Run the Monte-Carlo importance estimation in both modes: with
    // augmentation off it still defines the access-frequency model the
    // communication accounting uses (same yardstick for Table 4's
    // with/without comparison).
    let measured: Vec<AugmentedSubgraph> = augment_all(
        &dataset.graph,
        &part.assignment,
        cfg.partitions,
        &AugmentConfig {
            alpha: cfg.alpha,
            walk_length: cfg.layers,
            seed: cfg.seed,
            ..Default::default()
        },
    );
    let augs: Vec<AugmentedSubgraph> = if cfg.augment {
        measured.clone()
    } else {
        (0..cfg.partitions as u32)
            .map(|p| plain_part(&dataset.graph, &part.assignment, p))
            .collect()
    };

    // per-epoch cross-processor feature traffic under the random-walk
    // access model (paper §4.4): candidate v is fetched I(v)·|B(g)|
    // times per epoch unless replicated locally
    let feature_traffic: u64 = measured
        .iter()
        .zip(&augs)
        .map(|(m, a)| {
            let boundary = boundary_nodes(&dataset.graph, &part.assignment, m.part);
            weighted_feature_traffic_per_epoch(
                &m.candidate_importance,
                &a.replicas,
                boundary.len(),
                dataset.feature_dim(),
            )
        })
        .sum();

    let replicas_total = augs.iter().map(|a| a.replicas.len()).sum();

    // batches + ζ
    let mut batches: Vec<Batch> = Vec::with_capacity(augs.len());
    let mut zetas: Vec<f64> = Vec::with_capacity(augs.len());
    for (i, aug) in augs.iter().enumerate() {
        let b = batch_from_subgraph(dataset, aug, i as u64);
        zetas.push(batch_zeta(&b, aug, cfg.seed));
        batches.push(b);
    }

    // subgraph loading (§3.2.3)
    let sizes: Vec<usize> = batches.iter().map(|b| b.len()).collect();
    let alloc = allocate_subgraphs(&sizes, cfg.workers);

    // deal batches to workers
    let mut per_worker: Vec<(Vec<Batch>, Vec<f64>)> = (0..cfg.workers).map(|_| (Vec::new(), Vec::new())).collect();
    // iterate in reverse so `pop`-less moves stay O(1): collect by index
    let mut batch_opts: Vec<Option<Batch>> = batches.into_iter().map(Some).collect();
    for (w, owned) in alloc.iter().enumerate() {
        for &i in owned {
            per_worker[w].0.push(batch_opts[i].take().unwrap());
            per_worker[w].1.push(zetas[i]);
        }
    }
    let sources: Vec<Box<dyn BatchSource>> = per_worker
        .into_iter()
        .map(|(b, z)| Box::new(FixedSource::new(b, z)) as Box<dyn BatchSource>)
        .collect();

    train_with_plans(dataset, sources, feature_traffic, part.edge_cut, replicas_total, cfg)
}

/// Immutable wiring shared by both round engines: channels, counters,
/// and static run facts established at spawn time.
pub(super) struct Wiring<'a> {
    pub cfg: &'a TrainConfig,
    pub cmd_txs: &'a [mpsc::Sender<WorkerCommand>],
    pub result_rx: &'a mpsc::Receiver<WorkerResult>,
    /// Global rounds (= consensus updates) per epoch: the max over
    /// workers of their per-epoch batch counts.
    pub rounds_per_epoch: usize,
    /// Per-worker batches per epoch (for the async engine's cyclic
    /// batch cursors).
    pub worker_rounds: &'a [usize],
    pub ledger: &'a CommLedger,
    pub grad_bytes_per_sync: u64,
    pub feature_traffic_per_epoch_bytes: u64,
    pub params0: &'a GcnParams,
    /// Constructor for the run's optimizer — the single source of truth
    /// shared by the worker-spawn site and the async engine's leader
    /// shadow, so re-synced replicas can never receive a different
    /// optimizer than their peers started with.
    pub make_optimizer: &'a (dyn Fn() -> Box<dyn Optimizer> + Sync),
}

impl Wiring<'_> {
    pub fn workers(&self) -> usize {
        self.cmd_txs.len()
    }

    pub fn send(&self, worker: usize, cmd: WorkerCommand) -> Result<()> {
        self.cmd_txs[worker].send(cmd).map_err(|_| anyhow!("worker {worker} died"))
    }
}

/// Mutable per-run state both engines fill in while looping.
pub(super) struct LoopState {
    pub recorder: CurveRecorder,
    pub epochs_run: usize,
    pub final_train: AccuracyMeter,
    pub final_val: AccuracyMeter,
    pub final_test: AccuracyMeter,
    pub max_staleness_applied: usize,
    pub resyncs: u64,
}

/// Receive exactly `n` results, failing fast on worker errors.
pub(super) fn collect(rx: &mpsc::Receiver<WorkerResult>, n: usize) -> Result<Vec<WorkerResult>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        match rx.recv() {
            Ok(WorkerResult::Error { worker, message }) => {
                return Err(anyhow!("worker {worker}: {message}"));
            }
            Ok(r) => out.push(r),
            Err(_) => return Err(anyhow!("worker channel closed early")),
        }
    }
    Ok(out)
}

/// The generic training loop over arbitrary batch sources (used by
/// `train_gad` and every baseline): spawn one replica per source, run
/// the configured round engine — synchronous lock-step or bounded-
/// staleness async, per [`ConsensusMode`] — and assemble the report.
pub fn train_with_plans(
    dataset: &Dataset,
    sources: Vec<Box<dyn BatchSource>>,
    feature_traffic_per_epoch_bytes: u64,
    edge_cut: usize,
    replicas_total: usize,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let workers = sources.len();
    assert!(workers > 0, "need at least one worker");
    let started = Instant::now();

    // one "device" per worker: divide the process thread budget so
    // wall-clock scaling with worker count reflects a multi-device
    // deployment rather than intra-op threading saturating the whole
    // machine. Sizing from `threads::available()` (not raw core count)
    // and holding a lease for the run keeps co-resident pools honest:
    // a serve pool built while training sees only the leftover budget,
    // and vice versa. The per-worker figure is thread-local to each
    // worker (set inside `worker_main`), so concurrent runs in one
    // process don't race on it. Thread counts are wall-clock only —
    // results are bit-identical at any budget (see `crate::threads`).
    let budget = crate::threads::available();
    let intra_threads = (budget / workers).max(1);
    let _compute_lease = crate::threads::reserve(workers * intra_threads);

    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x6AD);
    let params0 = GcnParams::init(dataset.feature_dim(), cfg.hidden, dataset.num_classes, cfg.layers, &mut rng);
    let grad_bytes_per_sync = 2 * params0.nbytes() as u64; // up + down

    let worker_rounds: Vec<usize> = sources.iter().map(|s| s.batches_per_epoch()).collect();
    let rounds_per_epoch = worker_rounds.iter().copied().max().unwrap_or(0);
    if rounds_per_epoch == 0 {
        return Err(anyhow!("no batches to train on"));
    }
    let memory_per_worker: Vec<usize> =
        sources.iter().map(|s| s.resident_bytes() + params0.nbytes()).collect();

    let ledger = CommLedger::new();
    let factory = backend_factory(cfg.backend, &cfg.artifact_dir);
    // every replica — worker or leader shadow — is built by this one
    // closure, so they can never disagree on optimizer type or
    // hyperparameters
    let lr = cfg.lr;
    let make_optimizer = move || -> Box<dyn Optimizer> { Box::new(Adam::new(lr)) };

    // spawn workers
    let (result_tx, result_rx) = mpsc::channel::<WorkerResult>();
    let mut cmd_txs: Vec<mpsc::Sender<WorkerCommand>> = Vec::with_capacity(workers);
    let mut handles = Vec::with_capacity(workers);
    for (w, source) in sources.into_iter().enumerate() {
        let (cmd_tx, cmd_rx) = mpsc::channel::<WorkerCommand>();
        cmd_txs.push(cmd_tx);
        let plan = WorkerPlan {
            worker: w,
            source,
            factory: factory.clone(),
            init_params: params0.clone(),
            optimizer: make_optimizer(),
            intra_threads,
        };
        let tx = result_tx.clone();
        handles.push(std::thread::spawn(move || {
            crate::threads::label_current_with(|| format!("trainer-worker-{w}"));
            worker_main(plan, cmd_rx, tx)
        }));
    }
    drop(result_tx);

    let wiring = Wiring {
        cfg,
        cmd_txs: &cmd_txs,
        result_rx: &result_rx,
        rounds_per_epoch,
        worker_rounds: &worker_rounds,
        ledger: &ledger,
        grad_bytes_per_sync,
        feature_traffic_per_epoch_bytes,
        params0: &params0,
        make_optimizer: &make_optimizer,
    };
    let mut state = LoopState {
        recorder: CurveRecorder::new(cfg.conv_tol, cfg.conv_patience),
        epochs_run: 0,
        final_train: AccuracyMeter::default(),
        final_val: AccuracyMeter::default(),
        final_test: AccuracyMeter::default(),
        max_staleness_applied: 0,
        resyncs: 0,
    };

    let run = match cfg.consensus {
        ConsensusMode::Async(acfg) => async_engine::run_async_epochs(&wiring, &mut state, acfg),
        _ => run_sync_epochs(&wiring, &mut state),
    };

    // harvest the freshest replica (both engines leave workers quiescent
    // here) so the run's parameters survive worker teardown — sync-mode
    // crash faults leave stale replicas behind, hence max-version wins
    let final_params = if run.is_ok() {
        let mut asked = 0usize;
        for tx in &cmd_txs {
            if tx.send(WorkerCommand::FetchParams).is_ok() {
                asked += 1;
            }
        }
        let mut best: Option<(u64, GcnParams)> = None;
        let mut got = 0usize;
        while got < asked {
            match result_rx.recv() {
                Ok(WorkerResult::Params { params, version, .. }) => {
                    got += 1;
                    if best.as_ref().map(|(v, _)| version >= *v).unwrap_or(true) {
                        best = Some((version, params));
                    }
                }
                Ok(_) => continue, // drain any stray result
                Err(_) => break,
            }
        }
        best.map(|(_, p)| p)
    } else {
        None
    };

    for tx in &cmd_txs {
        let _ = tx.send(WorkerCommand::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
    run?;

    let network_time_est_sec = crate::comm::run_network_time_sec(
        cfg.topology,
        crate::comm::LinkSpec::default(),
        workers,
        params0.nbytes() as u64,
        state.epochs_run * rounds_per_epoch,
        ledger.feature_bytes(),
    );

    Ok(TrainReport {
        test_accuracy: state.final_test.value(),
        val_accuracy: state.final_val.value(),
        train_accuracy: state.final_train.value(),
        epochs_run: state.epochs_run,
        wall_seconds: started.elapsed().as_secs_f64(),
        time_to_converge: state.recorder.time_to_converge(),
        converged_epoch: state.recorder.converged().map(|(e, _)| e),
        curve: state.recorder.points.clone(),
        comm: CommStats::from_ledger(&ledger),
        network_time_est_sec,
        memory_per_worker,
        edge_cut,
        replicas_total,
        workers,
        max_staleness_applied: state.max_staleness_applied,
        resyncs: state.resyncs,
        final_params,
    })
}

/// The synchronous round engine (Algorithm 2): every alive worker
/// steps, the leader aggregates, every replica applies the identical
/// consensus update.
fn run_sync_epochs(w: &Wiring<'_>, st: &mut LoopState) -> Result<()> {
    let cfg = w.cfg;
    let workers = w.workers();
    for epoch in 0..cfg.epochs {
        let _espan = crate::span!("train.epoch", epoch = epoch);
        st.epochs_run = epoch + 1;
        let mut loss_sum = 0.0f64;
        let mut loss_count = 0usize;

        // fault injection: crashed workers stop receiving commands
        let alive: Vec<bool> = (0..workers).map(|i| !cfg.faults.crashed(i, epoch)).collect();
        let n_alive = alive.iter().filter(|&&a| a).count();
        if n_alive == 0 {
            return Err(anyhow!("all workers crashed at epoch {epoch}"));
        }

        // LR schedule: identical factor on every replica
        let lr_factor = cfg.schedule.factor(epoch);
        for i in 0..workers {
            if alive[i] {
                w.send(i, WorkerCommand::SetLr { factor: lr_factor })?;
            }
        }

        for round in 0..w.rounds_per_epoch {
            let _rspan = crate::span!("train.round", epoch = epoch, round = round);
            for i in 0..workers {
                if !alive[i] {
                    continue;
                }
                let delay_ms = cfg.faults.straggle_ms(i, epoch).unwrap_or(0);
                w.send(i, WorkerCommand::Step { epoch, round, delay_ms })?;
            }
            let mut results = collect(w.result_rx, n_alive)?;
            // results arrive in thread-completion order; sort by
            // worker id so float aggregation order (and thus the
            // whole run) is deterministic
            results.sort_by_key(|r| match r {
                WorkerResult::Step { worker, .. }
                | WorkerResult::Eval { worker, .. }
                | WorkerResult::Params { worker, .. } => *worker,
                WorkerResult::Error { worker, .. } => *worker,
            });

            let mut grads: Vec<Vec<Matrix>> = Vec::with_capacity(workers);
            let mut weights: Vec<f64> = Vec::with_capacity(workers);
            let mut active = 0u64;
            for r in results {
                if let WorkerResult::Step { grads: Some(g), loss, zeta, .. } = r {
                    weights.push(match cfg.consensus {
                        ConsensusMode::Plain => 1.0,
                        // guard: non-positive ζ falls back to plain weight
                        ConsensusMode::Weighted => if zeta > 0.0 { zeta } else { 1.0 },
                        // unreachable via train_with_plans (async runs its
                        // own engine); behave like its base weighting
                        ConsensusMode::Async(a) => {
                            if a.zeta_weighted && zeta > 0.0 { zeta } else { 1.0 }
                        }
                    });
                    grads.push(g);
                    loss_sum += loss as f64;
                    loss_count += 1;
                    active += 1;
                }
            }
            if grads.is_empty() {
                continue;
            }
            let consensus = aggregate_gradients(&grads, &weights);
            // a single co-located worker exchanges nothing over the
            // interconnect; otherwise every active worker uploads its
            // gradient and downloads the consensus
            if workers > 1 {
                w.ledger.record_gradient(active * w.grad_bytes_per_sync);
            }
            for i in 0..workers {
                if !alive[i] {
                    continue;
                }
                w.send(i, WorkerCommand::Update { grads: consensus.clone() })?;
            }
        }
        w.ledger.record_feature(w.feature_traffic_per_epoch_bytes);

        // distributed eval (crashed workers' shards go unreported,
        // like a real partial outage)
        for i in 0..workers {
            if !alive[i] {
                continue;
            }
            w.send(i, WorkerCommand::Eval)?;
        }
        let mut test_meter = AccuracyMeter::default();
        let mut val_meter = AccuracyMeter::default();
        let mut train_meter = AccuracyMeter::default();
        for r in collect(w.result_rx, n_alive)? {
            if let WorkerResult::Eval { train, val, test, .. } = r {
                train_meter.merge(train);
                val_meter.merge(val);
                test_meter.merge(test);
            }
        }
        st.final_train = train_meter;
        st.final_val = val_meter;
        st.final_test = test_meter;

        let mean_loss = if loss_count > 0 { (loss_sum / loss_count as f64) as f32 } else { 0.0 };
        let converged = st.recorder.record(epoch, mean_loss, test_meter.value());
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            eprintln!(
                "epoch {epoch:4}  loss {mean_loss:.4}  test_acc {:.4}",
                test_meter.value()
            );
        }
        if converged && cfg.stop_on_converge {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            partitions: 4,
            workers: 2,
            layers: 2,
            hidden: 32,
            lr: 0.02,
            epochs: 25,
            seed: 1,
            ..Default::default()
        }
    }

    #[test]
    fn gad_learns_tiny_dataset() {
        let ds = SyntheticSpec::tiny().generate(1);
        let report = train_gad(&ds, &quick_cfg()).unwrap();
        assert!(report.test_accuracy > 0.5, "test acc {}", report.test_accuracy);
        assert_eq!(report.curve.len(), report.epochs_run);
        assert!(report.comm.gradient_bytes > 0);
    }

    #[test]
    fn augmentation_reduces_feature_traffic() {
        let ds = SyntheticSpec::tiny().generate(2);
        let mut cfg = quick_cfg();
        cfg.epochs = 3;
        cfg.augment = true;
        cfg.alpha = 0.05;
        let with_aug = train_gad(&ds, &cfg).unwrap();
        cfg.augment = false;
        let without = train_gad(&ds, &cfg).unwrap();
        assert!(
            with_aug.comm.feature_bytes < without.comm.feature_bytes,
            "aug {} vs plain {}",
            with_aug.comm.feature_bytes,
            without.comm.feature_bytes
        );
        assert!(with_aug.replicas_total > 0);
        assert_eq!(without.replicas_total, 0);
    }

    #[test]
    fn single_worker_single_partition_runs() {
        let ds = SyntheticSpec::tiny().generate(3);
        let cfg = TrainConfig {
            partitions: 1,
            workers: 1,
            epochs: 5,
            hidden: 16,
            ..quick_cfg()
        };
        let report = train_gad(&ds, &cfg).unwrap();
        assert_eq!(report.workers, 1);
        assert_eq!(report.edge_cut, 0);
        assert_eq!(report.comm.feature_bytes, 0);
    }

    #[test]
    fn weighted_and_plain_consensus_both_run() {
        let ds = SyntheticSpec::tiny().generate(4);
        for mode in [ConsensusMode::Plain, ConsensusMode::Weighted] {
            let cfg = TrainConfig { consensus: mode, epochs: 5, ..quick_cfg() };
            let report = train_gad(&ds, &cfg).unwrap();
            assert!(report.test_accuracy > 0.2);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = SyntheticSpec::tiny().generate(5);
        let cfg = TrainConfig { epochs: 5, ..quick_cfg() };
        let a = train_gad(&ds, &cfg).unwrap();
        let b = train_gad(&ds, &cfg).unwrap();
        assert_eq!(a.test_accuracy, b.test_accuracy);
        assert_eq!(a.comm.feature_bytes, b.comm.feature_bytes);
    }

    #[test]
    fn final_params_are_harvested_and_deterministic() {
        let ds = SyntheticSpec::tiny().generate(7);
        let cfg = TrainConfig { epochs: 4, ..quick_cfg() };
        let a = train_gad(&ds, &cfg).unwrap();
        let b = train_gad(&ds, &cfg).unwrap();
        let pa = a.final_params.expect("params harvested");
        let pb = b.final_params.expect("params harvested");
        assert_eq!(pa.layers(), cfg.layers);
        assert_eq!(pa.ws[0].rows, ds.feature_dim());
        assert_eq!(pa.ws.last().unwrap().cols, ds.num_classes);
        assert_eq!(pa.max_abs_diff(&pb), 0.0, "same seed must yield identical params");
    }

    #[test]
    fn sync_engine_reports_zero_staleness() {
        let ds = SyntheticSpec::tiny().generate(6);
        let cfg = TrainConfig { epochs: 3, ..quick_cfg() };
        let r = train_gad(&ds, &cfg).unwrap();
        assert_eq!(r.max_staleness_applied, 0);
        assert_eq!(r.resyncs, 0);
        assert_eq!(r.comm.resync_bytes, 0);
    }
}
