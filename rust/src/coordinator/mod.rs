//! L3 coordinator: the distributed training loop (paper §3.3–3.4,
//! Algorithm 2) and its bounded-staleness asynchronous extension.
//!
//! Topology: one **leader** (the calling thread) plus `workers` worker
//! threads. Each worker owns a private model replica, its own compute
//! backend (constructed in-thread — PJRT handles are not `Send`) and a
//! set of subgraph batches. Two round engines share that scaffolding:
//!
//! * **Synchronous** ([`ConsensusMode::Plain`] / [`Weighted`]):
//!   1. every worker runs forward/backward on its next batch,
//!   2. the leader aggregates gradients — plain average (Eq. 11) or
//!      ζ-weighted consensus (Eq. 15),
//!   3. the consensus gradient is broadcast and every replica applies
//!      the identical optimizer update (Eq. 12/16), keeping replicas in
//!      lock-step without parameter exchange beyond the gradient.
//! * **Asynchronous** ([`ConsensusMode::Async`], [`async_engine`]):
//!   workers push gradients as soon as a step finishes; the leader
//!   applies a consensus update per quorum, weighting contributions by
//!   `ζ_i · λ^staleness_i`, with a hard staleness bound past which a
//!   laggard is dropped and re-synced. Membership is elastic under
//!   [`FaultPlan`] crashes/recoveries.
//!
//! Communication is accounted in a [`CommLedger`]: gradient bytes per
//! round, feature bytes per epoch for non-replicated remote candidates,
//! and replica re-sync bytes for the async engine's recovery path.
//!
//! [`Weighted`]: ConsensusMode::Weighted
//! [`CommLedger`]: crate::comm::CommLedger

mod async_engine;
mod config;
mod consensus;
mod fault;
mod loading;
mod trainer;
mod worker;

pub use config::{AsyncConfig, ConsensusMode, TrainConfig};
pub use consensus::{aggregate_gradients, grads_finite};
pub use fault::{Fault, FaultPlan};
pub use loading::allocate_subgraphs;
pub use trainer::{batch_from_subgraph, batch_zeta, train_gad, train_with_plans, TrainReport};
pub use worker::{fixed_source_is_stable, BatchSource, FixedSource, WorkerCommand, WorkerPlan, WorkerResult};
