//! Failure injection: worker crashes and stragglers.
//!
//! The paper's testbed assumes four healthy GPUs; a production
//! coordinator must survive less. The [`FaultPlan`] injects faults at
//! configured epochs and the trainer degrades gracefully:
//!
//! * **crash** — the worker stops responding; the leader detects it on
//!   the next collect, drops it from the consensus (weight 0 forever),
//!   and redistributes nothing (its subgraphs' gradient signal is lost,
//!   exactly like a synchronous data-parallel job running with a
//!   reduced denominator — accuracy degrades smoothly because every
//!   replica still applies the same consensus updates).
//! * **straggler** — the worker sleeps before each step; synchronous
//!   rounds stretch to the slowest worker, which is precisely the
//!   effect Fig. 7's flattening curve attributes to "communication and
//!   blocking".

use crate::rng::Rng;

/// A single injected fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Worker exits permanently at the start of `epoch`.
    Crash { worker: usize, epoch: usize },
    /// Worker sleeps `millis` before every step from `epoch` on.
    Straggle { worker: usize, epoch: usize, millis: u64 },
    /// Worker rejoins at the start of `epoch` after an earlier crash.
    /// Honoured by the async engine's elastic membership (the replica
    /// is re-pulled from the leader before the worker steps again);
    /// the synchronous loop has no re-sync channel, so there a crash
    /// stays permanent and `Recover` is ignored.
    Recover { worker: usize, epoch: usize },
}

/// The set of faults a run injects.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    /// One random crash in the first half of the run (chaos testing).
    pub fn random_crash(workers: usize, epochs: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        FaultPlan {
            faults: vec![Fault::Crash {
                worker: rng.gen_range(workers),
                epoch: 1 + rng.gen_range((epochs / 2).max(1)),
            }],
        }
    }

    /// True if `worker` is crashed at (or before) `epoch`.
    pub fn crashed(&self, worker: usize, epoch: usize) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::Crash { worker: w, epoch: e } if *w == worker && epoch >= *e)
        })
    }

    /// Sleep to inject for `worker` at `epoch`, if any.
    pub fn straggle_ms(&self, worker: usize, epoch: usize) -> Option<u64> {
        self.faults.iter().find_map(|f| match f {
            Fault::Straggle { worker: w, epoch: e, millis } if *w == worker && epoch >= *e => {
                Some(*millis)
            }
            _ => None,
        })
    }

    /// Workers still alive at `epoch`.
    pub fn alive_workers(&self, workers: usize, epoch: usize) -> usize {
        (0..workers).filter(|&w| !self.crashed(w, epoch)).count()
    }

    /// Elastic-membership view used by the async engine: is `worker`
    /// active at `epoch`, honouring [`Fault::Recover`]? The latest
    /// crash/recover event at or before `epoch` wins; a tie at the same
    /// epoch counts as crashed. Workers with no events are active.
    pub fn active(&self, worker: usize, epoch: usize) -> bool {
        // (event_epoch, is_crash) of the latest applicable event
        let mut last: Option<(usize, bool)> = None;
        for f in &self.faults {
            match *f {
                Fault::Crash { worker: w, epoch: e } if w == worker && e <= epoch => {
                    if last.map_or(true, |(le, _)| e >= le) {
                        last = Some((e, true));
                    }
                }
                Fault::Recover { worker: w, epoch: e } if w == worker && e <= epoch => {
                    if last.map_or(true, |(le, _)| e > le) {
                        last = Some((e, false));
                    }
                }
                _ => {}
            }
        }
        !last.map_or(false, |(_, crashed)| crashed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_is_permanent() {
        let p = FaultPlan { faults: vec![Fault::Crash { worker: 1, epoch: 5 }] };
        assert!(!p.crashed(1, 4));
        assert!(p.crashed(1, 5));
        assert!(p.crashed(1, 100));
        assert!(!p.crashed(0, 100));
    }

    #[test]
    fn straggler_from_epoch() {
        let p = FaultPlan {
            faults: vec![Fault::Straggle { worker: 2, epoch: 3, millis: 50 }],
        };
        assert_eq!(p.straggle_ms(2, 2), None);
        assert_eq!(p.straggle_ms(2, 3), Some(50));
        assert_eq!(p.straggle_ms(0, 9), None);
    }

    #[test]
    fn alive_count() {
        let p = FaultPlan {
            faults: vec![
                Fault::Crash { worker: 0, epoch: 2 },
                Fault::Crash { worker: 3, epoch: 7 },
            ],
        };
        assert_eq!(p.alive_workers(4, 0), 4);
        assert_eq!(p.alive_workers(4, 2), 3);
        assert_eq!(p.alive_workers(4, 7), 2);
    }

    #[test]
    fn recover_restores_active_membership() {
        let p = FaultPlan {
            faults: vec![
                Fault::Crash { worker: 1, epoch: 3 },
                Fault::Recover { worker: 1, epoch: 6 },
            ],
        };
        assert!(p.active(1, 2));
        assert!(!p.active(1, 3));
        assert!(!p.active(1, 5));
        assert!(p.active(1, 6));
        assert!(p.active(1, 100));
        // the synchronous view stays permanent
        assert!(p.crashed(1, 100));
        // untouched workers are unaffected
        assert!(p.active(0, 100));
    }

    #[test]
    fn crash_wins_ties_and_later_crash_overrides_recover() {
        let p = FaultPlan {
            faults: vec![
                Fault::Crash { worker: 0, epoch: 2 },
                Fault::Recover { worker: 0, epoch: 2 },
                Fault::Crash { worker: 0, epoch: 8 },
            ],
        };
        assert!(!p.active(0, 2), "same-epoch tie counts as crashed");
        assert!(!p.active(0, 9));
    }

    #[test]
    fn random_crash_in_range() {
        let p = FaultPlan::random_crash(4, 20, 9);
        match p.faults[0] {
            Fault::Crash { worker, epoch } => {
                assert!(worker < 4);
                assert!((1..=10).contains(&epoch));
            }
            _ => panic!("expected crash"),
        }
    }
}
