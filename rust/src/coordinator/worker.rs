//! Worker threads: each owns a model replica, a compute backend and a
//! batch source, and executes leader commands over mpsc channels.
//!
//! Every replica tracks a **parameter version** — the number of
//! consensus updates it has applied. The version rides along with every
//! step result so the leader (sync or async) can measure how stale a
//! gradient is; the async engine drops contributions past its bound and
//! re-syncs the laggard with [`WorkerCommand::LoadParams`].

use crate::backend::BackendFactory;
use crate::metrics::AccuracyMeter;
use crate::model::{Batch, GcnParams, Optimizer};
use crate::tensor::Matrix;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Supplies a worker's batches. Fixed plans (GAD, ClusterGCN) return
/// the same batches every epoch; sampler plans (SAGE, SAINT) draw fresh
/// ones. `zeta` rides along with each batch for weighted consensus.
pub trait BatchSource: Send {
    /// Rounds this worker participates in per epoch.
    fn batches_per_epoch(&self) -> usize;
    /// Batch for `(epoch, round)`; `None` if this worker idles that
    /// round (fewer subgraphs than the global round count).
    fn batch(&mut self, epoch: usize, round: usize) -> Option<(Arc<Batch>, f64)>;
    /// Bytes of graph state held resident (memory accounting).
    fn resident_bytes(&self) -> usize;
}

/// A fixed rotation of pre-built batches.
pub struct FixedSource {
    batches: Vec<Arc<Batch>>,
    zetas: Vec<f64>,
}

impl FixedSource {
    pub fn new(batches: Vec<Batch>, zetas: Vec<f64>) -> Self {
        assert_eq!(batches.len(), zetas.len());
        FixedSource { batches: batches.into_iter().map(Arc::new).collect(), zetas }
    }
}

impl BatchSource for FixedSource {
    fn batches_per_epoch(&self) -> usize {
        self.batches.len()
    }
    fn batch(&mut self, _epoch: usize, round: usize) -> Option<(Arc<Batch>, f64)> {
        (round < self.batches.len()).then(|| (self.batches[round].clone(), self.zetas[round]))
    }
    fn resident_bytes(&self) -> usize {
        self.batches.iter().map(|b| b.nbytes()).sum()
    }
}

/// What a worker is told to do.
pub enum WorkerCommand {
    /// Train on the batch for `(epoch, round)` and report gradients.
    /// `delay_ms` injects straggler latency (fault testing).
    Step { epoch: usize, round: usize, delay_ms: u64 },
    /// Apply the consensus gradient to the local replica (bumps the
    /// replica's parameter version).
    Update { grads: Vec<Matrix> },
    /// Replace the replica wholesale: parameters, optimizer state and
    /// version from the leader's shadow copy. Sent by the async engine
    /// when a laggard exceeded the staleness bound or a crashed worker
    /// rejoins — the "fresh replica pull".
    LoadParams { params: GcnParams, optimizer: Box<dyn Optimizer>, version: u64 },
    /// Set the schedule's learning-rate factor for this epoch.
    SetLr { factor: f32 },
    /// Evaluate the replica on all local batches.
    Eval,
    /// Report the replica's current parameters + version (the leader
    /// harvests the freshest replica at end of run for checkpointing
    /// and serving).
    FetchParams,
    Stop,
}

/// What a worker reports back.
pub enum WorkerResult {
    Step {
        worker: usize,
        /// `None` if the worker idled this round.
        grads: Option<Vec<Matrix>>,
        loss: f32,
        zeta: f64,
        batch_nodes: usize,
        /// Replica parameter version the gradient was computed at
        /// (consensus updates applied so far) — the leader derives
        /// staleness from this.
        param_version: u64,
    },
    Eval {
        worker: usize,
        train: AccuracyMeter,
        val: AccuracyMeter,
        test: AccuracyMeter,
    },
    /// Response to [`WorkerCommand::FetchParams`].
    Params { worker: usize, params: GcnParams, version: u64 },
    /// Backend construction or execution failed.
    Error { worker: usize, message: String },
}

/// Everything a worker thread needs at spawn.
pub struct WorkerPlan {
    pub worker: usize,
    pub source: Box<dyn BatchSource>,
    pub factory: BackendFactory,
    pub init_params: GcnParams,
    pub optimizer: Box<dyn Optimizer>,
    /// Intra-op thread budget for this worker's compute (0 = all
    /// cores). Set per worker thread, not globally, so concurrent
    /// training runs in one process cannot clobber each other.
    pub intra_threads: usize,
}

/// Worker thread body: construct the backend locally (PJRT handles are
/// not `Send`), then serve commands until `Stop`.
pub fn worker_main(plan: WorkerPlan, rx: Receiver<WorkerCommand>, tx: Sender<WorkerResult>) {
    let WorkerPlan { worker, mut source, factory, init_params, mut optimizer, intra_threads } =
        plan;
    crate::tensor::set_intra_threads(intra_threads);
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let _ = tx.send(WorkerResult::Error { worker, message: format!("backend init: {e:#}") });
            return;
        }
    };
    let mut params = init_params;
    let mut version: u64 = 0;

    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCommand::Step { epoch, round, delay_ms } => {
                if delay_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(delay_ms));
                }
                let msg = match source.batch(epoch, round) {
                    Some((batch, zeta)) => match backend.train_step(&batch, &params) {
                        Ok(out) => WorkerResult::Step {
                            worker,
                            grads: Some(out.grads),
                            loss: out.loss,
                            zeta,
                            batch_nodes: batch.len(),
                            param_version: version,
                        },
                        Err(e) => WorkerResult::Error { worker, message: format!("train: {e:#}") },
                    },
                    None => WorkerResult::Step {
                        worker,
                        grads: None,
                        loss: 0.0,
                        zeta: 0.0,
                        batch_nodes: 0,
                        param_version: version,
                    },
                };
                if tx.send(msg).is_err() {
                    return;
                }
            }
            WorkerCommand::Update { grads } => {
                optimizer.step(&mut params, &grads);
                version += 1;
            }
            WorkerCommand::LoadParams { params: fresh, optimizer: opt, version: v } => {
                params = fresh;
                optimizer = opt;
                version = v;
            }
            WorkerCommand::SetLr { factor } => {
                optimizer.set_lr_factor(factor);
            }
            WorkerCommand::Eval => {
                let msg = eval_all(worker, source.as_mut(), backend.as_mut(), &params);
                if tx.send(msg).is_err() {
                    return;
                }
            }
            WorkerCommand::FetchParams => {
                let msg = WorkerResult::Params { worker, params: params.clone(), version };
                if tx.send(msg).is_err() {
                    return;
                }
            }
            WorkerCommand::Stop => return,
        }
    }
}

fn eval_all(
    worker: usize,
    source: &mut dyn BatchSource,
    backend: &mut dyn crate::backend::Backend,
    params: &GcnParams,
) -> WorkerResult {
    let mut train = AccuracyMeter::default();
    let mut val = AccuracyMeter::default();
    let mut test = AccuracyMeter::default();
    for round in 0..source.batches_per_epoch() {
        // epoch 0 batches: for fixed sources this is the whole shard;
        // sampler sources evaluate on their epoch-0 draw (deterministic)
        if let Some((batch, _)) = source.batch(0, round) {
            match backend.predict(&batch, params) {
                Ok(preds) => {
                    train.add(&preds, &batch.labels, &batch.loss_mask);
                    val.add(&preds, &batch.labels, &batch.val_mask);
                    test.add(&preds, &batch.labels, &batch.test_mask);
                }
                Err(e) => {
                    return WorkerResult::Error { worker, message: format!("eval: {e:#}") };
                }
            }
        }
    }
    WorkerResult::Eval { worker, train, val, test }
}

/// Consistency check used by property tests: a [`FixedSource`] must
/// return the same batches every epoch.
#[doc(hidden)]
pub fn fixed_source_is_stable(src: &mut FixedSource) -> bool {
    let n = src.batches_per_epoch();
    for round in 0..n {
        let a = src.batch(0, round).map(|(b, z)| (b.id, z));
        let b = src.batch(7, round).map(|(b, z)| (b.id, z));
        if a != b {
            return false;
        }
    }
    src.batch(0, n).is_none()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::model::NormAdj;
    use crate::tensor::Matrix;

    fn mini_batch(id: u64) -> Batch {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        Batch {
            id,
            adj: NormAdj::from_csr(&g),
            features: Matrix::zeros(3, 4),
            labels: vec![0, 1, 0],
            loss_mask: vec![true; 3],
            val_mask: vec![false; 3],
            test_mask: vec![false; 3],
            num_classes: 2,
        }
    }

    #[test]
    fn fixed_source_rotation() {
        let mut src = FixedSource::new(vec![mini_batch(1), mini_batch(2)], vec![0.5, 1.5]);
        assert_eq!(src.batches_per_epoch(), 2);
        assert!(fixed_source_is_stable(&mut src));
        let (b, z) = src.batch(3, 1).unwrap();
        assert_eq!(b.id, 2);
        assert_eq!(z, 1.5);
    }

    #[test]
    fn resident_bytes_positive() {
        let src = FixedSource::new(vec![mini_batch(1)], vec![1.0]);
        assert!(src.resident_bytes() > 0);
    }

    #[test]
    fn worker_reports_param_version_and_resyncs() {
        use crate::backend::backend_factory;
        use crate::model::Adam;
        use crate::rng::Rng;
        use std::sync::mpsc;

        let mut rng = Rng::seed_from_u64(5);
        let params = GcnParams::init(4, 8, 2, 2, &mut rng);
        let plan = WorkerPlan {
            worker: 0,
            source: Box::new(FixedSource::new(vec![mini_batch(1)], vec![1.0])),
            factory: backend_factory(crate::backend::BackendKind::Native, "artifacts"),
            init_params: params.clone(),
            optimizer: Box::new(Adam::new(0.01)),
            intra_threads: 1,
        };
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        let h = std::thread::spawn(move || worker_main(plan, cmd_rx, res_tx));

        let step = |tx: &mpsc::Sender<WorkerCommand>| {
            tx.send(WorkerCommand::Step { epoch: 0, round: 0, delay_ms: 0 }).unwrap()
        };
        let version_of = |rx: &mpsc::Receiver<WorkerResult>| match rx.recv().unwrap() {
            WorkerResult::Step { param_version, grads, .. } => {
                assert!(grads.is_some());
                param_version
            }
            _ => panic!("expected step result"),
        };

        step(&cmd_tx);
        assert_eq!(version_of(&res_rx), 0);
        // one consensus update bumps the version
        let zero_grads: Vec<Matrix> = params.zeros_like();
        cmd_tx.send(WorkerCommand::Update { grads: zero_grads }).unwrap();
        step(&cmd_tx);
        assert_eq!(version_of(&res_rx), 1);
        // a re-sync overwrites it wholesale
        cmd_tx
            .send(WorkerCommand::LoadParams {
                params: params.clone(),
                optimizer: Box::new(Adam::new(0.01)),
                version: 9,
            })
            .unwrap();
        step(&cmd_tx);
        assert_eq!(version_of(&res_rx), 9);

        // the replica hands back its current params + version on demand
        cmd_tx.send(WorkerCommand::FetchParams).unwrap();
        match res_rx.recv().unwrap() {
            WorkerResult::Params { worker, params: p, version } => {
                assert_eq!(worker, 0);
                assert_eq!(version, 9);
                assert_eq!(p.layers(), params.layers());
            }
            _ => panic!("expected params result"),
        }

        cmd_tx.send(WorkerCommand::Stop).unwrap();
        h.join().unwrap();
    }
}
