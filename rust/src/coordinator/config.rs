//! Run configuration.

use crate::backend::BackendKind;

/// How the engine paces rounds and weights gradient aggregation.
///
/// * [`Plain`] — synchronous rounds, uniform average (Eq. 11).
/// * [`Weighted`] — synchronous rounds, ζ-weighted consensus (Eq. 15).
/// * [`Async`] — bounded-staleness asynchronous rounds: workers push
///   gradients as soon as a step finishes; the leader applies a
///   consensus update whenever a quorum has arrived, discounting each
///   contribution by `ζ_i · λ^staleness_i`. See [`AsyncConfig`].
///
/// [`Plain`]: ConsensusMode::Plain
/// [`Weighted`]: ConsensusMode::Weighted
/// [`Async`]: ConsensusMode::Async
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConsensusMode {
    Plain,
    Weighted,
    Async(AsyncConfig),
}

/// Knobs of the bounded-staleness asynchronous engine.
///
/// The degenerate setting `staleness: 0, quorum: 0 (= all alive),
/// lambda: 1.0` is guaranteed (and tested) to reproduce the
/// synchronous loop bit-for-bit given the same seed — that equivalence
/// is what makes switching engines safe.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsyncConfig {
    /// Hard staleness bound `s`: a gradient computed `k` consensus
    /// versions ago is still applied (discounted) while `k <= s`;
    /// beyond that it is dropped and the laggard's replica re-synced
    /// from the leader.
    pub staleness: usize,
    /// Contributions required before the leader applies an update;
    /// `0` means "every alive worker" (fully synchronous pacing).
    pub quorum: usize,
    /// Staleness decay: contribution weight is `base · λ^staleness`.
    pub lambda: f64,
    /// Base weight: ζ(g') as in Eq. 15 when true (the `Weighted`
    /// rule), a constant 1 when false (the `Plain` rule).
    pub zeta_weighted: bool,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig { staleness: 2, quorum: 0, lambda: 0.5, zeta_weighted: true }
    }
}

impl std::str::FromStr for ConsensusMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "plain" => Ok(ConsensusMode::Plain),
            "weighted" => Ok(ConsensusMode::Weighted),
            "async" => Ok(ConsensusMode::Async(AsyncConfig::default())),
            other => Err(format!("unknown consensus '{other}' (plain|weighted|async)")),
        }
    }
}

/// Everything a training run needs besides the dataset.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Subgraph count `k` of GAD-Partition.
    pub partitions: usize,
    /// Worker (processor) count `n`.
    pub workers: usize,
    /// GCN depth `l` (= augmentation walk length, Property 1).
    pub layers: usize,
    /// Hidden width `h`.
    pub hidden: usize,
    /// Learning rate η.
    pub lr: f32,
    /// Epoch budget.
    pub epochs: usize,
    /// Enable GAD-Partition augmentation.
    pub augment: bool,
    /// Replication coefficient α (Eq. 6).
    pub alpha: f64,
    /// Gradient aggregation rule.
    pub consensus: ConsensusMode,
    /// Compute engine.
    pub backend: BackendKind,
    /// Artifact directory for [`BackendKind::Xla`].
    pub artifact_dir: String,
    /// Convergence tolerance / patience (see `CurveRecorder`).
    pub conv_tol: f32,
    pub conv_patience: usize,
    /// Stop at convergence instead of exhausting `epochs`.
    pub stop_on_converge: bool,
    pub seed: u64,
    /// Print an epoch line every N epochs (0 = silent).
    pub log_every: usize,
    /// Learning-rate schedule applied on top of `lr`.
    pub schedule: crate::model::LrSchedule,
    /// Injected failures (crashes / stragglers); empty = healthy run.
    pub faults: super::FaultPlan,
    /// Interconnect model used for the estimated-network-time report.
    pub topology: crate::comm::Topology,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            partitions: 8,
            workers: 4,
            layers: 2,
            hidden: 128,
            lr: 0.01,
            epochs: 100,
            augment: true,
            alpha: 0.01,
            consensus: ConsensusMode::Weighted,
            backend: BackendKind::Native,
            artifact_dir: "artifacts".to_string(),
            conv_tol: 0.002,
            conv_patience: 10,
            stop_on_converge: false,
            seed: 0,
            log_every: 0,
            schedule: crate::model::LrSchedule::Constant,
            faults: super::FaultPlan::none(),
            topology: crate::comm::Topology::Star,
        }
    }
}

impl TrainConfig {
    /// The paper's per-dataset best settings (§4.2).
    pub fn paper_best(dataset: &str) -> TrainConfig {
        let (layers, hidden) = match dataset {
            "cora" => (3, 128),
            "pubmed" => (2, 256),
            "flickr" | "flicker" => (4, 128),
            "reddit" => (3, 256),
            _ => (2, 128),
        };
        TrainConfig { layers, hidden, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consensus_parse() {
        assert_eq!("plain".parse::<ConsensusMode>().unwrap(), ConsensusMode::Plain);
        assert_eq!("weighted".parse::<ConsensusMode>().unwrap(), ConsensusMode::Weighted);
        assert_eq!(
            "async".parse::<ConsensusMode>().unwrap(),
            ConsensusMode::Async(AsyncConfig::default())
        );
        assert!("x".parse::<ConsensusMode>().is_err());
    }

    #[test]
    fn paper_best_table() {
        assert_eq!(TrainConfig::paper_best("cora").layers, 3);
        assert_eq!(TrainConfig::paper_best("pubmed").hidden, 256);
        assert_eq!(TrainConfig::paper_best("flickr").layers, 4);
        assert_eq!(TrainConfig::paper_best("reddit").hidden, 256);
    }
}
