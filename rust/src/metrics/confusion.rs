//! Confusion matrix, per-class precision/recall and macro-F1 — the
//! class-imbalanced datasets (reddit: 41 classes) need more than plain
//! accuracy to see what a partition strategy loses.

/// `C x C` confusion counts; rows = true class, cols = predicted.
#[derive(Clone, Debug)]
pub struct ConfusionMatrix {
    pub classes: usize,
    counts: Vec<u64>,
}

impl ConfusionMatrix {
    pub fn new(classes: usize) -> Self {
        ConfusionMatrix { classes, counts: vec![0; classes * classes] }
    }

    /// Accumulate masked predictions.
    pub fn add(&mut self, preds: &[u32], labels: &[u32], mask: &[bool]) {
        for i in 0..labels.len() {
            if mask[i] {
                let t = labels[i] as usize;
                let p = preds[i] as usize;
                if t < self.classes && p < self.classes {
                    self.counts[t * self.classes + p] += 1;
                }
            }
        }
    }

    /// Merge another matrix (distributed eval).
    pub fn merge(&mut self, other: &ConfusionMatrix) {
        assert_eq!(self.classes, other.classes);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    #[inline]
    pub fn count(&self, true_class: usize, pred_class: usize) -> u64 {
        self.counts[true_class * self.classes + pred_class]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Overall accuracy.
    pub fn accuracy(&self) -> f64 {
        let correct: u64 = (0..self.classes).map(|c| self.count(c, c)).sum();
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Precision for one class (0 when the class was never predicted).
    pub fn precision(&self, c: usize) -> f64 {
        let tp = self.count(c, c) as f64;
        let predicted: u64 = (0..self.classes).map(|t| self.count(t, c)).sum();
        if predicted == 0 {
            0.0
        } else {
            tp / predicted as f64
        }
    }

    /// Recall for one class (0 when the class has no true members).
    pub fn recall(&self, c: usize) -> f64 {
        let tp = self.count(c, c) as f64;
        let actual: u64 = (0..self.classes).map(|p| self.count(c, p)).sum();
        if actual == 0 {
            0.0
        } else {
            tp / actual as f64
        }
    }

    /// Per-class F1.
    pub fn f1(&self, c: usize) -> f64 {
        let (p, r) = (self.precision(c), self.recall(c));
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Macro-averaged F1 over classes that actually appear.
    pub fn macro_f1(&self) -> f64 {
        let present: Vec<usize> = (0..self.classes)
            .filter(|&c| (0..self.classes).any(|p| self.count(c, p) > 0))
            .collect();
        if present.is_empty() {
            return 0.0;
        }
        present.iter().map(|&c| self.f1(c)).sum::<f64>() / present.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn perfect() -> ConfusionMatrix {
        let mut m = ConfusionMatrix::new(3);
        m.add(&[0, 1, 2, 0], &[0, 1, 2, 0], &[true; 4]);
        m
    }

    #[test]
    fn perfect_scores() {
        let m = perfect();
        assert_eq!(m.accuracy(), 1.0);
        assert_eq!(m.macro_f1(), 1.0);
        for c in 0..3 {
            assert_eq!(m.precision(c), 1.0);
            assert_eq!(m.recall(c), 1.0);
        }
    }

    #[test]
    fn masked_rows_ignored() {
        let mut m = ConfusionMatrix::new(2);
        m.add(&[1, 1], &[0, 1], &[false, true]);
        assert_eq!(m.total(), 1);
        assert_eq!(m.accuracy(), 1.0);
    }

    #[test]
    fn known_confusion() {
        // true 0 predicted as 1 twice; true 1 predicted correctly once
        let mut m = ConfusionMatrix::new(2);
        m.add(&[1, 1, 1], &[0, 0, 1], &[true; 3]);
        assert_eq!(m.count(0, 1), 2);
        assert!((m.accuracy() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.recall(0), 0.0);
        assert!((m.precision(1) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.recall(1), 1.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = perfect();
        let b = perfect();
        a.merge(&b);
        assert_eq!(a.total(), 8);
        assert_eq!(a.accuracy(), 1.0);
    }

    #[test]
    fn absent_class_excluded_from_macro_f1() {
        let mut m = ConfusionMatrix::new(3);
        m.add(&[0, 1], &[0, 1], &[true; 2]); // class 2 never appears
        assert_eq!(m.macro_f1(), 1.0);
    }
}
