//! Run metrics: loss/accuracy curves, convergence detection, timers,
//! memory accounting, and CSV/markdown emitters for the experiment
//! harness.

mod confusion;

pub use confusion::ConfusionMatrix;

use std::fmt::Write as _;
use std::time::Instant;

/// One point of a training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub epoch: usize,
    pub seconds: f64,
    pub loss: f32,
    pub accuracy: f32,
}

/// Records the loss/accuracy trajectory and detects convergence as the
/// paper plots it: the epoch after which the smoothed loss improves by
/// less than `tol` relative for `patience` consecutive epochs.
#[derive(Clone, Debug)]
pub struct CurveRecorder {
    start: Instant,
    pub points: Vec<CurvePoint>,
    best_loss: f32,
    stale: usize,
    pub tol: f32,
    pub patience: usize,
    converged_at: Option<(usize, f64)>,
}

impl CurveRecorder {
    pub fn new(tol: f32, patience: usize) -> Self {
        CurveRecorder {
            start: Instant::now(),
            points: Vec::new(),
            best_loss: f32::INFINITY,
            stale: 0,
            tol,
            patience,
            converged_at: None,
        }
    }

    /// Record an epoch; returns true the first time convergence fires.
    pub fn record(&mut self, epoch: usize, loss: f32, accuracy: f32) -> bool {
        let seconds = self.start.elapsed().as_secs_f64();
        self.points.push(CurvePoint { epoch, seconds, loss, accuracy });
        if loss < self.best_loss * (1.0 - self.tol) {
            self.best_loss = loss;
            self.stale = 0;
        } else {
            self.best_loss = self.best_loss.min(loss);
            self.stale += 1;
            if self.stale >= self.patience && self.converged_at.is_none() {
                self.converged_at = Some((epoch, seconds));
                return true;
            }
        }
        false
    }

    /// `(epoch, seconds)` at which convergence was declared.
    pub fn converged(&self) -> Option<(usize, f64)> {
        self.converged_at
    }

    /// Seconds to convergence, or total time if never converged.
    pub fn time_to_converge(&self) -> f64 {
        self.converged_at
            .map(|(_, s)| s)
            .or_else(|| self.points.last().map(|p| p.seconds))
            .unwrap_or(0.0)
    }

    /// CSV dump: `epoch,seconds,loss,accuracy`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("epoch,seconds,loss,accuracy\n");
        for p in &self.points {
            let _ = writeln!(s, "{},{:.4},{:.6},{:.4}", p.epoch, p.seconds, p.loss, p.accuracy);
        }
        s
    }
}

/// Accuracy = fraction of matching predictions among masked nodes.
pub fn masked_accuracy(preds: &[u32], labels: &[u32], mask: &[bool]) -> f32 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for i in 0..labels.len() {
        if mask[i] {
            total += 1;
            if preds[i] == labels[i] {
                hit += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hit as f32 / total as f32
    }
}

/// Counter-based accuracy accumulation across distributed subgraphs.
#[derive(Clone, Copy, Debug, Default)]
pub struct AccuracyMeter {
    pub hits: usize,
    pub total: usize,
}

impl AccuracyMeter {
    pub fn add(&mut self, preds: &[u32], labels: &[u32], mask: &[bool]) {
        for i in 0..labels.len() {
            if mask[i] {
                self.total += 1;
                if preds[i] == labels[i] {
                    self.hits += 1;
                }
            }
        }
    }

    pub fn merge(&mut self, other: AccuracyMeter) {
        self.hits += other.hits;
        self.total += other.total;
    }

    pub fn value(&self) -> f32 {
        if self.total == 0 {
            0.0
        } else {
            self.hits as f32 / self.total as f32
        }
    }
}

/// Write a file, creating parent dirs; helper for the results/ tree.
pub fn write_result_file(path: &str, content: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

/// Markdown table builder used by the CLI table commands.
pub struct MarkdownTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(header: &[&str]) -> Self {
        MarkdownTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(s, "|{}|", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convergence_fires_once_loss_plateaus() {
        let mut rec = CurveRecorder::new(0.01, 3);
        // fast descent then plateau
        let losses = [1.0f32, 0.8, 0.6, 0.5, 0.499, 0.498, 0.4985, 0.498];
        let mut fired_at = None;
        for (e, &l) in losses.iter().enumerate() {
            if rec.record(e, l, 0.5) && fired_at.is_none() {
                fired_at = Some(e);
            }
        }
        let fired = fired_at.expect("should converge");
        assert!(fired >= 5, "fired too early at {fired}");
        assert_eq!(rec.converged().unwrap().0, fired);
    }

    #[test]
    fn no_convergence_while_improving() {
        let mut rec = CurveRecorder::new(0.01, 3);
        for e in 0..20 {
            let loss = 1.0 / (e + 1) as f32;
            assert!(!rec.record(e, loss, 0.0), "epoch {e}");
        }
        assert!(rec.converged().is_none());
    }

    #[test]
    fn masked_accuracy_basic() {
        let preds = [0u32, 1, 2, 0];
        let labels = [0u32, 1, 0, 0];
        let mask = [true, true, true, false];
        assert!((masked_accuracy(&preds, &labels, &mask) - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(masked_accuracy(&preds, &labels, &[false; 4]), 0.0);
    }

    #[test]
    fn meter_merge() {
        let mut a = AccuracyMeter::default();
        a.add(&[1, 1], &[1, 0], &[true, true]);
        let mut b = AccuracyMeter::default();
        b.add(&[2], &[2], &[true]);
        a.merge(b);
        assert_eq!(a.hits, 2);
        assert_eq!(a.total, 3);
    }

    #[test]
    fn markdown_table_renders() {
        let mut t = MarkdownTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn csv_format() {
        let mut rec = CurveRecorder::new(0.01, 2);
        rec.record(0, 1.0, 0.1);
        let csv = rec.to_csv();
        assert!(csv.starts_with("epoch,seconds,loss,accuracy\n"));
        assert!(csv.lines().count() == 2);
    }
}
