//! One named, typed export surface over the counters the subsystems
//! already keep.
//!
//! Every tier ends a run holding its own snapshot struct —
//! [`ServeStats`], [`CommStats`], [`TrainReport`], [`SimResult`] —
//! each with its own field names and report formatting. The registry
//! flattens them into `tier.counter` metrics in a deterministic
//! (insertion) order with one of two types: **counter** (monotonic
//! `u64`) or **gauge** (point-in-time `f64`). Snapshotting reads the
//! existing structs; it adds no new accounting and touches no hot
//! path, so it inherits the source counters' determinism guarantees
//! unchanged.

use crate::comm::CommStats;
use crate::coordinator::TrainReport;
use crate::loadgen::SimResult;
use crate::metrics::MarkdownTable;
use crate::obs::hist::LogHistogram;
use crate::serve::ServeStats;
use std::fmt::Write as _;

/// A metric's typed value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MetricValue {
    /// Monotonic count (events, bytes, rows).
    Counter(u64),
    /// Point-in-time measurement (ratios, seconds, means).
    Gauge(f64),
}

/// One named metric.
#[derive(Clone, Debug)]
pub struct Metric {
    pub name: String,
    pub value: MetricValue,
}

/// Ordered collection of named metrics with md/csv/json emitters.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    metrics: Vec<Metric>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&mut self, name: impl Into<String>, v: u64) -> &mut Self {
        self.metrics.push(Metric { name: name.into(), value: MetricValue::Counter(v) });
        self
    }

    pub fn gauge(&mut self, name: impl Into<String>, v: f64) -> &mut Self {
        self.metrics.push(Metric { name: name.into(), value: MetricValue::Gauge(v) });
        self
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    pub fn get(&self, name: &str) -> Option<MetricValue> {
        self.metrics.iter().find(|m| m.name == name).map(|m| m.value)
    }

    /// Snapshot a [`CommStats`] under `prefix` (e.g. `serve.comm`).
    pub fn record_comm(&mut self, prefix: &str, c: &CommStats) -> &mut Self {
        self.counter(format!("{prefix}.feature_bytes"), c.feature_bytes)
            .counter(format!("{prefix}.gradient_bytes"), c.gradient_bytes)
            .counter(format!("{prefix}.resync_bytes"), c.resync_bytes)
            .counter(format!("{prefix}.serving_bytes"), c.serving_bytes)
            .counter(format!("{prefix}.rebalance_bytes"), c.rebalance_bytes)
    }

    /// Snapshot a full [`ServeStats`] (including its comm block).
    pub fn record_serve_stats(&mut self, prefix: &str, s: &ServeStats) -> &mut Self {
        self.counter(format!("{prefix}.queries"), s.queries)
            .counter(format!("{prefix}.micro_batches"), s.micro_batches)
            .counter(format!("{prefix}.cache_hits"), s.cache_hits)
            .counter(format!("{prefix}.rows_recomputed"), s.rows_recomputed)
            .counter(format!("{prefix}.rows_evicted"), s.rows_evicted)
            .counter(format!("{prefix}.gather_rows_reused"), s.gather_rows_reused)
            .counter(format!("{prefix}.gather_fetches_avoided"), s.gather_fetches_avoided)
            .counter(format!("{prefix}.gather_rows_invalidated"), s.gather_rows_invalidated)
            .counter(format!("{prefix}.slo_answers"), s.slo_answers)
            .counter(format!("{prefix}.late_answers"), s.late_answers)
            .counter(format!("{prefix}.queue_depth_max"), s.queue_depth_max)
            .gauge(format!("{prefix}.queue_depth_mean"), s.queue_depth_mean)
            .counter(format!("{prefix}.deltas_applied"), s.deltas_applied)
            .counter(format!("{prefix}.nodes_added"), s.nodes_added)
            .counter(format!("{prefix}.nodes_removed"), s.nodes_removed)
            .counter(format!("{prefix}.shard_rebuilds"), s.shard_rebuilds)
            .counter(format!("{prefix}.graph_compactions"), s.graph_compactions)
            .counter(format!("{prefix}.compaction_threshold"), s.compaction_threshold as u64)
            .counter(format!("{prefix}.rebalances"), s.rebalances)
            .counter(format!("{prefix}.nodes_migrated"), s.nodes_migrated)
            .gauge(format!("{prefix}.imbalance_ratio"), s.imbalance_ratio)
            .counter(format!("{prefix}.graph_version"), s.graph_version)
            .record_comm(&format!("{prefix}.comm"), &s.comm)
    }

    /// Snapshot the training-side counters of a [`TrainReport`].
    pub fn record_train_report(&mut self, prefix: &str, r: &TrainReport) -> &mut Self {
        self.gauge(format!("{prefix}.test_accuracy"), r.test_accuracy as f64)
            .gauge(format!("{prefix}.val_accuracy"), r.val_accuracy as f64)
            .gauge(format!("{prefix}.train_accuracy"), r.train_accuracy as f64)
            .counter(format!("{prefix}.epochs_run"), r.epochs_run as u64)
            .gauge(format!("{prefix}.wall_seconds"), r.wall_seconds)
            .gauge(format!("{prefix}.time_to_converge_sec"), r.time_to_converge)
            .counter(
                format!("{prefix}.converged_epoch"),
                r.converged_epoch.map(|e| e as u64).unwrap_or(0),
            )
            .gauge(format!("{prefix}.network_time_est_sec"), r.network_time_est_sec)
            .gauge(format!("{prefix}.memory_mb_per_worker"), r.memory_mb_per_worker())
            .counter(format!("{prefix}.edge_cut"), r.edge_cut as u64)
            .counter(format!("{prefix}.replicas_total"), r.replicas_total as u64)
            .counter(format!("{prefix}.workers"), r.workers as u64)
            .counter(format!("{prefix}.max_staleness_applied"), r.max_staleness_applied as u64)
            .counter(format!("{prefix}.resyncs"), r.resyncs)
            .record_comm(&format!("{prefix}.comm"), &r.comm)
    }

    /// Snapshot an open-loop replay's [`SimResult`] aggregates.
    pub fn record_sim_result(&mut self, prefix: &str, s: &SimResult) -> &mut Self {
        self.counter(format!("{prefix}.answered"), s.outcomes.len() as u64)
            .counter(format!("{prefix}.deltas_applied"), s.deltas_applied as u64)
            .counter(format!("{prefix}.end_us"), s.end_us)
            .counter(format!("{prefix}.flushes"), s.flushes as u64)
            .counter(format!("{prefix}.queue_depth_max"), s.queue_depth_max as u64)
            .gauge(format!("{prefix}.queue_depth_mean"), s.queue_depth_mean)
            .counter(format!("{prefix}.queue_depth_p99"), s.queue_depth_p99)
            .counter(format!("{prefix}.peak_inflight"), s.peak_inflight as u64)
    }

    /// Summarise a [`LogHistogram`] as count/mean/p50/p99/p999/max.
    pub fn record_histogram(&mut self, prefix: &str, h: &LogHistogram) -> &mut Self {
        self.counter(format!("{prefix}.count"), h.count())
            .gauge(format!("{prefix}.mean_us"), h.mean())
            .counter(format!("{prefix}.p50_us"), h.quantile(0.50))
            .counter(format!("{prefix}.p99_us"), h.quantile(0.99))
            .counter(format!("{prefix}.p999_us"), h.quantile(0.999))
            .counter(format!("{prefix}.max_us"), h.max())
    }

    /// `metric,type,value` rows.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("metric,type,value\n");
        for m in &self.metrics {
            match m.value {
                MetricValue::Counter(v) => {
                    let _ = writeln!(s, "{},counter,{}", m.name, v);
                }
                MetricValue::Gauge(v) => {
                    let _ = writeln!(s, "{},gauge,{:.6}", m.name, v);
                }
            }
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut t = MarkdownTable::new(&["metric", "type", "value"]);
        for m in &self.metrics {
            match m.value {
                MetricValue::Counter(v) => {
                    t.row(vec![m.name.clone(), "counter".into(), v.to_string()]);
                }
                MetricValue::Gauge(v) => {
                    t.row(vec![m.name.clone(), "gauge".into(), format!("{v:.6}")]);
                }
            }
        }
        t.render()
    }

    /// Hand-rolled JSON array (the crate is registry-free — no serde).
    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let sep = if i + 1 < self.metrics.len() { "," } else { "" };
            match m.value {
                MetricValue::Counter(v) => {
                    let _ = writeln!(
                        s,
                        "  {{\"name\": \"{}\", \"type\": \"counter\", \"value\": {}}}{}",
                        m.name, v, sep
                    );
                }
                MetricValue::Gauge(v) => {
                    let v = if v.is_finite() { v } else { 0.0 };
                    let _ = writeln!(
                        s,
                        "  {{\"name\": \"{}\", \"type\": \"gauge\", \"value\": {:.6}}}{}",
                        m.name, v, sep
                    );
                }
            }
        }
        s.push_str("]\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_order_is_deterministic_and_values_survive() {
        let stats = ServeStats { queries: 7, cache_hits: 3, ..Default::default() };
        let mut a = MetricsRegistry::new();
        a.record_serve_stats("serve", &stats);
        let mut b = MetricsRegistry::new();
        b.record_serve_stats("serve", &stats);
        assert_eq!(a.to_csv(), b.to_csv(), "same snapshot must serialise identically");
        assert_eq!(a.get("serve.queries"), Some(MetricValue::Counter(7)));
        assert_eq!(a.get("serve.cache_hits"), Some(MetricValue::Counter(3)));
        assert_eq!(a.get("serve.comm.serving_bytes"), Some(MetricValue::Counter(0)));
        assert!(a.get("serve.nonexistent").is_none());
    }

    #[test]
    fn emitters_cover_every_metric() {
        let mut r = MetricsRegistry::new();
        r.counter("x.count", 5).gauge("x.ratio", 0.25);
        let mut h = LogHistogram::new();
        h.record(10);
        h.record(1000);
        r.record_histogram("x.latency", &h);
        assert_eq!(r.len(), 2 + 6);
        let csv = r.to_csv();
        assert!(csv.starts_with("metric,type,value\n"));
        assert_eq!(csv.lines().count(), 1 + r.len());
        let md = r.to_markdown();
        assert!(md.contains("| x.count | counter | 5 |"));
        assert!(md.contains("| x.ratio | gauge | 0.250000 |"));
        let json = r.to_json();
        assert!(json.trim_start().starts_with('['));
        assert!(json.contains("\"name\": \"x.latency.p99_us\""));
        assert_eq!(json.matches("\"name\"").count(), r.len());
        // last entry carries no trailing comma
        assert!(!json.trim_end().trim_end_matches(']').trim_end().ends_with(','));
    }
}
