//! Observability: deterministic tracing + unified metrics.
//!
//! Three pieces, all zero-dependency and registry-free like the rest
//! of the crate:
//!
//! * [`trace`] — a process-global tracer with RAII scoped spans
//!   (`crate::span!("serve.gemm", shard = 3)`), cross-thread parent
//!   links for the scoped serve pool, loadgen **virtual-time** spans,
//!   and Chrome trace-event JSON export (Perfetto /
//!   `chrome://tracing`). Disabled (the default) a span site costs one
//!   relaxed atomic load; enabled or not, spans are **annotation
//!   only** — the determinism contract (see [`crate::threads`]) says
//!   tracing may change wall-clock, never answers, counters, or
//!   replay bytes, and the obs integration tests pin exactly that at
//!   serve widths 1 and 4.
//! * [`hist`] — the shared nearest-rank [`percentile`](hist::percentile)
//!   the serving and loadgen benches previously duplicated, plus a
//!   deterministic log₂-bucketed [`LogHistogram`](hist::LogHistogram)
//!   for streaming latency aggregation.
//! * [`registry`] — [`MetricsRegistry`](registry::MetricsRegistry):
//!   one named, typed (counter/gauge) export surface snapshotting the
//!   counters the tiers already keep (`ServeStats`, `CommStats`,
//!   `TrainReport`, `SimResult`) with md/csv/json emitters.
//!
//! [`profile`] combines all three into the fig15 per-phase time/byte
//! breakdown behind the `profile` CLI command; `--trace out/trace.json`
//! on `train` / `serve-bench` / `load-bench` dumps the raw span
//! timeline instead.

pub mod hist;
pub mod profile;
pub mod registry;
pub mod trace;

pub use hist::{percentile, sort_samples, LogHistogram};
pub use profile::{PhaseRow, ProfileReport};
pub use registry::{Metric, MetricValue, MetricsRegistry};
pub use trace::{SpanGuard, SpanRecord, Trace};
