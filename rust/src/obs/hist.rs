//! Shared latency statistics: the nearest-rank percentile every bench
//! used to carry its own copy of, and a deterministic log-bucketed
//! histogram for streaming aggregation.
//!
//! Before this module, `serve/bench.rs` and `loadgen/report.rs` each
//! had a byte-identical private `percentile()` over a sorted `Vec` —
//! now both call [`percentile`] here (old-vs-new equality is pinned in
//! the tests below). The sorted-`Vec` path stays the *reporting*
//! truth: exact, and fine at bench sample counts. [`LogHistogram`] is
//! the streaming counterpart for places that cannot afford to retain
//! every sample (the profile command, long traces): pure integer
//! bucketing — power-of-two edges, so `record` is a `leading_zeros`
//! and quantiles are reproducible on every platform — at the price of
//! a ≤ 2× relative quantile error (one bucket's width).

/// Nearest-rank percentile over an **ascending-sorted** slice;
/// `p` in `[0, 1]`. Empty input yields 0 (benches report 0 for "no
/// samples"). This is bit-for-bit the logic the serving and loadgen
/// benches always used.
pub fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * p).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// Sort samples ascending for [`percentile`] (total order; NaN-free
/// inputs by construction — latencies come from clocks and counters).
pub fn sort_samples(samples: &mut [f64]) {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("latency samples are never NaN"));
}

/// Number of power-of-two buckets: bucket 0 holds exactly 0, bucket
/// `i ≥ 1` holds `[2^(i-1), 2^i)`. 64 buckets cover every `u64`.
pub const BUCKETS: usize = 65;

/// Deterministic log₂-bucketed histogram of non-negative integer
/// samples (µs in this crate). Merge-able, allocation-free recording,
/// identical results on every platform.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { counts: [0; BUCKETS], count: 0, sum: 0, max: 0 }
    }

    /// Bucket index for a value: 0 for 0, else `1 + floor(log2 v)`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive upper edge of bucket `i` (`0`, `1`, `3`, `7`, …,
    /// `2^i - 1`): the value [`quantile`](Self::quantile) reports for
    /// samples landing in that bucket.
    #[inline]
    pub fn bucket_hi(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Inclusive lower edge of bucket `i`.
    #[inline]
    pub fn bucket_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact maximum recorded value (tracked outside the buckets).
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`), reported as the upper
    /// edge of the bucket holding that rank — deterministic, within 2×
    /// of the exact sample. The rank rule mirrors [`percentile`] so
    /// the two agree on which sample they aim at.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * q).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return Self::bucket_hi(i).min(self.max);
            }
        }
        self.max
    }

    /// Non-empty buckets as `(lo, hi, count)`, ascending.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (u64, u64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (Self::bucket_lo(i), Self::bucket_hi(i), c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact function `serve/bench.rs` and `loadgen/report.rs`
    /// carried privately before the extraction — kept here verbatim as
    /// the oracle pinning old-vs-new equality.
    fn percentile_old(sorted_us: &[f64], p: f64) -> f64 {
        if sorted_us.is_empty() {
            return 0.0;
        }
        let idx = ((sorted_us.len() - 1) as f64 * p).round() as usize;
        sorted_us[idx.min(sorted_us.len() - 1)]
    }

    #[test]
    fn percentile_matches_the_old_private_copies() {
        // deterministic pseudo-random latencies, several sizes
        // including the degenerate ones
        for n in [0usize, 1, 2, 3, 7, 100, 1001] {
            let mut xs: Vec<f64> = (0..n)
                .map(|i| {
                    let h = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
                    (h % 1_000_000) as f64 / 10.0
                })
                .collect();
            sort_samples(&mut xs);
            for p in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
                assert_eq!(
                    percentile(&xs, p),
                    percentile_old(&xs, p),
                    "n={n} p={p}: extraction changed the reported percentile"
                );
            }
        }
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[42.0], 0.0), 42.0);
        assert_eq!(percentile(&[42.0], 1.0), 42.0);
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 3.0); // nearest rank rounds up here
    }

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(7), 3);
        assert_eq!(LogHistogram::bucket_of(8), 4);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), 64);
        for i in 1..64usize {
            // every bucket's own edges map back into it
            assert_eq!(LogHistogram::bucket_of(LogHistogram::bucket_lo(i)), i, "lo edge of {i}");
            assert_eq!(LogHistogram::bucket_of(LogHistogram::bucket_hi(i)), i, "hi edge of {i}");
            assert!(LogHistogram::bucket_lo(i) <= LogHistogram::bucket_hi(i));
        }
    }

    #[test]
    fn histogram_counts_mean_max_quantiles() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 2, 3, 4, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 1110);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 1110.0 / 7.0).abs() < 1e-9);
        assert_eq!(h.quantile(0.0), 0, "rank 0 is the zero sample");
        assert_eq!(h.quantile(1.0), 1000, "top quantile is clamped to the exact max");
        // quantiles are monotone in q
        let qs: Vec<u64> = [0.0, 0.25, 0.5, 0.75, 0.9, 1.0].iter().map(|&q| h.quantile(q)).collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]), "quantiles must be monotone: {qs:?}");
        // within the log-bucket guarantee: upper edge of the true
        // sample's bucket
        let h50 = h.quantile(0.5);
        assert!(h50 >= 3 && h50 <= 7, "median sample is 3, bucket hi is 3..=7, got {h50}");
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut both = LogHistogram::new();
        for i in 0..200u64 {
            let v = i * 37 % 4096;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.max(), both.max());
        for q in [0.1, 0.5, 0.99] {
            assert_eq!(a.quantile(q), both.quantile(q));
        }
        let av: Vec<_> = a.nonzero_buckets().collect();
        let bv: Vec<_> = both.nonzero_buckets().collect();
        assert_eq!(av, bv);
    }
}
