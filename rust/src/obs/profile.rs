//! Fig 15 (ours): where the time and bytes actually go.
//!
//! The paper's headline numbers — 50% communication reduction, 2×
//! convergence speedup — are attribution claims, and until now the
//! repo could only restate them as end-of-run aggregates. This report
//! folds a drained [`Trace`] into a per-phase breakdown (count, total
//! time, share of its tier, p50/p99 from a [`LogHistogram`] over span
//! durations, bytes where spans carry a `bytes` arg) and appends the
//! [`MetricsRegistry`] snapshot, in the same md/csv/json triple every
//! fig11–14 bench emits. The `profile` CLI command drives one small
//! train → serve → open-loop-replay pass with tracing on and renders
//! the result as `fig15_profile.{md,csv,json}`.

use crate::metrics::MarkdownTable;
use crate::obs::hist::LogHistogram;
use crate::obs::registry::MetricsRegistry;
use crate::obs::trace::Trace;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Aggregate of every span sharing one `(clock, tier, phase)`.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub tier: String,
    pub phase: String,
    /// `"wall"` or `"virtual"` (loadgen virtual-time spans).
    pub clock: &'static str,
    pub count: u64,
    pub total_ms: f64,
    /// This phase's fraction of its tier's total on the same clock.
    pub share: f64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: f64,
    /// Sum of the spans' `bytes` args (0 when none carry one).
    pub bytes: u64,
}

/// The fig15 report: phase table + metrics snapshot.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    pub dataset: String,
    pub rows: Vec<PhaseRow>,
    pub registry: MetricsRegistry,
    /// Spans aggregated (before the [`MAX_EVENTS`] cap's drops).
    ///
    /// [`MAX_EVENTS`]: crate::obs::trace::MAX_EVENTS
    pub span_count: usize,
    pub dropped_spans: u64,
}

fn tier_rank(t: &str) -> usize {
    match t {
        "train" => 0,
        "serve" => 1,
        "loadgen" => 2,
        _ => 3,
    }
}

impl ProfileReport {
    /// Aggregate `trace` (grouping by clock/tier/phase) and attach the
    /// already-populated `registry`.
    pub fn from_trace(dataset: &str, trace: &Trace, registry: MetricsRegistry) -> ProfileReport {
        struct Acc {
            count: u64,
            total_us: f64,
            max_us: f64,
            bytes: u64,
            hist: LogHistogram,
        }
        let mut groups: BTreeMap<(bool, String, String), Acc> = BTreeMap::new();
        for e in &trace.events {
            let key = (e.virtual_clock, e.tier().to_string(), e.phase().to_string());
            let acc = groups.entry(key).or_insert_with(|| Acc {
                count: 0,
                total_us: 0.0,
                max_us: 0.0,
                bytes: 0,
                hist: LogHistogram::new(),
            });
            acc.count += 1;
            acc.total_us += e.dur_us;
            acc.max_us = acc.max_us.max(e.dur_us);
            acc.hist.record(e.dur_us.max(0.0).round() as u64);
            for (k, v) in &e.args {
                if *k == "bytes" && *v > 0 {
                    acc.bytes += *v as u64;
                }
            }
        }
        // tier totals per clock, for the share column
        let mut tier_total: BTreeMap<(bool, String), f64> = BTreeMap::new();
        for ((vc, tier, _), acc) in &groups {
            *tier_total.entry((*vc, tier.clone())).or_insert(0.0) += acc.total_us;
        }
        let mut rows: Vec<PhaseRow> = groups
            .into_iter()
            .map(|((vc, tier, phase), acc)| {
                let tt = tier_total.get(&(vc, tier.clone())).copied().unwrap_or(0.0);
                PhaseRow {
                    clock: if vc { "virtual" } else { "wall" },
                    share: if tt > 0.0 { acc.total_us / tt } else { 0.0 },
                    mean_us: if acc.count > 0 { acc.total_us / acc.count as f64 } else { 0.0 },
                    p50_us: acc.hist.quantile(0.50),
                    p99_us: acc.hist.quantile(0.99),
                    max_us: acc.max_us,
                    total_ms: acc.total_us / 1e3,
                    count: acc.count,
                    bytes: acc.bytes,
                    tier,
                    phase,
                }
            })
            .collect();
        rows.sort_by(|a, b| {
            (a.clock == "virtual")
                .cmp(&(b.clock == "virtual"))
                .then(tier_rank(&a.tier).cmp(&tier_rank(&b.tier)))
                .then(b.total_ms.partial_cmp(&a.total_ms).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.phase.cmp(&b.phase))
        });
        ProfileReport {
            dataset: dataset.to_string(),
            rows,
            registry,
            span_count: trace.events.len(),
            dropped_spans: trace.dropped,
        }
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!(
            "# Fig 15 — per-phase time/byte profile ({})\n\n{} spans aggregated{}.\n\n\
             Wall rows are RAII scopes (`Instant`); virtual rows are the load\n\
             generator's virtual-time annotations. `share` is the phase's\n\
             fraction of its tier's total on the same clock; p50/p99 come from\n\
             the deterministic log-bucketed histogram (≤ 2× bucket error).\n\n",
            self.dataset,
            self.span_count,
            if self.dropped_spans > 0 {
                format!(" ({} dropped past the event cap)", self.dropped_spans)
            } else {
                String::new()
            }
        );
        let mut t = MarkdownTable::new(&[
            "tier", "phase", "clock", "count", "total_ms", "share", "mean_us", "p50_us", "p99_us",
            "max_us", "bytes",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.tier.clone(),
                r.phase.clone(),
                r.clock.to_string(),
                r.count.to_string(),
                format!("{:.3}", r.total_ms),
                format!("{:.1}%", r.share * 100.0),
                format!("{:.1}", r.mean_us),
                r.p50_us.to_string(),
                r.p99_us.to_string(),
                format!("{:.1}", r.max_us),
                r.bytes.to_string(),
            ]);
        }
        s.push_str(&t.render());
        s.push_str("\n## Counter snapshot\n\n");
        s.push_str(&self.registry.to_markdown());
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("tier,phase,clock,count,total_ms,share,mean_us,p50_us,p99_us,max_us,bytes\n");
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{},{},{:.3},{:.4},{:.1},{},{},{:.1},{}",
                r.tier,
                r.phase,
                r.clock,
                r.count,
                r.total_ms,
                r.share,
                r.mean_us,
                r.p50_us,
                r.p99_us,
                r.max_us,
                r.bytes
            );
        }
        s
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        let _ = writeln!(s, "  \"figure\": \"fig15_profile\",");
        let _ = writeln!(s, "  \"dataset\": \"{}\",", self.dataset);
        let _ = writeln!(s, "  \"span_count\": {},", self.span_count);
        let _ = writeln!(s, "  \"dropped_spans\": {},", self.dropped_spans);
        s.push_str("  \"phases\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"tier\": \"{}\", \"phase\": \"{}\", \"clock\": \"{}\", \"count\": {}, \
                 \"total_ms\": {:.3}, \"share\": {:.4}, \"mean_us\": {:.1}, \"p50_us\": {}, \
                 \"p99_us\": {}, \"max_us\": {:.1}, \"bytes\": {}}}",
                r.tier,
                r.phase,
                r.clock,
                r.count,
                r.total_ms,
                r.share,
                r.mean_us,
                r.p50_us,
                r.p99_us,
                r.max_us,
                r.bytes
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n  \"metrics\": ");
        // registry.to_json() is a complete array; indent is cosmetic
        s.push_str(&self.registry.to_json());
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanRecord;

    fn span(name: &'static str, vc: bool, dur_us: f64, bytes: Option<i64>) -> SpanRecord {
        SpanRecord {
            name,
            id: 0,
            parent: None,
            tid: 1,
            start_us: 0.0,
            dur_us,
            virtual_clock: vc,
            args: bytes.map(|b| vec![("bytes", b)]).unwrap_or_default(),
        }
    }

    #[test]
    fn aggregates_by_phase_with_shares_and_bytes() {
        let trace = Trace {
            events: vec![
                span("serve.gemm", false, 300.0, None),
                span("serve.gemm", false, 100.0, None),
                span("serve.gather", false, 100.0, Some(4096)),
                span("train.epoch", false, 1000.0, None),
                span("loadgen.service", true, 50.0, None),
            ],
            thread_labels: vec![],
            dropped: 0,
        };
        let rep = ProfileReport::from_trace("tiny", &trace, MetricsRegistry::new());
        assert_eq!(rep.span_count, 5);
        assert_eq!(rep.rows.len(), 4);
        // ordering: wall (train, serve by total desc) then virtual
        assert_eq!(rep.rows[0].tier, "train");
        assert_eq!(rep.rows[1].phase, "gemm");
        assert_eq!(rep.rows[2].phase, "gather");
        assert_eq!(rep.rows[3].clock, "virtual");
        let gemm = &rep.rows[1];
        assert_eq!(gemm.count, 2);
        assert!((gemm.total_ms - 0.4).abs() < 1e-9);
        assert!((gemm.share - 0.8).abs() < 1e-9, "gemm is 400 of serve's 500µs");
        assert!((gemm.mean_us - 200.0).abs() < 1e-9);
        let gather = &rep.rows[2];
        assert_eq!(gather.bytes, 4096);
        let md = rep.to_markdown();
        assert!(md.contains("| serve | gemm | wall | 2 |"));
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 1 + 4);
        let json = rep.to_json();
        assert!(json.contains("\"figure\": \"fig15_profile\""));
        assert!(json.contains("\"phase\": \"gemm\""));
        assert!(json.contains("\"metrics\": ["));
    }

    #[test]
    fn empty_trace_yields_empty_but_valid_report() {
        let rep = ProfileReport::from_trace("tiny", &Trace::default(), MetricsRegistry::new());
        assert!(rep.rows.is_empty());
        assert!(rep.to_csv().lines().count() == 1);
        assert!(rep.to_json().contains("\"phases\": [\n  ]"));
    }
}
