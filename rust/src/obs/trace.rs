//! RAII scoped spans with Chrome trace-event export.
//!
//! The tracer is a process-global, same as the thread budget in
//! [`crate::threads`], and for the same reason: it obeys the
//! **determinism contract**. Spans are *annotation only* — they record
//! where wall-clock time went, never influence it being spent. Turning
//! tracing on or off changes no answer, no counter, and no replay
//! byte; the integration tests pin that at serve widths 1 and 4.
//! That is what makes a global with interior mutability safe here
//! where a result-affecting global would not be.
//!
//! Cost model: with tracing **disabled** (the default), every span
//! site is one relaxed atomic load plus building a small stack array
//! of argument pairs — no clock read, no allocation, no lock. Enabled,
//! a span costs two `Instant` reads and one short `Mutex` push at
//! drop. Span sites are placed at batch/phase granularity (a flush, a
//! GEMM over a micro-batch, a consensus round), never per node or per
//! row, so even enabled tracing stays out of inner loops.
//!
//! Two clocks share one trace file:
//!
//! * **wall spans** ([`SpanGuard`], the [`span!`](crate::span) macro) —
//!   RAII scopes timed with `Instant`, carrying thread id and the
//!   enclosing span on the same thread (or an explicit cross-thread
//!   parent via [`SpanGuard::enter_under`]) — exported under `pid 1`.
//! * **virtual spans** ([`virtual_span`]) — explicit `(start, dur)` in
//!   the load generator's virtual µs, one Chrome track per shard/queue
//!   — exported under `pid 2` so Perfetto draws the virtual timeline
//!   on its own process lane.
//!
//! Export is the Chrome trace-event JSON format (`"ph":"X"` complete
//! events + `"ph":"M"` thread/process-name metadata), loadable in
//! Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`,
//! hand-rolled like every other JSON emitter in this crate.

use std::cell::{Cell, RefCell};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Hard cap on buffered span records: beyond it the overflow policy
/// kicks in ([`set_ring_mode`]), so a pathological run degrades the
/// *trace*, never the process.
pub const MAX_EVENTS: usize = 1_000_000;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// Overflow policy: false (default) keeps the *oldest* spans — the
/// trace shows how the run started; true keeps the *newest* — the
/// trace shows how it ended (what you want when diagnosing a tail
/// slowdown hours into a run).
static RING_MODE: AtomicBool = AtomicBool::new(false);
/// Test hook: 0 means [`MAX_EVENTS`]; tests shrink it to exercise the
/// overflow paths without allocating a million records.
static CAPACITY: AtomicUsize = AtomicUsize::new(0);
/// Span storage plus the ring cursor: `start` is the index of the
/// logically-oldest record once ring mode has wrapped (0 otherwise).
/// One struct under one Mutex so cursor and buffer can never drift.
struct EventBuf {
    buf: Vec<SpanRecord>,
    start: usize,
}
// annotation-only global (see module docs): spans never feed answers
static EVENTS: Mutex<EventBuf> = Mutex::new(EventBuf { buf: Vec::new(), start: 0 });
static THREAD_LABELS: Mutex<Vec<(u64, String)>> = Mutex::new(Vec::new());

/// The instant all wall-span timestamps are relative to (first use).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    /// Small dense per-thread id for the Chrome `tid` field (0 = not
    /// yet assigned). `std::thread::ThreadId` is opaque; this stays a
    /// readable integer.
    static TID: Cell<u64> = Cell::new(0);
    /// Open spans on this thread; the top is the next span's parent.
    static STACK: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

fn current_tid() -> u64 {
    TID.with(|t| {
        let mut v = t.get();
        if v == 0 {
            v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(v);
        }
        v
    })
}

fn lock_events() -> MutexGuard<'static, EventBuf> {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner())
}

fn capacity() -> usize {
    match CAPACITY.load(Ordering::Relaxed) {
        0 => MAX_EVENTS,
        n => n,
    }
}

/// Select the buffer-full policy: `false` (default) drops *new* spans
/// past the cap, keeping the run's beginning; `true` overwrites the
/// *oldest*, keeping its end. Either way [`Trace::dropped`] counts the
/// casualties. Annotation-only like the rest of the tracer — the
/// policy changes which spans survive, never any answer byte.
pub fn set_ring_mode(on: bool) {
    RING_MODE.store(on, Ordering::SeqCst);
}

/// Current overflow policy (true = keep newest).
pub fn is_ring_mode() -> bool {
    RING_MODE.load(Ordering::Relaxed)
}

/// Test hook: shrink the buffer cap to exercise overflow without a
/// million allocations. `0` restores [`MAX_EVENTS`]. Takes effect for
/// spans recorded after the call; pair with [`exclusive`] in tests.
pub fn set_capacity_for_tests(n: usize) {
    CAPACITY.store(n, Ordering::SeqCst);
}

fn lock_labels() -> MutexGuard<'static, Vec<(u64, String)>> {
    THREAD_LABELS.lock().unwrap_or_else(|e| e.into_inner())
}

/// Start capturing spans. Idempotent.
pub fn enable() {
    epoch(); // pin the time origin before the first span reads it
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop capturing spans (already-open guards still record on drop).
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Whether span sites record. One relaxed load — the entire cost of a
/// span site while tracing is off.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Label the calling thread for the trace (Chrome `thread_name`
/// metadata). No-op while disabled; first label per thread wins.
pub fn set_thread_label(label: &str) {
    if !is_enabled() {
        return;
    }
    let tid = current_tid();
    let mut labels = lock_labels();
    if labels.iter().any(|(t, _)| *t == tid) {
        return;
    }
    labels.push((tid, label.to_string()));
}

/// Like [`set_thread_label`] but the label is only built when tracing
/// is actually on — call sites avoid a `format!` on the disabled path.
pub fn set_thread_label_with(f: impl FnOnce() -> String) {
    if !is_enabled() {
        return;
    }
    let label = f();
    set_thread_label(&label);
}

/// One finished span.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Dotted `tier.phase` name, e.g. `"serve.gemm"`.
    pub name: &'static str,
    pub id: u64,
    pub parent: Option<u64>,
    /// Chrome `tid`: dense thread id for wall spans, caller-chosen
    /// track for virtual spans.
    pub tid: u64,
    /// µs since the tracer epoch (wall) or virtual µs (loadgen).
    pub start_us: f64,
    pub dur_us: f64,
    /// True for loadgen virtual-time spans (exported under `pid 2`).
    pub virtual_clock: bool,
    pub args: Vec<(&'static str, i64)>,
}

impl SpanRecord {
    /// Tier = the dotted prefix (`"serve"` for `"serve.gemm"`).
    pub fn tier(&self) -> &'static str {
        self.name.split_once('.').map(|(t, _)| t).unwrap_or("misc")
    }

    /// Phase = the part after the tier (`"gemm"` for `"serve.gemm"`).
    pub fn phase(&self) -> &'static str {
        self.name.split_once('.').map(|(_, p)| p).unwrap_or(self.name)
    }
}

fn record(r: SpanRecord) {
    let cap = capacity();
    let mut ev = lock_events();
    if ev.buf.len() < cap {
        ev.buf.push(r);
        return;
    }
    DROPPED.fetch_add(1, Ordering::Relaxed);
    if RING_MODE.load(Ordering::Relaxed) {
        // overwrite the logically-oldest slot and advance the cursor;
        // modulo the *actual* length so a cap shrunk mid-run (test
        // hook) still indexes in bounds
        let len = ev.buf.len();
        let slot = ev.start % len;
        ev.buf[slot] = r;
        ev.start = (slot + 1) % len;
    }
}

/// RAII scope: records a span from construction to drop. Prefer the
/// [`span!`](crate::span) macro. An inert guard (tracing disabled at
/// construction) does nothing on drop.
pub struct SpanGuard {
    id: u64, // 0 = inert
    name: &'static str,
    parent: Option<u64>,
    tid: u64,
    start: Option<Instant>,
    start_us: f64,
    args: Vec<(&'static str, i64)>,
}

impl SpanGuard {
    /// Open a span; parent = the innermost open span on this thread.
    #[inline]
    pub fn enter(name: &'static str, args: &[(&'static str, i64)]) -> SpanGuard {
        Self::enter_under(name, None, args)
    }

    /// Open a span under an explicit parent id — the cross-thread
    /// link: a scoped worker passes the dispatching span's
    /// [`id`](Self::id) so the trace nests flushes under their wave.
    pub fn enter_under(
        name: &'static str,
        parent: Option<u64>,
        args: &[(&'static str, i64)],
    ) -> SpanGuard {
        if !is_enabled() {
            return SpanGuard {
                id: 0,
                name,
                parent: None,
                tid: 0,
                start: None,
                start_us: 0.0,
                args: Vec::new(),
            };
        }
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let tid = current_tid();
        let parent =
            parent.filter(|&p| p != 0).or_else(|| STACK.with(|s| s.borrow().last().copied()));
        STACK.with(|s| s.borrow_mut().push(id));
        let now = Instant::now();
        let start_us = now.saturating_duration_since(epoch()).as_secs_f64() * 1e6;
        SpanGuard { id, name, parent, tid, start: Some(now), start_us, args: args.to_vec() }
    }

    /// This span's id (0 when inert) — pass to [`Self::enter_under`]
    /// from another thread.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// False when the guard was created with tracing disabled.
    pub fn is_active(&self) -> bool {
        self.id != 0
    }

    /// Set (or overwrite) an arg after the span opened — for values
    /// only known mid-span, e.g. the bytes a gather phase ends up
    /// billing to the CommLedger. No-op on an inert guard.
    pub fn set_arg(&mut self, key: &'static str, value: i64) {
        if self.id == 0 {
            return;
        }
        match self.args.iter_mut().find(|(k, _)| *k == key) {
            Some(slot) => slot.1 = value,
            None => self.args.push((key, value)),
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        let dur_us = self.start.map(|s| s.elapsed().as_secs_f64() * 1e6).unwrap_or(0.0);
        STACK.with(|s| {
            let mut st = s.borrow_mut();
            // well-nested drops pop the top; out-of-order drop (guards
            // moved across scopes) still removes the right entry
            match st.last() {
                Some(&top) if top == self.id => {
                    st.pop();
                }
                _ => {
                    if let Some(pos) = st.iter().rposition(|&x| x == self.id) {
                        st.remove(pos);
                    }
                }
            }
        });
        record(SpanRecord {
            name: self.name,
            id: self.id,
            parent: self.parent,
            tid: self.tid,
            start_us: self.start_us,
            dur_us,
            virtual_clock: false,
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Record a **virtual-time** span (loadgen): explicit start/duration
/// in virtual µs on a caller-chosen `track` (Chrome `tid` under
/// `pid 2` — e.g. one track per shard). No nesting stack; virtual
/// spans are parentless timeline annotations.
pub fn virtual_span(name: &'static str, track: u64, start_us: u64, dur_us: u64, args: &[(&'static str, i64)]) {
    if !is_enabled() {
        return;
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    record(SpanRecord {
        name,
        id,
        parent: None,
        tid: track,
        start_us: start_us as f64,
        dur_us: dur_us as f64,
        virtual_clock: true,
        args: args.to_vec(),
    });
}

/// Open a wall-clock span. Name is dotted `tier.phase`; optional
/// `key = integer` args ride into the Chrome `args` object:
///
/// ```ignore
/// let _s = crate::span!("serve.gemm", shard = 3, rows = n);
/// ```
///
/// Binds the guard — `let _s = span!(...)`, never `let _ =` (which
/// drops immediately).
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::SpanGuard::enter($name, &[])
    };
    ($name:expr, $($k:ident = $v:expr),+ $(,)?) => {
        $crate::obs::trace::SpanGuard::enter($name, &[$((stringify!($k), ($v) as i64)),+])
    };
}

/// Everything captured since the last drain.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<SpanRecord>,
    /// `(tid, label)` pairs registered via [`set_thread_label`].
    pub thread_labels: Vec<(u64, String)>,
    /// Spans lost to the buffer cap: new spans discarded in the
    /// default policy, oldest spans overwritten in ring mode.
    pub dropped: u64,
}

impl Trace {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Spans whose dotted name equals `name`.
    pub fn count_named(&self, name: &str) -> usize {
        self.events.iter().filter(|e| e.name == name).count()
    }

    /// Distinct tiers present (sorted, deduped) — the three-tier
    /// acceptance check reads this.
    pub fn tiers(&self) -> Vec<&'static str> {
        let mut t: Vec<&'static str> = self.events.iter().map(|e| e.tier()).collect();
        t.sort_unstable();
        t.dedup();
        t
    }

    /// Chrome trace-event JSON (object form with `traceEvents`).
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::with_capacity(128 + self.events.len() * 96);
        s.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
        let mut first = true;
        let mut push = |s: &mut String, ev: String| {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            s.push_str(&ev);
        };
        // process lanes: wall clock vs the loadgen virtual clock
        push(&mut s, meta_event("process_name", 1, 0, "wall clock"));
        if self.events.iter().any(|e| e.virtual_clock) {
            push(&mut s, meta_event("process_name", 2, 0, "virtual time (loadgen)"));
        }
        for (tid, label) in &self.thread_labels {
            push(&mut s, meta_event("thread_name", 1, *tid, label));
        }
        for e in &self.events {
            let mut ev = String::with_capacity(96);
            let pid = if e.virtual_clock { 2 } else { 1 };
            let _ = write!(
                ev,
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"id\":{}",
                escape_json(e.name),
                escape_json(e.tier()),
                pid,
                e.tid,
                e.start_us,
                e.dur_us,
                e.id
            );
            if let Some(p) = e.parent {
                let _ = write!(ev, ",\"parent\":{p}");
            }
            for (k, v) in &e.args {
                let _ = write!(ev, ",\"{}\":{}", escape_json(k), v);
            }
            ev.push_str("}}");
            push(&mut s, ev);
        }
        s.push_str("\n]");
        if self.dropped > 0 {
            let _ = write!(s, ",\"droppedSpans\":{}", self.dropped);
        }
        s.push_str("}\n");
        s
    }
}

fn meta_event(name: &str, pid: u64, tid: u64, label: &str) -> String {
    format!(
        "{{\"name\":\"{}\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\"args\":{{\"name\":\"{}\"}}}}",
        name,
        pid,
        tid,
        escape_json(label)
    )
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Take everything captured so far and clear the buffers. The span-id
/// counter is *not* reset, so ids stay unique across drains.
pub fn drain() -> Trace {
    let events = {
        let mut ev = lock_events();
        let start = ev.start;
        ev.start = 0;
        let mut buf = std::mem::take(&mut ev.buf);
        // a wrapped ring stores oldest-at-`start`; rotate so callers
        // always see chronological order regardless of policy
        if start > 0 && !buf.is_empty() {
            buf.rotate_left(start % buf.len());
        }
        buf
    };
    let thread_labels = std::mem::take(&mut *lock_labels());
    let dropped = DROPPED.swap(0, Ordering::Relaxed);
    Trace { events, thread_labels, dropped }
}

/// Serialise tests (and only tests) that toggle the global tracer —
/// `cargo test` runs tests on concurrent threads, and two tests
/// enabling/draining the same global would capture each other's spans.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert_and_records_nothing() {
        let _x = exclusive();
        disable();
        drain(); // flush anything a prior holder left
        {
            let g = crate::span!("serve.gemm", shard = 3);
            assert!(!g.is_active(), "guard must be inert while disabled");
            assert_eq!(g.id(), 0);
            virtual_span("loadgen.service", 0, 10, 5, &[]);
            set_thread_label("should-not-register");
        }
        let t = drain();
        assert!(t.is_empty(), "disabled tracer captured {} spans", t.events.len());
        assert!(t.thread_labels.is_empty());
        assert_eq!(t.dropped, 0);
    }

    #[test]
    fn nesting_links_parents_on_one_thread() {
        let _x = exclusive();
        drain();
        enable();
        let (outer_id, inner_id);
        {
            let outer = crate::span!("train.epoch", epoch = 1);
            outer_id = outer.id();
            {
                let inner = crate::span!("train.round", round = 2);
                inner_id = inner.id();
                assert_ne!(inner_id, outer_id);
            }
        }
        disable();
        let t = drain();
        // inner dropped first, so it is recorded first
        let inner = t.events.iter().find(|e| e.id == inner_id).expect("inner recorded");
        let outer = t.events.iter().find(|e| e.id == outer_id).expect("outer recorded");
        assert_eq!(inner.parent, Some(outer_id), "inner span must point at its encloser");
        assert_eq!(outer.parent, None);
        assert_eq!(inner.name, "train.round");
        assert_eq!(inner.tier(), "train");
        assert_eq!(inner.phase(), "round");
        assert_eq!(inner.args, vec![("round", 2i64)]);
        assert!(outer.dur_us >= inner.dur_us, "encloser lasts at least as long");
        assert_eq!(t.tiers(), vec!["train"]);
    }

    #[test]
    fn explicit_parent_wins_over_stack() {
        let _x = exclusive();
        drain();
        enable();
        let wave = crate::span!("serve.flush_wave", n = 2);
        let wave_id = wave.id();
        // what a scoped worker does: link to the wave by id, not stack
        let child = SpanGuard::enter_under("serve.shard_flush", Some(wave_id), &[("shard", 1)]);
        let child_id = child.id();
        drop(child);
        drop(wave);
        disable();
        let t = drain();
        let child = t.events.iter().find(|e| e.id == child_id).unwrap();
        assert_eq!(child.parent, Some(wave_id));
    }

    #[test]
    fn chrome_export_shape_and_virtual_lane() {
        let _x = exclusive();
        drain();
        enable();
        set_thread_label_with(|| "unit-test-thread".to_string());
        {
            let _g = crate::span!("serve.gemm", shard = 0);
        }
        virtual_span("loadgen.service", 3, 100, 40, &[("batch", 4)]);
        disable();
        let t = drain();
        assert_eq!(t.count_named("serve.gemm"), 1);
        assert_eq!(t.count_named("loadgen.service"), 1);
        let json = t.to_chrome_json();
        assert!(json.starts_with("{\"displayTimeUnit\""));
        assert!(json.contains("\"traceEvents\":["));
        assert!(json.contains("\"name\":\"serve.gemm\""));
        assert!(json.contains("\"cat\":\"loadgen\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"pid\":2"), "virtual span must land on the virtual lane");
        assert!(json.contains("virtual time (loadgen)"));
        assert!(json.contains("unit-test-thread"));
        assert!(json.contains("\"batch\":4"));
        assert!(json.trim_end().ends_with('}'));
        // crude but effective structural check without a JSON parser:
        // braces and brackets balance, quotes pair up
        let balance = |open: char, close: char| {
            json.chars().filter(|&c| c == open).count() == json.chars().filter(|&c| c == close).count()
        };
        assert!(balance('{', '}'), "unbalanced braces");
        assert!(balance('[', ']'), "unbalanced brackets");
        assert_eq!(json.matches('"').count() % 2, 0, "unpaired quotes");
    }

    /// Emit `n` instant virtual spans tagged `seq = 0..n` so overflow
    /// tests can tell exactly which records survived.
    fn emit_numbered(n: i64) {
        for i in 0..n {
            virtual_span("loadgen.service", 0, i as u64, 1, &[("seq", i)]);
        }
    }

    fn seqs(t: &Trace) -> Vec<i64> {
        t.events.iter().map(|e| e.args[0].1).collect()
    }

    #[test]
    fn default_overflow_keeps_oldest_and_counts_drops() {
        let _x = exclusive();
        drain();
        set_capacity_for_tests(4);
        set_ring_mode(false);
        enable();
        emit_numbered(7);
        disable();
        let t = drain();
        set_capacity_for_tests(0);
        assert_eq!(seqs(&t), vec![0, 1, 2, 3], "head of the run survives");
        assert_eq!(t.dropped, 3, "three spans past the cap were discarded");
    }

    #[test]
    fn ring_overflow_keeps_newest_in_chronological_order() {
        let _x = exclusive();
        drain();
        set_capacity_for_tests(4);
        set_ring_mode(true);
        enable();
        emit_numbered(7);
        disable();
        let t = drain();
        set_ring_mode(false);
        set_capacity_for_tests(0);
        assert_eq!(seqs(&t), vec![3, 4, 5, 6], "tail of the run survives, oldest-first");
        assert_eq!(t.dropped, 3, "three overwritten spans are counted");
        // drain reset the cursor: the next capture starts clean
        enable();
        emit_numbered(2);
        disable();
        assert_eq!(seqs(&drain()), vec![0, 1]);
    }

    #[test]
    fn ring_below_capacity_behaves_identically_to_default() {
        let _x = exclusive();
        drain();
        set_capacity_for_tests(8);
        set_ring_mode(true);
        enable();
        emit_numbered(5);
        disable();
        let t = drain();
        set_ring_mode(false);
        set_capacity_for_tests(0);
        assert_eq!(seqs(&t), vec![0, 1, 2, 3, 4]);
        assert_eq!(t.dropped, 0);
    }

    #[test]
    fn virtual_spans_keep_exact_timestamps() {
        let _x = exclusive();
        drain();
        enable();
        virtual_span("loadgen.queueing", 101, 250, 17, &[]);
        disable();
        let t = drain();
        let e = &t.events[0];
        assert!(e.virtual_clock);
        assert_eq!(e.start_us, 250.0);
        assert_eq!(e.dur_us, 17.0);
        assert_eq!(e.tid, 101, "caller-chosen track is the tid");
    }
}
