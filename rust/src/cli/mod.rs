//! `gad` command-line interface (hand-rolled — clap is not in the
//! offline registry).
//!
//! ```text
//! gad <command> [--flag value] [--switch]
//!
//! commands:
//!   stats                     Table 1 dataset statistics
//!   partition                 partition quality report
//!   augment                   augmentation report for one dataset
//!   train                     one training run (gad or a baseline)
//!   table2 table3 table4      regenerate the paper's tables
//!   fig5 fig6 fig7 fig8 fig9  regenerate the paper's figures (CSV)
//!   all                       every table + figure (writes results/)
//! ```

pub mod experiments;

use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `args` (excluding argv[0]).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // `--flag value` or bare `--switch`
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else if out.cmd.is_empty() {
                out.cmd = a.clone();
                i += 1;
            } else {
                return Err(anyhow!("unexpected positional argument '{a}'"));
            }
        }
        Ok(out)
    }

    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flags.get(name).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants a number, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Shared experiment options extracted from flags.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub seed: u64,
    pub fast: bool,
    pub out_dir: String,
    pub backend: crate::backend::BackendKind,
    pub artifact_dir: String,
}

impl RunOpts {
    pub fn from_args(args: &Args) -> Result<RunOpts> {
        Ok(RunOpts {
            seed: args.get_usize("seed", 42)? as u64,
            fast: args.has("fast"),
            out_dir: args.get("out-dir", "results").to_string(),
            backend: args.get("backend", "native").parse().map_err(|e: String| anyhow!(e))?,
            artifact_dir: args.get("artifacts", "artifacts").to_string(),
        })
    }

    /// Dataset size scale: fast mode shrinks everything 8x.
    pub fn scale(&self) -> f64 {
        if self.fast {
            0.125
        } else {
            1.0
        }
    }

    /// Epoch budget scale.
    pub fn epochs(&self, full: usize) -> usize {
        if self.fast {
            (full / 5).max(5)
        } else {
            full
        }
    }
}

/// Top-level dispatch; returns process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let opts = RunOpts::from_args(&args)?;
    match args.cmd.as_str() {
        "stats" => experiments::table1_stats(&args, &opts),
        "partition" => experiments::partition_report(&args, &opts),
        "augment" => experiments::augment_report(&args, &opts),
        "train" => experiments::train_once(&args, &opts),
        "table2" => experiments::table2_accuracy(&args, &opts),
        "table3" => experiments::table3_stability(&args, &opts),
        "table4" => experiments::table4_augmentation(&args, &opts),
        "fig5" => experiments::fig5_curves(&args, &opts),
        "fig6" => experiments::fig6_time(&args, &opts),
        "fig7" => experiments::fig7_scaling(&args, &opts),
        "fig8" => experiments::fig8_partitions(&args, &opts),
        "fig9" => experiments::fig9_consensus(&args, &opts),
        "serve-bench" => experiments::serve_bench(&args, &opts),
        "load-bench" => experiments::load_bench(&args, &opts),
        "profile" => experiments::profile(&args, &opts),
        "kernel-bench" => experiments::kernel_bench(&args, &opts),
        "ablate" => experiments::ablation(&args, &opts),
        "all" => experiments::run_all(&args, &opts),
        "" | "help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{HELP}")),
    }
}

const HELP: &str = "\
gad — Graph-Augmentation-based Distributed GCN (paper reproduction)

usage: gad <command> [flags]

commands
  stats       Table 1 dataset statistics
  partition   partition quality (edge cut, balance) for one dataset
  augment     augmentation report (replicas, traffic) for one dataset
  train       one training run
  table2      accuracy of the 7 methods on the 4 datasets
  table3      accuracy stability across workers x layers (pubmed)
  table4      augmentation impact: accuracy / memory / comm
  fig5        accuracy-vs-epoch curves (CSV per dataset)
  fig6        convergence-time comparison
  fig7        training time vs workers x layers
  fig8        loss convergence vs partition count, aug on/off
  fig9        weighted vs plain consensus loss curves
  serve-bench train -> checkpoint -> serve: p50/p99 latency + QPS for
              cached / cold / unsharded serving (Fig 11, ours), then
              deltas/sec + p99 under churn, incremental vs rebuild
              (Fig 12, ours), then skewed elastic inserts with the
              online rebalancer on/off (Fig 13, ours)
  load-bench  open-loop load generator vs the serving tier: sweep the
              offered rate, fifo vs SLO-aware micro-batch scheduling,
              goodput + latency percentiles until the knee (Fig 14,
              ours)
  profile     train -> serve burst -> open-loop replay with the tracer
              on; per-phase time/byte table + unified counter snapshot
              across all three tiers (Fig 15, ours)
  kernel-bench raw-speed kernels: packed register-blocked GEMM,
              panelled gradient transposes and nnz-balanced SpMM vs
              the retained seed-era reference kernels on identical
              inputs — GFLOP/s + speedup, bit-identity asserted
              before timing (Fig 16, ours)
  ablate      design-choice ablations (+ crash-fault run)
  all         everything above into --out-dir

common flags
  --dataset <cora|pubmed|flickr|reddit|tiny>   (default cora)
  --method  <gcn|sage|clustergcn|saint-node|saint-edge|saint-rw|gad>
  --workers N --partitions N --layers N --hidden N --epochs N
  --lr F --alpha F --seed N --backend <native|xla> --artifacts DIR
  --consensus <plain|weighted|async> --no-augment
  --fast         8x-smaller datasets, 5x fewer epochs
  --out-dir DIR  where results/*.md and *.csv land (default results)
  --trace FILE   (train / serve-bench / load-bench / profile) record
                 scoped spans and write Chrome trace-event JSON to
                 FILE on exit — open in Perfetto or chrome://tracing.
                 Annotation only: answers and counters are bit-
                 identical with tracing on or off
  --trace-ring   (with --trace) when the span buffer fills, overwrite
                 the oldest spans instead of dropping new ones — the
                 trace shows how the run *ended* rather than how it
                 started; dropped-span count is reported either way

async consensus flags (with --consensus async)
  --staleness N  hard staleness bound s: older gradients are dropped
                 and the laggard re-synced (default 2)
  --quorum N     contributions per consensus update; 0 = all alive
                 workers (default 0)
  --lambda F     staleness decay: weight = zeta * lambda^staleness
                 (default 0.5)
  --plain-weights  base weight 1 instead of zeta (Eq. 11 rule)

serve-bench flags
  --shards N     serving shards (default 4)
  --queries N    queries per mode (default 2000; 400 with --fast)
  --batch N      micro-batch size for the sharded modes (default 32)
  --halo-alpha F > 0 switches the halo to Algorithm 1's budgeted
                 replicas; 0 = exact L-hop halo (default). Distinct
                 from --alpha, the training augmentation coefficient
  --gather       budgeted halos answer exactly by gathering missing
                 rows from their home shards (bytes accounted)
  --cache-budget-mb F  per-shard cap on retained cache rows; evicts
                 lowest Monte-Carlo importance I(v) first (0 = off)
  --gather-cache-mb F  cross-request gathered-row cache budget (gather
                 mode; same I(v) admission; 0 = off)
  --adaptive-compaction  tune the overlay compaction threshold from
                 the modelled splice-vs-flat read cost (Fig 12)
  --churn-rounds N   Fig 12 rounds per churn rate (default 6; 3 fast)
  --churn-queries N  Fig 12/13 queries per round (default 192; 64 fast)
  --rebalance-rounds N   Fig 13 skewed-insert rounds (default 8; 4 fast)
  --rebalance-inserts N  Fig 13 inserts per round (default 24; 12 fast)
  --rebalance-ratio F    Fig 13 max/min part-size trigger (default 1.5)
  --serve-threads N  serve-pool width: shard batches flush on N scoped
                 threads; adds a parallel-sharded row to Fig 11.
                 1 = sequential, 0 = auto (budget-capped); answers are
                 bit-identical at every width (default 1)

load-bench flags
  --shards N     serving shards (default 4)
  --slo-ms F     answer deadline in milliseconds (default 5.0)
  --batch-k N    SLO batcher's per-shard flush size (default 16)
  --zipf-s F     query popularity skew exponent (default 0.9)
  --churn-frac F fraction of arrivals that are graph deltas
                 (default 0.02)
  --load-events N  arrivals per offered-rate step (default 2000;
                 400 with --fast)
  --rate-qps F   first offered rate of the sweep; 0 = auto-calibrate
                 to 1/4 of the closed-loop capacity (default 0)
  --rate-steps N doublings to sweep (default 6; 4 with --fast)
  --serve-threads N  serve-pool width for the headline rows; > 1 also
                 replays every step at width 1 for the wall-clock
                 speedup column. 1 = sequential, 0 = auto (default 1)

kernel-bench flags
  --warmup N --samples N  timing repetitions (default 1 warmup,
                 5 samples; 3 samples with --fast, which also shrinks
                 the shapes); writes fig16_kernels.{md,csv,json}

profile flags
  --queries N    serve-burst queries (default 512; 128 with --fast)
  --load-events N  replay arrivals (default 1000; 200 with --fast)
  --rate-qps F   replay offered rate in QPS (default 2000)
  plus the load-bench --shards/--slo-ms/--batch-k/--zipf-s/
  --churn-frac and training flags; writes fig15_profile.{md,csv,json}
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_command_flags_switches() {
        let a = Args::parse(&argv("train --dataset cora --fast --epochs 10")).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("dataset", "x"), "cora");
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 10);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn parse_rejects_double_positional() {
        assert!(Args::parse(&argv("train extra")).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("stats")).unwrap();
        let o = RunOpts::from_args(&a).unwrap();
        assert_eq!(o.seed, 42);
        assert!(!o.fast);
        assert_eq!(o.scale(), 1.0);
    }

    #[test]
    fn fast_scales() {
        let a = Args::parse(&argv("stats --fast")).unwrap();
        let o = RunOpts::from_args(&a).unwrap();
        assert_eq!(o.scale(), 0.125);
        assert_eq!(o.epochs(100), 20);
    }
}
