//! Experiment drivers: one function per paper table/figure. Each
//! prints a markdown/CSV rendition of the corresponding result and
//! writes it under `--out-dir` for EXPERIMENTS.md.

use super::{Args, RunOpts};
use crate::augment::{augment_all, AugmentConfig};
use crate::baselines::{train_method, Method};
use crate::coordinator::{train_gad, ConsensusMode, TrainConfig, TrainReport};
use crate::datasets::Dataset;
use crate::metrics::{write_result_file, MarkdownTable};
use crate::partition::{partition, random, edge_cut, PartitionConfig};
use anyhow::{anyhow, Result};

/// The four evaluation datasets, in paper order.
const DATASETS: [&str; 4] = ["cora", "pubmed", "flickr", "reddit"];

fn load(name: &str, opts: &RunOpts) -> Result<Dataset> {
    Dataset::by_name_scaled(name, opts.seed, opts.scale())
        .ok_or_else(|| anyhow!("unknown dataset '{name}'"))
}

/// Paper batch size: 300 everywhere, 1500 on pubmed (§4.1).
fn paper_batch_size(dataset: &str) -> usize {
    if dataset == "pubmed" {
        1500
    } else {
        300
    }
}

/// Build a TrainConfig from flags, starting from the paper's
/// per-dataset best (l, h).
fn config(args: &Args, opts: &RunOpts, dataset: &str) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::paper_best(dataset);
    cfg.workers = args.get_usize("workers", 4)?;
    cfg.partitions = args.get_usize("partitions", (cfg.workers * 4).max(8))?;
    cfg.layers = args.get_usize("layers", cfg.layers)?;
    cfg.hidden = args.get_usize("hidden", cfg.hidden)?;
    cfg.epochs = opts.epochs(args.get_usize("epochs", 100)?);
    cfg.lr = args.get_f64("lr", 0.01)? as f32;
    cfg.alpha = args.get_f64("alpha", 0.01)?;
    cfg.augment = !args.has("no-augment");
    cfg.consensus = args.get("consensus", "weighted").parse().map_err(|e: String| anyhow!(e))?;
    if let crate::coordinator::ConsensusMode::Async(ref mut a) = cfg.consensus {
        a.staleness = args.get_usize("staleness", a.staleness)?;
        a.quorum = args.get_usize("quorum", a.quorum)?;
        a.lambda = args.get_f64("lambda", a.lambda)?;
        // ζ-weighting on by default; --plain-weights reverts the base
        // weight to the uniform Eq. 11 rule
        a.zeta_weighted = !args.has("plain-weights");
    }
    cfg.backend = opts.backend;
    cfg.artifact_dir = opts.artifact_dir.clone();
    cfg.seed = opts.seed;
    cfg.log_every = args.get_usize("log-every", 0)?;
    Ok(cfg)
}

// --------------------------------------------------------------------
// --trace wiring (obs/)
// --------------------------------------------------------------------

/// Arm the global tracer when `--trace FILE` is present. Tracing is
/// annotation-only — enabling it never changes answers or counters
/// (pinned by tests/integration_obs.rs) — so it is safe to thread
/// through any experiment driver.
fn trace_begin(args: &Args) -> Option<String> {
    let path = args.get("trace", "");
    if path.is_empty() {
        return None;
    }
    // --trace-ring flips the buffer-full policy from keep-oldest (see
    // how the run started) to keep-newest (see how it ended)
    crate::obs::trace::set_ring_mode(args.has("trace-ring"));
    crate::obs::trace::enable();
    Some(path.to_string())
}

/// Drain the tracer into Chrome trace-event JSON at `path` (no-op when
/// [`trace_begin`] saw no flag). Load the file in Perfetto or
/// chrome://tracing.
fn trace_finish(path: Option<String>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    crate::obs::trace::disable();
    let t = crate::obs::trace::drain();
    crate::obs::trace::set_ring_mode(false);
    write_result_file(&path, &t.to_chrome_json())?;
    if t.dropped > 0 {
        eprintln!("trace: buffer full, {} spans dropped (see --trace-ring)", t.dropped);
    }
    eprintln!("trace: {} spans -> {path}", t.events.len());
    Ok(())
}

// --------------------------------------------------------------------
// Table 1
// --------------------------------------------------------------------

/// Dataset statistics (paper Table 1).
pub fn table1_stats(_args: &Args, opts: &RunOpts) -> Result<()> {
    let mut md = String::from(
        "| Dataset | Nodes | Edges | Labels | Features | Train/Val/Test |\n|---|---|---|---|---|---|\n",
    );
    for name in DATASETS {
        let ds = load(name, opts)?;
        ds.validate().map_err(|e| anyhow!("{name}: {e}"))?;
        md.push_str(&ds.stats_row());
        md.push('\n');
    }
    println!("{md}");
    write_result_file(&format!("{}/table1_datasets.md", opts.out_dir), &md)?;
    Ok(())
}

// --------------------------------------------------------------------
// Partition / augmentation inspection commands
// --------------------------------------------------------------------

/// Edge-cut / balance report: multilevel vs random partitioner.
pub fn partition_report(args: &Args, opts: &RunOpts) -> Result<()> {
    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let k = args.get_usize("partitions", 16)?;
    let p = partition(&ds.graph, &PartitionConfig { k, seed: opts.seed, ..Default::default() });
    let rand_cut = edge_cut(&ds.graph, &random::random_partition(ds.num_nodes(), k, opts.seed));
    let mut t = MarkdownTable::new(&[
        "partitioner", "k", "edge cut", "cut %", "balance", "modularity", "avg conductance",
    ]);
    let total = ds.graph.num_edges();
    let rand_assign = random::random_partition(ds.num_nodes(), k, opts.seed);
    let rand_part = crate::partition::Partitioning {
        assignment: rand_assign.clone(),
        k,
        edge_cut: rand_cut,
        balance: 1.0,
    };
    t.row(vec![
        "multilevel (ours)".into(),
        k.to_string(),
        p.edge_cut.to_string(),
        format!("{:.1}%", 100.0 * p.edge_cut as f64 / total as f64),
        format!("{:.3}", p.balance),
        format!("{:.3}", crate::partition::modularity(&ds.graph, &p.assignment)),
        format!("{:.3}", crate::partition::avg_conductance(&ds.graph, &p)),
    ]);
    t.row(vec![
        "random".into(),
        k.to_string(),
        rand_cut.to_string(),
        format!("{:.1}%", 100.0 * rand_cut as f64 / total as f64),
        "1.000".into(),
        format!("{:.3}", crate::partition::modularity(&ds.graph, &rand_assign)),
        format!("{:.3}", crate::partition::avg_conductance(&ds.graph, &rand_part)),
    ]);
    let md = format!("## Partition quality — {name}\n\n{}", t.render());
    println!("{md}");
    write_result_file(&format!("{}/partition_{name}.md", opts.out_dir), &md)?;
    Ok(())
}

/// Augmentation report: replicas and walk counts per part.
pub fn augment_report(args: &Args, opts: &RunOpts) -> Result<()> {
    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let k = args.get_usize("partitions", 16)?;
    let layers = args.get_usize("layers", 2)?;
    let alpha = args.get_f64("alpha", 0.01)?;
    let p = partition(&ds.graph, &PartitionConfig { k, seed: opts.seed, ..Default::default() });
    let augs = augment_all(
        &ds.graph,
        &p.assignment,
        k,
        &AugmentConfig { alpha, walk_length: layers, seed: opts.seed, ..Default::default() },
    );
    let mut t = MarkdownTable::new(&["part", "base nodes", "replicas", "MC walks"]);
    for a in &augs {
        t.row(vec![
            a.part.to_string(),
            a.base_len().to_string(),
            a.replicas.len().to_string(),
            a.walks_used.to_string(),
        ]);
    }
    let total_rep: usize = augs.iter().map(|a| a.replicas.len()).sum();
    let md = format!(
        "## Augmentation — {name} (k={k}, α={alpha}, l={layers})\n\nedge cut {} | replicas total {} ({:.2}% of nodes)\n\n{}",
        p.edge_cut,
        total_rep,
        100.0 * total_rep as f64 / ds.num_nodes() as f64,
        t.render()
    );
    println!("{md}");
    write_result_file(&format!("{}/augment_{name}.md", opts.out_dir), &md)?;
    Ok(())
}

/// One training run, any method.
pub fn train_once(args: &Args, opts: &RunOpts) -> Result<()> {
    let name = args.get("dataset", "cora");
    let method: Method = args.get("method", "gad").parse().map_err(|e: String| anyhow!(e))?;
    let ds = load(name, opts)?;
    let cfg = config(args, opts, name)?;
    let trace = trace_begin(args);
    let r = train_method(&ds, method, &cfg, paper_batch_size(name))?;
    trace_finish(trace)?;
    print_report(name, method.label(), &r);
    Ok(())
}

fn print_report(dataset: &str, method: &str, r: &TrainReport) {
    println!("## {method} on {dataset}");
    println!("test accuracy    {:.4}", r.test_accuracy);
    println!("val accuracy     {:.4}", r.val_accuracy);
    println!("epochs           {}", r.epochs_run);
    println!("wall time        {:.2}s", r.wall_seconds);
    println!("time-to-converge {:.2}s (epoch {:?})", r.time_to_converge, r.converged_epoch);
    println!("comm: features {:.3} MB, gradients {:.3} MB", r.comm.feature_mb(), r.comm.gradient_bytes as f64 / 1e6);
    println!("memory/worker    {:.2} MB", r.memory_mb_per_worker());
    println!("edge cut {} | replicas {}", r.edge_cut, r.replicas_total);
}

// --------------------------------------------------------------------
// Table 2 + Fig 5 + Fig 6 (same runs)
// --------------------------------------------------------------------

fn run_all_methods(
    args: &Args,
    opts: &RunOpts,
    datasets: &[&str],
) -> Result<Vec<(String, Method, TrainReport)>> {
    let mut out = Vec::new();
    for &name in datasets {
        let ds = load(name, opts)?;
        for m in Method::ALL {
            // the paper skips SAINT-Edge on the big datasets (it "does
            // not support large-scale datasets")
            if m == Method::SaintEdge && (name == "flickr" || name == "reddit") {
                continue;
            }
            let mut cfg = config(args, opts, name)?;
            cfg.stop_on_converge = true;
            let r = train_method(&ds, m, &cfg, paper_batch_size(name))?;
            eprintln!(
                "  {name:8} {:28} acc {:.4}  t {:.1}s",
                m.label(),
                r.test_accuracy,
                r.wall_seconds
            );
            out.push((name.to_string(), m, r));
        }
    }
    Ok(out)
}

/// Table 2: final test accuracy per method per dataset.
pub fn table2_accuracy(args: &Args, opts: &RunOpts) -> Result<()> {
    let runs = run_all_methods(args, opts, &DATASETS)?;
    let md = render_table2(&runs);
    println!("{md}");
    write_result_file(&format!("{}/table2_accuracy.md", opts.out_dir), &md)?;
    Ok(())
}

pub(crate) fn render_table2(runs: &[(String, Method, TrainReport)]) -> String {
    let mut t = MarkdownTable::new(&["Method", "Cora", "Pubmed", "Flicker", "Reddit"]);
    for m in Method::ALL {
        let cell = |d: &str| {
            runs.iter()
                .find(|(name, mm, _)| name == d && *mm == m)
                .map(|(_, _, r)| format!("{:.4}", r.test_accuracy))
                .unwrap_or_else(|| "-".to_string())
        };
        t.row(vec![
            m.label().to_string(),
            cell("cora"),
            cell("pubmed"),
            cell("flickr"),
            cell("reddit"),
        ]);
    }
    format!("## Table 2 — test accuracy\n\n{}", t.render())
}

/// Fig 5: accuracy-vs-epoch curves (CSV per dataset).
pub fn fig5_curves(args: &Args, opts: &RunOpts) -> Result<()> {
    let runs = run_all_methods(args, opts, &DATASETS)?;
    for name in DATASETS {
        let mut csv = String::from("method,epoch,seconds,loss,test_accuracy\n");
        for (d, m, r) in &runs {
            if d == name {
                for p in &r.curve {
                    csv.push_str(&format!(
                        "{},{},{:.4},{:.6},{:.4}\n",
                        m.label(),
                        p.epoch,
                        p.seconds,
                        p.loss,
                        p.accuracy
                    ));
                }
            }
        }
        write_result_file(&format!("{}/fig5_{name}.csv", opts.out_dir), &csv)?;
        println!("wrote {}/fig5_{name}.csv", opts.out_dir);
    }
    Ok(())
}

/// Fig 6: average time-to-convergence per method + GAD speedups.
pub fn fig6_time(args: &Args, opts: &RunOpts) -> Result<()> {
    let runs = run_all_methods(args, opts, &DATASETS)?;
    let md = render_fig6(&runs);
    println!("{md}");
    write_result_file(&format!("{}/fig6_time_cost.md", opts.out_dir), &md)?;
    Ok(())
}

pub(crate) fn render_fig6(runs: &[(String, Method, TrainReport)]) -> String {
    let avg = |m: Method| -> f64 {
        let ts: Vec<f64> = runs
            .iter()
            .filter(|(_, mm, _)| *mm == m)
            .map(|(_, _, r)| r.time_to_converge)
            .collect();
        ts.iter().sum::<f64>() / ts.len().max(1) as f64
    };
    let gad = avg(Method::Gad);
    let mut t = MarkdownTable::new(&["Method", "avg convergence time (s)", "GAD speedup"]);
    for m in Method::ALL {
        let a = avg(m);
        t.row(vec![
            m.label().to_string(),
            format!("{a:.2}"),
            if m == Method::Gad { "1.0x".into() } else { format!("{:.1}x", a / gad.max(1e-9)) },
        ]);
    }
    format!("## Fig 6 — convergence time\n\n{}", t.render())
}

// --------------------------------------------------------------------
// Table 3 + Fig 7 (worker/layer sweep on pubmed)
// --------------------------------------------------------------------

fn stability_sweep(args: &Args, opts: &RunOpts) -> Result<Vec<(usize, usize, TrainReport)>> {
    let ds = load("pubmed", opts)?;
    let mut out = Vec::new();
    for workers in 1..=4usize {
        for layers in 2..=4usize {
            let mut cfg = config(args, opts, "pubmed")?;
            cfg.workers = workers;
            cfg.layers = layers;
            cfg.partitions = cfg.partitions.max(workers * 2);
            let r = train_gad(&ds, &cfg)?;
            eprintln!("  workers {workers} layers {layers}: acc {:.4} t {:.1}s", r.test_accuracy, r.wall_seconds);
            out.push((workers, layers, r));
        }
    }
    Ok(out)
}

/// Table 3: accuracy stability when workers and layers vary.
pub fn table3_stability(args: &Args, opts: &RunOpts) -> Result<()> {
    let runs = stability_sweep(args, opts)?;
    let mut t = MarkdownTable::new(&["Workers", "2 Layers", "3 Layers", "4 Layers"]);
    for w in 1..=4usize {
        let cell = |l: usize| {
            runs.iter()
                .find(|&&(ww, ll, _)| ww == w && ll == l)
                .map(|(_, _, r)| format!("{:.4}", r.test_accuracy))
                .unwrap_or_default()
        };
        t.row(vec![format!("{w} worker(s)"), cell(2), cell(3), cell(4)]);
    }
    let md = format!("## Table 3 — accuracy stability (pubmed)\n\n{}", t.render());
    println!("{md}");
    write_result_file(&format!("{}/table3_stability.md", opts.out_dir), &md)?;
    Ok(())
}

/// Fig 7: training time for the same sweep.
pub fn fig7_scaling(args: &Args, opts: &RunOpts) -> Result<()> {
    let runs = stability_sweep(args, opts)?;
    let mut csv = String::from("workers,layers,wall_seconds,seconds_per_epoch\n");
    let mut t = MarkdownTable::new(&["Workers", "2 Layers (s)", "3 Layers (s)", "4 Layers (s)"]);
    for w in 1..=4usize {
        let cell = |l: usize| {
            runs.iter()
                .find(|&&(ww, ll, _)| ww == w && ll == l)
                .map(|(_, _, r)| format!("{:.2}", r.wall_seconds))
                .unwrap_or_default()
        };
        t.row(vec![format!("{w}"), cell(2), cell(3), cell(4)]);
    }
    for (w, l, r) in &runs {
        csv.push_str(&format!(
            "{w},{l},{:.3},{:.4}\n",
            r.wall_seconds,
            r.wall_seconds / r.epochs_run.max(1) as f64
        ));
    }
    let md = format!("## Fig 7 — training time vs workers x layers (pubmed)\n\n{}", t.render());
    println!("{md}");
    write_result_file(&format!("{}/fig7_scaling.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig7_scaling.csv", opts.out_dir), &csv)?;
    Ok(())
}

// --------------------------------------------------------------------
// Table 4 (augmentation impact)
// --------------------------------------------------------------------

/// Table 4: accuracy / memory / communication with and without
/// augmentation, 1 vs 4 workers, cora + pubmed.
pub fn table4_augmentation(args: &Args, opts: &RunOpts) -> Result<()> {
    let mut t = MarkdownTable::new(&[
        "Dataset",
        "Workers",
        "Augmentation",
        "Accuracy",
        "Memory/worker (MB)",
        "Comm (MB)",
    ]);
    for name in ["cora", "pubmed"] {
        let ds = load(name, opts)?;
        for workers in [1usize, 4] {
            for augment in [false, true] {
                let mut cfg = config(args, opts, name)?;
                cfg.workers = workers;
                // paper Table 4: one partition per GPU
                cfg.partitions = workers;
                // our synthetic importance distribution is flatter than
                // real citation hubs; α=0.1 covers the traffic mass the
                // paper covered at α=0.01 (see EXPERIMENTS.md §Table 4)
                cfg.alpha = args.get_f64("alpha", 0.1)?;
                cfg.augment = augment;
                let r = train_gad(&ds, &cfg)?;
                eprintln!(
                    "  {name} w={workers} aug={augment}: acc {:.4} comm {:.3}MB mem {:.1}MB",
                    r.test_accuracy,
                    r.comm.feature_mb(),
                    r.memory_mb_per_worker()
                );
                t.row(vec![
                    name.into(),
                    workers.to_string(),
                    if augment { "Yes" } else { "No" }.into(),
                    format!("{:.4}", r.test_accuracy),
                    format!("{:.2}", r.memory_mb_per_worker()),
                    format!("{:.3}", r.comm.feature_mb()),
                ]);
            }
        }
    }
    let md = format!("## Table 4 — impact of graph augmentation\n\n{}", t.render());
    println!("{md}");
    write_result_file(&format!("{}/table4_augmentation.md", opts.out_dir), &md)?;
    Ok(())
}

// --------------------------------------------------------------------
// Fig 8 (partition count vs convergence) and Fig 9 (consensus)
// --------------------------------------------------------------------

/// Fig 8: loss convergence for partitions {10,50,100}, aug on/off
/// (pubmed, l=4, h=512 per the paper).
pub fn fig8_partitions(args: &Args, opts: &RunOpts) -> Result<()> {
    let ds = load("pubmed", opts)?;
    let parts = if opts.fast { vec![5usize, 10, 20] } else { vec![10, 50, 100] };
    let mut csv = String::from("augment,partitions,epoch,loss\n");
    for augment in [true, false] {
        for &k in &parts {
            let mut cfg = config(args, opts, "pubmed")?;
            cfg.layers = 4;
            cfg.hidden = if opts.fast { 64 } else { 512 };
            cfg.partitions = k;
            cfg.augment = augment;
            let r = train_gad(&ds, &cfg)?;
            for p in &r.curve {
                csv.push_str(&format!("{},{k},{},{:.6}\n", augment, p.epoch, p.loss));
            }
            eprintln!("  aug={augment} k={k}: final loss {:.4}", r.curve.last().map(|p| p.loss).unwrap_or(0.0));
        }
    }
    write_result_file(&format!("{}/fig8_partitions.csv", opts.out_dir), &csv)?;
    println!("wrote {}/fig8_partitions.csv", opts.out_dir);
    Ok(())
}

/// Fig 9: weighted vs plain consensus (flickr, l=4, h=128,
/// partitions {50,100}).
pub fn fig9_consensus(args: &Args, opts: &RunOpts) -> Result<()> {
    let ds = load("flickr", opts)?;
    let parts = if opts.fast { vec![10usize, 20] } else { vec![50, 100] };
    let mut csv = String::from("consensus,partitions,epoch,loss\n");
    for &k in &parts {
        for mode in [ConsensusMode::Weighted, ConsensusMode::Plain] {
            let mut cfg = config(args, opts, "flickr")?;
            cfg.layers = 4;
            cfg.hidden = 128;
            cfg.partitions = k;
            cfg.consensus = mode;
            let r = train_gad(&ds, &cfg)?;
            let label = if mode == ConsensusMode::Weighted { "weighted" } else { "plain" };
            for p in &r.curve {
                csv.push_str(&format!("{label},{k},{},{:.6}\n", p.epoch, p.loss));
            }
            eprintln!("  {label} k={k}: final loss {:.4}", r.curve.last().map(|p| p.loss).unwrap_or(0.0));
        }
    }
    write_result_file(&format!("{}/fig9_consensus.csv", opts.out_dir), &csv)?;
    println!("wrote {}/fig9_consensus.csv", opts.out_dir);
    Ok(())
}

/// Ablation: strip GAD's design choices one at a time (the DESIGN.md
/// §Experiment-index ablations) — full GAD, minus weighted consensus,
/// minus augmentation, minus multilevel partitioning (random instead),
/// plus a crash-fault run and the Jiang-style locality-aware sampler.
pub fn ablation(args: &Args, opts: &RunOpts) -> Result<()> {
    use crate::coordinator::FaultPlan;
    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let base = config(args, opts, name)?;

    let mut t = MarkdownTable::new(&[
        "Variant",
        "Accuracy",
        "Converge (s)",
        "Feature comm (MB)",
        "Edge cut",
    ]);
    let mut run = |label: &str, r: TrainReport| {
        eprintln!("  {label:34} acc {:.4}", r.test_accuracy);
        t.row(vec![
            label.to_string(),
            format!("{:.4}", r.test_accuracy),
            format!("{:.2}", r.time_to_converge),
            format!("{:.3}", r.comm.feature_mb()),
            r.edge_cut.to_string(),
        ]);
    };

    run("GAD (full)", train_gad(&ds, &base)?);

    let mut c = base.clone();
    c.consensus = ConsensusMode::Plain;
    run("- weighted consensus", train_gad(&ds, &c)?);

    let mut c = base.clone();
    c.augment = false;
    run("- augmentation", train_gad(&ds, &c)?);

    // random partitioning instead of multilevel = the plain GCN path
    run("- multilevel partition", train_method(&ds, Method::Gcn, &base, paper_batch_size(name))?);

    let mut c = base.clone();
    c.faults = FaultPlan::random_crash(c.workers, c.epochs, opts.seed);
    run("GAD + worker crash", train_gad(&ds, &c)?);

    let md = format!("## Ablation — {name}\n\n{}", t.render());
    println!("{md}");
    write_result_file(&format!("{}/ablation_{name}.md", opts.out_dir), &md)?;
    Ok(())
}

// --------------------------------------------------------------------
// Fig 11 (ours): serving latency · Fig 12 (ours): serving under churn
// --------------------------------------------------------------------

/// The full serving pipeline as one command: train briefly, checkpoint,
/// reload with dimension validation, then benchmark the three serving
/// modes (naive unsharded per-node, cold sharded, cached sharded) on a
/// shared random query stream (Fig 11), followed by the high-churn
/// scenario — interleaved delta streams at increasing rates, the
/// incremental overlay path vs per-delta rebuild (Fig 12).
pub fn serve_bench(args: &Args, opts: &RunOpts) -> Result<()> {
    use crate::model::checkpoint;
    use crate::serve::{
        run_churn_bench, run_rebalance_bench, run_serving_bench, ChurnBenchConfig, HaloPolicy,
        RebalanceBenchConfig, ServingBenchConfig,
    };

    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let trace = trace_begin(args);

    // 1. train (short by default — serving latency does not depend on
    //    model quality) and harvest the trained parameters
    let mut cfg = config(args, opts, name)?;
    cfg.epochs = opts.epochs(args.get_usize("epochs", 20)?);
    eprintln!("training {name} for {} epochs...", cfg.epochs);
    let report = train_gad(&ds, &cfg)?;
    let params = report
        .final_params
        .ok_or_else(|| anyhow!("training returned no parameters"))?;

    // 2. checkpoint round-trip, exercising the corrupt-input guards
    let ckpt = format!("{}/serve_model.ckpt", opts.out_dir);
    crate::metrics::write_result_file(&ckpt, &checkpoint::to_text(&params))?;
    let params = checkpoint::load_validated(&ckpt, ds.feature_dim(), ds.num_classes)?;
    eprintln!("checkpoint {ckpt} reloaded ({} params)", params.num_params());

    // 3. latency benchmark (--halo-alpha is deliberately distinct from
    //    the training augmentation coefficient --alpha)
    let halo_alpha = args.get_f64("halo-alpha", 0.0)?;
    let bcfg = ServingBenchConfig {
        shards: args.get_usize("shards", 4)?,
        queries: args.get_usize("queries", if opts.fast { 400 } else { 2000 })?,
        batch: args.get_usize("batch", 32)?,
        halo: if halo_alpha > 0.0 {
            HaloPolicy::Budgeted { alpha: halo_alpha }
        } else {
            HaloPolicy::Exact
        },
        cache_budget_bytes: (args.get_f64("cache-budget-mb", 0.0)? * 1e6) as u64,
        gather_missing: args.has("gather"),
        gather_cache_budget_bytes: (args.get_f64("gather-cache-mb", 0.0)? * 1e6) as u64,
        serve_threads: args.get_usize("serve-threads", 1)?,
        seed: opts.seed,
    };
    let rep = run_serving_bench(&ds, &params, &bcfg)?;
    let md = format!(
        "## Fig 11 — serving latency ({name}, k={}, {} queries, batch {})\n\n{}",
        bcfg.shards,
        bcfg.queries,
        bcfg.batch,
        rep.to_markdown()
    );
    println!("{md}");
    write_result_file(&format!("{}/fig11_serving_latency.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig11_serving_latency.csv", opts.out_dir), &rep.to_csv())?;
    write_result_file(&format!("{}/fig11_serving_latency.json", opts.out_dir), &rep.to_json())?;

    // 4. churn benchmark: deltas/sec and query p99 as the graph mutates
    //    under load, incremental overlay splicing vs per-delta rebuild
    let ccfg = ChurnBenchConfig {
        shards: bcfg.shards,
        rounds: args.get_usize("churn-rounds", if opts.fast { 3 } else { 6 })?,
        queries_per_round: args.get_usize("churn-queries", if opts.fast { 64 } else { 192 })?,
        batch: bcfg.batch,
        adaptive_compaction: args.has("adaptive-compaction"),
        seed: opts.seed,
        ..Default::default()
    };
    let crep = run_churn_bench(&ds, &params, &ccfg)?;
    let md = format!(
        "## Fig 12 — serving under churn ({name}, k={}, {} rounds x {} queries)\n\n{}",
        ccfg.shards,
        ccfg.rounds,
        ccfg.queries_per_round,
        crep.to_markdown()
    );
    println!("{md}");
    write_result_file(&format!("{}/fig12_churn.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig12_churn.csv", opts.out_dir), &crep.to_csv())?;
    write_result_file(&format!("{}/fig12_churn.json", opts.out_dir), &crep.to_json())?;

    // 5. skewed-insert scenario: imbalance ratio + p99 per round, the
    //    online rebalancer on vs off (Fig 13)
    let rcfg = RebalanceBenchConfig {
        shards: bcfg.shards,
        rounds: args.get_usize("rebalance-rounds", if opts.fast { 4 } else { 8 })?,
        inserts_per_round: args.get_usize("rebalance-inserts", if opts.fast { 12 } else { 24 })?,
        queries_per_round: args.get_usize("churn-queries", if opts.fast { 64 } else { 128 })?,
        batch: bcfg.batch,
        rebalance_ratio: args.get_f64("rebalance-ratio", 1.5)?,
        seed: opts.seed,
        ..Default::default()
    };
    let rrep = run_rebalance_bench(&ds, &params, &rcfg)?;
    let md = format!(
        "## Fig 13 — skewed elastic inserts, rebalancer on/off ({name}, k={}, {} rounds x {} inserts)\n\n{}",
        rcfg.shards,
        rcfg.rounds,
        rcfg.inserts_per_round,
        rrep.to_markdown()
    );
    println!("{md}");
    write_result_file(&format!("{}/fig13_rebalance.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig13_rebalance.csv", opts.out_dir), &rrep.to_csv())?;
    write_result_file(&format!("{}/fig13_rebalance.json", opts.out_dir), &rrep.to_json())?;
    trace_finish(trace)?;
    Ok(())
}

// --------------------------------------------------------------------
// Fig 14 (ours): open-loop load, the latency-vs-throughput knee
// --------------------------------------------------------------------

/// Train briefly, then drive the serving tier with the open-loop
/// generator: one seeded arrival schedule per offered-rate step,
/// replayed under FIFO and the SLO-aware micro-batcher, sweeping the
/// rate until both collapse past the knee (Fig 14).
pub fn load_bench(args: &Args, opts: &RunOpts) -> Result<()> {
    use crate::loadgen::{run_load_bench, LoadBenchConfig};

    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let trace = trace_begin(args);

    let mut cfg = config(args, opts, name)?;
    cfg.epochs = opts.epochs(args.get_usize("epochs", 20)?);
    eprintln!("training {name} for {} epochs...", cfg.epochs);
    let report = train_gad(&ds, &cfg)?;
    let params = report
        .final_params
        .ok_or_else(|| anyhow!("training returned no parameters"))?;

    let lcfg = LoadBenchConfig {
        shards: args.get_usize("shards", 4)?,
        slo_us: (args.get_f64("slo-ms", 5.0)? * 1e3) as u64,
        batch_k: args.get_usize("batch-k", 16)?,
        zipf_s: args.get_f64("zipf-s", 0.9)?,
        churn_frac: args.get_f64("churn-frac", 0.02)?,
        events_per_step: args
            .get_usize("load-events", if opts.fast { 400 } else { 2000 })?,
        rate_start_qps: args.get_f64("rate-qps", 0.0)?,
        rate_steps: args.get_usize("rate-steps", if opts.fast { 4 } else { 6 })?,
        serve_threads: args.get_usize("serve-threads", 1)?,
        seed: opts.seed,
        ..Default::default()
    };
    let rep = run_load_bench(&ds, &params, &lcfg)?;
    let md = format!(
        "## Fig 14 — open-loop load knee ({name}, k={}, {} events/step, SLO {:.1} ms)\n\n{}",
        lcfg.shards,
        lcfg.events_per_step,
        lcfg.slo_us as f64 / 1e3,
        rep.to_markdown()
    );
    println!("{md}");
    write_result_file(&format!("{}/fig14_load_knee.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig14_load_knee.csv", opts.out_dir), &rep.to_csv())?;
    write_result_file(&format!("{}/fig14_load_knee.json", opts.out_dir), &rep.to_json())?;
    trace_finish(trace)?;
    Ok(())
}

// --------------------------------------------------------------------
// Fig 15 (ours): per-phase profile across train, serve, and loadgen
// --------------------------------------------------------------------

/// One small train → serve-burst → open-loop-replay pass with the
/// tracer on the whole time, folded into a per-phase time/byte table
/// plus one [`MetricsRegistry`] snapshot spanning all three tiers
/// (Fig 15). `--trace FILE` additionally keeps the raw Chrome trace.
///
/// [`MetricsRegistry`]: crate::obs::MetricsRegistry
pub fn profile(args: &Args, opts: &RunOpts) -> Result<()> {
    use crate::loadgen::{
        generate_schedule, run_open_loop, SimOptions, SloBatchScheduler, WorkloadConfig,
    };
    use crate::obs::{MetricsRegistry, ProfileReport};
    use crate::serve::{ServeConfig, Server};

    let name = args.get("dataset", "cora");
    let ds = load(name, opts)?;
    let trace_path = args.get("trace", "").to_string();

    crate::obs::trace::enable();

    // 1. train tier (epoch/round/consensus spans); short runs suffice —
    //    the profile wants phase shape, not model quality
    let mut cfg = config(args, opts, name)?;
    cfg.epochs = opts.epochs(args.get_usize("epochs", 10)?);
    eprintln!("profiling {name}: training for {} epochs...", cfg.epochs);
    let report = train_gad(&ds, &cfg)?;
    let params = report
        .final_params
        .clone()
        .ok_or_else(|| anyhow!("training returned no parameters"))?;

    // 2. serve tier: a direct query burst (gather / GEMM / cache spans)
    let scfg = ServeConfig {
        shards: args.get_usize("shards", 4)?,
        serve_threads: args.get_usize("serve-threads", 1)?,
        seed: opts.seed,
        ..Default::default()
    };
    let mut srv = Server::for_dataset(&ds, params, scfg)?;
    let queries = args.get_usize("queries", if opts.fast { 128 } else { 512 })?;
    let batch = args.get_usize("batch", 32)?.max(1);
    let nodes: Vec<u32> =
        (0..queries as u32).map(|i| i % ds.num_nodes().max(1) as u32).collect();
    for chunk in nodes.chunks(batch) {
        srv.query_batch(chunk)?;
    }

    // 3. loadgen tier: one open-loop replay (virtual-time spans)
    let wcfg = WorkloadConfig {
        rate_qps: args.get_f64("rate-qps", 2000.0)?,
        events: args.get_usize("load-events", if opts.fast { 200 } else { 1000 })?,
        zipf_s: args.get_f64("zipf-s", 0.9)?,
        churn_frac: args.get_f64("churn-frac", 0.02)?,
        seed: opts.seed,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let slo_us = (args.get_f64("slo-ms", 5.0)? * 1e3) as u64;
    let mut sched =
        SloBatchScheduler::new(srv.num_shards(), args.get_usize("batch-k", 16)?, slo_us / 4);
    let sim =
        run_open_loop(&mut srv, &schedule, &mut sched, &SimOptions { slo_us, ..Default::default() })?;

    crate::obs::trace::disable();
    let trace = crate::obs::trace::drain();

    // 4. fold: one registry over all three tiers + the phase table
    let mut reg = MetricsRegistry::new();
    reg.record_train_report("train", &report);
    reg.record_serve_stats("serve", &srv.stats());
    reg.record_sim_result("loadgen", &sim);
    let prof = ProfileReport::from_trace(name, &trace, reg);

    let md = prof.to_markdown();
    println!("{md}");
    write_result_file(&format!("{}/fig15_profile.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig15_profile.csv", opts.out_dir), &prof.to_csv())?;
    write_result_file(&format!("{}/fig15_profile.json", opts.out_dir), &prof.to_json())?;
    if !trace_path.is_empty() {
        write_result_file(&trace_path, &trace.to_chrome_json())?;
        eprintln!("trace: {} spans -> {trace_path}", trace.events.len());
    }
    Ok(())
}

/// Fig 16 (ours): raw-speed kernel comparison — the retained seed-era
/// reference kernels vs the packed register-blocked GEMM, panelled
/// gradient transposes and nnz-balanced SpMM, on identical inputs.
/// The runner asserts bit-identity per case before timing it, so the
/// table can never report a speedup on answers that moved.
pub fn kernel_bench(args: &Args, opts: &RunOpts) -> Result<()> {
    let warmup = args.get_usize("warmup", 1)?;
    let samples = args.get_usize("samples", if opts.fast { 3 } else { 5 })?;
    let rep = crate::bench_util::run_fig16_kernels(opts.fast, warmup, samples);
    let md = rep.to_markdown();
    println!("{md}");
    write_result_file(&format!("{}/fig16_kernels.md", opts.out_dir), &md)?;
    write_result_file(&format!("{}/fig16_kernels.csv", opts.out_dir), &rep.to_csv())?;
    write_result_file(&format!("{}/fig16_kernels.json", opts.out_dir), &rep.to_json())?;
    Ok(())
}

/// Everything, in order. Table 2 / Fig 5 / Fig 6 share one sweep and
/// Table 3 / Fig 7 share another (the paper derives them from the same
/// runs too).
pub fn run_all(args: &Args, opts: &RunOpts) -> Result<()> {
    table1_stats(args, opts)?;

    // shared sweep: table2 + fig5 + fig6
    let runs = run_all_methods(args, opts, &DATASETS)?;
    let t2 = render_table2(&runs);
    println!("{t2}");
    write_result_file(&format!("{}/table2_accuracy.md", opts.out_dir), &t2)?;
    for name in DATASETS {
        let mut csv = String::from("method,epoch,seconds,loss,test_accuracy\n");
        for (d, m, r) in &runs {
            if d == name {
                for p in &r.curve {
                    csv.push_str(&format!(
                        "{},{},{:.4},{:.6},{:.4}\n",
                        m.label(),
                        p.epoch,
                        p.seconds,
                        p.loss,
                        p.accuracy
                    ));
                }
            }
        }
        write_result_file(&format!("{}/fig5_{name}.csv", opts.out_dir), &csv)?;
    }
    let f6 = render_fig6(&runs);
    println!("{f6}");
    write_result_file(&format!("{}/fig6_time_cost.md", opts.out_dir), &f6)?;

    // shared sweep: table3 + fig7
    let sweep = stability_sweep(args, opts)?;
    let mut t3 = MarkdownTable::new(&["Workers", "2 Layers", "3 Layers", "4 Layers"]);
    let mut t7 = MarkdownTable::new(&["Workers", "2 Layers (s)", "3 Layers (s)", "4 Layers (s)"]);
    for w in 1..=4usize {
        let acc = |l: usize| {
            sweep
                .iter()
                .find(|&&(ww, ll, _)| ww == w && ll == l)
                .map(|(_, _, r)| format!("{:.4}", r.test_accuracy))
                .unwrap_or_default()
        };
        let tim = |l: usize| {
            sweep
                .iter()
                .find(|&&(ww, ll, _)| ww == w && ll == l)
                .map(|(_, _, r)| format!("{:.2}", r.wall_seconds))
                .unwrap_or_default()
        };
        t3.row(vec![format!("{w} worker(s)"), acc(2), acc(3), acc(4)]);
        t7.row(vec![format!("{w}"), tim(2), tim(3), tim(4)]);
    }
    let t3md = format!("## Table 3 — accuracy stability (pubmed)\n\n{}", t3.render());
    let t7md = format!("## Fig 7 — training time vs workers x layers (pubmed)\n\n{}", t7.render());
    println!("{t3md}\n{t7md}");
    write_result_file(&format!("{}/table3_stability.md", opts.out_dir), &t3md)?;
    write_result_file(&format!("{}/fig7_scaling.md", opts.out_dir), &t7md)?;

    table4_augmentation(args, opts)?;
    fig8_partitions(args, opts)?;
    fig9_consensus(args, opts)?;
    serve_bench(args, opts)?;
    load_bench(args, opts)?;
    profile(args, opts)?;
    kernel_bench(args, opts)?;
    Ok(())
}
