//! Process-wide thread budget.
//!
//! Three subsystems spawn compute threads: training workers (one OS
//! thread per worker, each running GEMMs at `intra_threads`), the
//! tensor kernels' row-panel pools ([`crate::tensor::ops`]), and the
//! serving tier's per-shard fan-out ([`crate::serve::Server`] with
//! `serve_threads > 1`). Before this module each sized itself from
//! `available_parallelism()` alone, so a co-resident train + serve
//! process oversubscribed the machine: `workers * intra + serve_pool`
//! threads on `cores` cores. Now every pool takes a [`ThreadLease`] on
//! the shared budget and sizes itself from [`available`] — what the
//! machine has minus what standing pools already claimed.
//!
//! **Determinism contract:** the counters here may only ever change
//! *thread counts*, never *bits*. Every parallel kernel in this crate
//! is bit-identical at any thread count — GEMM/SpMM split output rows
//! into disjoint panels whose per-row accumulation order is fixed, and
//! the serve fan-out merges per-shard outcomes in ascending shard
//! order (see README "Threading model"). So concurrent tests racing on
//! these atomics (cargo runs tests in parallel threads) can shrink each
//! other's budgets — wall-clock only, results unchanged. That is why
//! plain relaxed atomics are safe here where a result-affecting global
//! would not be (cf. the `INTRA_THREADS` thread-local history note in
//! `tensor/ops.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Label the calling thread for trace export (Chrome `thread_name`
/// metadata; see [`crate::obs::trace`]). Pools call this right after
/// spawning so a trace shows "trainer-worker-2" / "serve-worker-5"
/// instead of bare thread numbers. The label closure only runs when
/// tracing is enabled, so the disabled path pays one relaxed load and
/// never formats.
pub fn label_current_with(label: impl FnOnce() -> String) {
    crate::obs::trace::set_thread_label_with(label);
}

/// Configured budget override; 0 = use `available_parallelism()`.
static TOTAL_OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// Threads currently claimed by standing pools (leases).
static RESERVED: AtomicUsize = AtomicUsize::new(0);

/// The process's total thread budget: the configured override, or the
/// machine's core count when none is set.
pub fn total() -> usize {
    match TOTAL_OVERRIDE.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// Override the process budget (0 restores the core-count default).
/// Wall-clock sizing only — never affects results.
pub fn set_total(n: usize) {
    TOTAL_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Threads currently held by leases.
pub fn reserved() -> usize {
    RESERVED.load(Ordering::Relaxed)
}

/// Budget left for a new pool, with a floor of one: a thread asking
/// "how parallel may I be" always gets at least itself.
pub fn available() -> usize {
    total().saturating_sub(reserved()).max(1)
}

/// RAII claim on `n` threads of the process budget. Pools hold one for
/// their lifetime (the trainer across a run, a parallel `Server` while
/// it exists); dropping it returns the threads to [`available`].
#[must_use = "dropping the lease immediately returns the threads"]
pub struct ThreadLease {
    n: usize,
}

impl ThreadLease {
    /// Threads this lease holds.
    pub fn threads(&self) -> usize {
        self.n
    }
}

/// Claim `n` threads. Over-reservation is allowed (the machine will
/// time-slice); [`available`] just bottoms out at 1 for everyone else.
pub fn reserve(n: usize) -> ThreadLease {
    RESERVED.fetch_add(n, Ordering::Relaxed);
    ThreadLease { n }
}

impl Drop for ThreadLease {
    fn drop(&mut self) {
        RESERVED.fetch_sub(self.n, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_never_hits_zero() {
        // claim far more than the machine has: everyone else still
        // sees a floor of one (robust against concurrent tests holding
        // their own leases — their claims only push further past total)
        let grab = total() * 4;
        let lease = reserve(grab);
        assert_eq!(lease.threads(), grab);
        assert_eq!(available(), 1, "over-reservation still leaves a floor of one");
        drop(lease);
        assert!(available() >= 1);
    }

    #[test]
    fn lease_returns_threads_on_drop() {
        // every assertion here survives concurrent tests holding their
        // own leases: while we hold 2×total, the budget is saturated no
        // matter what anyone else reserves or releases; after the drop
        // the only race-free fact is the floor (a concurrent lease may
        // still legitimately hold the budget down)
        let l = reserve(total() * 2);
        assert_eq!(l.threads(), total() * 2);
        assert_eq!(available(), 1, "our own claim saturates the budget");
        drop(l);
        assert!(available() >= 1);
        assert!(total() >= 1);
    }
}
