//! GAD-Optimizer part 1: variance-based subgraph importance ζ
//! (paper §3.4.1, Eq. 14).
//!
//! `ζ(g') = Σ_{i<j} p(v_i) p(v_j) / (d(i,j) + β)` where `p(v)` is the
//! degree-proportional selection probability and `d(i,j)` the Euclidean
//! feature distance. By Property 2, Σ p_i p_j is maximised when node
//! degrees are uniform — so low-variance (structurally regular)
//! subgraphs get *high* ζ and dominate the weighted consensus.
//!
//! The paper's Example 3 (Fig. 4) reports ζ = 3.75 / 3.61 / 3.59 for
//! degree sequences (2,2,2,2) / (1,2,2,1) / (3,2,2,1) with d(i,j)=0;
//! those values correspond to β = 0.1 (with the stated "β = 1" they
//! would be 0.375/0.361/0.359 — same ordering, scaled). We default to
//! β = 0.1 to match the published numbers exactly; ζ only enters the
//! consensus through its *relative* size, so either choice trains
//! identically when d≈const.

use crate::graph::Csr;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Configuration for ζ computation.
#[derive(Clone, Debug)]
pub struct ZetaConfig {
    /// β of Eq. 14 (see module docs on the 0.1-vs-1 discrepancy).
    pub beta: f64,
    /// Pair-sampling cap: subgraphs with more than this many node pairs
    /// estimate the sum by Monte-Carlo over this many sampled pairs.
    pub max_pairs: usize,
    pub seed: u64,
}

impl Default for ZetaConfig {
    fn default() -> Self {
        ZetaConfig { beta: 0.1, max_pairs: 50_000, seed: 0 }
    }
}

/// Degree-proportional selection probabilities `p(v) = deg(v)/Σdeg`.
pub fn selection_probabilities(g: &Csr) -> Vec<f64> {
    let total: f64 = (0..g.num_nodes()).map(|v| g.degree(v) as f64).sum();
    if total == 0.0 {
        let n = g.num_nodes().max(1);
        return vec![1.0 / n as f64; g.num_nodes()];
    }
    (0..g.num_nodes()).map(|v| g.degree(v) as f64 / total).collect()
}

/// Sparse view of the feature rows: per node, the sorted (dim, value)
/// pairs plus the squared norm. Node features are row-normalized
/// bag-of-words (~1% density), so pairwise distances via a sorted
/// merge are ~30x cheaper than dense row scans (§Perf iteration 2).
struct SparseRows {
    nnz: Vec<Vec<(u32, f32)>>,
    sqnorm: Vec<f64>,
}

impl SparseRows {
    fn new(features: &Matrix) -> SparseRows {
        let mut nnz = Vec::with_capacity(features.rows);
        let mut sqnorm = Vec::with_capacity(features.rows);
        for i in 0..features.rows {
            let row: Vec<(u32, f32)> = features
                .row(i)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(d, &v)| (d as u32, v))
                .collect();
            sqnorm.push(row.iter().map(|&(_, v)| (v as f64) * (v as f64)).sum());
            nnz.push(row);
        }
        SparseRows { nnz, sqnorm }
    }

    /// ||x_i - x_j||: ||x_i||² + ||x_j||² - 2<x_i, x_j> with the dot
    /// product over the nonzero intersection (sorted merge).
    fn dist(&self, i: usize, j: usize) -> f64 {
        let (a, b) = (&self.nnz[i], &self.nnz[j]);
        let mut dot = 0.0f64;
        let (mut p, mut q) = (0usize, 0usize);
        while p < a.len() && q < b.len() {
            match a[p].0.cmp(&b[q].0) {
                std::cmp::Ordering::Less => p += 1,
                std::cmp::Ordering::Greater => q += 1,
                std::cmp::Ordering::Equal => {
                    dot += a[p].1 as f64 * b[q].1 as f64;
                    p += 1;
                    q += 1;
                }
            }
        }
        (self.sqnorm[i] + self.sqnorm[j] - 2.0 * dot).max(0.0).sqrt()
    }
}

/// ζ(g') of Eq. 14 over a (local) graph and its node features
/// (`features.rows == g.num_nodes()`); pass `None` for featureless
/// graphs (d(i,j) = 0, as in the paper's Example 3).
pub fn zeta(g: &Csr, features: Option<&Matrix>, cfg: &ZetaConfig) -> f64 {
    let n = g.num_nodes();
    if n < 2 {
        return 0.0;
    }
    let p = selection_probabilities(g);
    let n_pairs = n * (n - 1) / 2;
    let sparse = features.map(SparseRows::new);
    let dist = |i: usize, j: usize| sparse.as_ref().map_or(0.0, |s| s.dist(i, j));

    if n_pairs <= cfg.max_pairs {
        let mut acc = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                acc += p[i] * p[j] / (dist(i, j) + cfg.beta);
            }
        }
        acc
    } else {
        // Monte-Carlo estimate: sample pairs uniformly, scale by the
        // pair count. Deterministic per seed.
        let mut rng = Rng::seed_from_u64(cfg.seed ^ n as u64);
        let mut acc = 0.0;
        for _ in 0..cfg.max_pairs {
            let i = rng.gen_range(n);
            let mut j = rng.gen_range(n - 1);
            if j >= i {
                j += 1;
            }
            acc += p[i] * p[j] / (dist(i, j) + cfg.beta);
        }
        acc * n_pairs as f64 / cfg.max_pairs as f64
    }
}

/// ζ for every subgraph in a batch, normalised to sum to the batch
/// size (so plain consensus is the all-ones special case).
pub fn zeta_weights(zs: &[f64]) -> Vec<f64> {
    let sum: f64 = zs.iter().sum();
    if sum <= 0.0 {
        return vec![1.0; zs.len()];
    }
    let k = zs.len() as f64;
    zs.iter().map(|z| z * k / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// Paper Fig. 4 / Example 3: three 4-node graphs, d(i,j)=0, β=0.1.
    #[test]
    fn example3_matches_paper_values() {
        let cfg = ZetaConfig { beta: 0.1, ..Default::default() };
        // (a) cycle: degrees (2,2,2,2) -> 3.75
        let a = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3), (3, 0)]).build();
        // (b) triangle + tail: degrees (3,2,2,1) -> 3.59
        let b = GraphBuilder::new(4).edges(&[(0, 1), (0, 2), (1, 2), (0, 3)]).build();
        // (c) path: degrees (1,2,2,1) -> 3.61
        let c = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let (za, zb, zc) = (zeta(&a, None, &cfg), zeta(&b, None, &cfg), zeta(&c, None, &cfg));
        assert!((za - 3.75).abs() < 1e-9, "za={za}");
        assert!((zb - 3.59375).abs() < 2e-2, "zb={zb}");
        assert!((zc - 3.6111).abs() < 2e-2, "zc={zc}");
        assert!(za > zc && zc > zb, "ordering 3.75 > 3.61 > 3.59");
    }

    #[test]
    fn uniform_degrees_maximise_zeta() {
        // Property 2: among same-size graphs, more regular -> larger Σp_ip_j
        let cfg = ZetaConfig { beta: 1.0, ..Default::default() };
        let regular = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)])
            .build();
        let star = GraphBuilder::new(6)
            .edges(&[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2)])
            .build();
        assert!(zeta(&regular, None, &cfg) > zeta(&star, None, &cfg));
    }

    #[test]
    fn feature_distance_lowers_zeta() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3), (3, 0)]).build();
        let cfg = ZetaConfig { beta: 0.1, ..Default::default() };
        let close = Matrix::zeros(4, 8); // identical features: d = 0
        let mut far = Matrix::zeros(4, 8);
        for i in 0..4 {
            far[(i, i)] = 10.0;
        }
        assert!(zeta(&g, Some(&close), &cfg) > zeta(&g, Some(&far), &cfg));
    }

    #[test]
    fn sampled_estimate_close_to_exact() {
        // force the Monte-Carlo path with a tiny cap; compare to exact
        let g = GraphBuilder::new(40)
            .edges(&(0..39).map(|i| (i as u32, i as u32 + 1)).collect::<Vec<_>>())
            .build();
        let exact = zeta(&g, None, &ZetaConfig { beta: 0.5, max_pairs: usize::MAX, seed: 0 });
        let approx = zeta(&g, None, &ZetaConfig { beta: 0.5, max_pairs: 400, seed: 0 });
        assert!((approx - exact).abs() / exact < 0.15, "exact {exact} approx {approx}");
    }

    #[test]
    fn weights_normalised_to_count() {
        let w = zeta_weights(&[1.0, 2.0, 3.0]);
        assert!((w.iter().sum::<f64>() - 3.0).abs() < 1e-12);
        assert!(w[2] > w[0]);
        // degenerate: all-zero -> uniform
        assert_eq!(zeta_weights(&[0.0, 0.0]), vec![1.0, 1.0]);
    }

    #[test]
    fn probabilities_sum_to_one() {
        let g = GraphBuilder::new(5).edges(&[(0, 1), (1, 2), (3, 4)]).build();
        let p = selection_probabilities(&g);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
