//! PJRT runtime: loads the HLO-text artifacts that `make artifacts`
//! produced (L2 JAX model + L1 Pallas kernel, AOT-lowered) and executes
//! them on the CPU PJRT client. Python is never on this path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

mod manifest;

pub use manifest::{parse_manifest_str, ArtifactKind, ManifestEntry};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape key an executable is compiled for.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: ArtifactKind,
    pub layers: usize,
    /// Padded node count.
    pub nodes: usize,
    pub fdim: usize,
    pub hidden: usize,
    pub classes: usize,
}

/// PJRT client + lazily compiled executable cache over an artifact dir.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    entries: Vec<ManifestEntry>,
    cache: HashMap<BucketKey, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open `dir` (must contain `manifest.txt`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let entries = manifest::parse_manifest(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, entries, cache: HashMap::new() })
    }

    /// All manifest entries.
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// Smallest bucket satisfying the request, if any.
    pub fn find_bucket(
        &self,
        kind: ArtifactKind,
        layers: usize,
        fdim: usize,
        hidden: usize,
        classes: usize,
        min_nodes: usize,
    ) -> Option<BucketKey> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.layers == layers
                    && e.fdim == fdim
                    && e.hidden == hidden
                    && e.classes == classes
                    && e.nodes >= min_nodes
            })
            .min_by_key(|e| e.nodes)
            .map(|e| BucketKey { kind, layers, nodes: e.nodes, fdim, hidden, classes })
    }

    fn entry_for(&self, key: &BucketKey) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.kind == key.kind
                && e.layers == key.layers
                && e.nodes == key.nodes
                && e.fdim == key.fdim
                && e.hidden == key.hidden
                && e.classes == key.classes
        })
    }

    /// Compile (or fetch cached) and execute with the given inputs;
    /// returns the decomposed output tuple as host literals.
    pub fn execute(&mut self, key: &BucketKey, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if !self.cache.contains_key(key) {
            let entry = self
                .entry_for(key)
                .ok_or_else(|| anyhow!("no artifact for {key:?}"))?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.cache.insert(key.clone(), exe);
        }
        let exe = self.cache.get(key).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {key:?}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Number of compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

/// Build an `[r, c]` f32 literal from a row-major slice.
pub fn literal_2d(data: &[f32], r: usize, c: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), r * c);
    xla::Literal::vec1(data)
        .reshape(&[r as i64, c as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build a `[n]` f32 literal.
pub fn literal_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}
