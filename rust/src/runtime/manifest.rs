//! Artifact manifest: a plain whitespace format (no serde offline).
//!
//! ```text
//! # kind layers nodes fdim hidden classes file
//! train   2 512 1433 128 7 train_l2_n512_f1433_h128_c7.hlo.txt
//! predict 2 512 1433 128 7 predict_l2_n512_f1433_h128_c7.hlo.txt
//! ```

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(adj, x, y_onehot, mask, w*) -> (loss, grad_w*)`
    Train,
    /// `(adj, x, w*) -> (logits,)`
    Predict,
}

impl std::str::FromStr for ArtifactKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "train" => Ok(ArtifactKind::Train),
            "predict" => Ok(ArtifactKind::Predict),
            other => Err(anyhow!("unknown artifact kind '{other}'")),
        }
    }
}

/// One line of the manifest.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub kind: ArtifactKind,
    pub layers: usize,
    pub nodes: usize,
    pub fdim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub file: String,
}

/// Parse `manifest.txt`.
pub fn parse_manifest(path: &Path) -> Result<Vec<ManifestEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_manifest_str(&text)
}

/// Parse manifest text (split out for tests).
pub fn parse_manifest_str(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 7 {
            return Err(anyhow!("manifest line {}: want 7 fields, got {}", lineno + 1, fields.len()));
        }
        out.push(ManifestEntry {
            kind: fields[0].parse()?,
            layers: fields[1].parse().context("layers")?,
            nodes: fields[2].parse().context("nodes")?,
            fdim: fields[3].parse().context("fdim")?,
            hidden: fields[4].parse().context("hidden")?,
            classes: fields[5].parse().context("classes")?,
            file: fields[6].to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_entries() {
        let text = "# comment\n\ntrain 2 512 1433 128 7 a.hlo.txt\npredict 2 512 1433 128 7 b.hlo.txt\n";
        let es = parse_manifest_str(text).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].kind, ArtifactKind::Train);
        assert_eq!(es[1].kind, ArtifactKind::Predict);
        assert_eq!(es[0].nodes, 512);
        assert_eq!(es[0].file, "a.hlo.txt");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_manifest_str("train 2 512\n").is_err());
        assert!(parse_manifest_str("frobnicate 2 512 1433 128 7 a\n").is_err());
        assert!(parse_manifest_str("train x 512 1433 128 7 a\n").is_err());
    }
}
