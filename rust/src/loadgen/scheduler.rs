//! Pluggable dequeue policies for the open-loop event loop.
//!
//! The scheduler owns every query that has arrived but not yet been
//! served. The event loop asks it two things: "would you flush a batch
//! at virtual time `t`?" ([`Scheduler::pop`]) and "when would a held
//! query next force a flush?" ([`Scheduler::next_flush_at`], so the
//! loop can advance the clock straight to that instant when idle).
//! Policies must be deterministic: given the same enqueue/pop call
//! sequence they must make the same decisions, because answer
//! bit-identity tests replay schedules against them.

use crate::obs::hist::LogHistogram;
use std::collections::VecDeque;

/// A query waiting in the scheduler.
#[derive(Clone, Debug)]
pub struct PendingQuery {
    /// Position in the arrival schedule — the stable identity that
    /// ties an outcome back to the generator's event order.
    pub id: u64,
    pub node: u32,
    /// Home shard (the SLO batcher buckets by it; a flush is always
    /// one shard's micro-batch).
    pub shard: u32,
    /// Virtual arrival time (µs).
    pub arrival_us: u64,
    /// `arrival + SLO`: an answer completing later counts as late.
    pub deadline_us: u64,
}

/// See module docs.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Admit an arrived query.
    fn enqueue(&mut self, q: PendingQuery);

    /// The next micro-batch to dispatch at virtual time `now_us`, if
    /// the policy wants to flush one. All returned queries share one
    /// home shard. `drain = true` overrides the policy's batching
    /// patience (the event loop drains before a delta barrier and at
    /// end of schedule). Equivalent to [`pop_avoiding`](Self::pop_avoiding)
    /// with nothing busy.
    fn pop(&mut self, now_us: u64, drain: bool) -> Option<Vec<PendingQuery>> {
        self.pop_avoiding(now_us, drain, &|_| false)
    }

    /// Like [`pop`](Self::pop), but skip any batch homed on a shard
    /// `busy` reports `true` for — the event loop marks shards with an
    /// in-flight flush (or one already picked for the current wave),
    /// since two concurrent flushes may never share an engine. The
    /// oldest *eligible* work dispatches instead. Contract: under
    /// `drain`, return `Some` whenever any non-busy shard has held
    /// work; with nothing busy this must behave exactly like the
    /// sequential `pop` (bit-identity tests replay both).
    fn pop_avoiding(
        &mut self,
        now_us: u64,
        drain: bool,
        busy: &dyn Fn(u32) -> bool,
    ) -> Option<Vec<PendingQuery>>;

    /// Earliest virtual time at which a currently-held query forces a
    /// flush, if the policy is waiting on one. `None` means "nothing
    /// held" or "I never flush on time alone" (FIFO).
    fn next_flush_at(&self) -> Option<u64>;

    /// Queries currently held.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Streaming histogram of queue depth, one sample per
    /// [`enqueue`](Self::enqueue) (depth *after* admitting). The
    /// event loop reads max/mean/p99 from here instead of keeping its
    /// own counters — `LogHistogram` tracks exact max and sum, so the
    /// reported max/mean are bit-identical to the retired counter trio
    /// while p99 comes for free.
    fn queue_depth_hist(&self) -> &LogHistogram;
}

/// Strict arrival order, one query per flush — the classic baseline.
/// Its knee is set entirely by per-query service time: once the
/// offered rate exceeds `1 / service`, the queue grows without bound.
#[derive(Debug, Default)]
pub struct FifoScheduler {
    q: VecDeque<PendingQuery>,
    depth_hist: LogHistogram,
}

impl FifoScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn enqueue(&mut self, q: PendingQuery) {
        self.q.push_back(q);
        self.depth_hist.record(self.q.len() as u64);
    }

    fn pop_avoiding(
        &mut self,
        _now_us: u64,
        _drain: bool,
        busy: &dyn Fn(u32) -> bool,
    ) -> Option<Vec<PendingQuery>> {
        // multi-server FIFO: the oldest query whose shard is free goes
        // next (head-of-line blocking would idle the other slots).
        // With nothing busy this is exactly `pop_front`.
        let idx = self.q.iter().position(|p| !busy(p.shard))?;
        let q = self.q.remove(idx).expect("position came from this deque");
        Some(vec![q])
    }

    fn next_flush_at(&self) -> Option<u64> {
        // FIFO is always willing to serve immediately; the event loop
        // only consults this when it chose not to pop, i.e. when empty
        None
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn queue_depth_hist(&self) -> &LogHistogram {
        &self.depth_hist
    }
}

/// SLO-aware per-shard micro-batcher.
///
/// Queries accumulate in per-home-shard buckets. A bucket flushes
/// (whole, through the server's one-GEMM micro-batch path) when either
///
/// * it holds `batch_k` or more queries — the amortisation target, or
/// * its **oldest** query's deadline slack is spent: virtual time has
///   reached `deadline - reserve_us`, where `reserve_us` is the
///   service allowance withheld so a slack-triggered flush still has
///   time to actually execute before the deadline.
///
/// Among simultaneously-ready buckets the one with the oldest head
/// flushes first, shard id breaking ties — fully deterministic.
pub struct SloBatchScheduler {
    batch_k: usize,
    reserve_us: u64,
    buckets: Vec<VecDeque<PendingQuery>>,
    held: usize,
    depth_hist: LogHistogram,
}

impl SloBatchScheduler {
    /// `shards` must cover every shard id the event loop will route
    /// (use [`Server::num_shards`](crate::serve::Server::num_shards)).
    pub fn new(shards: usize, batch_k: usize, reserve_us: u64) -> Self {
        SloBatchScheduler {
            batch_k: batch_k.max(1),
            reserve_us,
            buckets: vec![VecDeque::new(); shards.max(1)],
            held: 0,
            depth_hist: LogHistogram::new(),
        }
    }

    fn flush_deadline(&self, q: &PendingQuery) -> u64 {
        q.deadline_us.saturating_sub(self.reserve_us)
    }

    /// Oldest-head bucket among those `ready` admits and `busy` does
    /// not veto; shard id breaks ties.
    fn pick(
        &self,
        busy: &dyn Fn(u32) -> bool,
        ready: impl Fn(&VecDeque<PendingQuery>) -> bool,
    ) -> Option<usize> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(s, b)| !b.is_empty() && !busy(*s as u32) && ready(b))
            .min_by_key(|(s, b)| (b.front().expect("non-empty").arrival_us, *s))
            .map(|(s, _)| s)
    }
}

impl Scheduler for SloBatchScheduler {
    fn name(&self) -> &'static str {
        "slo-batch"
    }

    fn enqueue(&mut self, q: PendingQuery) {
        let s = q.shard as usize;
        assert!(s < self.buckets.len(), "query routed to unknown shard {s}");
        self.buckets[s].push_back(q);
        self.held += 1;
        self.depth_hist.record(self.held as u64);
    }

    fn pop_avoiding(
        &mut self,
        now_us: u64,
        drain: bool,
        busy: &dyn Fn(u32) -> bool,
    ) -> Option<Vec<PendingQuery>> {
        let k = self.batch_k;
        let s = if drain {
            self.pick(busy, |_| true)
        } else {
            // K first (a full bucket amortises best), deadline second;
            // a flush takes the whole bucket, so under backlog a batch
            // can exceed K — that only amortises harder
            self.pick(busy, |b| b.len() >= k).or_else(|| {
                self.pick(busy, |b| self.flush_deadline(b.front().expect("non-empty")) <= now_us)
            })
        }?;
        let batch: Vec<PendingQuery> = self.buckets[s].drain(..).collect();
        self.held -= batch.len();
        Some(batch)
    }

    fn next_flush_at(&self) -> Option<u64> {
        self.buckets.iter().filter_map(|b| b.front()).map(|q| self.flush_deadline(q)).min()
    }

    fn len(&self) -> usize {
        self.held
    }

    fn queue_depth_hist(&self) -> &LogHistogram {
        &self.depth_hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64, shard: u32, arrival_us: u64, deadline_us: u64) -> PendingQuery {
        PendingQuery { id, node: id as u32, shard, arrival_us, deadline_us }
    }

    #[test]
    fn fifo_serves_in_arrival_order_one_at_a_time() {
        let mut f = FifoScheduler::new();
        for id in 0..3 {
            f.enqueue(q(id, (id % 2) as u32, id * 10, 1_000));
        }
        assert_eq!(f.len(), 3);
        for want in 0..3u64 {
            let batch = f.pop(0, false).expect("non-empty");
            assert_eq!(batch.len(), 1, "fifo never batches");
            assert_eq!(batch[0].id, want);
        }
        assert!(f.pop(0, false).is_none());
        assert!(f.next_flush_at().is_none());
    }

    #[test]
    fn batcher_flushes_whole_bucket_on_k() {
        let mut s = SloBatchScheduler::new(2, 2, 0);
        s.enqueue(q(0, 1, 0, 1_000_000));
        assert!(s.pop(0, false).is_none(), "below K with slack left: hold");
        s.enqueue(q(1, 1, 5, 1_000_000));
        let batch = s.pop(5, false).expect("bucket reached K");
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|p| p.shard == 1));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn batcher_flushes_on_deadline_slack() {
        let mut s = SloBatchScheduler::new(1, 100, 10);
        s.enqueue(q(0, 0, 0, 50));
        assert_eq!(s.next_flush_at(), Some(40), "deadline minus reserve");
        assert!(s.pop(39, false).is_none(), "slack remains: hold for more");
        let batch = s.pop(40, false).expect("slack exhausted");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batcher_buckets_per_shard_and_prefers_oldest_head() {
        let mut s = SloBatchScheduler::new(3, 2, 0);
        s.enqueue(q(0, 2, 0, 1_000));
        s.enqueue(q(1, 0, 1, 1_000));
        s.enqueue(q(2, 0, 2, 1_000));
        s.enqueue(q(3, 2, 3, 1_000));
        // both shard 0 and shard 2 buckets are at K; shard 2's head is
        // older so it flushes first
        let first = s.pop(3, false).expect("two buckets ready");
        assert!(first.iter().all(|p| p.shard == 2));
        let second = s.pop(3, false).expect("shard 0 still ready");
        assert!(second.iter().all(|p| p.shard == 0));
        assert_eq!(second.iter().map(|p| p.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn fifo_skips_busy_shard_then_resumes_order() {
        let mut f = FifoScheduler::new();
        f.enqueue(q(0, 1, 0, 1_000));
        f.enqueue(q(1, 2, 1, 1_000));
        f.enqueue(q(2, 1, 2, 1_000));
        // shard 1 has an in-flight flush: the oldest eligible query
        // (shard 2) dispatches instead of head-of-line blocking
        let batch = f.pop_avoiding(0, false, &|s| s == 1).expect("shard 2 is free");
        assert_eq!(batch[0].id, 1);
        // everything left is busy → nothing to dispatch this wave
        assert!(f.pop_avoiding(0, false, &|s| s == 1).is_none());
        assert_eq!(f.len(), 2, "skipped queries stay queued");
        // shard frees up → strict arrival order resumes
        assert_eq!(f.pop(0, false).expect("free again")[0].id, 0);
        assert_eq!(f.pop(0, false).expect("free again")[0].id, 2);
    }

    #[test]
    fn batcher_avoids_busy_bucket_even_under_drain() {
        let mut s = SloBatchScheduler::new(3, 2, 0);
        s.enqueue(q(0, 2, 0, 1_000));
        s.enqueue(q(1, 0, 1, 1_000));
        // shard 2's head is older, but its engine is busy: drain must
        // still make progress on shard 0 rather than stall the wave
        let first = s.pop_avoiding(3, true, &|sh| sh == 2).expect("shard 0 free");
        assert!(first.iter().all(|p| p.shard == 0));
        assert!(s.pop_avoiding(3, true, &|sh| sh == 2).is_none(), "only busy work left");
        let second = s.pop(3, true).expect("busy veto lifted");
        assert!(second.iter().all(|p| p.shard == 2));
        assert!(s.is_empty());
    }

    #[test]
    fn queue_depth_histogram_samples_every_enqueue() {
        let mut s = SloBatchScheduler::new(2, 4, 0);
        for id in 0..3u64 {
            s.enqueue(q(id, (id % 2) as u32, id, 1_000));
        }
        let h = s.queue_depth_hist();
        assert_eq!(h.count(), 3, "one sample per enqueue");
        assert_eq!(h.max(), 3, "exact max, tracked outside the buckets");
        assert!((h.mean() - 2.0).abs() < 1e-9, "depths were 1, 2, 3");
        // pops don't sample; the next enqueue sees the drained depth
        while s.pop(0, true).is_some() {}
        s.enqueue(q(9, 0, 10, 1_000));
        assert_eq!(s.queue_depth_hist().count(), 4);
        assert_eq!(s.queue_depth_hist().max(), 3, "depth after drain is 1 again");

        let mut f = FifoScheduler::new();
        f.enqueue(q(0, 0, 0, 1_000));
        f.enqueue(q(1, 0, 1, 1_000));
        assert_eq!(f.queue_depth_hist().count(), 2);
        assert_eq!(f.queue_depth_hist().max(), 2);
    }

    #[test]
    fn drain_overrides_batching_patience() {
        let mut s = SloBatchScheduler::new(2, 100, 0);
        s.enqueue(q(0, 0, 0, u64::MAX));
        assert!(s.pop(0, false).is_none(), "neither K nor deadline reached");
        let batch = s.pop(0, true).expect("drain must flush");
        assert_eq!(batch.len(), 1);
        assert!(s.is_empty());
    }
}
