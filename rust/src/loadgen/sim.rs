//! Virtual-time open-loop event loop with concurrent in-flight
//! flushes.
//!
//! One virtual clock, up to [`Server::serve_parallelism`] flush
//! *slots*. Arrivals from the pre-generated schedule are admitted when
//! the clock passes their instant; the scheduler fills every free slot
//! with a batch for a distinct free shard (two flushes never share an
//! engine), and the whole wave executes **physically in parallel** on
//! the server's scoped-thread pool
//! ([`Server::flush_shard_batches`]). Each flush's service time is its
//! own wall-clock span, measured inside its worker thread and folded
//! back into the virtual clock: a flush dispatched at `t` completes at
//! `t + span`, slots free as the clock passes completions, and while
//! shards are busy further scheduled arrivals pile up — queue depth
//! evolves exactly as it would against an N-way replica group under
//! that offered rate. With one slot this degrades to the original
//! sequential loop, decision for decision.
//!
//! Deltas are **barriers**: when the schedule yields a delta, the loop
//! stops admitting (the schedule is time-ordered, so everything behind
//! the delta stays out), drains the scheduler *and every in-flight
//! flush*, applies the delta, then resumes. This is precisely the
//! ordering a single mutation queue would impose, and it is what makes
//! every answer bit-identical to a sequential replay of the same
//! schedule at **any** slot count — batching cannot change answers
//! (per-row compute is independent; enforced by the serve tests),
//! queries never mutate state so their physical execution order is
//! irrelevant, and the barrier pins each query to the same graph
//! version it would see sequentially. Only the measured spans (and so
//! virtual latencies) are wall-clock-dependent; answers, predictions,
//! and versions are not.
//!
//! [`Server::serve_parallelism`]: crate::serve::Server::serve_parallelism
//! [`Server::flush_shard_batches`]: crate::serve::Server::flush_shard_batches

use super::generator::{Arrival, ArrivalKind};
use super::scheduler::{PendingQuery, Scheduler};
use crate::serve::Server;
use anyhow::Result;
use std::time::Instant;

/// Event-loop knobs.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// End-to-end SLO per query, in µs of virtual time.
    pub slo_us: u64,
    /// Keep each answer's probability vector on its outcome (the
    /// bit-identity tests compare them; benches leave this off to
    /// avoid the copies).
    pub record_probs: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { slo_us: 5_000, record_probs: false }
    }
}

/// One answered query with its queueing provenance.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Schedule position of the arrival.
    pub id: u64,
    pub node: u32,
    pub shard: u32,
    pub arrival_us: u64,
    /// When the scheduler handed the query to the server.
    pub dispatch_us: u64,
    /// When its flush finished (virtual clock).
    pub complete_us: u64,
    /// Queries sharing the flush (1 under FIFO).
    pub batch_size: usize,
    pub within_slo: bool,
    pub pred: u32,
    pub graph_version: u64,
    /// Present when [`SimOptions::record_probs`] is set.
    pub probs: Option<Vec<f32>>,
}

impl RequestOutcome {
    /// Time spent waiting in the scheduler (µs).
    pub fn queueing_us(&self) -> u64 {
        self.dispatch_us - self.arrival_us
    }

    /// Flush execution time (µs; wall-clock folded into virtual time,
    /// shared by the whole batch).
    pub fn service_us(&self) -> u64 {
        self.complete_us - self.dispatch_us
    }

    /// End-to-end latency (µs).
    pub fn latency_us(&self) -> u64 {
        self.complete_us - self.arrival_us
    }
}

/// Aggregate result of one schedule replay.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// One entry per scheduled query, sorted by schedule position.
    pub outcomes: Vec<RequestOutcome>,
    pub deltas_applied: usize,
    /// Virtual clock when the last event finished (µs).
    pub end_us: u64,
    /// Server flushes issued (batches, not queries).
    pub flushes: usize,
    /// Deepest scheduler queue observed (sampled at each admission;
    /// exact — the scheduler's [`LogHistogram`] tracks max outside its
    /// buckets).
    ///
    /// [`LogHistogram`]: crate::obs::hist::LogHistogram
    pub queue_depth_max: usize,
    /// Mean queue depth over those samples (exact, from the
    /// histogram's integer sum).
    pub queue_depth_mean: f64,
    /// p99 queue depth (log₂-bucketed nearest-rank, ≤ 2× relative
    /// error) — free now that the scheduler streams depths into a
    /// histogram instead of a counter trio.
    pub queue_depth_p99: u64,
    /// Most flushes ever simultaneously in flight (1 when the server
    /// serves sequentially; > 1 proves cross-shard overlap happened).
    pub peak_inflight: usize,
}

/// Replay `schedule` against `srv` under `sched`. See module docs for
/// the clock and barrier semantics.
pub fn run_open_loop(
    srv: &mut Server,
    schedule: &[Arrival],
    sched: &mut dyn Scheduler,
    opts: &SimOptions,
) -> Result<SimResult> {
    let slots = srv.serve_parallelism().max(1);
    // wall-clock scope over the whole replay; the virtual_span calls
    // below annotate the *virtual* timeline (queueing vs service vs
    // barrier drains) on their own trace lane. Annotation only — the
    // tracer never feeds back into the clock or the answers.
    let _loop_span =
        crate::span!("loadgen.run_open_loop", events = schedule.len(), slots = slots);
    let mut now_us: u64 = 0;
    let mut idx = 0usize;
    let mut armed_delta: Option<&crate::serve::GraphDelta> = None;
    let mut armed_at_us: u64 = 0;
    let mut outcomes: Vec<RequestOutcome> = Vec::new();
    let mut deltas_applied = 0usize;
    let mut flushes = 0usize;
    // flushes whose virtual completion the clock has not reached yet:
    // (home shard, complete_us). Length never exceeds `slots`.
    let mut inflight: Vec<(u32, u64)> = Vec::new();
    let mut peak_inflight = 0usize;
    loop {
        // 0. retire in-flight flushes the clock has reached — their
        //    shards and slots are free again
        inflight.retain(|&(_, c)| c > now_us);
        // 1. admit everything the clock has passed — but never past an
        //    unapplied delta
        while armed_delta.is_none() && idx < schedule.len() && schedule[idx].at_us <= now_us {
            match &schedule[idx].kind {
                ArrivalKind::Query { node } => {
                    let arrival_us = schedule[idx].at_us;
                    sched.enqueue(PendingQuery {
                        id: idx as u64,
                        node: *node,
                        shard: srv.shard_of(*node),
                        arrival_us,
                        deadline_us: arrival_us.saturating_add(opts.slo_us),
                    });
                    // the scheduler's histogram sampled this admission
                    // inside enqueue; mirror it into the server stats
                    srv.record_queue_depth(sched.len());
                }
                ArrivalKind::Delta(d) => {
                    armed_delta = Some(d);
                    armed_at_us = schedule[idx].at_us;
                }
            }
            idx += 1;
        }
        // 2. fill every free slot with a batch for a distinct free
        //    shard, then execute the wave physically in parallel. Each
        //    flush dispatches at `now` and completes at `now + span`,
        //    span measured inside its own worker thread.
        let drain = armed_delta.is_some() || idx >= schedule.len();
        let mut wave: Vec<Vec<PendingQuery>> = Vec::new();
        while inflight.len() + wave.len() < slots {
            let popped = {
                let busy = |s: u32| {
                    inflight.iter().any(|&(b, _)| b == s)
                        || wave.iter().any(|w: &Vec<PendingQuery>| w[0].shard == s)
                };
                sched.pop_avoiding(now_us, drain, &busy)
            };
            match popped {
                Some(batch) => {
                    debug_assert!(
                        batch.iter().all(|p| p.shard == batch[0].shard),
                        "a flush is one shard's batch"
                    );
                    wave.push(batch);
                }
                None => break,
            }
        }
        if !wave.is_empty() {
            let batches: Vec<(u32, Vec<u32>)> = wave
                .iter()
                .map(|b| (b[0].shard, b.iter().map(|p| p.node).collect()))
                .collect();
            let flushed = srv.flush_shard_batches(&batches)?;
            for (batch, f) in wave.iter().zip(flushed) {
                let complete_us = now_us + f.service_us;
                crate::obs::trace::virtual_span(
                    "loadgen.service",
                    batch[0].shard as u64,
                    now_us,
                    f.service_us,
                    &[("shard", batch[0].shard as i64), ("batch", batch.len() as i64)],
                );
                for (p, r) in batch.iter().zip(f.results) {
                    let within = complete_us <= p.deadline_us;
                    srv.record_slo_outcome(within);
                    crate::obs::trace::virtual_span(
                        "loadgen.queueing",
                        100 + batch[0].shard as u64,
                        p.arrival_us,
                        now_us.saturating_sub(p.arrival_us),
                        &[("id", p.id as i64), ("shard", batch[0].shard as i64)],
                    );
                    outcomes.push(RequestOutcome {
                        id: p.id,
                        node: p.node,
                        shard: batch[0].shard,
                        arrival_us: p.arrival_us,
                        dispatch_us: now_us,
                        complete_us,
                        batch_size: batch.len(),
                        within_slo: within,
                        pred: r.pred,
                        graph_version: r.graph_version,
                        probs: if opts.record_probs { Some(r.probs) } else { None },
                    });
                }
                flushes += 1;
                inflight.push((batch[0].shard, complete_us));
            }
            peak_inflight = peak_inflight.max(inflight.len());
            // don't advance the clock here: the next iteration may
            // retire nothing and fall through to step 4, which jumps
            // to the earliest of completion / arrival / deadline — so
            // a freed slot can dispatch again mid-overlap
            continue;
        }
        // 3. scheduler drained AND nothing in flight: the armed delta
        //    (if any) takes the whole server — deltas stay barriers at
        //    every slot count
        if armed_delta.is_some() && sched.is_empty() && inflight.is_empty() {
            let d = armed_delta.take().expect("just checked");
            let wall = Instant::now();
            srv.apply_delta(d)?;
            now_us += (wall.elapsed().as_secs_f64() * 1e6).ceil().max(1.0) as u64;
            deltas_applied += 1;
            // the barrier drain spans from when the delta arrived (and
            // admission stopped) to when its apply finished — the full
            // window the mutation held the server
            crate::obs::trace::virtual_span(
                "loadgen.delta_barrier",
                999,
                armed_at_us,
                now_us.saturating_sub(armed_at_us),
                &[("delta", deltas_applied as i64)],
            );
            continue;
        }
        // 4. idle at `now`: jump the clock to the next event strictly
        //    ahead of it — an arrival (unless a delta blocks
        //    admission), a scheduler deadline, or an in-flight
        //    completion — or finish
        let next_arrival = if armed_delta.is_none() && idx < schedule.len() {
            Some(schedule[idx].at_us)
        } else {
            None
        };
        let next_completion = inflight.iter().map(|&(_, c)| c).min();
        let wake = next_arrival
            .into_iter()
            .chain(sched.next_flush_at())
            .chain(next_completion)
            .filter(|&t| t > now_us)
            .min();
        match wake {
            Some(t) => now_us = t,
            None => break, // schedule exhausted, scheduler + slots drained
        }
    }
    debug_assert!(sched.is_empty(), "drain semantics leave nothing behind");
    debug_assert!(inflight.is_empty(), "every dispatched flush completed");
    outcomes.sort_by_key(|o| o.id);
    let depth = sched.queue_depth_hist();
    Ok(SimResult {
        outcomes,
        deltas_applied,
        end_us: now_us,
        flushes,
        queue_depth_max: depth.max() as usize,
        queue_depth_mean: depth.mean(),
        queue_depth_p99: depth.quantile(0.99),
        peak_inflight,
    })
}
