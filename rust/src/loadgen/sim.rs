//! Virtual-time open-loop event loop.
//!
//! One thread, one virtual clock. Arrivals from the pre-generated
//! schedule are admitted when the clock passes their instant; the
//! scheduler decides flushes; each flush's service time is measured
//! **wall-clock** and folded back into the virtual clock, so while the
//! server is "busy" serving a batch, further scheduled arrivals pile
//! up — queue depth evolves exactly as it would against a
//! single-threaded replica of the server under that offered rate.
//!
//! Deltas are **barriers**: when the schedule yields a delta, the loop
//! stops admitting (the schedule is time-ordered, so everything behind
//! the delta stays out), drains the scheduler, applies the delta, then
//! resumes. This is precisely the ordering a single mutation queue
//! would impose, and it is what makes every answer bit-identical to a
//! sequential replay of the same schedule — the batching itself cannot
//! change answers (per-row compute is independent; enforced by the
//! serve tests), and the barrier pins each query to the same graph
//! version it would see sequentially.

use super::generator::{Arrival, ArrivalKind};
use super::scheduler::{PendingQuery, Scheduler};
use crate::serve::Server;
use anyhow::Result;
use std::time::Instant;

/// Event-loop knobs.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// End-to-end SLO per query, in µs of virtual time.
    pub slo_us: u64,
    /// Keep each answer's probability vector on its outcome (the
    /// bit-identity tests compare them; benches leave this off to
    /// avoid the copies).
    pub record_probs: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { slo_us: 5_000, record_probs: false }
    }
}

/// One answered query with its queueing provenance.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Schedule position of the arrival.
    pub id: u64,
    pub node: u32,
    pub shard: u32,
    pub arrival_us: u64,
    /// When the scheduler handed the query to the server.
    pub dispatch_us: u64,
    /// When its flush finished (virtual clock).
    pub complete_us: u64,
    /// Queries sharing the flush (1 under FIFO).
    pub batch_size: usize,
    pub within_slo: bool,
    pub pred: u32,
    pub graph_version: u64,
    /// Present when [`SimOptions::record_probs`] is set.
    pub probs: Option<Vec<f32>>,
}

impl RequestOutcome {
    /// Time spent waiting in the scheduler (µs).
    pub fn queueing_us(&self) -> u64 {
        self.dispatch_us - self.arrival_us
    }

    /// Flush execution time (µs; wall-clock folded into virtual time,
    /// shared by the whole batch).
    pub fn service_us(&self) -> u64 {
        self.complete_us - self.dispatch_us
    }

    /// End-to-end latency (µs).
    pub fn latency_us(&self) -> u64 {
        self.complete_us - self.arrival_us
    }
}

/// Aggregate result of one schedule replay.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// One entry per scheduled query, sorted by schedule position.
    pub outcomes: Vec<RequestOutcome>,
    pub deltas_applied: usize,
    /// Virtual clock when the last event finished (µs).
    pub end_us: u64,
    /// Server flushes issued (batches, not queries).
    pub flushes: usize,
    /// Deepest scheduler queue observed (sampled at each admission).
    pub queue_depth_max: usize,
    /// Mean queue depth over those samples.
    pub queue_depth_mean: f64,
}

/// Replay `schedule` against `srv` under `sched`. See module docs for
/// the clock and barrier semantics.
pub fn run_open_loop(
    srv: &mut Server,
    schedule: &[Arrival],
    sched: &mut dyn Scheduler,
    opts: &SimOptions,
) -> Result<SimResult> {
    let mut now_us: u64 = 0;
    let mut idx = 0usize;
    let mut armed_delta: Option<&crate::serve::GraphDelta> = None;
    let mut outcomes: Vec<RequestOutcome> = Vec::new();
    let mut deltas_applied = 0usize;
    let mut flushes = 0usize;
    let mut depth_max = 0usize;
    let mut depth_sum = 0u64;
    let mut depth_samples = 0u64;
    loop {
        // 1. admit everything the clock has passed — but never past an
        //    unapplied delta
        while armed_delta.is_none() && idx < schedule.len() && schedule[idx].at_us <= now_us {
            match &schedule[idx].kind {
                ArrivalKind::Query { node } => {
                    let arrival_us = schedule[idx].at_us;
                    sched.enqueue(PendingQuery {
                        id: idx as u64,
                        node: *node,
                        shard: srv.shard_of(*node),
                        arrival_us,
                        deadline_us: arrival_us.saturating_add(opts.slo_us),
                    });
                    let depth = sched.len();
                    depth_max = depth_max.max(depth);
                    depth_sum += depth as u64;
                    depth_samples += 1;
                    srv.record_queue_depth(depth);
                }
                ArrivalKind::Delta(d) => armed_delta = Some(d),
            }
            idx += 1;
        }
        // 2. the server is free at `now`: flush if the policy will
        let drain = armed_delta.is_some() || idx >= schedule.len();
        if let Some(batch) = sched.pop(now_us, drain) {
            let shard = batch[0].shard;
            debug_assert!(batch.iter().all(|p| p.shard == shard), "a flush is one shard's batch");
            let nodes: Vec<u32> = batch.iter().map(|p| p.node).collect();
            let wall = Instant::now();
            let results = srv.flush_shard_batch(shard, &nodes)?;
            let service_us = (wall.elapsed().as_secs_f64() * 1e6).ceil().max(1.0) as u64;
            let complete_us = now_us + service_us;
            for (p, r) in batch.iter().zip(results) {
                let within = complete_us <= p.deadline_us;
                srv.record_slo_outcome(within);
                outcomes.push(RequestOutcome {
                    id: p.id,
                    node: p.node,
                    shard,
                    arrival_us: p.arrival_us,
                    dispatch_us: now_us,
                    complete_us,
                    batch_size: batch.len(),
                    within_slo: within,
                    pred: r.pred,
                    graph_version: r.graph_version,
                    probs: if opts.record_probs { Some(r.probs.clone()) } else { None },
                });
            }
            flushes += 1;
            now_us = complete_us;
            continue;
        }
        // 3. queue drained: the armed delta (if any) takes the server
        if let Some(d) = armed_delta.take() {
            let wall = Instant::now();
            srv.apply_delta(d)?;
            now_us += (wall.elapsed().as_secs_f64() * 1e6).ceil().max(1.0) as u64;
            deltas_applied += 1;
            continue;
        }
        // 4. idle: jump the clock to the next wake-up, or finish
        let next_arrival = if idx < schedule.len() { Some(schedule[idx].at_us) } else { None };
        match next_arrival.into_iter().chain(sched.next_flush_at()).min() {
            Some(t) => now_us = now_us.max(t),
            None => break, // schedule exhausted, scheduler drained
        }
    }
    debug_assert!(sched.is_empty(), "drain semantics leave nothing behind");
    outcomes.sort_by_key(|o| o.id);
    Ok(SimResult {
        outcomes,
        deltas_applied,
        end_us: now_us,
        flushes,
        queue_depth_max: depth_max,
        queue_depth_mean: if depth_samples > 0 {
            depth_sum as f64 / depth_samples as f64
        } else {
            0.0
        },
    })
}
