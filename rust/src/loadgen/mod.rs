//! Open-loop load generation against the serving tier.
//!
//! Every serving number up to fig13 is **closed-loop**: the next query
//! waits for the previous answer, so the harness can never offer more
//! load than the server absorbs and queueing collapse is invisible by
//! construction. This subsystem is the open-loop counterpart — the
//! ROADMAP's "millions-of-users test" — built from four pieces:
//!
//! * [`generator`] — a deterministic workload schedule: seeded
//!   exponential (Poisson-process) inter-arrival times at a
//!   configurable offered rate, Zipfian query-node popularity with
//!   configurable skew, and a mixed traffic class that interleaves
//!   [`GraphDelta`](crate::serve::GraphDelta) churn at a configurable
//!   fraction. The generator never reads server state, so the same
//!   seed replays the exact same byte sequence of arrivals against any
//!   scheduler — the property every A/B comparison below leans on.
//! * [`scheduler`] — the pluggable dequeue policy behind the
//!   [`Scheduler`] trait: [`FifoScheduler`] (strict arrival order, one
//!   query per flush — the baseline every queueing textbook collapses
//!   first) and [`SloBatchScheduler`] (accumulate per home shard until
//!   batch size `K` or the oldest request's deadline slack runs out,
//!   then flush the bucket through the server's micro-batched
//!   recompute path).
//! * [`sim`] — a virtual-time event loop with up to
//!   [`Server::serve_parallelism`](crate::serve::Server::serve_parallelism)
//!   concurrent in-flight flushes: arrivals enqueue at their scheduled
//!   virtual instant, the scheduler fills every free slot with a batch
//!   for a distinct free shard, the wave executes physically in
//!   parallel on the server's scoped-thread pool, and each flush's
//!   **own wall-clock span** is folded back into the virtual clock —
//!   queue depth evolves exactly as it would against an N-way replica
//!   group. Deltas act as barriers (drain scheduler *and* in-flight
//!   work, apply, resume), which keeps every answer bit-identical to a
//!   sequential replay of the same schedule at any slot count.
//! * [`report`] — the fig14 sweep: offered rate doubles per step until
//!   both schedulers are past the knee, each step running FIFO and the
//!   SLO batcher on the identical seeded schedule (at serve-pool width
//!   1 and N when configured, for the wall-clock comparison),
//!   reporting goodput (answers within SLO), p50/p99/p999 latency,
//!   queueing-vs-service split, queue depth, and physical replay
//!   wall-clock — md + csv + json like the fig11–13 family.

pub mod generator;
pub mod report;
pub mod scheduler;
pub mod sim;

pub use generator::{generate_schedule, Arrival, ArrivalKind, WorkloadConfig};
pub use report::{run_load_bench, LoadBenchConfig, LoadBenchReport, RateRow};
pub use scheduler::{FifoScheduler, PendingQuery, Scheduler, SloBatchScheduler};
pub use sim::{run_open_loop, RequestOutcome, SimOptions, SimResult};
