//! Fig 14 (ours): the latency-vs-offered-rate knee.
//!
//! The sweep anchors on a closed-loop capacity probe (warm
//! single-query qps), then doubles the offered rate per step. Each
//! step generates **one** seeded schedule and replays it against two
//! fresh servers — FIFO and the SLO-aware micro-batcher — so every
//! comparison row saw byte-identical arrivals, popularity, and churn.
//! Below the knee both schedulers answer nearly everything within SLO;
//! past it FIFO's queue grows without bound while the batcher folds
//! the backlog into ever-larger per-shard flushes and keeps a strictly
//! higher goodput. The sweep stops early once both modes are past the
//! knee — the collapse only deepens from there.
//!
//! With [`LoadBenchConfig::serve_threads`] > 1 every `(rate, mode)`
//! step additionally replays at serve-pool width 1, so each report
//! carries its own sequential-vs-parallel wall-clock comparison
//! (`wall_ms` column + headline speedup) on bit-identical answers —
//! the physical-overlap evidence the virtual clock alone can't give.

use super::generator::{generate_schedule, WorkloadConfig};
use super::scheduler::{FifoScheduler, Scheduler, SloBatchScheduler};
use super::sim::{run_open_loop, SimOptions, SimResult};
use crate::datasets::Dataset;
use crate::model::GcnParams;
use crate::obs::hist::percentile;
use crate::serve::{ServeConfig, Server};
use anyhow::Result;
use std::fmt::Write as _;
use std::time::Instant;

/// Fig 14 sweep configuration.
#[derive(Clone, Debug)]
pub struct LoadBenchConfig {
    /// Serving shards.
    pub shards: usize,
    /// Per-query deadline (virtual µs).
    pub slo_us: u64,
    /// SLO batcher flush size K.
    pub batch_k: usize,
    /// Zipf popularity skew (0 = uniform).
    pub zipf_s: f64,
    /// Fraction of arrivals that are graph deltas.
    pub churn_frac: f64,
    /// Edge churn ops per delta.
    pub edges_per_delta: usize,
    /// Arrivals per offered-rate step.
    pub events_per_step: usize,
    /// First offered rate in qps; 0 = auto-calibrate (the sweep then
    /// starts at a quarter of the measured closed-loop capacity, so
    /// the knee lands inside the sweep on any machine).
    pub rate_start_qps: f64,
    /// Geometric rate multiplier between steps.
    pub rate_mult: f64,
    /// Offered-rate steps (early-stopped once both schedulers
    /// collapse).
    pub rate_steps: usize,
    /// Serve-pool width for the headline rows
    /// ([`ServeConfig::serve_threads`]; 0 = auto). When the resolved
    /// width exceeds 1, each step also replays at width 1 for the
    /// wall-clock comparison columns.
    pub serve_threads: usize,
    pub seed: u64,
}

impl Default for LoadBenchConfig {
    fn default() -> Self {
        LoadBenchConfig {
            shards: 4,
            slo_us: 5_000,
            batch_k: 16,
            zipf_s: 0.9,
            churn_frac: 0.02,
            edges_per_delta: 4,
            events_per_step: 2_000,
            rate_start_qps: 0.0,
            rate_mult: 2.0,
            rate_steps: 6,
            serve_threads: 1,
            seed: 0,
        }
    }
}

/// One `(scheduler, offered rate)` sweep row.
#[derive(Clone, Debug)]
pub struct RateRow {
    pub mode: String,
    pub offered_qps: f64,
    /// Answers delivered per virtual second.
    pub achieved_qps: f64,
    /// Answers *within SLO* per virtual second — the goodput axis.
    pub goodput_qps: f64,
    /// Fraction of answers within SLO.
    pub goodput_ratio: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    /// Mean time a query waited in the scheduler.
    pub mean_queue_us: f64,
    /// Mean flush (service) time per answer.
    pub mean_service_us: f64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// p99 queue depth from the scheduler's streaming histogram
    /// (log₂-bucketed nearest-rank).
    pub queue_depth_p99: u64,
    pub answered: usize,
    pub deltas: usize,
    /// Serve-pool width this row ran at (1 = sequential replay).
    pub serve_threads: usize,
    /// Most flushes simultaneously in flight during the replay.
    pub peak_inflight: usize,
    /// Physical wall-clock of the whole replay, in ms — the
    /// before/after axis for the parallel serve path.
    pub wall_ms: f64,
}

/// Full sweep result; renders the fig14 md + csv.
#[derive(Clone, Debug)]
pub struct LoadBenchReport {
    pub rows: Vec<RateRow>,
    pub slo_us: u64,
    /// Closed-loop single-query capacity the sweep anchored on (qps).
    pub calibrated_qps: f64,
    /// Resolved headline serve-pool width; knee/goodput headlines read
    /// only rows at this width (the width-1 rows exist for the
    /// wall-clock comparison).
    pub serve_threads: usize,
}

impl LoadBenchReport {
    /// Highest offered rate at which `mode` still met ≥ 95% of
    /// deadlines — the operational definition of "before the knee".
    /// Reads the headline-width rows only.
    pub fn knee_qps(&self, mode: &str) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| {
                r.mode == mode
                    && r.serve_threads == self.serve_threads
                    && r.goodput_ratio >= 0.95
            })
            .map(|r| r.offered_qps)
            .fold(None, |acc: Option<f64>, q| Some(acc.map_or(q, |a| a.max(q))))
    }

    /// Total physical replay wall-clock at width 1 over width N across
    /// matched `(mode, rate)` rows — the parallel serve path's
    /// before/after headline. `None` when the sweep ran at width 1
    /// only (nothing to compare).
    pub fn wall_clock_speedup(&self) -> Option<f64> {
        if self.serve_threads <= 1 {
            return None;
        }
        let (mut seq_ms, mut par_ms, mut matched) = (0.0f64, 0.0f64, 0usize);
        for r in self.rows.iter().filter(|r| r.serve_threads == self.serve_threads) {
            if let Some(s) = self.rows.iter().find(|s| {
                s.serve_threads == 1 && s.mode == r.mode && s.offered_qps == r.offered_qps
            }) {
                seq_ms += s.wall_ms;
                par_ms += r.wall_ms;
                matched += 1;
            }
        }
        (matched > 0 && par_ms > 0.0).then(|| seq_ms / par_ms)
    }

    /// Goodput comparison at the highest swept rate past FIFO's knee:
    /// `(offered, fifo goodput, slo-batch goodput)` when such a step
    /// exists. The acceptance headline: the batcher's entry must be
    /// strictly higher.
    pub fn past_knee_goodput(&self) -> Option<(f64, f64, f64)> {
        let knee = self.knee_qps("fifo").unwrap_or(0.0);
        let head = self.serve_threads;
        let mut best: Option<(f64, f64, f64)> = None;
        for r in self
            .rows
            .iter()
            .filter(|r| r.mode == "fifo" && r.serve_threads == head && r.offered_qps > knee)
        {
            if let Some(b) = self.rows.iter().find(|b| {
                b.mode == "slo-batch" && b.serve_threads == head && b.offered_qps == r.offered_qps
            }) {
                if best.map_or(true, |(q, _, _)| r.offered_qps > q) {
                    best = Some((r.offered_qps, r.goodput_qps, b.goodput_qps));
                }
            }
        }
        best
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "| scheduler | threads | offered qps | goodput qps | within SLO | p50 ms | p99 ms \
             | p999 ms | wait µs | service µs | depth mean | depth p99 | depth max | deltas | wall ms |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {:.0} | {:.0} | {:.1}% | {:.2} | {:.2} | {:.2} | {:.0} | {:.0} | {:.1} | {} | {} | {} | {:.1} |",
                r.mode,
                r.serve_threads,
                r.offered_qps,
                r.goodput_qps,
                r.goodput_ratio * 100.0,
                r.p50_us / 1e3,
                r.p99_us / 1e3,
                r.p999_us / 1e3,
                r.mean_queue_us,
                r.mean_service_us,
                r.queue_depth_mean,
                r.queue_depth_p99,
                r.queue_depth_max,
                r.deltas,
                r.wall_ms,
            );
        }
        let _ = writeln!(
            s,
            "\ncalibrated closed-loop capacity ≈ {:.0} qps; SLO = {:.1} ms",
            self.calibrated_qps,
            self.slo_us as f64 / 1e3
        );
        if let Some(x) = self.wall_clock_speedup() {
            let _ = writeln!(
                s,
                "serve pool {} threads: total replay wall-clock {:.2}x vs sequential width 1 \
                 (answers bit-identical at both widths)",
                self.serve_threads, x,
            );
        }
        for mode in ["fifo", "slo-batch"] {
            match self.knee_qps(mode) {
                Some(k) => {
                    let _ = writeln!(s, "{mode} knee: last ≥95%-goodput rate ≈ {k:.0} qps");
                }
                None => {
                    let _ = writeln!(s, "{mode} knee: below the first swept rate");
                }
            }
        }
        if let Some((rate, fifo, batch)) = self.past_knee_goodput() {
            let _ = writeln!(
                s,
                "past the fifo knee (offered {:.0} qps): slo-batch goodput **{:.0} qps** vs fifo \
                 **{:.0} qps** ({:.2}x)",
                rate,
                batch,
                fifo,
                batch / fifo.max(1e-9),
            );
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "mode,serve_threads,offered_qps,achieved_qps,goodput_qps,goodput_ratio,p50_us,p99_us,\
             p999_us,mean_queue_us,mean_service_us,queue_depth_mean,queue_depth_p99,\
             queue_depth_max,answered,deltas,peak_inflight,wall_ms\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.2},{:.2},{:.2},{:.4},{:.1},{:.1},{:.1},{:.1},{:.1},{:.2},{},{},{},{},{},{:.2}",
                r.mode,
                r.serve_threads,
                r.offered_qps,
                r.achieved_qps,
                r.goodput_qps,
                r.goodput_ratio,
                r.p50_us,
                r.p99_us,
                r.p999_us,
                r.mean_queue_us,
                r.mean_service_us,
                r.queue_depth_mean,
                r.queue_depth_p99,
                r.queue_depth_max,
                r.answered,
                r.deltas,
                r.peak_inflight,
                r.wall_ms,
            );
        }
        s
    }

    /// Machine-readable form for the perf trajectory
    /// (`BENCH_fig14.json`). Hand-rolled — the build is registry-free,
    /// so no serde.
    pub fn to_json(&self) -> String {
        let knee = |m: &str| {
            self.knee_qps(m).map_or_else(|| "null".to_string(), |k| format!("{k:.2}"))
        };
        let mut s = String::from("{\n");
        let _ = writeln!(s, "  \"bench\": \"fig14_load_knee\",");
        let _ = writeln!(s, "  \"slo_us\": {},", self.slo_us);
        let _ = writeln!(s, "  \"calibrated_qps\": {:.2},", self.calibrated_qps);
        let _ = writeln!(s, "  \"serve_threads\": {},", self.serve_threads);
        let _ = writeln!(
            s,
            "  \"wall_clock_speedup\": {},",
            self.wall_clock_speedup()
                .map_or_else(|| "null".to_string(), |x| format!("{x:.3}"))
        );
        let _ = writeln!(s, "  \"knee_qps\": {{\"fifo\": {}, \"slo-batch\": {}}},", knee("fifo"), knee("slo-batch"));
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"mode\": \"{}\", \"serve_threads\": {}, \"offered_qps\": {:.2}, \
                 \"goodput_qps\": {:.2}, \"goodput_ratio\": {:.4}, \"p50_us\": {:.1}, \
                 \"p99_us\": {:.1}, \"p999_us\": {:.1}, \"peak_inflight\": {}, \
                 \"wall_ms\": {:.2}}}",
                r.mode,
                r.serve_threads,
                r.offered_qps,
                r.goodput_qps,
                r.goodput_ratio,
                r.p50_us,
                r.p99_us,
                r.p999_us,
                r.peak_inflight,
                r.wall_ms,
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn build_server(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &LoadBenchConfig,
    serve_threads: usize,
) -> Result<Server> {
    let scfg =
        ServeConfig { shards: cfg.shards, seed: cfg.seed, serve_threads, ..Default::default() };
    let mut srv = Server::for_dataset(ds, params.clone(), scfg)?;
    // warm to steady state first: the open-loop question is about
    // queueing under load, not cold caches
    let all: Vec<u32> = (0..ds.graph.num_nodes() as u32).collect();
    for chunk in all.chunks(256) {
        srv.query_batch(chunk)?;
    }
    Ok(srv)
}

/// Closed-loop warm single-query capacity (qps) — the sweep's anchor.
fn calibrate_qps(srv: &mut Server, n: usize) -> Result<f64> {
    let probes = 256.min(n.max(1));
    let t = Instant::now();
    for i in 0..probes {
        srv.query((i % n) as u32)?;
    }
    let mean_s = t.elapsed().as_secs_f64() / probes as f64;
    Ok(1.0 / mean_s.max(1e-9))
}

/// Run the full fig14 sweep. Each rate step replays one seeded
/// schedule under both schedulers on fresh warmed servers.
pub fn run_load_bench(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &LoadBenchConfig,
) -> Result<LoadBenchReport> {
    // resolve the headline pool width here, mirroring the server's own
    // resolution (shard count clamps to the node count at build), so
    // report rows are explicit even under `serve_threads: 0` (auto)
    let k = cfg.shards.clamp(1, ds.graph.num_nodes().max(1));
    let head_threads = match cfg.serve_threads {
        0 => crate::threads::available().min(k).max(1),
        n => n.min(k).max(1),
    };
    // width-1 replays ride along for the wall-clock comparison; at a
    // headline width of 1 there is nothing to compare
    let thread_set: Vec<usize> = if head_threads > 1 { vec![1, head_threads] } else { vec![1] };
    let calibrated = {
        let mut srv = build_server(ds, params, cfg, 1)?;
        calibrate_qps(&mut srv, ds.graph.num_nodes())?
    };
    let rate0 = if cfg.rate_start_qps > 0.0 { cfg.rate_start_qps } else { calibrated * 0.25 };
    let opts = SimOptions { slo_us: cfg.slo_us, record_probs: false };
    let mut rows: Vec<RateRow> = Vec::new();
    for step in 0..cfg.rate_steps {
        let rate = rate0 * cfg.rate_mult.powi(step as i32);
        let wcfg = WorkloadConfig {
            rate_qps: rate,
            events: cfg.events_per_step,
            zipf_s: cfg.zipf_s,
            churn_frac: cfg.churn_frac,
            edges_per_delta: cfg.edges_per_delta,
            // one seed per step, shared by both schedulers and both
            // pool widths: identical arrivals, popularity, and churn
            seed: cfg.seed ^ (step as u64 + 1).wrapping_mul(0x9E37_79B9),
        };
        let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
        let mut head_collapsed = true;
        for &threads in &thread_set {
            for mode in ["fifo", "slo-batch"] {
                let mut srv = build_server(ds, params, cfg, threads)?;
                let mut fifo = FifoScheduler::new();
                let mut batch =
                    SloBatchScheduler::new(srv.num_shards(), cfg.batch_k, cfg.slo_us / 4);
                let sched: &mut dyn Scheduler =
                    if mode == "fifo" { &mut fifo } else { &mut batch };
                let wall = Instant::now();
                let sim = run_open_loop(&mut srv, &schedule, sched, &opts)?;
                let wall_ms = wall.elapsed().as_secs_f64() * 1e3;
                let row = summarize(mode, rate, &sim, srv.serve_parallelism(), wall_ms);
                if row.serve_threads == head_threads && row.goodput_ratio >= 0.5 {
                    head_collapsed = false;
                }
                rows.push(row);
            }
        }
        // early-stop on the headline width: once both schedulers are
        // well past the knee there, the collapse only deepens
        if head_collapsed {
            break;
        }
    }
    Ok(LoadBenchReport {
        rows,
        slo_us: cfg.slo_us,
        calibrated_qps: calibrated,
        serve_threads: head_threads,
    })
}

fn summarize(
    mode: &str,
    offered_qps: f64,
    sim: &SimResult,
    serve_threads: usize,
    wall_ms: f64,
) -> RateRow {
    let answered = sim.outcomes.len();
    let denom = answered.max(1) as f64;
    let mut lat: Vec<f64> = sim.outcomes.iter().map(|o| o.latency_us() as f64).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let dur_s = (sim.end_us as f64 / 1e6).max(1e-9);
    let within = sim.outcomes.iter().filter(|o| o.within_slo).count();
    RateRow {
        mode: mode.to_string(),
        serve_threads,
        offered_qps,
        achieved_qps: answered as f64 / dur_s,
        goodput_qps: within as f64 / dur_s,
        goodput_ratio: within as f64 / denom,
        p50_us: percentile(&lat, 0.50),
        p99_us: percentile(&lat, 0.99),
        p999_us: percentile(&lat, 0.999),
        mean_queue_us: sim.outcomes.iter().map(|o| o.queueing_us() as f64).sum::<f64>() / denom,
        mean_service_us: sim.outcomes.iter().map(|o| o.service_us() as f64).sum::<f64>() / denom,
        queue_depth_mean: sim.queue_depth_mean,
        queue_depth_max: sim.queue_depth_max,
        queue_depth_p99: sim.queue_depth_p99,
        peak_inflight: sim.peak_inflight,
        answered,
        deltas: sim.deltas_applied,
        wall_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(mode: &str, offered: f64, ratio: f64) -> RateRow {
        row_at(mode, offered, ratio, 1, 100.0)
    }

    fn row_at(mode: &str, offered: f64, ratio: f64, threads: usize, wall_ms: f64) -> RateRow {
        RateRow {
            mode: mode.to_string(),
            offered_qps: offered,
            achieved_qps: offered * ratio,
            goodput_qps: offered * ratio,
            goodput_ratio: ratio,
            p50_us: 100.0,
            p99_us: 400.0,
            p999_us: 900.0,
            mean_queue_us: 50.0,
            mean_service_us: 80.0,
            queue_depth_mean: 1.5,
            queue_depth_max: 9,
            queue_depth_p99: 7,
            answered: 100,
            deltas: 2,
            serve_threads: threads,
            peak_inflight: threads,
            wall_ms,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn knee_and_past_knee_headline() {
        let rep = LoadBenchReport {
            rows: vec![
                row("fifo", 100.0, 1.0),
                row("slo-batch", 100.0, 1.0),
                row("fifo", 200.0, 0.97),
                row("slo-batch", 200.0, 0.99),
                row("fifo", 400.0, 0.30),
                row("slo-batch", 400.0, 0.90),
            ],
            slo_us: 5_000,
            calibrated_qps: 250.0,
            serve_threads: 1,
        };
        assert_eq!(rep.knee_qps("fifo"), Some(200.0));
        assert_eq!(rep.knee_qps("slo-batch"), Some(200.0));
        let (rate, fifo, batch) = rep.past_knee_goodput().expect("a step past the knee");
        assert_eq!(rate, 400.0);
        assert!(batch > fifo);
        assert!(rep.wall_clock_speedup().is_none(), "width-1 sweep has nothing to compare");
        let md = rep.to_markdown();
        assert!(md.contains("past the fifo knee"));
        assert!(md.contains("slo-batch"));
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 1 + rep.rows.len());
        assert!(csv.starts_with("mode,serve_threads,offered_qps"));
        let json = rep.to_json();
        assert!(json.contains("\"bench\": \"fig14_load_knee\""));
        assert!(json.contains("\"wall_clock_speedup\": null"));
    }

    #[test]
    fn parallel_rows_drive_headlines_and_speedup() {
        // a two-width sweep: knee/goodput headlines must read only the
        // width-4 rows, and the speedup must come from matched pairs
        let rep = LoadBenchReport {
            rows: vec![
                row_at("fifo", 100.0, 1.0, 1, 200.0),
                row_at("slo-batch", 100.0, 1.0, 1, 180.0),
                row_at("fifo", 100.0, 1.0, 4, 80.0),
                row_at("slo-batch", 100.0, 1.0, 4, 60.0),
                row_at("fifo", 200.0, 0.30, 1, 400.0),
                row_at("slo-batch", 200.0, 0.90, 1, 300.0),
                row_at("fifo", 200.0, 0.40, 4, 150.0),
                row_at("slo-batch", 200.0, 0.97, 4, 120.0),
            ],
            slo_us: 5_000,
            calibrated_qps: 250.0,
            serve_threads: 4,
        };
        // width-4 slo-batch holds 0.97 at 200 qps; width-1's 0.90 must
        // not leak into the knee
        assert_eq!(rep.knee_qps("slo-batch"), Some(200.0));
        assert_eq!(rep.knee_qps("fifo"), Some(100.0));
        let (rate, fifo, batch) = rep.past_knee_goodput().expect("width-4 step past the knee");
        assert_eq!(rate, 200.0);
        assert!(batch > fifo);
        let x = rep.wall_clock_speedup().expect("two widths present");
        let want = (200.0 + 180.0 + 400.0 + 300.0) / (80.0 + 60.0 + 150.0 + 120.0);
        assert!((x - want).abs() < 1e-9, "speedup {x} vs {want}");
        assert!(rep.to_markdown().contains("serve pool 4 threads"));
        assert!(rep.to_json().contains("\"serve_threads\": 4"));
    }
}
