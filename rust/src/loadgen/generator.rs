//! Deterministic open-loop workload schedules.
//!
//! The schedule is generated **up front** from a seed and the initial
//! graph alone — it never observes server state, so the same config
//! replays byte-identically no matter which scheduler consumes it or
//! how slowly the server runs. That is the defining property of an
//! open-loop generator (arrivals keep coming whether or not the server
//! keeps up) and what makes FIFO-vs-batcher comparisons apples to
//! apples.

use crate::graph::Csr;
use crate::rng::{Rng, Zipf};
use crate::serve::GraphDelta;
use std::collections::HashSet;

/// Workload shape for one offered-rate step.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Offered arrival rate in events per *virtual* second. The
    /// inter-arrival gaps are exponential with this rate (a Poisson
    /// process), so bursts occur naturally.
    pub rate_qps: f64,
    /// Total arrivals (queries + deltas) in the schedule.
    pub events: usize,
    /// Zipf popularity skew over query nodes; 0 = uniform.
    pub zipf_s: f64,
    /// Fraction of arrivals that are [`GraphDelta`] churn instead of
    /// queries.
    pub churn_frac: f64,
    /// Edge add/removes per delta (each delta also rewrites one
    /// feature row).
    pub edges_per_delta: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            rate_qps: 1_000.0,
            events: 2_000,
            zipf_s: 0.9,
            churn_frac: 0.02,
            edges_per_delta: 4,
            seed: 0,
        }
    }
}

/// What arrives: a query for one node, or a graph mutation.
#[derive(Clone, Debug)]
pub enum ArrivalKind {
    Query { node: u32 },
    Delta(GraphDelta),
}

/// One schedule event at a virtual instant.
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Virtual arrival time, microseconds from schedule start.
    /// Non-decreasing across the schedule.
    pub at_us: u64,
    pub kind: ArrivalKind,
}

/// Generate the full time-ordered arrival schedule for `cfg` against
/// the *initial* graph.
///
/// Popularity: Zipf ranks are mapped onto node ids through a seeded
/// permutation, so the hot set is spread across shards rather than
/// being the lowest ids (which partitioners tend to co-locate). Churn:
/// deltas are drawn from an evolving edge pool exactly like the fig12
/// churn schedule — adds avoid duplicates, removals pick live edges —
/// plus one feature-row rewrite each. Deltas deliberately never add or
/// remove *nodes*: the Zipf universe must stay alive for the whole
/// run so any scheduled query is always answerable.
pub fn generate_schedule(graph: &Csr, feature_dim: usize, cfg: &WorkloadConfig) -> Vec<Arrival> {
    let n = graph.num_nodes();
    assert!(n > 0, "cannot generate load against an empty graph");
    assert!(cfg.rate_qps > 0.0, "offered rate must be positive");
    assert!((0.0..=1.0).contains(&cfg.churn_frac), "churn_frac is a fraction");
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x10AD_F00D);
    let zipf = Zipf::new(n, cfg.zipf_s);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let mut edges: Vec<(u32, u32)> = graph.edges().collect();
    let mut present: HashSet<(u32, u32)> = edges.iter().copied().collect();
    let mut t_us = 0.0f64;
    let mut out = Vec::with_capacity(cfg.events);
    for _ in 0..cfg.events {
        // exponential inter-arrival by inverse-CDF; the clock advances
        // regardless of anything the server will later do
        let u = rng.gen_f64();
        t_us += -(1.0 - u).ln() / cfg.rate_qps * 1e6;
        let kind = if rng.gen_bool(cfg.churn_frac) {
            ArrivalKind::Delta(next_delta(
                &mut rng,
                n,
                feature_dim,
                cfg.edges_per_delta,
                &mut edges,
                &mut present,
            ))
        } else {
            ArrivalKind::Query { node: perm[zipf.sample(&mut rng)] }
        };
        out.push(Arrival { at_us: t_us as u64, kind });
    }
    out
}

fn next_delta(
    rng: &mut Rng,
    n: usize,
    feature_dim: usize,
    edges_per_delta: usize,
    edges: &mut Vec<(u32, u32)>,
    present: &mut HashSet<(u32, u32)>,
) -> GraphDelta {
    let mut d = GraphDelta::default();
    for _ in 0..edges_per_delta {
        if rng.gen_bool(0.5) && edges.len() > 1 {
            let i = rng.gen_range(edges.len());
            let e = edges.swap_remove(i);
            present.remove(&e);
            d.removed_edges.push(e);
        } else {
            // a few attempts to find a non-duplicate edge; give up
            // quietly on dense luck — the delta just carries one op less
            for _ in 0..8 {
                let u = rng.gen_range(n) as u32;
                let v = rng.gen_range(n) as u32;
                if u == v {
                    continue;
                }
                let c = if u < v { (u, v) } else { (v, u) };
                if present.insert(c) {
                    edges.push(c);
                    d.added_edges.push(c);
                    break;
                }
            }
        }
    }
    let fv = rng.gen_range(n) as u32;
    let row: Vec<f32> = (0..feature_dim).map(|_| rng.gen_f32() - 0.5).collect();
    d.updated_features.push((fv, row));
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn ring(n: usize) -> Csr {
        let edges: Vec<(u32, u32)> =
            (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
        GraphBuilder::new(n).edges(&edges).build()
    }

    #[test]
    fn schedule_is_time_ordered_and_mixed() {
        let g = ring(40);
        let cfg = WorkloadConfig {
            rate_qps: 10_000.0,
            events: 400,
            churn_frac: 0.1,
            ..Default::default()
        };
        let s = generate_schedule(&g, 3, &cfg);
        assert_eq!(s.len(), 400);
        assert!(s.windows(2).all(|w| w[0].at_us <= w[1].at_us), "arrivals must be time-ordered");
        let deltas = s.iter().filter(|a| matches!(a.kind, ArrivalKind::Delta(_))).count();
        assert!(deltas > 0 && deltas < 100, "churn mixes in at roughly churn_frac ({deltas})");
        for a in &s {
            if let ArrivalKind::Query { node } = a.kind {
                assert!((node as usize) < 40);
            }
        }
    }

    #[test]
    fn rate_controls_horizon() {
        let g = ring(20);
        let slow = generate_schedule(
            &g,
            2,
            &WorkloadConfig { rate_qps: 100.0, events: 200, ..Default::default() },
        );
        let fast = generate_schedule(
            &g,
            2,
            &WorkloadConfig { rate_qps: 10_000.0, events: 200, ..Default::default() },
        );
        // 200 events at 100 qps span ~2 s of virtual time; at 10k qps
        // only ~20 ms — two orders of magnitude apart
        assert!(slow.last().unwrap().at_us > 10 * fast.last().unwrap().at_us);
    }
}
