//! Interconnect topology model.
//!
//! The paper's testbed is "four 1080 Ti with **no NVLink**" — i.e. a
//! star over PCIe through host memory. This module models the three
//! topologies a deployment would pick from and converts the byte
//! ledger into estimated network time, which is what separates Fig. 7's
//! flattening from ideal linear scaling.

/// Interconnect shape between `n` workers and the leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every worker exchanges with the leader over a shared root link
    /// (PCIe-without-NVLink, the paper's testbed).
    Star,
    /// Ring all-reduce: 2(n-1)/n of the payload crosses each of n links
    /// in parallel.
    Ring,
    /// Dedicated full-mesh links; leader exchange fully parallel.
    FullMesh,
}

impl std::str::FromStr for Topology {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "star" => Ok(Topology::Star),
            "ring" => Ok(Topology::Ring),
            "mesh" | "fullmesh" => Ok(Topology::FullMesh),
            other => Err(format!("unknown topology '{other}' (star|ring|mesh)")),
        }
    }
}

/// Link parameters (defaults ≈ PCIe 3.0 x16: 12 GB/s, 5 µs latency).
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    pub bandwidth_bytes_per_sec: f64,
    pub latency_sec: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec { bandwidth_bytes_per_sec: 12.0e9, latency_sec: 5.0e-6 }
    }
}

/// Estimated wall-clock seconds for one synchronous gradient exchange
/// of `payload` bytes per worker across `workers` workers.
pub fn sync_time_sec(topology: Topology, link: LinkSpec, workers: usize, payload: u64) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let n = workers as f64;
    let p = payload as f64;
    match topology {
        // all up-loads + all down-loads serialise over the root link
        Topology::Star => 2.0 * n * p / link.bandwidth_bytes_per_sec + 2.0 * link.latency_sec,
        // ring all-reduce: 2(n-1) steps, each moving p/n per link in parallel
        Topology::Ring => {
            2.0 * (n - 1.0) * (p / n) / link.bandwidth_bytes_per_sec
                + 2.0 * (n - 1.0) * link.latency_sec
        }
        // parallel dedicated links: one up + one down
        Topology::FullMesh => 2.0 * p / link.bandwidth_bytes_per_sec + 2.0 * link.latency_sec,
    }
}

/// Estimated network seconds for a whole run.
pub fn run_network_time_sec(
    topology: Topology,
    link: LinkSpec,
    workers: usize,
    payload_per_round: u64,
    rounds: usize,
    feature_bytes_total: u64,
) -> f64 {
    let grads = sync_time_sec(topology, link, workers, payload_per_round) * rounds as f64;
    // feature fetches: pairwise transfers, overlap across workers on
    // non-star topologies
    let feat = match topology {
        Topology::Star => feature_bytes_total as f64 / link.bandwidth_bytes_per_sec,
        _ => feature_bytes_total as f64 / link.bandwidth_bytes_per_sec / workers.max(1) as f64,
    };
    grads + feat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_costs_nothing() {
        for t in [Topology::Star, Topology::Ring, Topology::FullMesh] {
            assert_eq!(sync_time_sec(t, LinkSpec::default(), 1, 1 << 20), 0.0);
        }
    }

    #[test]
    fn star_scales_linearly_with_workers() {
        let l = LinkSpec::default();
        let t2 = sync_time_sec(Topology::Star, l, 2, 1 << 20);
        let t8 = sync_time_sec(Topology::Star, l, 8, 1 << 20);
        assert!(t8 > 3.5 * t2, "t2 {t2} t8 {t8}");
    }

    #[test]
    fn ring_beats_star_at_scale() {
        let l = LinkSpec::default();
        let payload = 100u64 << 20;
        let star = sync_time_sec(Topology::Star, l, 8, payload);
        let ring = sync_time_sec(Topology::Ring, l, 8, payload);
        assert!(ring < star, "ring {ring} star {star}");
    }

    #[test]
    fn mesh_is_worker_count_independent() {
        let l = LinkSpec::default();
        let a = sync_time_sec(Topology::FullMesh, l, 2, 1 << 20);
        let b = sync_time_sec(Topology::FullMesh, l, 16, 1 << 20);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn parse_topologies() {
        assert_eq!("star".parse::<Topology>().unwrap(), Topology::Star);
        assert_eq!("ring".parse::<Topology>().unwrap(), Topology::Ring);
        assert_eq!("mesh".parse::<Topology>().unwrap(), Topology::FullMesh);
        assert!("torus".parse::<Topology>().is_err());
    }

    #[test]
    fn run_time_accumulates_rounds() {
        let l = LinkSpec::default();
        let one = run_network_time_sec(Topology::Star, l, 4, 1 << 20, 1, 0);
        let ten = run_network_time_sec(Topology::Star, l, 4, 1 << 20, 10, 0);
        assert!((ten - 10.0 * one).abs() < 1e-9);
    }
}
