//! Simulated interconnect accounting.
//!
//! The paper's testbed measures two traffic classes we reproduce as
//! first-class counters (Table 4 "Communication Size"):
//!
//! * **feature traffic** — node features/embeddings crossing processor
//!   boundaries during neighbourhood aggregation. Each 1-hop candidate
//!   replication node transmits once per incident cross-partition edge
//!   per epoch; deeper-hop candidates transmit once per epoch
//!   (recursive prefetch). Locally replicated nodes transmit nothing —
//!   that is exactly the saving GAD-Partition buys.
//! * **gradient traffic** — the (weighted) global consensus exchange:
//!   every round each worker uploads its gradient and downloads the
//!   consensus parameters.
//!
//! Two more classes extend the same story beyond lock-step training:
//! **resync traffic** (async engine replica pulls) and **serving
//! traffic** (the inference subsystem's halo replication and
//! [`GraphDelta`](crate::serve::GraphDelta) propagation — the bytes a
//! sharded serving tier moves so that queries themselves need zero
//! cross-shard feature fetches).

pub mod topology;

pub use topology::{run_network_time_sec, sync_time_sec, LinkSpec, Topology};

use crate::graph::{candidate_replication_nodes, Csr};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};

/// Byte counters, shared across worker threads.
#[derive(Default, Debug)]
pub struct CommLedger {
    /// Halo/feature pulls during subgraph construction.
    feature_bytes: AtomicU64,
    /// Worker->leader gradient pushes; relaxed ordering is safe because
    /// counters are read only after the thread scope joins.
    gradient_bytes: AtomicU64,
    /// Replica re-synchronisation traffic (async engine: a laggard
    /// whose gradient exceeded the staleness bound, or a recovered
    /// worker rejoining, pulls a fresh parameter snapshot from the
    /// leader). Accounted separately from gradient traffic so the
    /// async mode's recovery overhead is visible in reports.
    resync_bytes: AtomicU64,
    /// Inference-serving traffic: halo feature replication at shard
    /// build time and graph-delta propagation to the shards that hold
    /// the touched region. Queries themselves are shard-local (that is
    /// the augmented-subgraph win applied to serving), so this class is
    /// the *entire* cross-shard cost of the serving tier.
    serving_bytes: AtomicU64,
    /// Online shard-rebalancing traffic: boundary-node migrations
    /// (feature rows, cache rows, halo joins) moved between shards to
    /// restore load balance after elastic-membership skew. Accounted
    /// separately from the serving class so the bench can compare the
    /// rebalancer's cost against a full repartition's replication bill.
    rebalance_bytes: AtomicU64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_feature(&self, bytes: u64) {
        self.feature_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_gradient(&self, bytes: u64) {
        self.gradient_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_resync(&self, bytes: u64) {
        self.resync_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_serving(&self, bytes: u64) {
        self.serving_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_rebalance(&self, bytes: u64) {
        self.rebalance_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn feature_bytes(&self) -> u64 {
        self.feature_bytes.load(Ordering::Relaxed)
    }

    pub fn gradient_bytes(&self) -> u64 {
        self.gradient_bytes.load(Ordering::Relaxed)
    }

    pub fn resync_bytes(&self) -> u64 {
        self.resync_bytes.load(Ordering::Relaxed)
    }

    pub fn serving_bytes(&self) -> u64 {
        self.serving_bytes.load(Ordering::Relaxed)
    }

    pub fn rebalance_bytes(&self) -> u64 {
        self.rebalance_bytes.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        self.feature_bytes()
            + self.gradient_bytes()
            + self.resync_bytes()
            + self.serving_bytes()
            + self.rebalance_bytes()
    }
}

/// Snapshot for reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    pub feature_bytes: u64,
    pub gradient_bytes: u64,
    pub resync_bytes: u64,
    pub serving_bytes: u64,
    pub rebalance_bytes: u64,
}

impl CommStats {
    pub fn from_ledger(l: &CommLedger) -> Self {
        CommStats {
            feature_bytes: l.feature_bytes(),
            gradient_bytes: l.gradient_bytes(),
            resync_bytes: l.resync_bytes(),
            serving_bytes: l.serving_bytes(),
            rebalance_bytes: l.rebalance_bytes(),
        }
    }

    pub fn total_mb(&self) -> f64 {
        (self.feature_bytes
            + self.gradient_bytes
            + self.resync_bytes
            + self.serving_bytes
            + self.rebalance_bytes) as f64
            / 1e6
    }

    pub fn feature_mb(&self) -> f64 {
        self.feature_bytes as f64 / 1e6
    }

    pub fn resync_mb(&self) -> f64 {
        self.resync_bytes as f64 / 1e6
    }

    pub fn serving_mb(&self) -> f64 {
        self.serving_bytes as f64 / 1e6
    }

    pub fn rebalance_mb(&self) -> f64 {
        self.rebalance_bytes as f64 / 1e6
    }
}

/// Per-epoch feature traffic (bytes) for one part, given the nodes it
/// has locally replicated. `hops` = GCN layer count.
pub fn feature_traffic_per_epoch(
    graph: &Csr,
    assignment: &[u32],
    part: u32,
    replicas: &[u32],
    hops: usize,
    feature_dim: usize,
) -> u64 {
    let replicated: HashSet<u32> = replicas.iter().copied().collect();
    let candidates = candidate_replication_nodes(graph, assignment, part, hops);
    let bytes_per_node = (feature_dim * std::mem::size_of::<f32>()) as u64;
    let mut transfers = 0u64;
    for &v in &candidates {
        if replicated.contains(&v) {
            continue;
        }
        // edges from v into the part => one embedding message each;
        // candidates with no direct edge (deeper hops) cost one prefetch
        let cross = graph
            .neighbors(v as usize)
            .iter()
            .filter(|&&t| assignment[t as usize] == part)
            .count() as u64;
        transfers += cross.max(1);
    }
    transfers * bytes_per_node
}

/// Access-frequency-weighted feature traffic (bytes per epoch) — the
/// paper's own model: every boundary node's aggregation follows the
/// random-walk access pattern, so candidate `v` is fetched
/// `I(v) × |B(g)|` times per epoch unless locally replicated. This is
/// the quantity GAD-Partition halves: replicas are chosen as the
/// top-importance walks, i.e. exactly the heaviest terms of this sum.
pub fn weighted_feature_traffic_per_epoch(
    importance: &[(u32, f64)],
    replicas: &[u32],
    boundary_count: usize,
    feature_dim: usize,
) -> u64 {
    let replicated: HashSet<u32> = replicas.iter().copied().collect();
    let bytes_per_node = (feature_dim * std::mem::size_of::<f32>()) as f64;
    let mut expected = 0.0f64;
    for &(v, i) in importance {
        if !replicated.contains(&v) {
            expected += i * boundary_count as f64;
        }
    }
    (expected * bytes_per_node) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// 0,1 in part 0; 2 (hub, 3 cross edges... build): edges 0-2,1-2,2-3
    fn fixture() -> (Csr, Vec<u32>) {
        let g = GraphBuilder::new(4).edges(&[(0, 2), (1, 2), (2, 3)]).build();
        (g, vec![0, 0, 1, 1])
    }

    #[test]
    fn traffic_counts_cross_edges() {
        let (g, a) = fixture();
        // candidates for part 0 (2 hops): {2, 3}; node 2 has 2 cross
        // edges into part 0, node 3 none (1 prefetch) -> 3 transfers
        let bytes = feature_traffic_per_epoch(&g, &a, 0, &[], 2, 10);
        assert_eq!(bytes, 3 * 10 * 4);
    }

    #[test]
    fn replication_removes_traffic() {
        let (g, a) = fixture();
        let without = feature_traffic_per_epoch(&g, &a, 0, &[], 2, 10);
        let with_hub = feature_traffic_per_epoch(&g, &a, 0, &[2], 2, 10);
        assert!(with_hub < without);
        assert_eq!(with_hub, 10 * 4); // only node 3's prefetch remains
        let all = feature_traffic_per_epoch(&g, &a, 0, &[2, 3], 2, 10);
        assert_eq!(all, 0);
    }

    #[test]
    fn weighted_traffic_drops_with_replication() {
        let imp = vec![(10u32, 0.5), (11, 0.3), (12, 0.01)];
        let all = weighted_feature_traffic_per_epoch(&imp, &[], 10, 8);
        let hub_gone = weighted_feature_traffic_per_epoch(&imp, &[10], 10, 8);
        assert!(hub_gone < all);
        // replicating the hub removes the lion's share
        assert!((hub_gone as f64) < 0.5 * all as f64, "{hub_gone} vs {all}");
        let none_left = weighted_feature_traffic_per_epoch(&imp, &[10, 11, 12], 10, 8);
        assert_eq!(none_left, 0);
    }

    #[test]
    fn ledger_accumulates_across_threads() {
        let ledger = CommLedger::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        ledger.record_feature(3);
                        ledger.record_gradient(5);
                        ledger.record_resync(2);
                        ledger.record_serving(7);
                        ledger.record_rebalance(1);
                    }
                });
            }
        });
        assert_eq!(ledger.feature_bytes(), 1200);
        assert_eq!(ledger.gradient_bytes(), 2000);
        assert_eq!(ledger.resync_bytes(), 800);
        assert_eq!(ledger.serving_bytes(), 2800);
        assert_eq!(ledger.rebalance_bytes(), 400);
        assert_eq!(ledger.total_bytes(), 7200);
        assert_eq!(CommStats::from_ledger(&ledger).total_mb(), 7200.0 / 1e6);
    }
}
