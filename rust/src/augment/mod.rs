//! GAD-Partition local subgraph augmentation (paper §3.2.2, Algorithm 1).
//!
//! After partitioning, each subgraph is augmented with *replicated*
//! copies of important remote nodes so that training needs (almost) no
//! cross-processor neighbour fetches:
//!
//! 1. [`importance`] — Monte-Carlo random-walk importance `I(v)` over
//!    the candidate replication nodes (Eq. 3), with the walk budget
//!    chosen from the Monte-Carlo error bound (Eq. 4) and walk length
//!    `l =` number of GCN layers (Property 1).
//! 2. [`select`] — replication budget `n(g) = α (1 + d(g)) |v|`
//!    (Eq. 5–6) and depth-first whole-walk selection, which cannot
//!    produce dangling replicas (every walk starts at a boundary node).

mod importance;
mod select;

pub use importance::{walk_importance, ImportanceReport};
pub use select::select_replicas;

use crate::graph::{candidate_replication_nodes, GraphView, Subgraph};
use crate::rng::Rng;

/// Tunables for augmentation.
#[derive(Clone, Debug)]
pub struct AugmentConfig {
    /// Replication coefficient α of Eq. 6 (paper: 0.01).
    pub alpha: f64,
    /// Walk length = GCN layer count (Property 1).
    pub walk_length: usize,
    /// Monte-Carlo relative error target E of Eq. 4 (paper: 0.05).
    pub mc_error: f64,
    /// z-statistic for the confidence level (paper: 1.96 ≙ 95%).
    pub z_c: f64,
    /// Hard cap on walks per subgraph (guards pathological variance).
    pub max_walks: usize,
    pub seed: u64,
}

impl Default for AugmentConfig {
    fn default() -> Self {
        AugmentConfig {
            alpha: 0.01,
            walk_length: 2,
            mc_error: 0.05,
            z_c: 1.96,
            max_walks: 200_000,
            seed: 0,
        }
    }
}

/// A partition subgraph extended with replicated remote nodes.
#[derive(Clone, Debug)]
pub struct AugmentedSubgraph {
    /// Which part this came from.
    pub part: u32,
    /// Induced subgraph over base + replicated nodes (global ids in
    /// `sub.global_ids`).
    pub sub: Subgraph,
    /// Per-local-node flag: true -> replica (excluded from the loss;
    /// provides neighbourhood context only).
    pub is_replica: Vec<bool>,
    /// Importance I(v) of every candidate replication node considered
    /// (global id -> importance), kept for communication accounting.
    pub candidate_importance: Vec<(u32, f64)>,
    /// Replicated global ids (sorted).
    pub replicas: Vec<u32>,
    /// Walks performed by the Monte-Carlo estimator (diagnostics).
    pub walks_used: usize,
}

impl AugmentedSubgraph {
    /// Number of base (non-replica) nodes.
    pub fn base_len(&self) -> usize {
        self.is_replica.iter().filter(|&&r| !r).count()
    }
}

/// Augment one part of `assignment` per Algorithm 1.
pub fn augment_part<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    part: u32,
    cfg: &AugmentConfig,
) -> AugmentedSubgraph {
    let base_nodes: Vec<u32> = (0..graph.num_nodes() as u32)
        .filter(|&v| assignment[v as usize] == part)
        .collect();
    let candidates = candidate_replication_nodes(graph, assignment, part, cfg.walk_length);

    let mut rng = Rng::seed_from_u64(cfg.seed ^ (part as u64).wrapping_mul(0x9E37_79B9));
    let report = walk_importance(graph, assignment, part, &candidates, cfg, &mut rng);
    let replicas = select_replicas(graph, &base_nodes, &candidates, &report, cfg);

    let mut all = base_nodes.clone();
    all.extend_from_slice(&replicas);
    let sub = Subgraph::induce(graph, &all);
    let base_set: std::collections::HashSet<u32> = base_nodes.iter().copied().collect();
    let is_replica = sub
        .global_ids
        .iter()
        .map(|g| !base_set.contains(g))
        .collect();

    AugmentedSubgraph {
        part,
        sub,
        is_replica,
        candidate_importance: report.importance,
        replicas,
        walks_used: report.walks_used,
    }
}

/// Augment every part; returns one [`AugmentedSubgraph`] per part.
pub fn augment_all<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    k: usize,
    cfg: &AugmentConfig,
) -> Vec<AugmentedSubgraph> {
    (0..k as u32)
        .map(|p| augment_part(graph, assignment, p, cfg))
        .collect()
}

/// A non-augmented part wrapped in the same type (replicas empty) so
/// the trainer can run either mode through one code path.
pub fn plain_part<G: GraphView>(graph: &G, assignment: &[u32], part: u32) -> AugmentedSubgraph {
    let base_nodes: Vec<u32> = (0..graph.num_nodes() as u32)
        .filter(|&v| assignment[v as usize] == part)
        .collect();
    let sub = Subgraph::induce(graph, &base_nodes);
    let n = sub.len();
    AugmentedSubgraph {
        part,
        sub,
        is_replica: vec![false; n],
        candidate_importance: Vec::new(),
        replicas: Vec::new(),
        walks_used: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::graph::Csr;
    use crate::partition::{partition, PartitionConfig};

    fn fixture() -> (Csr, Vec<u32>) {
        let d = SyntheticSpec::tiny().generate(1);
        let p = partition(&d.graph, &PartitionConfig { k: 4, seed: 1, ..Default::default() });
        (d.graph, p.assignment)
    }

    #[test]
    fn replicas_are_remote_nodes() {
        let (g, a) = fixture();
        let aug = augment_part(&g, &a, 0, &AugmentConfig::default());
        for &r in &aug.replicas {
            assert_ne!(a[r as usize], 0, "replica {r} should be remote");
        }
    }

    #[test]
    fn budget_respected() {
        let (g, a) = fixture();
        let cfg = AugmentConfig { alpha: 0.01, ..Default::default() };
        let aug = augment_part(&g, &a, 0, &cfg);
        let base = aug.base_len();
        // n(g) = alpha * (1 + d) * |v| <= alpha * 2 * |v| (+1 walk slack)
        let max_budget = (cfg.alpha * 2.0 * base as f64).ceil() as usize + cfg.walk_length + 1;
        assert!(
            aug.replicas.len() <= max_budget.max(1),
            "replicas {} > budget {max_budget}",
            aug.replicas.len()
        );
    }

    #[test]
    fn no_dangling_replicas() {
        // every replica must be connected to the subgraph (depth-first
        // whole-walk selection guarantees a path to a boundary node)
        let (g, a) = fixture();
        let aug = augment_part(&g, &a, 1, &AugmentConfig::default());
        // BFS from base nodes within the augmented subgraph
        let n = aug.sub.len();
        let mut seen: Vec<bool> = aug.is_replica.iter().map(|&r| !r).collect();
        let mut queue: std::collections::VecDeque<usize> =
            (0..n).filter(|&i| seen[i]).collect();
        while let Some(v) = queue.pop_front() {
            for &t in aug.sub.csr.neighbors(v) {
                if !seen[t as usize] {
                    seen[t as usize] = true;
                    queue.push_back(t as usize);
                }
            }
        }
        for i in 0..n {
            if aug.is_replica[i] {
                assert!(seen[i], "dangling replica local={i}");
            }
        }
    }

    #[test]
    fn plain_part_has_no_replicas() {
        let (g, a) = fixture();
        let p = plain_part(&g, &a, 2);
        assert!(p.replicas.is_empty());
        assert!(p.is_replica.iter().all(|&r| !r));
        assert_eq!(p.base_len(), p.sub.len());
    }

    #[test]
    fn augment_all_covers_every_part() {
        let (g, a) = fixture();
        let augs = augment_all(&g, &a, 4, &AugmentConfig::default());
        assert_eq!(augs.len(), 4);
        let total_base: usize = augs.iter().map(|s| s.base_len()).sum();
        assert_eq!(total_base, g.num_nodes());
    }

    #[test]
    fn deterministic_per_seed() {
        let (g, a) = fixture();
        let c = AugmentConfig { seed: 9, ..Default::default() };
        let x = augment_part(&g, &a, 0, &c);
        let y = augment_part(&g, &a, 0, &c);
        assert_eq!(x.replicas, y.replicas);
    }
}
