//! Depth-first whole-walk replica selection (paper Algorithm 1 lines
//! 18–26).
//!
//! Naively copying the top-`n(g)` important candidates can produce
//! *dangling* replicas (no path back to the subgraph). The paper's fix:
//! score whole walks `I(RW) = Σ_{v∈RW} I(v)`, take walks in descending
//! score order, and add their unseen candidate nodes until the budget
//! `n(g) = α (1 + d(g)) |v|` (Eq. 6) is filled. Every walk starts at a
//! boundary node, so every replica arrives with a path into the part.

use super::importance::ImportanceReport;
use super::AugmentConfig;
use crate::graph::{density, GraphView, Subgraph};
use std::collections::HashSet;

/// Replication budget `n(g)` of Eq. 6 for a part with `base_nodes`.
pub fn replication_budget<G: GraphView>(graph: &G, base_nodes: &[u32], alpha: f64) -> usize {
    let sub = Subgraph::induce(graph, base_nodes);
    let d = density(&sub.csr);
    (alpha * (1.0 + d) * base_nodes.len() as f64).ceil() as usize
}

/// Pick replicas per the depth-first walk strategy. Returns sorted
/// global ids, at most `budget (+ one final walk's overshoot)` — the
/// paper fills until `|v'| = n(g)`, we stop the moment the budget is
/// met mid-walk, so the bound is exact.
pub fn select_replicas<G: GraphView>(
    graph: &G,
    base_nodes: &[u32],
    candidates: &[u32],
    report: &ImportanceReport,
    cfg: &AugmentConfig,
) -> Vec<u32> {
    let budget = replication_budget(graph, base_nodes, cfg.alpha);
    if budget == 0 || candidates.is_empty() || report.walks.is_empty() {
        return Vec::new();
    }
    let cand_set: HashSet<u32> = candidates.iter().copied().collect();

    // score each walk: sum of I(v) over its candidate nodes
    let mut scored: Vec<(f64, usize)> = report
        .walks
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let s: f64 = w
                .iter()
                .filter(|v| cand_set.contains(v))
                .map(|&v| report.get(v))
                .sum();
            (s, i)
        })
        .filter(|&(s, _)| s > 0.0)
        .collect();
    // descending by score; stable tiebreak on index for determinism
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));

    // Importance threshold: the I(v) of the budget-th best candidate.
    // Within a walk we keep descending only while nodes clear the
    // threshold — otherwise whole-walk copying burns the budget on a
    // hub's low-importance walk tail (hub + 2 arbitrary neighbours)
    // instead of the next hub. Connectivity is preserved because a
    // node is added only while its walk prefix is local or chosen.
    let theta = {
        let mut imps: Vec<f64> = candidates.iter().map(|&c| report.get(c)).collect();
        imps.sort_by(|a, b| b.partial_cmp(a).unwrap());
        imps.get(budget.saturating_sub(1)).copied().unwrap_or(0.0)
    };

    let base_set: HashSet<u32> = base_nodes.iter().copied().collect();
    let mut chosen: Vec<u32> = Vec::with_capacity(budget);
    let mut seen: HashSet<u32> = HashSet::with_capacity(budget * 2);
    // two passes: strict threshold first, then fill leftover budget
    for pass_theta in [theta, 0.0] {
        'walks: for &(_, wi) in &scored {
            for &v in &report.walks[wi] {
                if base_set.contains(&v) || seen.contains(&v) {
                    continue; // local or already replicated: stays connected
                }
                if !cand_set.contains(&v) {
                    continue 'walks; // left the candidate region
                }
                if report.get(v) < pass_theta {
                    continue 'walks; // deeper nodes would dangle off a skipped one
                }
                seen.insert(v);
                chosen.push(v);
                if chosen.len() >= budget {
                    chosen.sort_unstable();
                    return chosen;
                }
            }
        }
    }
    chosen.sort_unstable();
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::augment::walk_importance;
    use crate::graph::{candidate_replication_nodes, GraphBuilder};
    use crate::rng::Rng;

    #[test]
    fn budget_formula_matches_eq6() {
        // path graph of 4 nodes: density = 0.5, alpha=0.5 ->
        // n = ceil(0.5 * 1.5 * 4) = 3
        let g = GraphBuilder::new(8)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7)])
            .build();
        let base = [0u32, 1, 2, 3];
        assert_eq!(replication_budget(&g, &base, 0.5), 3);
        // alpha=0 -> no replication
        assert_eq!(replication_budget(&g, &base, 0.0), 0);
    }

    #[test]
    fn selection_never_exceeds_budget() {
        let g = GraphBuilder::new(10)
            .edges(&[
                (0, 1),
                (1, 2),
                (2, 3),
                (2, 4),
                (4, 5),
                (5, 6),
                (6, 7),
                (7, 8),
                (8, 9),
            ])
            .build();
        let a = vec![0, 0, 0, 1, 1, 1, 1, 1, 1, 1];
        let base: Vec<u32> = vec![0, 1, 2];
        let cands = candidate_replication_nodes(&g, &a, 0, 3);
        let cfg = AugmentConfig { alpha: 0.4, walk_length: 3, seed: 1, ..Default::default() };
        let mut rng = Rng::seed_from_u64(1);
        let rep = walk_importance(&g, &a, 0, &cands, &cfg, &mut rng);
        let budget = replication_budget(&g, &base, cfg.alpha);
        let sel = select_replicas(&g, &base, &cands, &rep, &cfg);
        assert!(sel.len() <= budget);
        for v in &sel {
            assert!(cands.contains(v));
        }
    }

    #[test]
    fn zero_alpha_selects_nothing() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let a = vec![0, 0, 1, 1];
        let cands = candidate_replication_nodes(&g, &a, 0, 2);
        let cfg = AugmentConfig { alpha: 0.0, ..Default::default() };
        let mut rng = Rng::seed_from_u64(2);
        let rep = walk_importance(&g, &a, 0, &cands, &cfg, &mut rng);
        assert!(select_replicas(&g, &[0, 1], &cands, &rep, &cfg).is_empty());
    }
}
