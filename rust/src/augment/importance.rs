//! Monte-Carlo random-walk importance (paper Eq. 3–4, Algorithm 1
//! lines 1–17).
//!
//! Walks of length `l` (= GCN layers, Property 1) start from uniformly
//! random boundary nodes of the part and step uniformly over the
//! *original* graph, so they can leave the part and touch candidate
//! replication nodes. `I(v)` is the fraction of walks that visit `v`.
//! The pilot phase runs `d̄(B) · |B|` walks, estimates the visit
//! distribution's mean/σ, and sizes the full run with the Monte-Carlo
//! error formula `n = (z_c σ / (x̄ E))²` (Eq. 4).

use super::AugmentConfig;
use crate::graph::{avg_degree, boundary_nodes, GraphView};
use crate::rng::Rng;
use std::collections::HashMap;

/// Result of the importance estimation for one part.
#[derive(Clone, Debug)]
pub struct ImportanceReport {
    /// `(global id, I(v))` per candidate, sorted by id.
    pub importance: Vec<(u32, f64)>,
    /// All walks performed (each = the node sequence).
    pub walks: Vec<Vec<u32>>,
    /// Total walk count actually used (pilot + main).
    pub walks_used: usize,
}

impl ImportanceReport {
    /// I(v) lookup.
    pub fn get(&self, v: u32) -> f64 {
        self.importance
            .binary_search_by_key(&v, |&(g, _)| g)
            .map(|i| self.importance[i].1)
            .unwrap_or(0.0)
    }
}

/// One uniform random walk of `len` steps starting at `start`.
fn random_walk<G: GraphView>(graph: &G, start: u32, len: usize, rng: &mut Rng) -> Vec<u32> {
    let mut seq = Vec::with_capacity(len + 1);
    seq.push(start);
    let mut cur = start as usize;
    for _ in 0..len {
        let nbrs = graph.neighbors(cur);
        if nbrs.is_empty() {
            break;
        }
        cur = nbrs[rng.gen_range(nbrs.len())] as usize;
        seq.push(cur as u32);
    }
    seq
}

/// Estimate `I(v)` for each node of `candidates` (Eq. 3).
pub fn walk_importance<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    part: u32,
    candidates: &[u32],
    cfg: &AugmentConfig,
    rng: &mut Rng,
) -> ImportanceReport {
    let boundary = boundary_nodes(graph, assignment, part);
    if boundary.is_empty() || candidates.is_empty() {
        return ImportanceReport {
            importance: candidates.iter().map(|&c| (c, 0.0)).collect(),
            walks: Vec::new(),
            walks_used: 0,
        };
    }
    let cand_index: HashMap<u32, usize> =
        candidates.iter().enumerate().map(|(i, &c)| (c, i)).collect();

    let mut visit_counts = vec![0u64; candidates.len()];
    let mut walks: Vec<Vec<u32>> = Vec::new();

    let run_walks = |count: usize,
                         walks: &mut Vec<Vec<u32>>,
                         visit_counts: &mut Vec<u64>,
                         rng: &mut Rng| {
        for _ in 0..count {
            let start = boundary[rng.gen_range(boundary.len())];
            let seq = random_walk(graph, start, cfg.walk_length, rng);
            // Eq.3: RW_j(v) = 1 if v appears in the walk (dedup within a walk)
            let mut seen_in_walk: Vec<usize> = seq
                .iter()
                .filter_map(|g| cand_index.get(g).copied())
                .collect();
            seen_in_walk.sort_unstable();
            seen_in_walk.dedup();
            for i in seen_in_walk {
                visit_counts[i] += 1;
            }
            walks.push(seq);
        }
    };

    // --- pilot: d̄(B) * |B| walks (Algorithm 1 line 4) -------------------
    let pilot = ((avg_degree(graph, &boundary) * boundary.len() as f64).ceil() as usize)
        .clamp(8, cfg.max_walks);
    run_walks(pilot, &mut walks, &mut visit_counts, rng);

    // --- size main run from MC error bound (Eq. 4) ----------------------
    let probs: Vec<f64> = visit_counts
        .iter()
        .map(|&c| c as f64 / walks.len() as f64)
        .collect();
    let mean = probs.iter().sum::<f64>() / probs.len().max(1) as f64;
    let var = probs.iter().map(|p| (p - mean) * (p - mean)).sum::<f64>()
        / probs.len().max(1) as f64;
    let sigma = var.sqrt();
    let n_total = if mean > 0.0 {
        let n = (cfg.z_c * sigma / (mean * cfg.mc_error)).powi(2);
        (n.ceil() as usize).clamp(pilot, cfg.max_walks)
    } else {
        pilot
    };
    if n_total > pilot {
        run_walks(n_total - pilot, &mut walks, &mut visit_counts, rng);
    }

    let total = walks.len() as f64;
    let importance: Vec<(u32, f64)> = candidates
        .iter()
        .zip(&visit_counts)
        .map(|(&c, &n)| (c, n as f64 / total))
        .collect();

    let walks_used = walks.len();
    ImportanceReport { importance, walks, walks_used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{candidate_replication_nodes, Csr, GraphBuilder};

    /// Star of remote nodes behind a single boundary: 0,1 local (part 0),
    /// 2 remote hub, 3..6 remote leaves. Hub must dominate importance.
    fn hub_fixture() -> (Csr, Vec<u32>) {
        let g = GraphBuilder::new(7)
            .edges(&[(0, 1), (1, 2), (2, 3), (2, 4), (2, 5), (2, 6)])
            .build();
        let a = vec![0, 0, 1, 1, 1, 1, 1];
        (g, a)
    }

    #[test]
    fn hub_more_important_than_leaves() {
        let (g, a) = hub_fixture();
        let cands = candidate_replication_nodes(&g, &a, 0, 2);
        assert!(cands.contains(&2));
        let cfg = AugmentConfig { walk_length: 2, seed: 3, ..Default::default() };
        let mut rng = Rng::seed_from_u64(3);
        let rep = walk_importance(&g, &a, 0, &cands, &cfg, &mut rng);
        let hub = rep.get(2);
        for leaf in [3u32, 4, 5, 6] {
            if cands.contains(&leaf) {
                assert!(hub > rep.get(leaf), "hub {hub} vs leaf {}", rep.get(leaf));
            }
        }
    }

    #[test]
    fn importance_bounded_zero_one() {
        let (g, a) = hub_fixture();
        let cands = candidate_replication_nodes(&g, &a, 0, 2);
        let cfg = AugmentConfig::default();
        let mut rng = Rng::seed_from_u64(5);
        let rep = walk_importance(&g, &a, 0, &cands, &cfg, &mut rng);
        for &(_, i) in &rep.importance {
            assert!((0.0..=1.0).contains(&i));
        }
    }

    #[test]
    fn empty_boundary_gives_zero_importance() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (2, 3)]).build();
        let a = vec![0, 0, 1, 1];
        let cfg = AugmentConfig::default();
        let mut rng = Rng::seed_from_u64(7);
        let rep = walk_importance(&g, &a, 0, &[], &cfg, &mut rng);
        assert!(rep.importance.is_empty());
        assert_eq!(rep.walks_used, 0);
    }

    #[test]
    fn walk_stays_on_graph_edges() {
        let (g, _) = hub_fixture();
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..50 {
            let w = random_walk(&g, 1, 3, &mut rng);
            for pair in w.windows(2) {
                assert!(g.has_edge(pair[0] as usize, pair[1] as usize));
            }
        }
    }

    #[test]
    fn eq4_sample_size_scales_with_variance() {
        // direct check of the Eq.4 arithmetic used inside walk_importance
        let n = |sigma: f64, mean: f64| (1.96 * sigma / (mean * 0.05)).powi(2);
        assert!(n(0.2, 0.5) > n(0.1, 0.5));
        assert!(n(0.1, 0.25) > n(0.1, 0.5));
    }
}
