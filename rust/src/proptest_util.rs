//! Tiny property-testing helper (proptest is not in the offline
//! registry): run a predicate over many seeded random cases and report
//! the first failing seed so the case replays exactly.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries skip the crate's rpath flags and
//! // cannot load libstdc++ from the xla extension bundle)
//! use gad::proptest_util::forall;
//! forall("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.gen_range(1000) as u64, rng.gen_range(1000) as u64);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::rng::Rng;

/// Run `cases` random trials of `property`. Each trial gets an
/// [`Rng`] derived from the trial index, so failures print a
/// reproduction seed. Panics (test failure) on the first `Err`.
pub fn forall(name: &str, cases: usize, property: impl Fn(&mut Rng) -> Result<(), String>) {
    const SEED_BASE: u64 = 0x5eed_ba5e_0000_0000;
    for case in 0..cases {
        let seed = SEED_BASE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Draw a random undirected graph: `n` in [n_min, n_max], edge
/// probability `p`; returns the edge list and node count.
pub fn arb_graph(rng: &mut Rng, n_min: usize, n_max: usize, p: f64) -> (usize, Vec<(u32, u32)>) {
    let n = n_min + rng.gen_range(n_max - n_min + 1);
    let mut edges = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            if rng.gen_bool(p) {
                edges.push((u, v));
            }
        }
    }
    // ensure connectivity-ish: chain fallback so partitioners have work
    for v in 1..n as u32 {
        if rng.gen_bool(0.5) {
            edges.push((v - 1, v));
        }
    }
    (n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("true", 50, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn arb_graph_bounds() {
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..20 {
            let (n, edges) = arb_graph(&mut rng, 3, 10, 0.3);
            assert!((3..=10).contains(&n));
            for (u, v) in edges {
                assert!((u as usize) < n && (v as usize) < n && u < v);
            }
        }
    }
}
