//! One serving shard: a partition part plus its replicated halo, the
//! layer-wise forward over the local subgraph, and the lazy
//! cache-filling micro-batch pipeline.
//!
//! The local adjacency is a [`DeltaCsr`] and the normalized adjacency
//! keeps a patched-row overlay, so a [`GraphDelta`] whose churn leaves
//! shard *membership* unchanged is spliced in place — O(Δ · deg) local
//! work plus validity-bit invalidation — instead of re-inducing the
//! subgraph. Membership changes (halo join/leave, elastic node
//! insert/remove) fall back to a shard-local rebuild that migrates the
//! surviving cache rows; nothing ever rebuilds globally.
//!
//! [`GraphDelta`]: super::GraphDelta

use super::cache::EmbeddingCache;
use super::delta::EdgeChurn;
use super::{HaloPolicy, ServeConfig};
use crate::augment::{augment_part, walk_importance, AugmentConfig};
use crate::graph::{
    boundary_nodes, candidate_replication_from_boundary, DeltaCsr, GraphView, Subgraph,
};
use crate::model::{GcnParams, NormAdj};
use crate::rng::Rng;
use crate::tensor::{gemm, relu, softmax_rows, Matrix};
use std::collections::{HashMap, HashSet};

/// Outcome of one shard micro-batch, rows in query order.
#[derive(Clone, Debug)]
pub struct ShardServeOutcome {
    /// Softmax class probabilities per queried node.
    pub probs: Matrix,
    /// Argmax class per queried node.
    pub preds: Vec<u32>,
    /// Per queried node: was its output-layer row already cached?
    pub cached: Vec<bool>,
    /// Queried nodes whose output-layer row was already cached.
    pub cached_hits: usize,
    /// Embedding rows recomputed (across all layers) by this call.
    pub rows_recomputed: usize,
}

/// Everything a shard needs to fold one applied [`GraphDelta`]
/// (post-mutation state plus the delta's O(Δ) working set).
///
/// [`GraphDelta`]: super::GraphDelta
pub struct ShardDeltaCtx<'a> {
    /// The mutated overlay graph.
    pub graph: &'a DeltaCsr,
    /// Global feature matrix (already carries the delta's updates).
    pub global_features: &'a Matrix,
    /// Updated global `1/sqrt(deg+1)` factors.
    pub inv_sqrt: &'a [f32],
    /// Home part per node (`u32::MAX` = retired id).
    pub assignment: &'a [u32],
    /// Effective edge churn (no-ops resolved).
    pub churn: &'a EdgeChurn,
    /// The delta's feature replacements.
    pub updated_features: &'a [(u32, Vec<f32>)],
    /// Nodes this delta homed into the shard's part (elastic insert).
    pub base_added: &'a [u32],
    /// Nodes this delta retired from the shard's part (elastic remove).
    pub base_removed: &'a [u32],
    /// Min-over-old-and-new hop distance to the nearest delta seed,
    /// sparse: absent = farther than L hops (untouched).
    pub dist: &'a HashMap<u32, u32>,
    /// GCN depth (= halo hops).
    pub layers: usize,
    /// Per-layer output widths.
    pub dims: &'a [usize],
    /// More than one shard exists → cross-shard bytes are real.
    pub multi_shard: bool,
}

/// What folding a delta into one shard did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardDeltaOutcome {
    /// The shard re-induced its subgraph (membership changed) instead
    /// of splicing in place.
    pub rebuilt: bool,
    /// Cached rows dropped by this delta on this shard.
    pub rows_invalidated: u64,
    /// Cross-shard bytes this shard's update cost.
    pub bytes: u64,
}

/// See module docs.
pub struct ShardEngine {
    pub part: u32,
    /// Parent-graph node id per local id (sorted ascending; base + halo).
    pub global_ids: Vec<u32>,
    /// Local adjacency over the induced edges — an overlay CSR so
    /// deltas splice in place.
    pub local: DeltaCsr,
    /// `true` -> halo replica (cannot be queried here; its home shard
    /// owns it).
    pub is_replica: Vec<bool>,
    /// Replicated global ids (the halo, sorted).
    pub replicas: Vec<u32>,
    /// Base nodes with ≥1 cross-part edge (global ids, sorted) —
    /// maintained incrementally under churn so halo recomputation
    /// needs a bounded BFS, not a full-part rescan.
    boundary: Vec<u32>,
    /// Â over the local subgraph with **global-degree** normalization,
    /// so local entries match the full graph's wherever both endpoints
    /// keep their complete neighbourhood (see [`NormAdj::with_inv_sqrt`]).
    adj: NormAdj,
    /// Mirror of the global `1/sqrt(deg+1)` factors for local nodes.
    inv_local: Vec<f32>,
    /// Local copies of the member nodes' feature rows.
    features: Matrix,
    /// Cache admission score per local node: Monte-Carlo `I(v)` for
    /// replicas, 1.0 for base nodes. Only populated when a cache byte
    /// budget is set (or the halo itself was importance-sampled).
    scores: Vec<f32>,
    /// `I(v)` over the *full* candidate set (members or not) — the
    /// gathered-row cache's admission scores for rows this shard
    /// fetches from elsewhere. Keyed by global id.
    candidate_scores: HashMap<u32, f32>,
    /// Retained-row byte budget (0 = unbounded), from [`ServeConfig`].
    cache_budget: u64,
    pub cache: EmbeddingCache,
}

/// `I(v)` over the exact halo, for cache admission: only computed when
/// a byte budget makes the scores matter.
fn halo_importance<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    part: u32,
    halo: &[u32],
    layers: usize,
    cfg: &ServeConfig,
) -> Vec<(u32, f64)> {
    if cfg.cache_budget_bytes == 0 || halo.is_empty() {
        return Vec::new();
    }
    let acfg = AugmentConfig { walk_length: layers, seed: cfg.seed, ..Default::default() };
    let mut rng = Rng::seed_from_u64(cfg.seed ^ (part as u64).wrapping_mul(0x9E37_79B9));
    walk_importance(graph, assignment, part, halo, &acfg, &mut rng).importance
}

impl ShardEngine {
    /// Build the shard for `part`. `inv_sqrt_global[v] = 1/sqrt(deg(v)+1)`
    /// over the *full* graph; `layers` is the GCN depth (= halo hops,
    /// Property 1).
    pub fn build<G: GraphView>(
        graph: &G,
        global_features: &Matrix,
        inv_sqrt_global: &[f32],
        assignment: &[u32],
        part: u32,
        layers: usize,
        cfg: &ServeConfig,
    ) -> ShardEngine {
        let base: Vec<u32> = (0..graph.num_nodes() as u32)
            .filter(|&v| assignment[v as usize] == part)
            .collect();
        let boundary = boundary_nodes(graph, assignment, part);
        let (replicas, importance) = match cfg.halo {
            HaloPolicy::Exact => {
                let halo = candidate_replication_from_boundary(
                    graph, assignment, &boundary, part, layers,
                );
                let imp = halo_importance(graph, assignment, part, &halo, layers, cfg);
                (halo, imp)
            }
            HaloPolicy::Budgeted { alpha } => {
                let aug = augment_part(
                    graph,
                    assignment,
                    part,
                    &AugmentConfig {
                        alpha,
                        walk_length: layers,
                        seed: cfg.seed,
                        ..Default::default()
                    },
                );
                (aug.replicas, aug.candidate_importance)
            }
        };
        Self::assemble(
            graph,
            global_features,
            inv_sqrt_global,
            part,
            base,
            replicas,
            boundary,
            &importance,
            cfg,
        )
    }

    /// Induce the subgraph over `base ∪ replicas` and materialise every
    /// derived structure. The one constructor both the offline build
    /// and the online membership-change rebuild go through.
    #[allow(clippy::too_many_arguments)]
    fn assemble<G: GraphView>(
        graph: &G,
        global_features: &Matrix,
        inv_sqrt_global: &[f32],
        part: u32,
        base: Vec<u32>,
        replicas: Vec<u32>,
        boundary: Vec<u32>,
        importance: &[(u32, f64)],
        cfg: &ServeConfig,
    ) -> ShardEngine {
        let mut all = base.clone();
        all.extend_from_slice(&replicas);
        let Subgraph { global_ids, csr } = Subgraph::induce(graph, &all);
        let base_set: HashSet<u32> = base.into_iter().collect();
        let is_replica: Vec<bool> = global_ids.iter().map(|g| !base_set.contains(g)).collect();

        let n = global_ids.len();
        let f = global_features.cols;
        let mut features = Matrix::zeros(n, f);
        let mut inv_local = Vec::with_capacity(n);
        for (l, &g) in global_ids.iter().enumerate() {
            features.row_mut(l).copy_from_slice(global_features.row(g as usize));
            inv_local.push(inv_sqrt_global[g as usize]);
        }
        let adj = NormAdj::with_inv_sqrt(&csr, &inv_local);
        let imp: HashMap<u32, f64> = importance.iter().copied().collect();
        let scores: Vec<f32> = global_ids
            .iter()
            .zip(&is_replica)
            .map(|(&g, &r)| if r { imp.get(&g).copied().unwrap_or(0.0) as f32 } else { 1.0 })
            .collect();
        let candidate_scores: HashMap<u32, f32> =
            imp.iter().map(|(&g, &s)| (g, s as f32)).collect();
        ShardEngine {
            part,
            global_ids,
            local: DeltaCsr::new(csr),
            is_replica,
            replicas,
            boundary,
            adj,
            inv_local,
            features,
            scores,
            candidate_scores,
            cache_budget: cfg.cache_budget_bytes,
            cache: EmbeddingCache::new(cfg.cache),
        }
    }

    /// `I(v)` of a global node as seen from this shard (candidate score
    /// when known, 0.0 otherwise) — the gathered-row cache's admission
    /// key for rows this shard fetches.
    pub(crate) fn candidate_score(&self, global: u32) -> f32 {
        self.candidate_scores.get(&global).copied().unwrap_or(0.0)
    }

    /// Local id of a global node, if a member (binary search).
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.global_ids.binary_search(&global).ok().map(|i| i as u32)
    }

    /// The incrementally maintained boundary (base nodes with ≥1
    /// cross-part edge, global ids, sorted) — the rebalancer's
    /// candidate pool.
    pub(crate) fn boundary_set(&self) -> &[u32] {
        &self.boundary
    }

    /// Node count (base + halo).
    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }

    /// Base (queryable) node count.
    pub fn base_len(&self) -> usize {
        self.is_replica.iter().filter(|&&r| !r).count()
    }

    /// Resident bytes: features + adjacency (flat + overlays) + cached
    /// embeddings.
    pub fn nbytes(&self) -> usize {
        self.features.nbytes() + self.adj.nbytes() + self.local.nbytes() + self.cache.nbytes()
    }

    /// Answer a micro-batch of local node ids. `pruned = false`
    /// recomputes every (invalid) row of the shard instead of just the
    /// queries' dependency cone — the naive baseline mode.
    pub fn serve(&mut self, params: &GcnParams, q: &[u32], pruned: bool) -> ShardServeOutcome {
        let layer_count = params.layers();
        let n = self.global_ids.len();
        let dims: Vec<usize> = params.ws.iter().map(|w| w.cols).collect();
        if !self.cache.is_allocated(layer_count) || self.cache.num_nodes() != n {
            self.cache.allocate(n, &dims);
        }

        let out_l = layer_count - 1;
        let cached: Vec<bool> = q.iter().map(|&v| self.cache.is_valid(out_l, v as usize)).collect();
        let cached_hits = cached.iter().filter(|&&h| h).count();

        // ---- plan: which rows must be computed at each layer --------
        let mut need: Vec<Vec<u32>> = vec![Vec::new(); layer_count];
        if pruned {
            // top-down dependency cone: layer l feeds the closed
            // neighbourhoods of whatever layer l+1 recomputes
            let mut mark = vec![false; n];
            for &v in q {
                let v = v as usize;
                if !mark[v] && !self.cache.is_valid(out_l, v) {
                    mark[v] = true;
                    need[out_l].push(v as u32);
                }
            }
            for l in (0..out_l).rev() {
                let mut mark = vec![false; n];
                let mut nl = Vec::new();
                for &v in &need[l + 1] {
                    let v = v as usize;
                    if !mark[v] && !self.cache.is_valid(l, v) {
                        mark[v] = true;
                        nl.push(v as u32);
                    }
                    for &t in self.local.neighbors(v) {
                        let t = t as usize;
                        if !mark[t] && !self.cache.is_valid(l, t) {
                            mark[t] = true;
                            nl.push(t as u32);
                        }
                    }
                }
                nl.sort_unstable();
                need[l] = nl;
            }
        } else {
            for (l, nl) in need.iter_mut().enumerate() {
                *nl = (0..n as u32).filter(|&v| !self.cache.is_valid(l, v as usize)).collect();
            }
        }

        // ---- compute bottom-up: gather rows -> one GEMM per layer ---
        // The per-row aggregation replays `spmm_csr`'s inner loop and
        // the GEMM computes each output row independently of which
        // other rows are present, so a partial recompute is
        // bit-identical to the full-shard forward.
        let mut rows_recomputed = 0usize;
        // Gather rows of the *next* layer assembled while this layer's
        // GEMM ran: (position in need[l+1], finished aggregate row).
        let mut prefetched: Vec<(usize, Vec<f32>)> = Vec::new();
        for l in 0..layer_count {
            if need[l].is_empty() {
                debug_assert!(prefetched.is_empty(), "prefetch for a layer with no work");
                continue;
            }
            let sel = std::mem::take(&mut need[l]);
            let in_dim = params.ws[l].rows;
            let mut agg = Matrix::zeros(sel.len(), in_dim);
            let pf = std::mem::take(&mut prefetched);
            {
                let _gspan =
                    crate::span!("serve.gather", layer = l, rows = sel.len(), prefetched = pf.len());
                let mut done = vec![false; sel.len()];
                for (i, row) in &pf {
                    agg.row_mut(*i).copy_from_slice(row);
                    done[*i] = true;
                }
                for (i, &v) in sel.iter().enumerate() {
                    if done[i] {
                        continue;
                    }
                    let (tgts, vals) = self.adj.row(v as usize);
                    let orow = agg.row_mut(i);
                    for (e, &j) in tgts.iter().enumerate() {
                        let w = vals[e];
                        let drow =
                            if l == 0 { self.features.row(j as usize) } else { self.cache.row(l - 1, j as usize) };
                        for c in 0..in_dim {
                            orow[c] += w * drow[c];
                        }
                    }
                }
            }
            // Gather→GEMM pipelining: while this layer's GEMM runs,
            // assemble the next layer's *safe* gather rows — rows none
            // of whose inputs are recomputed this layer. Those inputs
            // are already final in the cache (the cone plan pulls any
            // invalid neighbour into need[l], and budget eviction only
            // runs after the layer loop), and the stores below touch
            // only `sel` rows, so the prefetch reads the exact f32s the
            // in-line gather would and the answers stay bit-identical.
            let pf_plan: Vec<usize> = if l + 1 < layer_count && !need[l + 1].is_empty() {
                // sel is ascending for every l < out_l, so membership
                // is a binary search
                need[l + 1]
                    .iter()
                    .enumerate()
                    .filter(|&(_, &v)| {
                        let (tgts, _) = self.adj.row(v as usize);
                        tgts.iter().all(|j| sel.binary_search(j).is_err())
                    })
                    .map(|(i, _)| i)
                    .collect()
            } else {
                Vec::new()
            };
            let (z, pf_out) = if pf_plan.is_empty() {
                let _gspan = crate::span!("serve.gemm", layer = l, rows = sel.len());
                (gemm(&agg, &params.ws[l]), Vec::new())
            } else {
                let pspan =
                    crate::span!("serve.pipeline", layer = l, prefetch_rows = pf_plan.len());
                let pid = pspan.id();
                let _lease = crate::threads::reserve(1);
                let next = &need[l + 1];
                let next_dim = params.ws[l + 1].rows;
                let cache = &self.cache;
                let adj = &self.adj;
                std::thread::scope(|scope| {
                    let worker = scope.spawn(move || {
                        let _wspan = crate::obs::trace::SpanGuard::enter_under(
                            "serve.gather_prefetch",
                            Some(pid),
                            &[("layer", (l + 1) as i64), ("rows", pf_plan.len() as i64)],
                        );
                        let mut out: Vec<(usize, Vec<f32>)> = Vec::with_capacity(pf_plan.len());
                        for &i in &pf_plan {
                            let mut row = vec![0.0f32; next_dim];
                            let (tgts, vals) = adj.row(next[i] as usize);
                            for (e, &j) in tgts.iter().enumerate() {
                                let w = vals[e];
                                let drow = cache.row(l, j as usize);
                                for c in 0..next_dim {
                                    row[c] += w * drow[c];
                                }
                            }
                            out.push((i, row));
                        }
                        out
                    });
                    let z = {
                        let _gspan = crate::span!("serve.gemm", layer = l, rows = sel.len());
                        gemm(&agg, &params.ws[l])
                    };
                    (z, worker.join().expect("gather prefetch worker panicked"))
                })
            };
            prefetched = pf_out;
            let mut z = z;
            if l + 1 < layer_count {
                relu(&mut z);
            }
            for (i, &v) in sel.iter().enumerate() {
                self.cache.store(l, v as usize, z.row(i));
            }
            rows_recomputed += sel.len();
        }

        // ---- answer from the (now valid) output layer ---------------
        let _cspan = crate::span!("serve.cache_answer", rows = q.len());
        let classes = dims[out_l];
        let mut logits = Matrix::zeros(q.len(), classes);
        for (i, &v) in q.iter().enumerate() {
            logits.row_mut(i).copy_from_slice(self.cache.row(out_l, v as usize));
        }
        let probs = softmax_rows(&logits);
        let preds = probs.argmax_rows();

        if !self.cache.enabled() {
            self.cache.clear_validity();
        } else if self.cache_budget > 0 {
            // admission policy: retain the most important rows only
            self.cache.enforce_budget(self.cache_budget, &self.scores);
        }
        ShardServeOutcome { probs, preds, cached, cached_hits, rows_recomputed }
    }

    /// Fold one applied delta into this shard (Exact-halo path). When
    /// membership is untouched the churn is spliced in place; when the
    /// halo or the base changed (including elastic node insert/remove)
    /// the shard re-induces locally and migrates surviving cache rows.
    pub fn apply_delta(&mut self, cfg: &ServeConfig, ctx: &ShardDeltaCtx) -> ShardDeltaOutcome {
        // 1. refresh boundary status of churn endpoints (boundary
        //    membership can only change for nodes whose incident edges
        //    or neighbours' assignments changed — all of which appear
        //    in `degree_changed`)
        for &g in &ctx.churn.degree_changed {
            let in_part = ctx.assignment[g as usize] == self.part;
            let is_boundary = in_part
                && ctx
                    .graph
                    .neighbors(g as usize)
                    .iter()
                    .any(|&t| ctx.assignment[t as usize] != self.part);
            match (self.boundary.binary_search(&g), is_boundary) {
                (Ok(i), false) => {
                    self.boundary.remove(i);
                }
                (Err(i), true) => {
                    self.boundary.insert(i, g);
                }
                _ => {}
            }
        }

        // 2. the halo this shard now needs: bounded BFS from the
        //    (incrementally maintained) boundary — never a global scan
        let new_halo = candidate_replication_from_boundary(
            ctx.graph,
            ctx.assignment,
            &self.boundary,
            self.part,
            ctx.layers,
        );

        let membership_changed = !ctx.base_added.is_empty()
            || !ctx.base_removed.is_empty()
            || new_halo != self.replicas;

        if membership_changed {
            return self.rebuild_local(cfg, ctx, new_halo);
        }

        // 3. in-place splice: membership identical, so only edges,
        //    Â rows, feature rows and cache validity move
        let before_invalid = self.cache.rows_invalidated;
        for &(u, v) in &ctx.churn.added {
            if let (Some(lu), Some(lv)) = (self.local_of(u), self.local_of(v)) {
                self.local.add_edge(lu, lv);
            }
        }
        for &(u, v) in &ctx.churn.removed {
            if let (Some(lu), Some(lv)) = (self.local_of(u), self.local_of(v)) {
                self.local.remove_edge(lu, lv);
            }
        }
        // Â rows to refresh: members whose global degree changed, plus
        // their current local neighbours (their rows reference the
        // changed inv-sqrt factors)
        let mut touched_locals: Vec<u32> = Vec::new();
        for &g in &ctx.churn.degree_changed {
            if let Some(l) = self.local_of(g) {
                self.inv_local[l as usize] = ctx.inv_sqrt[g as usize];
                touched_locals.push(l);
            }
        }
        let mut affected = touched_locals.clone();
        for &l in &touched_locals {
            affected.extend_from_slice(self.local.neighbors(l as usize));
        }
        affected.sort_unstable();
        affected.dedup();
        self.adj.refresh_rows(&self.local, &self.inv_local, &affected);

        for (v, row) in ctx.updated_features {
            if let Some(l) = self.local_of(*v) {
                self.features.row_mut(l as usize).copy_from_slice(row);
            }
        }
        self.invalidate_by_distance(ctx.dist, ctx.layers);

        // compaction cadence: fold overlays on the DeltaCsr's schedule
        if self.local.maybe_compact() || self.adj.patched_rows() * 4 > self.global_ids.len() {
            self.adj.compact();
        }

        let bytes = if ctx.multi_shard {
            let frow = (ctx.global_features.cols * 4) as u64;
            self.replica_churn_bytes(ctx.churn, ctx.updated_features, frow)
        } else {
            0
        };
        ShardDeltaOutcome {
            rebuilt: false,
            rows_invalidated: self.cache.rows_invalidated - before_invalid,
            bytes,
        }
    }

    /// Membership changed: re-induce this shard over the overlay graph
    /// (shard-local cost) and migrate every surviving cache row.
    fn rebuild_local(
        &mut self,
        cfg: &ServeConfig,
        ctx: &ShardDeltaCtx,
        new_halo: Vec<u32>,
    ) -> ShardDeltaOutcome {
        let removed: HashSet<u32> = ctx.base_removed.iter().copied().collect();
        let mut base: Vec<u32> = self
            .global_ids
            .iter()
            .zip(&self.is_replica)
            .filter(|&(g, &r)| !r && !removed.contains(g))
            .map(|(&g, _)| g)
            .collect();
        base.extend_from_slice(ctx.base_added);
        base.sort_unstable();
        base.dedup();

        // admission scores are heuristic weights, not correctness: carry
        // the surviving replicas' I(v) over by global id instead of
        // re-running the Monte-Carlo estimator on every rebuild (halo
        // joiners start at 0.0 — evicted first until a full build or
        // deployment restart re-estimates them)
        let importance: Vec<(u32, f64)> = if cfg.cache_budget_bytes > 0 {
            self.global_ids
                .iter()
                .zip(&self.is_replica)
                .zip(&self.scores)
                .filter(|((_, &r), _)| r)
                .map(|((&g, _), &s)| (g, s as f64))
                .collect()
        } else {
            Vec::new()
        };
        let mut fresh = Self::assemble(
            ctx.graph,
            ctx.global_features,
            ctx.inv_sqrt,
            self.part,
            base,
            new_halo,
            std::mem::take(&mut self.boundary),
            &importance,
            cfg,
        );
        fresh.migrate_cache_from(self, ctx.dist, ctx.dims);
        let rows_invalidated = fresh.cache.rows_invalidated - self.cache.rows_invalidated;
        let mut bytes = 0u64;
        if ctx.multi_shard {
            let frow = (ctx.global_features.cols * 4) as u64;
            bytes = fresh.halo_join_bytes(self, frow)
                + fresh.replica_churn_bytes(ctx.churn, ctx.updated_features, frow);
        }
        *self = fresh;
        ShardDeltaOutcome { rebuilt: true, rows_invalidated, bytes }
    }

    /// Feature rows shipped for nodes that joined this shard's halo
    /// relative to its predecessor — the one accounting rule every
    /// rebuild path (in-place fallback and [`DeltaMode::Rebuild`])
    /// shares, so the two modes can never drift apart.
    ///
    /// [`DeltaMode::Rebuild`]: super::DeltaMode::Rebuild
    pub(crate) fn halo_join_bytes(&self, old: &ShardEngine, frow: u64) -> u64 {
        self.global_ids
            .iter()
            .enumerate()
            .filter(|&(l, &g)| self.is_replica[l] && old.local_of(g).is_none())
            .count() as u64
            * frow
    }

    /// Cross-shard bytes a delta costs this shard beyond membership
    /// churn: updated feature rows re-shipped to replicas, plus churned
    /// edges visible through a replica. Shared by both delta modes.
    pub(crate) fn replica_churn_bytes(
        &self,
        churn: &EdgeChurn,
        updated_features: &[(u32, Vec<f32>)],
        frow: u64,
    ) -> u64 {
        let mut bytes = 0u64;
        for (v, _) in updated_features {
            if let Some(l) = self.local_of(*v) {
                if self.is_replica[l as usize] {
                    bytes += frow;
                }
            }
        }
        let replica = |l: Option<u32>| l.map(|i| self.is_replica[i as usize]).unwrap_or(false);
        for &(u, v) in churn.added.iter().chain(&churn.removed) {
            let lu = self.local_of(u);
            let lv = self.local_of(v);
            if (lu.is_some() || lv.is_some()) && (replica(lu) || replica(lv)) {
                bytes += 8;
            }
        }
        bytes
    }

    /// Drop the cached rows the delta's influence cone reaches: the
    /// layer-`l` row of a node within `l+1` hops of a seed (`dist` is
    /// the sparse min-over-old-and-new-graph seed distance). Iterates
    /// the cone, not the membership — O(|cone|·log) per shard.
    pub fn invalidate_by_distance(&mut self, dist: &HashMap<u32, u32>, layer_count: usize) {
        if !self.cache.is_allocated(layer_count) {
            return; // never queried — nothing cached
        }
        for (&g, &d) in dist {
            let Some(local) = self.local_of(g) else { continue };
            for l in 0..layer_count {
                // layer l of the cache holds H_{l+1}: stale within l+1 hops
                if d <= (l + 1) as u32 {
                    self.cache.invalidate(l, local as usize);
                }
            }
        }
    }

    /// Carry forward cache rows that survive a [`GraphDelta`]
    /// (membership matched by global id, layer-`l` rows dropped inside
    /// `l+1` hops of a seed — `dist` is the min-over-old-and-new-graph
    /// seed distance). Counters carry over so lifetime stats survive
    /// rebuilds.
    ///
    /// [`GraphDelta`]: super::GraphDelta
    pub fn migrate_cache_from(&mut self, old: &ShardEngine, dist: &HashMap<u32, u32>, dims: &[usize]) {
        let layer_count = dims.len();
        let n = self.global_ids.len();
        if !self.cache.is_allocated(layer_count) || self.cache.num_nodes() != n {
            self.cache.allocate(n, dims);
        }
        self.cache.rows_recomputed += old.cache.rows_recomputed;
        self.cache.rows_invalidated += old.cache.rows_invalidated;
        self.cache.rows_evicted += old.cache.rows_evicted;
        if !old.cache.is_allocated(layer_count) {
            return; // old shard was never queried — nothing to carry
        }
        let mut adopted = 0u64;
        for (local, &g) in self.global_ids.iter().enumerate() {
            let Some(old_local) = old.local_of(g) else { continue };
            let d = dist.get(&g).copied().unwrap_or(u32::MAX);
            for l in 0..layer_count {
                // layer l of the cache holds H_{l+1}: stale within l+1 hops
                let touched = d != u32::MAX && d <= (l + 1) as u32;
                if !touched && old.cache.is_valid(l, old_local as usize) {
                    self.cache.adopt(l, local, old.cache.row(l, old_local as usize));
                    adopted += 1;
                }
            }
        }
        let old_valid = old.cache.valid_rows() as u64;
        self.cache.rows_invalidated += old_valid.saturating_sub(adopted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::graph::candidate_replication_nodes;
    use crate::partition::{partition, PartitionConfig};
    use crate::rng::Rng;

    fn fixture() -> (crate::datasets::Dataset, Vec<u32>, Vec<f32>) {
        let ds = SyntheticSpec::tiny().generate(3);
        let p = partition(&ds.graph, &PartitionConfig { k: 3, seed: 1, ..Default::default() });
        let inv = NormAdj::inv_sqrt_degrees(&ds.graph);
        (ds, p.assignment, inv)
    }

    #[test]
    fn exact_halo_contains_all_candidates() {
        let (ds, assign, inv) = fixture();
        let cfg = ServeConfig { shards: 3, ..Default::default() };
        let sh = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &cfg);
        let expect = candidate_replication_nodes(&ds.graph, &assign, 0, 2);
        assert_eq!(sh.replicas, expect);
        assert_eq!(sh.len(), sh.base_len() + expect.len());
        assert!(sh.local.validate().is_ok());
    }

    #[test]
    fn budgeted_halo_is_smaller() {
        let (ds, assign, inv) = fixture();
        let exact = ShardEngine::build(
            &ds.graph,
            &ds.features,
            &inv,
            &assign,
            0,
            2,
            &ServeConfig::default(),
        );
        let budgeted = ShardEngine::build(
            &ds.graph,
            &ds.features,
            &inv,
            &assign,
            0,
            2,
            &ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.01 }, ..Default::default() },
        );
        assert!(budgeted.replicas.len() < exact.replicas.len());
        assert!(budgeted.nbytes() < exact.nbytes());
    }

    #[test]
    fn pruned_serve_matches_full_recompute() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(5);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServeConfig { shards: 3, ..Default::default() };
        let mut a = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 1, 2, &cfg);
        let mut b = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 1, 2, &cfg);
        let q: Vec<u32> = (0..a.len() as u32).filter(|&v| !a.is_replica[v as usize]).collect();
        let pruned = a.serve(&params, &q, true);
        let full = b.serve(&params, &q, false);
        assert_eq!(pruned.preds, full.preds);
        assert_eq!(
            pruned.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            full.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "dependency-cone compute must be bit-identical to full-shard compute"
        );
        assert!(pruned.rows_recomputed <= full.rows_recomputed);
    }

    #[test]
    fn second_query_is_all_cache_hits() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(6);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let mut sh =
            ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &ServeConfig::default());
        let q: Vec<u32> = (0..sh.len().min(4) as u32).collect();
        let first = sh.serve(&params, &q, true);
        assert_eq!(first.cached_hits, 0);
        assert!(first.rows_recomputed > 0);
        let second = sh.serve(&params, &q, true);
        assert_eq!(second.cached_hits, q.len());
        assert_eq!(second.rows_recomputed, 0);
        assert_eq!(first.preds, second.preds);
    }

    #[test]
    fn disabled_cache_never_reuses() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(7);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServeConfig { cache: false, ..Default::default() };
        let mut sh = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &cfg);
        let q = vec![0u32];
        let a = sh.serve(&params, &q, true);
        let b = sh.serve(&params, &q, true);
        assert_eq!(b.cached_hits, 0);
        assert_eq!(a.rows_recomputed, b.rows_recomputed);
        assert_eq!(a.preds, b.preds);
    }

    #[test]
    fn cache_budget_keeps_important_rows_under_cap() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(8);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        // budget sized to hold only a few rows
        let budget = 8 * 4 * 4; // 4 hidden rows' worth
        let cfg = ServeConfig { shards: 3, cache_budget_bytes: budget as u64, ..Default::default() };
        let mut sh = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &cfg);
        let q: Vec<u32> = (0..sh.len() as u32).filter(|&v| !sh.is_replica[v as usize]).collect();
        let out = sh.serve(&params, &q, true);
        assert!(out.rows_recomputed > 0);
        assert!(sh.cache.cached_bytes() <= budget as u64, "budget enforced after the batch");
        assert!(sh.cache.rows_evicted > 0, "something had to go");
        // answers stay correct: evicted rows just recompute next time
        let mut unbounded =
            ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &ServeConfig {
                shards: 3,
                ..Default::default()
            });
        let reference = unbounded.serve(&params, &q, true);
        let again = sh.serve(&params, &q, true);
        assert_eq!(again.preds, reference.preds);
        assert_eq!(
            again.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            reference.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "eviction may cost recomputes, never answers"
        );
    }
}
