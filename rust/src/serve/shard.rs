//! One serving shard: a partition part plus its replicated halo, the
//! layer-wise forward over the local subgraph, and the lazy
//! cache-filling micro-batch pipeline.

use super::cache::EmbeddingCache;
use super::{HaloPolicy, ServeConfig};
use crate::augment::{augment_part, AugmentConfig};
use crate::graph::{candidate_replication_nodes, Csr, Subgraph};
use crate::model::{GcnParams, NormAdj};
use crate::tensor::{gemm, relu, softmax_rows, Matrix};
use std::collections::HashSet;

/// Outcome of one shard micro-batch, rows in query order.
#[derive(Clone, Debug)]
pub struct ShardServeOutcome {
    /// Softmax class probabilities per queried node.
    pub probs: Matrix,
    /// Argmax class per queried node.
    pub preds: Vec<u32>,
    /// Per queried node: was its output-layer row already cached?
    pub cached: Vec<bool>,
    /// Queried nodes whose output-layer row was already cached.
    pub cached_hits: usize,
    /// Embedding rows recomputed (across all layers) by this call.
    pub rows_recomputed: usize,
}

/// See module docs.
pub struct ShardEngine {
    pub part: u32,
    /// Base + halo nodes, local CSR over the induced edges.
    pub sub: Subgraph,
    /// `true` -> halo replica (cannot be queried here; its home shard
    /// owns it).
    pub is_replica: Vec<bool>,
    /// Replicated global ids (the halo).
    pub replicas: Vec<u32>,
    /// Â over the local subgraph with **global-degree** normalization,
    /// so local entries match the full graph's wherever both endpoints
    /// keep their complete neighbourhood (see [`NormAdj::with_inv_sqrt`]).
    adj: NormAdj,
    /// Local copies of the member nodes' feature rows.
    features: Matrix,
    pub cache: EmbeddingCache,
}

impl ShardEngine {
    /// Build the shard for `part`. `inv_sqrt_global[v] = 1/sqrt(deg(v)+1)`
    /// over the *full* graph; `layers` is the GCN depth (= halo hops,
    /// Property 1).
    pub fn build(
        graph: &Csr,
        global_features: &Matrix,
        inv_sqrt_global: &[f32],
        assignment: &[u32],
        part: u32,
        layers: usize,
        cfg: &ServeConfig,
    ) -> ShardEngine {
        let (sub, is_replica, replicas) = match cfg.halo {
            HaloPolicy::Exact => {
                let base: Vec<u32> = (0..graph.num_nodes() as u32)
                    .filter(|&v| assignment[v as usize] == part)
                    .collect();
                let halo = candidate_replication_nodes(graph, assignment, part, layers);
                let mut all = base.clone();
                all.extend_from_slice(&halo);
                let sub = Subgraph::induce(graph, &all);
                let base_set: HashSet<u32> = base.into_iter().collect();
                let is_replica: Vec<bool> =
                    sub.global_ids.iter().map(|g| !base_set.contains(g)).collect();
                (sub, is_replica, halo)
            }
            HaloPolicy::Budgeted { alpha } => {
                let aug = augment_part(
                    graph,
                    assignment,
                    part,
                    &AugmentConfig { alpha, walk_length: layers, seed: cfg.seed, ..Default::default() },
                );
                (aug.sub, aug.is_replica, aug.replicas)
            }
        };

        let n = sub.len();
        let f = global_features.cols;
        let mut features = Matrix::zeros(n, f);
        let mut inv_local = Vec::with_capacity(n);
        for (l, &g) in sub.global_ids.iter().enumerate() {
            features.row_mut(l).copy_from_slice(global_features.row(g as usize));
            inv_local.push(inv_sqrt_global[g as usize]);
        }
        let adj = NormAdj::with_inv_sqrt(&sub.csr, &inv_local);
        ShardEngine {
            part,
            sub,
            is_replica,
            replicas,
            adj,
            features,
            cache: EmbeddingCache::new(cfg.cache),
        }
    }

    /// Node count (base + halo).
    pub fn len(&self) -> usize {
        self.sub.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sub.is_empty()
    }

    /// Base (queryable) node count.
    pub fn base_len(&self) -> usize {
        self.is_replica.iter().filter(|&&r| !r).count()
    }

    /// Resident bytes: features + adjacency + cached embeddings.
    pub fn nbytes(&self) -> usize {
        self.features.nbytes() + self.adj.nbytes() + self.cache.nbytes()
    }

    /// Answer a micro-batch of local node ids. `pruned = false`
    /// recomputes every (invalid) row of the shard instead of just the
    /// queries' dependency cone — the naive baseline mode.
    pub fn serve(&mut self, params: &GcnParams, q: &[u32], pruned: bool) -> ShardServeOutcome {
        let layer_count = params.layers();
        let n = self.sub.len();
        let dims: Vec<usize> = params.ws.iter().map(|w| w.cols).collect();
        if !self.cache.is_allocated(layer_count) || self.cache.num_nodes() != n {
            self.cache.allocate(n, &dims);
        }

        let out_l = layer_count - 1;
        let cached: Vec<bool> = q.iter().map(|&v| self.cache.is_valid(out_l, v as usize)).collect();
        let cached_hits = cached.iter().filter(|&&h| h).count();

        // ---- plan: which rows must be computed at each layer --------
        let mut need: Vec<Vec<u32>> = vec![Vec::new(); layer_count];
        if pruned {
            // top-down dependency cone: layer l feeds the closed
            // neighbourhoods of whatever layer l+1 recomputes
            let mut mark = vec![false; n];
            for &v in q {
                let v = v as usize;
                if !mark[v] && !self.cache.is_valid(out_l, v) {
                    mark[v] = true;
                    need[out_l].push(v as u32);
                }
            }
            for l in (0..out_l).rev() {
                let mut mark = vec![false; n];
                let mut nl = Vec::new();
                for &v in &need[l + 1] {
                    let v = v as usize;
                    if !mark[v] && !self.cache.is_valid(l, v) {
                        mark[v] = true;
                        nl.push(v as u32);
                    }
                    for &t in self.sub.csr.neighbors(v) {
                        let t = t as usize;
                        if !mark[t] && !self.cache.is_valid(l, t) {
                            mark[t] = true;
                            nl.push(t as u32);
                        }
                    }
                }
                nl.sort_unstable();
                need[l] = nl;
            }
        } else {
            for (l, nl) in need.iter_mut().enumerate() {
                *nl = (0..n as u32).filter(|&v| !self.cache.is_valid(l, v as usize)).collect();
            }
        }

        // ---- compute bottom-up: gather rows -> one GEMM per layer ---
        // The per-row aggregation replays `spmm_csr`'s inner loop and
        // the GEMM computes each output row independently of which
        // other rows are present, so a partial recompute is
        // bit-identical to the full-shard forward.
        let mut rows_recomputed = 0usize;
        for l in 0..layer_count {
            if need[l].is_empty() {
                continue;
            }
            let sel = std::mem::take(&mut need[l]);
            let in_dim = params.ws[l].rows;
            let mut agg = Matrix::zeros(sel.len(), in_dim);
            {
                let (offs, tgts, vals) = self.adj.raw();
                for (i, &v) in sel.iter().enumerate() {
                    let orow = agg.row_mut(i);
                    for e in offs[v as usize]..offs[v as usize + 1] {
                        let j = tgts[e] as usize;
                        let w = vals[e];
                        let drow =
                            if l == 0 { self.features.row(j) } else { self.cache.row(l - 1, j) };
                        for c in 0..in_dim {
                            orow[c] += w * drow[c];
                        }
                    }
                }
            }
            let mut z = gemm(&agg, &params.ws[l]);
            if l + 1 < layer_count {
                relu(&mut z);
            }
            for (i, &v) in sel.iter().enumerate() {
                self.cache.store(l, v as usize, z.row(i));
            }
            rows_recomputed += sel.len();
        }

        // ---- answer from the (now valid) output layer ---------------
        let classes = dims[out_l];
        let mut logits = Matrix::zeros(q.len(), classes);
        for (i, &v) in q.iter().enumerate() {
            logits.row_mut(i).copy_from_slice(self.cache.row(out_l, v as usize));
        }
        let probs = softmax_rows(&logits);
        let preds = probs.argmax_rows();

        if !self.cache.enabled() {
            self.cache.clear_validity();
        }
        ShardServeOutcome { probs, preds, cached, cached_hits, rows_recomputed }
    }

    /// Carry forward cache rows that survive a [`GraphDelta`]
    /// (membership matched by global id, layer-`l` rows dropped inside
    /// `l+1` hops of a seed — `dist` is the min-over-old-and-new-graph
    /// seed distance). Counters carry over so lifetime stats survive
    /// rebuilds.
    ///
    /// [`GraphDelta`]: super::GraphDelta
    pub fn migrate_cache_from(&mut self, old: &ShardEngine, dist: &[u32], dims: &[usize]) {
        let layer_count = dims.len();
        let n = self.sub.len();
        if !self.cache.is_allocated(layer_count) || self.cache.num_nodes() != n {
            self.cache.allocate(n, dims);
        }
        self.cache.rows_recomputed += old.cache.rows_recomputed;
        self.cache.rows_invalidated += old.cache.rows_invalidated;
        if !old.cache.is_allocated(layer_count) {
            return; // old shard was never queried — nothing to carry
        }
        let mut adopted = 0u64;
        for (local, &g) in self.sub.global_ids.iter().enumerate() {
            let Some(old_local) = old.sub.local_of(g) else { continue };
            let d = dist[g as usize];
            for l in 0..layer_count {
                // layer l of the cache holds H_{l+1}: stale within l+1 hops
                let touched = d != u32::MAX && d <= (l + 1) as u32;
                if !touched && old.cache.is_valid(l, old_local as usize) {
                    self.cache.adopt(l, local, old.cache.row(l, old_local as usize));
                    adopted += 1;
                }
            }
        }
        let old_valid = old.cache.valid_rows() as u64;
        self.cache.rows_invalidated += old_valid.saturating_sub(adopted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::partition::{partition, PartitionConfig};
    use crate::rng::Rng;

    fn fixture() -> (crate::datasets::Dataset, Vec<u32>, Vec<f32>) {
        let ds = SyntheticSpec::tiny().generate(3);
        let p = partition(&ds.graph, &PartitionConfig { k: 3, seed: 1, ..Default::default() });
        let inv = NormAdj::inv_sqrt_degrees(&ds.graph);
        (ds, p.assignment, inv)
    }

    #[test]
    fn exact_halo_contains_all_candidates() {
        let (ds, assign, inv) = fixture();
        let cfg = ServeConfig { shards: 3, ..Default::default() };
        let sh = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &cfg);
        let expect = candidate_replication_nodes(&ds.graph, &assign, 0, 2);
        assert_eq!(sh.replicas, expect);
        assert_eq!(sh.len(), sh.base_len() + expect.len());
        assert!(sh.sub.csr.validate().is_ok());
    }

    #[test]
    fn budgeted_halo_is_smaller() {
        let (ds, assign, inv) = fixture();
        let exact = ShardEngine::build(
            &ds.graph,
            &ds.features,
            &inv,
            &assign,
            0,
            2,
            &ServeConfig::default(),
        );
        let budgeted = ShardEngine::build(
            &ds.graph,
            &ds.features,
            &inv,
            &assign,
            0,
            2,
            &ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.01 }, ..Default::default() },
        );
        assert!(budgeted.replicas.len() < exact.replicas.len());
        assert!(budgeted.nbytes() < exact.nbytes());
    }

    #[test]
    fn pruned_serve_matches_full_recompute() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(5);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServeConfig { shards: 3, ..Default::default() };
        let mut a = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 1, 2, &cfg);
        let mut b = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 1, 2, &cfg);
        let q: Vec<u32> = (0..a.len() as u32).filter(|&v| !a.is_replica[v as usize]).collect();
        let pruned = a.serve(&params, &q, true);
        let full = b.serve(&params, &q, false);
        assert_eq!(pruned.preds, full.preds);
        assert_eq!(
            pruned.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            full.probs.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "dependency-cone compute must be bit-identical to full-shard compute"
        );
        assert!(pruned.rows_recomputed <= full.rows_recomputed);
    }

    #[test]
    fn second_query_is_all_cache_hits() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(6);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let mut sh =
            ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &ServeConfig::default());
        let q: Vec<u32> = (0..sh.len().min(4) as u32).collect();
        let first = sh.serve(&params, &q, true);
        assert_eq!(first.cached_hits, 0);
        assert!(first.rows_recomputed > 0);
        let second = sh.serve(&params, &q, true);
        assert_eq!(second.cached_hits, q.len());
        assert_eq!(second.rows_recomputed, 0);
        assert_eq!(first.preds, second.preds);
    }

    #[test]
    fn disabled_cache_never_reuses() {
        let (ds, assign, inv) = fixture();
        let mut rng = Rng::seed_from_u64(7);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServeConfig { cache: false, ..Default::default() };
        let mut sh = ShardEngine::build(&ds.graph, &ds.features, &inv, &assign, 0, 2, &cfg);
        let q = vec![0u32];
        let a = sh.serve(&params, &q, true);
        let b = sh.serve(&params, &q, true);
        assert_eq!(b.cached_hits, 0);
        assert_eq!(a.rows_recomputed, b.rows_recomputed);
        assert_eq!(a.preds, b.preds);
    }
}
