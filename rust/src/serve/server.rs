//! The query frontend: shard routing, per-shard micro-batching, online
//! graph deltas (incremental by default, see [`DeltaMode`]), elastic
//! node membership, provenance and traffic accounting.

use super::delta::{EdgeChurn, GraphDelta};
use super::gather;
use super::rebalance::{self, RebalanceReport};
use super::shard::{ShardDeltaCtx, ShardEngine, ShardServeOutcome};
use super::{DeltaMode, HaloPolicy, ServeConfig};
use crate::comm::{CommLedger, CommStats};
use crate::datasets::Dataset;
use crate::graph::{bounded_bfs_distances_sparse, Csr, DeltaCsr, GraphView};
use crate::model::{GcnParams, NormAdj};
use crate::partition::{partition, PartitionConfig};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::time::Instant;

/// Home-part sentinel for a retired (removed) node id.
pub(crate) const RETIRED: u32 = u32::MAX;

// The serve pool hands each worker thread a disjoint `&mut ShardEngine`
// and a shared `&GcnParams`; both bounds are load-bearing for
// `std::thread::scope` and checked here so a future non-Send field
// (Rc, raw pointer) fails at this line instead of deep in a spawn.
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_sync<T: Sync>() {}
    assert_send::<ShardEngine>();
    assert_sync::<GcnParams>();
};

/// One scheduler flush answered by [`Server::flush_shard_batches`]:
/// the batch's results plus the flush's own wall-clock span, measured
/// inside the worker thread that served it. The load harness folds
/// `service_us` into its virtual clock per flush, so overlapping
/// flushes each keep an honest (contended) service time.
pub struct FlushOutcome {
    /// Answers in the flushed batch's node order.
    pub results: Vec<QueryResult>,
    /// Wall-clock service span of this flush alone, in µs (≥ 1).
    pub service_us: u64,
}

/// One answered query with its provenance.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Queried (global) node id.
    pub node: u32,
    /// Predicted class.
    pub pred: u32,
    /// Softmax class probabilities.
    pub probs: Vec<f32>,
    /// Shard that answered (always the node's home shard — queries are
    /// shard-local by construction).
    pub shard: u32,
    /// Graph version the answer is valid for.
    pub graph_version: u64,
    /// Output-layer embedding came straight from the cache.
    pub cache_hit: bool,
    /// Embedding rows recomputed by the micro-batch that served this
    /// query (shared across the batch's queries on the same shard).
    pub rows_recomputed: usize,
}

/// Lifetime serving counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServeStats {
    pub queries: u64,
    pub micro_batches: u64,
    /// Queries answered from a valid output-layer row.
    pub cache_hits: u64,
    /// Embedding rows recomputed across all layers.
    pub rows_recomputed: u64,
    /// Cache rows dropped by the byte-budget admission policy.
    pub rows_evicted: u64,
    /// Gathered-row cache: embedding recomputes skipped cross-request.
    pub gather_rows_reused: u64,
    /// Gathered-row cache: cross-shard fetches skipped cross-request.
    pub gather_fetches_avoided: u64,
    /// Gathered-row cache: rows dropped by surgical delta-cone
    /// invalidation (rows outside the cone survive the delta).
    pub gather_rows_invalidated: u64,
    /// Open-loop load harness: answers that met their SLO deadline —
    /// the goodput numerator. Both SLO counters stay 0 outside
    /// [`loadgen`](crate::loadgen) runs.
    pub slo_answers: u64,
    /// Open-loop load harness: answers that completed past deadline.
    pub late_answers: u64,
    /// Deepest scheduler queue the load harness observed (sampled at
    /// each admission).
    pub queue_depth_max: u64,
    /// Mean sampled scheduler queue depth.
    pub queue_depth_mean: f64,
    pub deltas_applied: u64,
    /// Nodes inserted online over the deployment's lifetime.
    pub nodes_added: u64,
    /// Nodes retired online over the deployment's lifetime.
    pub nodes_removed: u64,
    /// Shards that re-induced their subgraph (membership churn) rather
    /// than splicing a delta in place.
    pub shard_rebuilds: u64,
    /// Overlay-CSR compactions (batched O(V+E) folds).
    pub graph_compactions: u64,
    /// Current overlay compaction threshold (moves under the adaptive
    /// policy, static otherwise).
    pub compaction_threshold: usize,
    /// Rebalance passes that migrated at least one node.
    pub rebalances: u64,
    /// Nodes migrated between parts by the online rebalancer.
    pub nodes_migrated: u64,
    /// Current max/min base-node ratio across parts.
    pub imbalance_ratio: f64,
    pub graph_version: u64,
    /// Cross-shard serving traffic (halo replication + delta
    /// propagation + budgeted-mode row gathers; the Exact-halo query
    /// path moves nothing). Rebalance migrations land in their own
    /// class (`comm.rebalance_bytes`).
    pub comm: CommStats,
}

/// What one [`GraphDelta`] did to the deployment.
#[derive(Clone, Copy, Debug)]
pub struct DeltaReport {
    /// Version after the delta.
    pub graph_version: u64,
    /// Epicentre size (distinct touched nodes).
    pub seeds: usize,
    /// Cached embedding rows dropped by L-hop invalidation (including
    /// halo-membership churn).
    pub rows_invalidated: u64,
    /// Cross-shard bytes spent propagating the delta.
    pub serving_bytes: u64,
    /// Nodes inserted by this delta.
    pub nodes_added: usize,
    /// Nodes retired by this delta.
    pub nodes_removed: usize,
    /// Shards that fell back to a local re-induction (membership
    /// changed); the rest were spliced in place or untouched.
    pub shards_rebuilt: usize,
    /// This delta's application folded the overlay into a flat CSR.
    pub compacted: bool,
    /// Nodes the post-delta rebalance pass migrated (0 when the
    /// rebalancer is off or balance held).
    pub rebalance_moves: usize,
    /// Bytes that pass shipped (also in the ledger's rebalance class).
    pub rebalance_bytes: u64,
}

/// See module docs ([`crate::serve`]).
pub struct Server {
    pub(crate) cfg: ServeConfig,
    /// The served graph: a versioned overlay CSR mutated in place by
    /// deltas, compacted on a batched cadence.
    pub(crate) graph: DeltaCsr,
    pub(crate) features: Matrix,
    pub(crate) params: GcnParams,
    /// Home part per node id; [`RETIRED`] marks removed ids.
    pub(crate) assignment: Vec<u32>,
    /// Global `1/sqrt(deg+1)` factors, updated in O(Δ) per delta.
    pub(crate) inv_sqrt: Vec<f32>,
    /// Base-node count per part (elastic homing picks the least loaded
    /// part for isolated inserts).
    pub(crate) base_counts: Vec<usize>,
    pub(crate) shards: Vec<ShardEngine>,
    /// Cross-request gathered-row cache (budgeted-gather mode with a
    /// byte budget configured; see [`ServeConfig::gather_cache_budget_bytes`]).
    pub(crate) gather_cache: Option<gather::GatherRowCache>,
    /// Resolved serve-pool width (1 = sequential; see
    /// [`ServeConfig::serve_threads`]). Fixed at build so a server's
    /// physical parallelism can't drift mid-run with budget churn.
    serve_pool: usize,
    /// Standing claim on the process thread budget while this server
    /// can fan out (held only when `serve_pool > 1`), so co-resident
    /// trainers size their workers around us. Wall-clock only.
    _serve_lease: Option<crate::threads::ThreadLease>,
    pub(crate) ledger: CommLedger,
    pub(crate) queries: u64,
    pub(crate) micro_batches: u64,
    pub(crate) cache_hits: u64,
    pub(crate) rows_recomputed: u64,
    deltas_applied: u64,
    nodes_added: u64,
    nodes_removed: u64,
    shard_rebuilds: u64,
    pub(crate) rebalances: u64,
    pub(crate) nodes_migrated: u64,
    slo_answers: u64,
    late_answers: u64,
    queue_depth_max: u64,
    queue_depth_sum: u64,
    queue_depth_samples: u64,
}

impl Server {
    /// Shard `graph` and stand the deployment up. Fails cleanly on a
    /// model whose input width does not match the features.
    pub fn build(graph: Csr, features: Matrix, params: GcnParams, cfg: ServeConfig) -> Result<Server> {
        let n = graph.num_nodes();
        if n == 0 {
            return Err(anyhow!("cannot serve an empty graph"));
        }
        if features.rows != n {
            return Err(anyhow!("features have {} rows for {} nodes", features.rows, n));
        }
        if params.ws.is_empty() {
            return Err(anyhow!("model has no layers"));
        }
        if params.ws[0].rows != features.cols {
            return Err(anyhow!(
                "model expects {}-dim features, graph has {}-dim",
                params.ws[0].rows,
                features.cols
            ));
        }
        let k = cfg.shards.clamp(1, n);
        let layers = params.layers();
        let part = partition(&graph, &PartitionConfig { k, seed: cfg.seed, ..Default::default() });
        let inv = NormAdj::inv_sqrt_degrees(&graph);
        let ledger = CommLedger::new();
        let mut shards = Vec::with_capacity(k);
        for p in 0..k as u32 {
            let sh = ShardEngine::build(&graph, &features, &inv, &part.assignment, p, layers, &cfg);
            if k > 1 {
                // the halo is the only thing serving ever ships:
                // replicated feature rows move once at build, queries
                // then stay shard-local
                ledger.record_serving((sh.replicas.len() * features.cols * 4) as u64);
            }
            shards.push(sh);
        }
        let base_counts = (0..k as u32)
            .map(|p| part.assignment.iter().filter(|&&a| a == p).count())
            .collect();
        let mut overlay = DeltaCsr::new(graph);
        if cfg.adaptive_compaction {
            overlay.enable_adaptive_compaction(1.5);
        }
        let gather_cache = (cfg.gather_missing && cfg.gather_cache_budget_bytes > 0)
            .then(|| gather::GatherRowCache::new(cfg.gather_cache_budget_bytes));
        // resolve the serve-pool width once: explicit N capped at the
        // shard count (more threads than shards can never help — the
        // fan-out unit is a whole shard), 0 = take what the process
        // budget has left. Never affects answers, only wall-clock.
        let serve_pool = match cfg.serve_threads {
            0 => crate::threads::available().min(k).max(1),
            n => n.min(k),
        };
        let _serve_lease = (serve_pool > 1).then(|| crate::threads::reserve(serve_pool));
        Ok(Server {
            cfg,
            graph: overlay,
            features,
            params,
            assignment: part.assignment,
            inv_sqrt: inv,
            base_counts,
            shards,
            gather_cache,
            serve_pool,
            _serve_lease,
            ledger,
            queries: 0,
            micro_batches: 0,
            cache_hits: 0,
            rows_recomputed: 0,
            deltas_applied: 0,
            nodes_added: 0,
            nodes_removed: 0,
            shard_rebuilds: 0,
            rebalances: 0,
            nodes_migrated: 0,
            slo_answers: 0,
            late_answers: 0,
            queue_depth_max: 0,
            queue_depth_sum: 0,
            queue_depth_samples: 0,
        })
    }

    /// Build from a dataset (graph + features are cloned; labels and
    /// splits are a training concern the serving tier never sees).
    pub fn for_dataset(ds: &Dataset, params: GcnParams, cfg: ServeConfig) -> Result<Server> {
        Self::build(ds.graph.clone(), ds.features.clone(), params, cfg)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Resolved serve-pool width: how many distinct shards this server
    /// runs concurrently per query/flush wave (1 = sequential). The
    /// load harness uses this as its in-flight flush slot count.
    pub fn serve_parallelism(&self) -> usize {
        self.serve_pool
    }

    /// Node-id space size (retired ids included; they reject queries).
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }

    pub fn graph_version(&self) -> u64 {
        self.graph.version()
    }

    pub fn params(&self) -> &GcnParams {
        &self.params
    }

    /// Shard inspection (tests / reporting).
    pub fn shard(&self, i: usize) -> &ShardEngine {
        &self.shards[i]
    }

    /// Home shard of a node.
    pub fn shard_of(&self, node: u32) -> u32 {
        self.assignment[node as usize]
    }

    /// Is this id live (in range and not retired)?
    pub fn is_alive(&self, node: u32) -> bool {
        (node as usize) < self.assignment.len() && self.assignment[node as usize] != RETIRED
    }

    /// Resident bytes across shards (features + adjacency + cache),
    /// plus the gathered-row cache when configured.
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.nbytes()).sum::<usize>()
            + self.gather_cache.as_ref().map(|c| c.resident_bytes() as usize).unwrap_or(0)
    }

    /// Classify one node.
    pub fn query(&mut self, node: u32) -> Result<QueryResult> {
        let mut v = self.query_batch(std::slice::from_ref(&node))?;
        Ok(v.pop().expect("one query, one result"))
    }

    /// Classify a batch. Queries are grouped per home shard and each
    /// group is answered by one gather-rows → GEMM pipeline pass —
    /// the micro-batching that amortises the forward across queries.
    /// Results come back in input order; batching cannot change any
    /// answer (per-row compute is independent, enforced by tests).
    pub fn query_batch(&mut self, nodes: &[u32]) -> Result<Vec<QueryResult>> {
        let _qspan =
            crate::span!("serve.query_batch", n = nodes.len(), width = self.serve_pool);
        let n = self.graph.num_nodes();
        for &v in nodes {
            if v as usize >= n {
                return Err(anyhow!("query node {v} out of range (n={n})"));
            }
            if self.assignment[v as usize] == RETIRED {
                return Err(anyhow!("query node {v} has been removed"));
            }
        }
        if self.cfg.gather_missing && matches!(self.cfg.halo, HaloPolicy::Budgeted { .. }) {
            // budgeted halos answering exactly: gather the rows the
            // halo lacks from their home shards (bytes accounted)
            return gather::query_batch_gather(self, nodes);
        }
        let mut groups: Vec<Vec<(usize, u32)>> = vec![Vec::new(); self.shards.len()];
        for (i, &v) in nodes.iter().enumerate() {
            let s = self.assignment[v as usize] as usize;
            let local = self.shards[s]
                .local_of(v)
                .expect("home shard always contains its base nodes");
            groups[s].push((i, local));
        }
        let mut results: Vec<Option<QueryResult>> = vec![None; nodes.len()];
        let version = self.graph.version();
        let active = groups.iter().filter(|g| !g.is_empty()).count();
        if self.serve_pool > 1 && active > 1 {
            // Parallel fan-out: each worker owns a disjoint
            // `&mut ShardEngine` (per-shard caches included), so shard
            // isolation is structural — no locks to get wrong. Workers
            // pin their GEMM panels to one thread; panel width never
            // changes bits (fixed per-row accumulation order), this
            // only keeps the pool from over-forking. Outcomes merge
            // below in ascending shard order — the same order the
            // sequential loop visits — so answers AND counters are
            // bit-identical to `serve_threads = 1`.
            struct ShardTask<'a> {
                s: usize,
                engine: &'a mut ShardEngine,
                locals: Vec<u32>,
                out: Option<ShardServeOutcome>,
            }
            let mut tasks: Vec<ShardTask<'_>> = self
                .shards
                .iter_mut()
                .enumerate()
                .filter(|(s, _)| !groups[*s].is_empty())
                .map(|(s, engine)| ShardTask {
                    s,
                    engine,
                    locals: groups[s].iter().map(|&(_, l)| l).collect(),
                    out: None,
                })
                .collect();
            let nthreads = self.serve_pool.min(tasks.len());
            let per = tasks.len().div_ceil(nthreads);
            let params = &self.params;
            let pruned = self.cfg.pruned;
            // workers link their flush spans to the dispatching span by
            // id — the thread-local stack cannot cross the scope spawn
            let wave_parent = _qspan.id();
            std::thread::scope(|scope| {
                for (wi, chunk) in tasks.chunks_mut(per).enumerate() {
                    scope.spawn(move || {
                        crate::threads::label_current_with(|| format!("serve-worker-{wi}"));
                        crate::tensor::set_intra_threads(1);
                        for t in chunk.iter_mut() {
                            let _fspan = crate::obs::trace::SpanGuard::enter_under(
                                "serve.shard_flush",
                                Some(wave_parent),
                                &[("shard", t.s as i64), ("batch", t.locals.len() as i64)],
                            );
                            t.out = Some(t.engine.serve(params, &t.locals, pruned));
                        }
                    });
                }
            });
            for t in &tasks {
                let out = t.out.as_ref().expect("worker served every task");
                self.micro_batches += 1;
                self.cache_hits += out.cached_hits as u64;
                self.rows_recomputed += out.rows_recomputed as u64;
                for (ri, &(orig, _)) in groups[t.s].iter().enumerate() {
                    results[orig] = Some(QueryResult {
                        node: nodes[orig],
                        pred: out.preds[ri],
                        probs: out.probs.row(ri).to_vec(),
                        shard: t.s as u32,
                        graph_version: version,
                        cache_hit: out.cached[ri],
                        rows_recomputed: out.rows_recomputed,
                    });
                }
            }
        } else {
            for (s, group) in groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let locals: Vec<u32> = group.iter().map(|&(_, l)| l).collect();
                let _fspan =
                    crate::span!("serve.shard_flush", shard = s, batch = locals.len());
                let out = self.shards[s].serve(&self.params, &locals, self.cfg.pruned);
                self.micro_batches += 1;
                self.cache_hits += out.cached_hits as u64;
                self.rows_recomputed += out.rows_recomputed as u64;
                for (ri, &(orig, _)) in group.iter().enumerate() {
                    results[orig] = Some(QueryResult {
                        node: nodes[orig],
                        pred: out.preds[ri],
                        probs: out.probs.row(ri).to_vec(),
                        shard: s as u32,
                        graph_version: version,
                        cache_hit: out.cached[ri],
                        rows_recomputed: out.rows_recomputed,
                    });
                }
            }
        }
        self.queries += nodes.len() as u64;
        Ok(results.into_iter().map(|r| r.expect("every query answered")).collect())
    }

    /// Serve one micro-batch the caller has already grouped by home
    /// shard — the open-loop scheduler's flush path
    /// ([`loadgen`](crate::loadgen)). Every node must be live and
    /// homed on `shard`; the batch then maps onto exactly one
    /// per-shard micro-batch group inside
    /// [`query_batch`](Self::query_batch), so answers are bit-identical
    /// to routing the same nodes there directly (no duplicated
    /// compute path to drift).
    pub fn flush_shard_batch(&mut self, shard: u32, nodes: &[u32]) -> Result<Vec<QueryResult>> {
        if (shard as usize) >= self.shards.len() {
            return Err(anyhow!("flush targets unknown shard {shard}"));
        }
        for &v in nodes {
            if !self.is_alive(v) {
                return Err(anyhow!("flush node {v} is out of range or removed"));
            }
            if self.assignment[v as usize] != shard {
                return Err(anyhow!(
                    "flush node {v} is homed on shard {}, not {shard}",
                    self.assignment[v as usize]
                ));
            }
        }
        self.query_batch(nodes)
    }

    /// Serve a *wave* of scheduler flushes — one batch per distinct
    /// shard — concurrently on the serve pool, timing each flush's own
    /// wall-clock span inside its worker thread. This is the load
    /// harness's physical overlap primitive: with `serve_threads = 1`
    /// (or a single batch, or the gather path) it degrades to the
    /// sequential [`flush_shard_batch`](Self::flush_shard_batch) loop,
    /// so answers and counters are bit-identical at any pool width —
    /// only the measured spans (wall-clock) differ.
    ///
    /// Outcomes come back in `batches` order; validation mirrors the
    /// single-flush path (known shard, live + correctly homed nodes)
    /// plus a distinct-shards check, since two flushes racing on one
    /// engine is exactly what the scheduler contract forbids.
    pub fn flush_shard_batches(&mut self, batches: &[(u32, Vec<u32>)]) -> Result<Vec<FlushOutcome>> {
        let mut want: Vec<Option<usize>> = vec![None; self.shards.len()];
        for (bi, (shard, nodes)) in batches.iter().enumerate() {
            let s = *shard as usize;
            if s >= self.shards.len() {
                return Err(anyhow!("flush targets unknown shard {shard}"));
            }
            if want[s].replace(bi).is_some() {
                return Err(anyhow!("flush wave targets shard {shard} twice"));
            }
            for &v in nodes {
                if !self.is_alive(v) {
                    return Err(anyhow!("flush node {v} is out of range or removed"));
                }
                if self.assignment[v as usize] != *shard {
                    return Err(anyhow!(
                        "flush node {v} is homed on shard {}, not {shard}",
                        self.assignment[v as usize]
                    ));
                }
            }
        }
        let gather_path =
            self.cfg.gather_missing && matches!(self.cfg.halo, HaloPolicy::Budgeted { .. });
        if self.serve_pool <= 1 || batches.len() <= 1 || gather_path {
            // sequential: one flush at a time through the audited
            // single-flush path, each span measured around its call
            return batches
                .iter()
                .map(|(shard, nodes)| {
                    let t0 = Instant::now();
                    let results = self.flush_shard_batch(*shard, nodes)?;
                    let service_us = (t0.elapsed().as_micros() as u64).max(1);
                    Ok(FlushOutcome { results, service_us })
                })
                .collect();
        }
        // Parallel fan-out over disjoint engines — one worker per
        // flush (a wave never exceeds the pool width: the harness
        // sizes waves by `serve_parallelism`). Same structural
        // isolation and ascending-shard-order merge as `query_batch`.
        let version = self.graph.version();
        struct FlushTask<'a> {
            bi: usize,
            shard: u32,
            engine: &'a mut ShardEngine,
            locals: Vec<u32>,
            out: Option<(ShardServeOutcome, u64)>,
        }
        let mut tasks: Vec<FlushTask<'_>> = self
            .shards
            .iter_mut()
            .enumerate()
            .filter_map(|(s, engine)| {
                want[s].map(|bi| {
                    let locals: Vec<u32> = batches[bi]
                        .1
                        .iter()
                        .map(|&v| {
                            engine.local_of(v).expect("home shard always contains its base nodes")
                        })
                        .collect();
                    FlushTask { bi, shard: s as u32, engine, locals, out: None }
                })
            })
            .collect();
        let params = &self.params;
        let pruned = self.cfg.pruned;
        let wave_span = crate::span!("serve.flush_wave", batches = batches.len());
        let wave_parent = wave_span.id();
        std::thread::scope(|scope| {
            for (wi, t) in tasks.iter_mut().enumerate() {
                scope.spawn(move || {
                    crate::threads::label_current_with(|| format!("serve-worker-{wi}"));
                    crate::tensor::set_intra_threads(1);
                    let _fspan = crate::obs::trace::SpanGuard::enter_under(
                        "serve.shard_flush",
                        Some(wave_parent),
                        &[("shard", t.shard as i64), ("batch", t.locals.len() as i64)],
                    );
                    let t0 = Instant::now();
                    let out = t.engine.serve(params, &t.locals, pruned);
                    let span = (t0.elapsed().as_micros() as u64).max(1);
                    t.out = Some((out, span));
                });
            }
        });
        // merge counters in ascending shard order (tasks order), then
        // assemble outcomes back in the caller's `batches` order
        let mut outcomes: Vec<Option<FlushOutcome>> = Vec::new();
        outcomes.resize_with(batches.len(), || None);
        for t in &tasks {
            let (out, span) = t.out.as_ref().expect("worker served every flush");
            self.micro_batches += 1;
            self.cache_hits += out.cached_hits as u64;
            self.rows_recomputed += out.rows_recomputed as u64;
            self.queries += batches[t.bi].1.len() as u64;
            let results = batches[t.bi]
                .1
                .iter()
                .enumerate()
                .map(|(ri, &node)| QueryResult {
                    node,
                    pred: out.preds[ri],
                    probs: out.probs.row(ri).to_vec(),
                    shard: t.shard,
                    graph_version: version,
                    cache_hit: out.cached[ri],
                    rows_recomputed: out.rows_recomputed,
                })
                .collect();
            outcomes[t.bi] = Some(FlushOutcome { results, service_us: *span });
        }
        Ok(outcomes.into_iter().map(|o| o.expect("every flush answered")).collect())
    }

    /// Open-loop harness hook: record one scheduler queue-depth sample
    /// (max/mean land in [`ServeStats`]).
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.queue_depth_max = self.queue_depth_max.max(depth as u64);
        self.queue_depth_sum += depth as u64;
        self.queue_depth_samples += 1;
    }

    /// Open-loop harness hook: record whether an answer met its SLO
    /// deadline (goodput accounting in [`ServeStats`]).
    pub fn record_slo_outcome(&mut self, within_slo: bool) {
        if within_slo {
            self.slo_answers += 1;
        } else {
            self.late_answers += 1;
        }
    }

    /// Home for an online-inserted node: the part owning the plurality
    /// of its neighbours (ties → lowest part id); an isolated insert
    /// goes to the least-loaded part.
    fn choose_home(&self, id: u32) -> u32 {
        let mut counts: HashMap<u32, usize> = HashMap::new();
        for &t in self.graph.neighbors(id as usize) {
            let p = self.assignment[t as usize];
            if p != RETIRED {
                *counts.entry(p).or_default() += 1;
            }
        }
        if let Some((&part, _)) =
            counts.iter().max_by(|(pa, ca), (pb, cb)| ca.cmp(cb).then(pb.cmp(pa)))
        {
            return part;
        }
        (0..self.base_counts.len())
            .min_by_key(|&p| (self.base_counts[p], p))
            .expect("at least one shard") as u32
    }

    /// Apply online mutations **in place**: splice the edge churn and
    /// elastic node churn through the overlay CSR (O(Δ)), bump the
    /// graph version, update inverse-sqrt-degree factors for exactly
    /// the degree-changed nodes, and fold the delta into each touched
    /// shard — splicing local adjacency + Â rows and clearing exactly
    /// the cached rows whose L-hop dependency cone the delta reaches
    /// (distances taken as the min over the old and new graph, so
    /// removals invalidate conservatively too). Shards whose halo or
    /// base membership changed re-induce *locally* and migrate
    /// surviving rows; untouched shards do nothing. Budgeted-halo
    /// shards the delta touched restart cold instead: their halo is
    /// re-sampled, so no old row is trustworthy. With
    /// [`DeltaMode::Rebuild`] every touched shard rebuilds from a
    /// freshly compacted flat CSR — the O(E) pre-overlay behaviour,
    /// kept as benchmark baseline and property-test oracle.
    pub fn apply_delta(&mut self, delta: &GraphDelta) -> Result<DeltaReport> {
        let mut _dspan = crate::span!(
            "serve.apply_delta",
            added_edges = delta.added_edges.len(),
            removed_edges = delta.removed_edges.len(),
            added_nodes = delta.added_nodes.len(),
            removed_nodes = delta.removed_nodes.len(),
        );
        let old_n = self.graph.num_nodes();
        delta.validate(old_n, self.features.cols)?;
        // liveness: retired ids cannot be referenced again
        let check_alive = |v: u32| -> Result<()> {
            if (v as usize) < old_n && self.assignment[v as usize] == RETIRED {
                return Err(anyhow!("delta references removed node {v}"));
            }
            Ok(())
        };
        for &(u, v) in delta.added_edges.iter().chain(&delta.removed_edges) {
            check_alive(u)?;
            check_alive(v)?;
        }
        for (v, _) in &delta.updated_features {
            check_alive(*v)?;
        }
        for nn in &delta.added_nodes {
            for &e in &nn.edges {
                check_alive(e)?;
            }
        }
        for &v in &delta.removed_nodes {
            check_alive(v)?;
        }
        if delta.is_empty() {
            return Ok(DeltaReport {
                graph_version: self.graph.version(),
                seeds: 0,
                rows_invalidated: 0,
                serving_bytes: 0,
                nodes_added: 0,
                nodes_removed: 0,
                shards_rebuilt: 0,
                compacted: false,
                rebalance_moves: 0,
                rebalance_bytes: 0,
            });
        }
        let layers = self.params.layers();
        let dims: Vec<usize> = self.params.ws.iter().map(|w| w.cols).collect();

        // ---- seed distances on the pre-delta graph (sparse: memory
        //      proportional to the delta's L-hop cone, never to V) ----
        let seeds_all = delta.seeds(old_n);
        let seeds_old: Vec<u32> =
            seeds_all.iter().copied().filter(|&s| (s as usize) < old_n).collect();
        let dist_old = bounded_bfs_distances_sparse(&self.graph, &seeds_old, layers);

        // ---- mutate through the overlay: O(Δ) -----------------------
        let mut churn = EdgeChurn::default();
        let mut added_ids: Vec<u32> = Vec::with_capacity(delta.added_nodes.len());
        for nn in &delta.added_nodes {
            let id = self.graph.add_node();
            self.features.push_row(&nn.features);
            self.inv_sqrt.push(NormAdj::inv_sqrt_degree(0));
            self.assignment.push(RETIRED); // homed below, once edges exist
            added_ids.push(id);
        }
        // removals before insertions, matching `GraphDelta::apply_to`:
        // an edge listed in both ends up present
        for &(u, v) in &delta.removed_edges {
            if self.graph.remove_edge(u, v) {
                churn.removed.push((u, v));
            }
        }
        for &(u, v) in &delta.added_edges {
            if self.graph.add_edge(u, v) {
                churn.added.push((u, v));
            }
        }
        for (i, nn) in delta.added_nodes.iter().enumerate() {
            for &e in &nn.edges {
                if self.graph.add_edge(added_ids[i], e) {
                    churn.added.push((added_ids[i], e));
                }
            }
        }
        let mut base_removed_by_part: HashMap<u32, Vec<u32>> = HashMap::new();
        for &v in &delta.removed_nodes {
            let part = self.assignment[v as usize];
            base_removed_by_part.entry(part).or_default().push(v);
            self.base_counts[part as usize] -= 1;
            for t in self.graph.isolate(v) {
                churn.removed.push((v, t));
            }
            self.assignment[v as usize] = RETIRED;
        }
        churn.finish();
        self.graph.bump_version();
        let compactions_before = self.graph.compactions();
        match self.cfg.delta_mode {
            DeltaMode::Rebuild => self.graph.compact(),
            DeltaMode::Incremental => {
                self.graph.maybe_compact();
            }
        }
        let compacted = self.graph.compactions() > compactions_before;

        // home the inserted nodes now that their edges exist
        for &id in &added_ids {
            let home = self.choose_home(id);
            self.assignment[id as usize] = home;
            self.base_counts[home as usize] += 1;
        }

        // O(Δ) factor refresh: only degree-changed nodes move
        for &g in &churn.degree_changed {
            self.inv_sqrt[g as usize] = NormAdj::inv_sqrt_degree(self.graph.degree(g as usize));
        }
        for (v, row) in &delta.updated_features {
            self.features.row_mut(*v as usize).copy_from_slice(row);
        }

        // ---- conservative influence cone over old ∪ new graph -------
        let mut dist = bounded_bfs_distances_sparse(&self.graph, &seeds_all, layers);
        for (g, d) in dist_old {
            dist.entry(g).and_modify(|cur| *cur = (*cur).min(d)).or_insert(d);
        }
        // gathered rows are computed over the *global* graph (that is
        // what makes gather mode exact), so the same L-hop cone rule
        // the embedding caches use applies verbatim: drop exactly the
        // rows the delta's influence cone reaches, keep the rest.
        // Shard/halo re-sampling below cannot stale them — validity
        // never depended on any shard's membership
        if let Some(c) = &mut self.gather_cache {
            c.invalidate_cone(&dist);
        }
        // membership probes are per affected node (binary search), so
        // touched-shard detection costs O(|cone| · k · log), not O(V)
        let affected: Vec<u32> = dist.keys().copied().collect();

        // ---- fold into shards ---------------------------------------
        let version = self.graph.version();
        let k = self.shards.len();
        let multi = k > 1;
        let mut rows_invalidated = 0u64;
        let mut serving_bytes = 0u64;
        let mut rebuilds = 0usize;
        for si in 0..k {
            let part = self.shards[si].part;
            let base_added: Vec<u32> = added_ids
                .iter()
                .copied()
                .filter(|&v| self.assignment[v as usize] == part)
                .collect();
            let base_removed = base_removed_by_part.get(&part).cloned().unwrap_or_default();
            let touched = !base_added.is_empty()
                || !base_removed.is_empty()
                || affected.iter().any(|&g| self.shards[si].local_of(g).is_some());
            if !touched {
                // No member within L hops of any seed (the dist BFS is
                // bounded at L, so MAX means "farther"). Then no cached
                // row is stale, and membership/Â/features are unchanged
                // too — a new candidate path or a degree change would
                // need a seed within L hops of a member.
                self.shards[si].cache.set_version(version);
                continue;
            }
            let incremental = self.cfg.delta_mode == DeltaMode::Incremental
                && matches!(self.cfg.halo, HaloPolicy::Exact);
            if incremental {
                let ctx = ShardDeltaCtx {
                    graph: &self.graph,
                    global_features: &self.features,
                    inv_sqrt: &self.inv_sqrt,
                    assignment: &self.assignment,
                    churn: &churn,
                    updated_features: &delta.updated_features,
                    base_added: &base_added,
                    base_removed: &base_removed,
                    dist: &dist,
                    layers,
                    dims: &dims,
                    multi_shard: multi,
                };
                let out = self.shards[si].apply_delta(&self.cfg, &ctx);
                rows_invalidated += out.rows_invalidated;
                serving_bytes += out.bytes;
                if out.rebuilt {
                    rebuilds += 1;
                }
            } else {
                // full shard rebuild: Rebuild mode (baseline/oracle)
                // and every touched Budgeted shard (its halo is
                // re-sampled on the mutated graph, so the rebuilt shard
                // starts cold — no old row is trustworthy)
                let mut fresh = ShardEngine::build(
                    &self.graph,
                    &self.features,
                    &self.inv_sqrt,
                    &self.assignment,
                    part,
                    layers,
                    &self.cfg,
                );
                let old = &self.shards[si];
                let invalidated_before = old.cache.rows_invalidated;
                match self.cfg.halo {
                    // exact halos: structure around far-away nodes is
                    // provably unchanged, so their rows survive
                    HaloPolicy::Exact => fresh.migrate_cache_from(old, &dist, &dims),
                    HaloPolicy::Budgeted { .. } => {
                        fresh.cache.carry_counters_discarding(&old.cache)
                    }
                }
                rows_invalidated += fresh.cache.rows_invalidated - invalidated_before;
                if multi {
                    // same helpers as the incremental path, so the two
                    // delta modes can never account bytes differently
                    let frow = (self.features.cols * 4) as u64;
                    serving_bytes += fresh.halo_join_bytes(old, frow)
                        + fresh.replica_churn_bytes(&churn, &delta.updated_features, frow);
                }
                rebuilds += 1;
                self.shards[si] = fresh;
            }
            self.shards[si].cache.set_version(version);
        }
        self.ledger.record_serving(serving_bytes);
        self.deltas_applied += 1;
        self.nodes_added += added_ids.len() as u64;
        self.nodes_removed += delta.removed_nodes.len() as u64;
        self.shard_rebuilds += rebuilds as u64;

        // post-delta trigger: elastic churn is what drifts the base
        // counts, so balance is re-checked exactly when it can break
        let reb = if self.cfg.rebalance && self.imbalance_ratio() > self.cfg.rebalance_ratio {
            rebalance::run(self)
        } else {
            RebalanceReport::default()
        };
        self.debug_assert_counts_consistent();
        // bytes the delta billed across ledger classes (halo resync +
        // rebalance migration) — fig15's bytes column for this phase
        _dspan.set_arg("bytes", (serving_bytes + reb.bytes) as i64);
        Ok(DeltaReport {
            graph_version: version,
            seeds: seeds_all.len(),
            rows_invalidated,
            serving_bytes,
            nodes_added: added_ids.len(),
            nodes_removed: delta.removed_nodes.len(),
            shards_rebuilt: rebuilds,
            compacted,
            rebalance_moves: reb.moves,
            rebalance_bytes: reb.bytes,
        })
    }

    /// Current max/min base-node ratio across parts (empty parts count
    /// as size 1 so the ratio stays finite).
    pub fn imbalance_ratio(&self) -> f64 {
        rebalance::imbalance_ratio(&self.base_counts)
    }

    /// Run one bounded rebalance pass now, regardless of the configured
    /// trigger (benchmarks and tests; [`apply_delta`](Self::apply_delta)
    /// calls the same pass automatically when
    /// [`ServeConfig::rebalance`] is on and the ratio exceeds
    /// [`ServeConfig::rebalance_ratio`]).
    pub fn rebalance(&mut self) -> RebalanceReport {
        let rep = rebalance::run(self);
        self.debug_assert_counts_consistent();
        rep
    }

    /// Reconcile `base_counts` against both the assignment vector and
    /// every shard's owned-node count — the accounting that elastic
    /// homing and the rebalancer lean on. Debug builds run this after
    /// every delta and rebalance pass; release builds skip it.
    pub(crate) fn debug_assert_counts_consistent(&self) {
        if cfg!(debug_assertions) {
            let mut from_assignment = vec![0usize; self.base_counts.len()];
            for &p in &self.assignment {
                if p != RETIRED {
                    from_assignment[p as usize] += 1;
                }
            }
            assert_eq!(
                self.base_counts, from_assignment,
                "base_counts diverged from the assignment vector"
            );
            for sh in &self.shards {
                assert_eq!(
                    sh.base_len(),
                    self.base_counts[sh.part as usize],
                    "shard {} owns a different node count than base_counts",
                    sh.part
                );
            }
        }
    }

    /// Lifetime counters + traffic snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            queries: self.queries,
            micro_batches: self.micro_batches,
            cache_hits: self.cache_hits,
            rows_recomputed: self.rows_recomputed,
            rows_evicted: self.shards.iter().map(|s| s.cache.rows_evicted).sum(),
            gather_rows_reused: self.gather_cache.as_ref().map(|c| c.rows_reused).unwrap_or(0),
            gather_fetches_avoided: self
                .gather_cache
                .as_ref()
                .map(|c| c.fetches_avoided)
                .unwrap_or(0),
            gather_rows_invalidated: self
                .gather_cache
                .as_ref()
                .map(|c| c.rows_invalidated)
                .unwrap_or(0),
            slo_answers: self.slo_answers,
            late_answers: self.late_answers,
            queue_depth_max: self.queue_depth_max,
            queue_depth_mean: if self.queue_depth_samples > 0 {
                self.queue_depth_sum as f64 / self.queue_depth_samples as f64
            } else {
                0.0
            },
            deltas_applied: self.deltas_applied,
            nodes_added: self.nodes_added,
            nodes_removed: self.nodes_removed,
            shard_rebuilds: self.shard_rebuilds,
            graph_compactions: self.graph.compactions(),
            compaction_threshold: self.graph.compaction_threshold(),
            rebalances: self.rebalances,
            nodes_migrated: self.nodes_migrated,
            imbalance_ratio: self.imbalance_ratio(),
            graph_version: self.graph.version(),
            comm: CommStats::from_ledger(&self.ledger),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::rng::Rng;
    use crate::serve::{HaloPolicy, NewNode};

    fn fixture() -> (Dataset, GcnParams) {
        let ds = SyntheticSpec::tiny().generate(11);
        let mut rng = Rng::seed_from_u64(11);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        (ds, params)
    }

    #[test]
    fn build_rejects_mismatched_model() {
        let (ds, _) = fixture();
        let mut rng = Rng::seed_from_u64(1);
        let wrong = GcnParams::init(ds.feature_dim() + 1, 8, ds.num_classes, 2, &mut rng);
        assert!(Server::for_dataset(&ds, wrong, ServeConfig::default()).is_err());
    }

    #[test]
    fn batch_order_and_routing() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let nodes = vec![5u32, 0, 17, 5];
        let res = srv.query_batch(&nodes).unwrap();
        assert_eq!(res.len(), 4);
        for (r, &v) in res.iter().zip(&nodes) {
            assert_eq!(r.node, v);
            assert_eq!(r.shard, srv.shard_of(v));
            assert_eq!(r.probs.len(), ds.num_classes);
            let sum: f32 = r.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // duplicates agree with each other
        assert_eq!(res[0].pred, res[3].pred);
        let st = srv.stats();
        assert_eq!(st.queries, 4);
        assert!(st.micro_batches >= 1);
    }

    #[test]
    fn out_of_range_query_fails() {
        let (ds, params) = fixture();
        let n = ds.num_nodes() as u32;
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        assert!(srv.query(n).is_err());
    }

    #[test]
    fn halo_replication_is_accounted() {
        let (ds, params) = fixture();
        let srv = Server::for_dataset(&ds, params.clone(), ServeConfig::default()).unwrap();
        assert!(srv.stats().comm.serving_bytes > 0, "multi-shard halos must cost bytes");
        let single = Server::for_dataset(
            &ds,
            params,
            ServeConfig { shards: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(single.stats().comm.serving_bytes, 0, "one shard ships nothing");
    }

    #[test]
    fn budgeted_halo_ships_fewer_bytes() {
        let (ds, params) = fixture();
        let exact = Server::for_dataset(&ds, params.clone(), ServeConfig::default()).unwrap();
        let budgeted = Server::for_dataset(
            &ds,
            params,
            ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.01 }, ..Default::default() },
        )
        .unwrap();
        assert!(
            budgeted.stats().comm.serving_bytes < exact.stats().comm.serving_bytes,
            "importance-sampled halos are the cheap mode"
        );
    }

    #[test]
    fn delta_bumps_version_and_invalidates() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        // warm every shard
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        srv.query_batch(&all).unwrap();
        let warm_hits = srv.query(0).unwrap();
        assert!(warm_hits.cache_hit);

        let delta = GraphDelta {
            added_edges: vec![(0, (ds.num_nodes() - 1) as u32)],
            ..Default::default()
        };
        let rep = srv.apply_delta(&delta).unwrap();
        assert_eq!(rep.graph_version, 1);
        assert_eq!(rep.seeds, 2);
        assert!(rep.rows_invalidated > 0);
        let r = srv.query(0).unwrap();
        assert_eq!(r.graph_version, 1);
        assert!(!r.cache_hit, "rows at the epicentre must be recomputed");
        assert!(r.rows_recomputed > 0);
        // invalidation is surgical: nodes far from both seeds (and any
        // shard the delta never reached) still answer from cache
        let res = srv.query_batch(&all).unwrap();
        let hits = res.iter().filter(|r| r.cache_hit).count();
        assert!(hits > 0, "far-away rows must survive the delta");
    }

    #[test]
    fn incremental_delta_avoids_shard_rebuilds_on_interior_churn() {
        // churn confined to one part's interior (both endpoints share a
        // shard and sit far from any boundary halo change) splices in
        // place: membership identical → zero rebuilds for that delta
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        srv.query_batch(&all).unwrap();
        // find an existing edge whose removal+reinsertion keeps
        // membership identical: any edge works for splice-vs-rebuild
        // only if the halo set is unchanged, so just assert the far
        // cheaper property: incremental mode never does MORE rebuilds
        // than there are touched shards, and a feature-only delta (no
        // structural change at all) does zero rebuilds
        let delta = GraphDelta {
            updated_features: vec![(0, vec![0.5; ds.feature_dim()])],
            ..Default::default()
        };
        let rep = srv.apply_delta(&delta).unwrap();
        assert_eq!(rep.shards_rebuilt, 0, "feature updates never change membership");
        assert!(rep.rows_invalidated > 0, "but they do invalidate the local cone");
    }

    #[test]
    fn budgeted_delta_restarts_touched_shards_cold() {
        let (ds, params) = fixture();
        let cfg = ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.02 }, ..Default::default() };
        let mut srv = Server::for_dataset(&ds, params, cfg).unwrap();
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        srv.query_batch(&all).unwrap();
        let delta = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
        let rep = srv.apply_delta(&delta).unwrap();
        assert!(rep.rows_invalidated > 0, "touched budgeted shards drop their cache");
        let r = srv.query(0).unwrap();
        assert_eq!(r.graph_version, 1);
        assert!(!r.cache_hit, "the re-sampled shard must answer fresh");
    }

    #[test]
    fn surgical_gather_invalidation_matches_wholesale_clear_bitwise() {
        // the surgical cone (invalidate_cone) vs the old wholesale
        // clear: answers after a delta must be bit-identical, while
        // the surgical cache demonstrably retains rows the cone missed
        let (ds, params) = fixture();
        let cfg = ServeConfig {
            halo: HaloPolicy::Budgeted { alpha: 0.02 },
            gather_missing: true,
            gather_cache_budget_bytes: 64 << 20,
            ..Default::default()
        };
        let mut surgical = Server::for_dataset(&ds, params.clone(), cfg.clone()).unwrap();
        let mut wholesale = Server::for_dataset(&ds, params, cfg).unwrap();
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        surgical.query_batch(&all).unwrap();
        wholesale.query_batch(&all).unwrap();
        let delta = GraphDelta {
            added_edges: vec![(0, (ds.num_nodes() - 1) as u32)],
            updated_features: vec![(1, vec![0.25; ds.feature_dim()])],
            ..Default::default()
        };
        surgical.apply_delta(&delta).unwrap();
        wholesale.apply_delta(&delta).unwrap();
        // emulate the old behaviour on the baseline (the delta path no
        // longer reads the cache after invalidation, so clearing here
        // is exactly the wholesale-on-delta semantics)
        wholesale.gather_cache.as_mut().unwrap().clear();
        let st = surgical.stats();
        assert!(st.gather_rows_invalidated > 0, "the cone must drop stale rows");
        assert!(
            surgical.gather_cache.as_ref().unwrap().resident_bytes() > 0,
            "rows outside the cone must survive the delta"
        );
        let avoided_before = st.gather_fetches_avoided;
        let a = surgical.query_batch(&all).unwrap();
        let b = wholesale.query_batch(&all).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pred, y.pred);
            assert_eq!(x.probs.len(), y.probs.len());
            for (p, q) in x.probs.iter().zip(&y.probs) {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "surgical invalidation must not change any answer"
                );
            }
        }
        assert!(
            surgical.stats().gather_fetches_avoided > avoided_before,
            "surviving rows must actually be reused"
        );
    }

    #[test]
    fn empty_delta_is_noop() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let rep = srv.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(rep.graph_version, 0);
        assert_eq!(srv.stats().deltas_applied, 0);
    }

    #[test]
    fn delta_rejects_bad_input() {
        let (ds, params) = fixture();
        let n = ds.num_nodes() as u32;
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let bad = GraphDelta { added_edges: vec![(0, n)], ..Default::default() };
        assert!(srv.apply_delta(&bad).is_err());
        assert_eq!(srv.graph_version(), 0, "failed delta must not advance the version");
    }

    #[test]
    fn elastic_insert_routes_and_serves() {
        let (ds, params) = fixture();
        let fdim = ds.feature_dim();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let n0 = srv.num_nodes() as u32;
        let delta = GraphDelta {
            added_nodes: vec![NewNode { features: vec![0.1; fdim], edges: vec![0, 1] }],
            ..Default::default()
        };
        let rep = srv.apply_delta(&delta).unwrap();
        assert_eq!(rep.nodes_added, 1);
        assert_eq!(srv.num_nodes() as u32, n0 + 1);
        assert!(srv.is_alive(n0));
        let r = srv.query(n0).unwrap();
        assert_eq!(r.node, n0);
        assert_eq!(r.shard, srv.shard_of(n0));
        // the new node's home is a neighbour's home (plurality rule)
        let homes = [srv.shard_of(0), srv.shard_of(1)];
        assert!(homes.contains(&r.shard));
        assert!((r.probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn elastic_remove_retires_the_id() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let victim = 3u32;
        let rep =
            srv.apply_delta(&GraphDelta { removed_nodes: vec![victim], ..Default::default() })
                .unwrap();
        assert_eq!(rep.nodes_removed, 1);
        assert!(!srv.is_alive(victim));
        assert!(srv.query(victim).is_err(), "retired ids reject queries");
        // neighbours still answer; removing twice fails cleanly
        srv.query(0).unwrap();
        assert!(srv
            .apply_delta(&GraphDelta { removed_nodes: vec![victim], ..Default::default() })
            .is_err());
    }

    #[test]
    fn manual_rebalance_is_a_noop_on_a_balanced_deployment() {
        let (ds, params) = fixture();
        let cfg = ServeConfig { rebalance_ratio: 4.0, ..Default::default() };
        let mut srv = Server::for_dataset(&ds, params, cfg).unwrap();
        assert!(srv.imbalance_ratio() >= 1.0);
        let rep = srv.rebalance();
        assert!(!rep.triggered, "a balanced deployment must not migrate");
        assert_eq!(rep.moves, 0);
        assert_eq!(rep.ratio_before, rep.ratio_after);
        assert_eq!(srv.stats().comm.rebalance_bytes, 0);
        assert_eq!(srv.stats().rebalances, 0);
    }

    #[test]
    fn rebalance_report_rides_the_delta_report() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let delta = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
        let rep = srv.apply_delta(&delta).unwrap();
        assert_eq!(rep.rebalance_moves, 0, "rebalancer is off by default");
        assert_eq!(rep.rebalance_bytes, 0);
    }

    #[test]
    fn isolated_insert_goes_to_least_loaded_part() {
        let (ds, params) = fixture();
        let fdim = ds.feature_dim();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let least = (0..srv.base_counts.len())
            .min_by_key(|&p| (srv.base_counts[p], p))
            .unwrap() as u32;
        let delta = GraphDelta {
            added_nodes: vec![NewNode { features: vec![0.0; fdim], edges: vec![] }],
            ..Default::default()
        };
        srv.apply_delta(&delta).unwrap();
        let id = (srv.num_nodes() - 1) as u32;
        assert_eq!(srv.shard_of(id), least);
    }
}
