//! The query frontend: shard routing, per-shard micro-batching, online
//! graph deltas, provenance and traffic accounting.

use super::delta::{seed_distances, GraphDelta};
use super::shard::ShardEngine;
use super::ServeConfig;
use crate::comm::{CommLedger, CommStats};
use crate::datasets::Dataset;
use crate::graph::Csr;
use crate::model::GcnParams;
use crate::partition::{partition, PartitionConfig};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};

/// One answered query with its provenance.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// Queried (global) node id.
    pub node: u32,
    /// Predicted class.
    pub pred: u32,
    /// Softmax class probabilities.
    pub probs: Vec<f32>,
    /// Shard that answered (always the node's home shard — queries are
    /// shard-local by construction).
    pub shard: u32,
    /// Graph version the answer is valid for.
    pub graph_version: u64,
    /// Output-layer embedding came straight from the cache.
    pub cache_hit: bool,
    /// Embedding rows recomputed by the micro-batch that served this
    /// query (shared across the batch's queries on the same shard).
    pub rows_recomputed: usize,
}

/// Lifetime serving counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub queries: u64,
    pub micro_batches: u64,
    /// Queries answered from a valid output-layer row.
    pub cache_hits: u64,
    /// Embedding rows recomputed across all layers.
    pub rows_recomputed: u64,
    pub deltas_applied: u64,
    pub graph_version: u64,
    /// Cross-shard serving traffic (halo replication + delta
    /// propagation; the query path moves nothing).
    pub comm: CommStats,
}

/// What one [`GraphDelta`] did to the deployment.
#[derive(Clone, Copy, Debug)]
pub struct DeltaReport {
    /// Version after the delta.
    pub graph_version: u64,
    /// Epicentre size (distinct touched nodes).
    pub seeds: usize,
    /// Cached embedding rows dropped by L-hop invalidation (including
    /// halo-membership churn).
    pub rows_invalidated: u64,
    /// Cross-shard bytes spent propagating the delta.
    pub serving_bytes: u64,
}

/// See module docs ([`crate::serve`]).
pub struct Server {
    cfg: ServeConfig,
    graph: Csr,
    features: Matrix,
    params: GcnParams,
    assignment: Vec<u32>,
    shards: Vec<ShardEngine>,
    version: u64,
    ledger: CommLedger,
    queries: u64,
    micro_batches: u64,
    cache_hits: u64,
    rows_recomputed: u64,
    deltas_applied: u64,
}

/// `1/sqrt(deg+1)` per node over the full graph — the factors that make
/// shard-local Â entries agree with the full graph's. Delegates to the
/// training-time formula so the two can never diverge.
fn global_inv_sqrt(graph: &Csr) -> Vec<f32> {
    crate::model::NormAdj::inv_sqrt_degrees(graph)
}

impl Server {
    /// Shard `graph` and stand the deployment up. Fails cleanly on a
    /// model whose input width does not match the features.
    pub fn build(graph: Csr, features: Matrix, params: GcnParams, cfg: ServeConfig) -> Result<Server> {
        let n = graph.num_nodes();
        if n == 0 {
            return Err(anyhow!("cannot serve an empty graph"));
        }
        if features.rows != n {
            return Err(anyhow!("features have {} rows for {} nodes", features.rows, n));
        }
        if params.ws.is_empty() {
            return Err(anyhow!("model has no layers"));
        }
        if params.ws[0].rows != features.cols {
            return Err(anyhow!(
                "model expects {}-dim features, graph has {}-dim",
                params.ws[0].rows,
                features.cols
            ));
        }
        let k = cfg.shards.clamp(1, n);
        let layers = params.layers();
        let part = partition(&graph, &PartitionConfig { k, seed: cfg.seed, ..Default::default() });
        let inv = global_inv_sqrt(&graph);
        let ledger = CommLedger::new();
        let mut shards = Vec::with_capacity(k);
        for p in 0..k as u32 {
            let sh = ShardEngine::build(&graph, &features, &inv, &part.assignment, p, layers, &cfg);
            if k > 1 {
                // the halo is the only thing serving ever ships:
                // replicated feature rows move once at build, queries
                // then stay shard-local
                ledger.record_serving((sh.replicas.len() * features.cols * 4) as u64);
            }
            shards.push(sh);
        }
        Ok(Server {
            cfg,
            graph,
            features,
            params,
            assignment: part.assignment,
            shards,
            version: 0,
            ledger,
            queries: 0,
            micro_batches: 0,
            cache_hits: 0,
            rows_recomputed: 0,
            deltas_applied: 0,
        })
    }

    /// Build from a dataset (graph + features are cloned; labels and
    /// splits are a training concern the serving tier never sees).
    pub fn for_dataset(ds: &Dataset, params: GcnParams, cfg: ServeConfig) -> Result<Server> {
        Self::build(ds.graph.clone(), ds.features.clone(), params, cfg)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn graph_version(&self) -> u64 {
        self.version
    }

    pub fn params(&self) -> &GcnParams {
        &self.params
    }

    /// Shard inspection (tests / reporting).
    pub fn shard(&self, i: usize) -> &ShardEngine {
        &self.shards[i]
    }

    /// Home shard of a node.
    pub fn shard_of(&self, node: u32) -> u32 {
        self.assignment[node as usize]
    }

    /// Resident bytes across shards (features + adjacency + cache).
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.nbytes()).sum()
    }

    /// Classify one node.
    pub fn query(&mut self, node: u32) -> Result<QueryResult> {
        let mut v = self.query_batch(std::slice::from_ref(&node))?;
        Ok(v.pop().expect("one query, one result"))
    }

    /// Classify a batch. Queries are grouped per home shard and each
    /// group is answered by one gather-rows → GEMM pipeline pass —
    /// the micro-batching that amortises the forward across queries.
    /// Results come back in input order; batching cannot change any
    /// answer (per-row compute is independent, enforced by tests).
    pub fn query_batch(&mut self, nodes: &[u32]) -> Result<Vec<QueryResult>> {
        let n = self.graph.num_nodes();
        for &v in nodes {
            if v as usize >= n {
                return Err(anyhow!("query node {v} out of range (n={n})"));
            }
        }
        let mut groups: Vec<Vec<(usize, u32)>> = vec![Vec::new(); self.shards.len()];
        for (i, &v) in nodes.iter().enumerate() {
            let s = self.assignment[v as usize] as usize;
            let local = self.shards[s]
                .sub
                .local_of(v)
                .expect("home shard always contains its base nodes");
            groups[s].push((i, local));
        }
        let mut results: Vec<Option<QueryResult>> = vec![None; nodes.len()];
        for (s, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let locals: Vec<u32> = group.iter().map(|&(_, l)| l).collect();
            let out = self.shards[s].serve(&self.params, &locals, self.cfg.pruned);
            self.micro_batches += 1;
            self.cache_hits += out.cached_hits as u64;
            self.rows_recomputed += out.rows_recomputed as u64;
            for (ri, &(orig, _)) in group.iter().enumerate() {
                results[orig] = Some(QueryResult {
                    node: nodes[orig],
                    pred: out.preds[ri],
                    probs: out.probs.row(ri).to_vec(),
                    shard: s as u32,
                    graph_version: self.version,
                    cache_hit: out.cached[ri],
                    rows_recomputed: out.rows_recomputed,
                });
            }
        }
        self.queries += nodes.len() as u64;
        Ok(results.into_iter().map(|r| r.expect("every query answered")).collect())
    }

    /// Apply online mutations: bump the graph version, rebuild shard
    /// structure, and drop exactly the cached rows whose L-hop
    /// dependency cone touches the delta (layer-`l` rows within `l`
    /// hops of a seed, distances taken as the min over the old and new
    /// graph so removals invalidate conservatively too). Everything
    /// else is recomputed lazily by later queries. Budgeted-halo shards
    /// whose region the delta touched restart cold instead: their halo
    /// is re-sampled, so no old row is trustworthy.
    pub fn apply_delta(&mut self, delta: &GraphDelta) -> Result<DeltaReport> {
        delta.validate(self.graph.num_nodes(), self.features.cols)?;
        if delta.is_empty() {
            return Ok(DeltaReport {
                graph_version: self.version,
                seeds: 0,
                rows_invalidated: 0,
                serving_bytes: 0,
            });
        }
        let layers = self.params.layers();
        let seeds = delta.seeds();
        let new_graph = delta.apply_to(&self.graph);
        let dist_old = seed_distances(&self.graph, &seeds, layers);
        let dist_new = seed_distances(&new_graph, &seeds, layers);
        let dist: Vec<u32> =
            dist_old.iter().zip(&dist_new).map(|(&a, &b)| a.min(b)).collect();

        for (v, row) in &delta.updated_features {
            self.features.row_mut(*v as usize).copy_from_slice(row);
        }

        self.version += 1;
        let inv = global_inv_sqrt(&new_graph);
        let dims: Vec<usize> = self.params.ws.iter().map(|w| w.cols).collect();
        let k = self.shards.len();
        let mut rows_invalidated = 0u64;
        let mut serving_bytes = 0u64;
        let old_shards = std::mem::take(&mut self.shards);
        for old in old_shards {
            // Untouched shard: no member within L hops of any seed (the
            // dist BFS is bounded at L, so MAX means "farther"). Then no
            // cached row is stale, and membership/Â/features are
            // unchanged too — a new candidate path or a degree change
            // would need a seed within L hops of a member. Keep the
            // shard as-is instead of an O(V+E) rebuild.
            let touched = old.sub.global_ids.iter().any(|&g| dist[g as usize] != u32::MAX);
            if !touched {
                let mut keep = old;
                keep.cache.set_version(self.version);
                self.shards.push(keep);
                continue;
            }
            let mut fresh = ShardEngine::build(
                &new_graph,
                &self.features,
                &inv,
                &self.assignment,
                old.part,
                layers,
                &self.cfg,
            );
            let invalidated_before = old.cache.rows_invalidated;
            match self.cfg.halo {
                // exact halos: structure around far-away nodes is
                // provably unchanged, so their rows survive
                super::HaloPolicy::Exact => fresh.migrate_cache_from(&old, &dist, &dims),
                // budgeted halos are re-sampled on the mutated graph —
                // the local adjacency can change anywhere, so the
                // rebuilt shard starts cold
                super::HaloPolicy::Budgeted { .. } => {
                    fresh.cache.carry_counters_discarding(&old.cache)
                }
            }
            fresh.cache.set_version(self.version);
            rows_invalidated += fresh.cache.rows_invalidated - invalidated_before;

            if k > 1 {
                // propagation cost: updated feature rows shipped to the
                // shards that replicate the node, churned edges to the
                // shards that see them through a replica, and feature
                // rows for nodes newly pulled into the halo
                let mut bytes = 0u64;
                let frow = (self.features.cols * 4) as u64;
                for (v, _) in &delta.updated_features {
                    if let Some(l) = fresh.sub.local_of(*v) {
                        if fresh.is_replica[l as usize] {
                            bytes += frow;
                        }
                    }
                }
                for &(u, v) in delta.added_edges.iter().chain(&delta.removed_edges) {
                    let lu = fresh.sub.local_of(u);
                    let lv = fresh.sub.local_of(v);
                    let replica = |l: Option<u32>| {
                        l.map(|i| fresh.is_replica[i as usize]).unwrap_or(false)
                    };
                    if (lu.is_some() || lv.is_some()) && (replica(lu) || replica(lv)) {
                        bytes += 8;
                    }
                }
                for (l, &g) in fresh.sub.global_ids.iter().enumerate() {
                    if fresh.is_replica[l] && old.sub.local_of(g).is_none() {
                        bytes += frow; // node joined this halo
                    }
                }
                self.ledger.record_serving(bytes);
                serving_bytes += bytes;
            }
            self.shards.push(fresh);
        }
        self.graph = new_graph;
        self.deltas_applied += 1;
        Ok(DeltaReport {
            graph_version: self.version,
            seeds: seeds.len(),
            rows_invalidated,
            serving_bytes,
        })
    }

    /// Lifetime counters + traffic snapshot.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            queries: self.queries,
            micro_batches: self.micro_batches,
            cache_hits: self.cache_hits,
            rows_recomputed: self.rows_recomputed,
            deltas_applied: self.deltas_applied,
            graph_version: self.version,
            comm: CommStats::from_ledger(&self.ledger),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::rng::Rng;
    use crate::serve::HaloPolicy;

    fn fixture() -> (Dataset, GcnParams) {
        let ds = SyntheticSpec::tiny().generate(11);
        let mut rng = Rng::seed_from_u64(11);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        (ds, params)
    }

    #[test]
    fn build_rejects_mismatched_model() {
        let (ds, _) = fixture();
        let mut rng = Rng::seed_from_u64(1);
        let wrong = GcnParams::init(ds.feature_dim() + 1, 8, ds.num_classes, 2, &mut rng);
        assert!(Server::for_dataset(&ds, wrong, ServeConfig::default()).is_err());
    }

    #[test]
    fn batch_order_and_routing() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let nodes = vec![5u32, 0, 17, 5];
        let res = srv.query_batch(&nodes).unwrap();
        assert_eq!(res.len(), 4);
        for (r, &v) in res.iter().zip(&nodes) {
            assert_eq!(r.node, v);
            assert_eq!(r.shard, srv.shard_of(v));
            assert_eq!(r.probs.len(), ds.num_classes);
            let sum: f32 = r.probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // duplicates agree with each other
        assert_eq!(res[0].pred, res[3].pred);
        let st = srv.stats();
        assert_eq!(st.queries, 4);
        assert!(st.micro_batches >= 1);
    }

    #[test]
    fn out_of_range_query_fails() {
        let (ds, params) = fixture();
        let n = ds.num_nodes() as u32;
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        assert!(srv.query(n).is_err());
    }

    #[test]
    fn halo_replication_is_accounted() {
        let (ds, params) = fixture();
        let srv = Server::for_dataset(&ds, params.clone(), ServeConfig::default()).unwrap();
        assert!(srv.stats().comm.serving_bytes > 0, "multi-shard halos must cost bytes");
        let single = Server::for_dataset(
            &ds,
            params,
            ServeConfig { shards: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(single.stats().comm.serving_bytes, 0, "one shard ships nothing");
    }

    #[test]
    fn budgeted_halo_ships_fewer_bytes() {
        let (ds, params) = fixture();
        let exact = Server::for_dataset(&ds, params.clone(), ServeConfig::default()).unwrap();
        let budgeted = Server::for_dataset(
            &ds,
            params,
            ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.01 }, ..Default::default() },
        )
        .unwrap();
        assert!(
            budgeted.stats().comm.serving_bytes < exact.stats().comm.serving_bytes,
            "importance-sampled halos are the cheap mode"
        );
    }

    #[test]
    fn delta_bumps_version_and_invalidates() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        // warm every shard
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        srv.query_batch(&all).unwrap();
        let warm_hits = srv.query(0).unwrap();
        assert!(warm_hits.cache_hit);

        let delta = GraphDelta {
            added_edges: vec![(0, (ds.num_nodes() - 1) as u32)],
            ..Default::default()
        };
        let rep = srv.apply_delta(&delta).unwrap();
        assert_eq!(rep.graph_version, 1);
        assert_eq!(rep.seeds, 2);
        assert!(rep.rows_invalidated > 0);
        let r = srv.query(0).unwrap();
        assert_eq!(r.graph_version, 1);
        assert!(!r.cache_hit, "rows at the epicentre must be recomputed");
        assert!(r.rows_recomputed > 0);
        // invalidation is surgical: nodes far from both seeds (and any
        // shard the delta never reached) still answer from cache
        let res = srv.query_batch(&all).unwrap();
        let hits = res.iter().filter(|r| r.cache_hit).count();
        assert!(hits > 0, "far-away rows must survive the delta");
    }

    #[test]
    fn budgeted_delta_restarts_touched_shards_cold() {
        let (ds, params) = fixture();
        let cfg = ServeConfig { halo: HaloPolicy::Budgeted { alpha: 0.02 }, ..Default::default() };
        let mut srv = Server::for_dataset(&ds, params, cfg).unwrap();
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        srv.query_batch(&all).unwrap();
        let delta = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
        let rep = srv.apply_delta(&delta).unwrap();
        assert!(rep.rows_invalidated > 0, "touched budgeted shards drop their cache");
        let r = srv.query(0).unwrap();
        assert_eq!(r.graph_version, 1);
        assert!(!r.cache_hit, "the re-sampled shard must answer fresh");
    }

    #[test]
    fn empty_delta_is_noop() {
        let (ds, params) = fixture();
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let rep = srv.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(rep.graph_version, 0);
        assert_eq!(srv.stats().deltas_applied, 0);
    }

    #[test]
    fn delta_rejects_bad_input() {
        let (ds, params) = fixture();
        let n = ds.num_nodes() as u32;
        let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
        let bad = GraphDelta { added_edges: vec![(0, n)], ..Default::default() };
        assert!(srv.apply_delta(&bad).is_err());
        assert_eq!(srv.graph_version(), 0, "failed delta must not advance the version");
    }
}
