//! Partition-aware inference serving.
//!
//! Training produces a checkpoint; this subsystem turns it into a
//! query-answering service — the ROADMAP's "serve heavy traffic" leg.
//! The paper's augmented-subgraph insight (§3.2.2) applies directly:
//! a shard that carries a replicated L-hop halo of its boundary
//! (Property 1: walk/halo depth = GCN layer count) can answer
//! node-classification queries **entirely shard-locally** — the same
//! communication win GAD-Partition buys at training time, moved to the
//! serving tier. Three layers:
//!
//! * [`ShardEngine`] — one partition part plus its halo. Runs the
//!   layer-wise GCN forward over the local subgraph with a
//!   gather-rows → one-GEMM micro-batch pipeline, materialising
//!   per-layer node embeddings. With [`HaloPolicy::Exact`] the halo is
//!   the complete L-hop candidate set and base-node predictions are
//!   **bit-identical** to a full-graph forward (global-degree
//!   normalization via [`NormAdj::with_inv_sqrt`]); with
//!   [`HaloPolicy::Budgeted`] the halo is Algorithm 1's
//!   importance-sampled replica set — the training-time approximation,
//!   at a fraction of the memory.
//! * [`EmbeddingCache`] — per-shard `(layer, node)` embedding rows
//!   versioned by `graph_version`. A [`GraphDelta`] bumps the version
//!   and invalidates exactly the rows within `l` hops of the touched
//!   region at layer `l`; everything else survives and recomputation
//!   happens lazily on the next query that needs it.
//! * [`Server`] — the query frontend: routes single and batched
//!   queries to their shard, micro-batches per shard, applies deltas,
//!   and reports per-query provenance (owning shard, cache hit, rows
//!   recomputed). All cross-shard bytes — halo replication at build,
//!   delta propagation at mutation — land in the
//!   [`CommLedger`](crate::comm::CommLedger)'s serving traffic class;
//!   the query path itself moves zero bytes.
//!
//! [`NormAdj::with_inv_sqrt`]: crate::model::NormAdj::with_inv_sqrt

pub mod bench;
mod cache;
mod delta;
mod server;
mod shard;

pub use bench::{run_serving_bench, LatencySummary, ServingBenchConfig, ServingBenchReport};
pub use cache::EmbeddingCache;
pub use delta::GraphDelta;
pub use server::{DeltaReport, QueryResult, Server, ServeStats};
pub use shard::{ShardEngine, ShardServeOutcome};

/// How a shard's halo (replicated remote nodes) is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HaloPolicy {
    /// The complete L-hop candidate replication set (paper Def. 2 with
    /// no budget). Base-node predictions are bit-identical to a
    /// full-graph forward — serving's correctness mode.
    Exact,
    /// Algorithm 1's Monte-Carlo importance-sampled replicas with
    /// replication coefficient α (Eq. 5–6). Approximate at the
    /// boundary, much smaller resident halo.
    Budgeted { alpha: f64 },
}

/// Serving deployment configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Shard count (clamped to the node count at build).
    pub shards: usize,
    /// Halo construction policy.
    pub halo: HaloPolicy,
    /// Keep per-layer embeddings between queries. Off = every query
    /// recomputes (the "cold" mode of the latency benchmark).
    pub cache: bool,
    /// Restrict each layer's compute to the rows the queried nodes
    /// actually need (the L-hop cone). Off = recompute the whole shard
    /// every query — only useful as the naive baseline in benchmarks.
    pub pruned: bool,
    /// Partitioner / halo-sampling seed.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { shards: 4, halo: HaloPolicy::Exact, cache: true, pruned: true, seed: 0 }
    }
}
