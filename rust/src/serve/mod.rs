//! Partition-aware inference serving.
//!
//! Training produces a checkpoint; this subsystem turns it into a
//! query-answering service — the ROADMAP's "serve heavy traffic" leg.
//! The paper's augmented-subgraph insight (§3.2.2) applies directly:
//! a shard that carries a replicated L-hop halo of its boundary
//! (Property 1: walk/halo depth = GCN layer count) can answer
//! node-classification queries **entirely shard-locally** — the same
//! communication win GAD-Partition buys at training time, moved to the
//! serving tier. Three layers:
//!
//! * [`ShardEngine`] — one partition part plus its halo. Runs the
//!   layer-wise GCN forward over the local subgraph with a
//!   gather-rows → one-GEMM micro-batch pipeline, materialising
//!   per-layer node embeddings. With [`HaloPolicy::Exact`] the halo is
//!   the complete L-hop candidate set and base-node predictions are
//!   **bit-identical** to a full-graph forward (global-degree
//!   normalization via [`NormAdj::with_inv_sqrt`]); with
//!   [`HaloPolicy::Budgeted`] the halo is Algorithm 1's
//!   importance-sampled replica set — the training-time approximation,
//!   at a fraction of the memory (or exact again with
//!   [`ServeConfig::gather_missing`], which fetches the rows the halo
//!   lacks from their home shards, bytes accounted).
//! * [`EmbeddingCache`] — per-shard `(layer, node)` embedding rows
//!   versioned by the overlay graph's version. A [`GraphDelta`] bumps
//!   the version and invalidates exactly the rows within `l` hops of
//!   the touched region at layer `l`; everything else survives and
//!   recomputation happens lazily on the next query that needs it. An
//!   optional byte budget ([`ServeConfig::cache_budget_bytes`]) admits
//!   retained rows by Monte-Carlo importance `I(v)` and evicts the
//!   least important first.
//! * [`Server`] — the query frontend: routes single and batched
//!   queries to their shard, micro-batches per shard, applies deltas
//!   **in place** through a versioned [`DeltaCsr`](crate::graph::DeltaCsr)
//!   overlay (O(Δ·affected-hops), compaction amortised — see
//!   [`DeltaMode`]), supports **online elastic membership** (node
//!   insertion/removal with incremental shard + halo + cache updates,
//!   no offline reshard), and reports per-query provenance. All
//!   cross-shard bytes — halo replication at build, delta propagation
//!   and halo churn at mutation, missing-row gathers in budgeted mode —
//!   land in the [`CommLedger`](crate::comm::CommLedger)'s serving
//!   traffic class; the Exact-halo query path itself moves zero bytes.
//!   When elastic churn skews the per-part load, an optional **online
//!   rebalancer** ([`ServeConfig::rebalance`]) migrates boundary nodes
//!   from overloaded to underloaded parts by minimum edge-cut delta —
//!   bit-identical answers, bytes in a dedicated rebalance traffic
//!   class (see [`rebalance`](RebalanceReport)).
//!
//! [`NormAdj::with_inv_sqrt`]: crate::model::NormAdj::with_inv_sqrt

pub mod bench;
mod cache;
mod delta;
mod gather;
mod rebalance;
mod server;
mod shard;

pub use bench::{
    run_churn_bench, run_rebalance_bench, run_serving_bench, ChurnBenchConfig, ChurnBenchReport,
    ChurnSummary, LatencySummary, RebalanceBenchConfig, RebalanceBenchReport, RebalanceRound,
    ServingBenchConfig, ServingBenchReport,
};
pub use cache::EmbeddingCache;
pub use delta::{EdgeChurn, GraphDelta, NewNode};
pub use rebalance::RebalanceReport;
pub use server::{DeltaReport, FlushOutcome, QueryResult, Server, ServeStats};
pub use shard::{ShardEngine, ShardServeOutcome};

/// How a shard's halo (replicated remote nodes) is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HaloPolicy {
    /// The complete L-hop candidate replication set (paper Def. 2 with
    /// no budget). Base-node predictions are bit-identical to a
    /// full-graph forward — serving's correctness mode.
    Exact,
    /// Algorithm 1's Monte-Carlo importance-sampled replicas with
    /// replication coefficient α (Eq. 5–6). Approximate at the
    /// boundary, much smaller resident halo.
    Budgeted { alpha: f64 },
}

/// How a [`GraphDelta`] is folded into the running deployment.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeltaMode {
    /// Splice the delta through the overlay CSR and update only the
    /// affected shard state: O(Δ·affected-hops) per delta, flat-CSR
    /// compaction amortised over many deltas. The production path.
    #[default]
    Incremental,
    /// Compact to a flat CSR and rebuild every touched shard from
    /// scratch per delta (the pre-overlay behaviour): O(E). Kept as
    /// the churn benchmark's baseline and the property tests' oracle.
    Rebuild,
}

/// Serving deployment configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Shard count (clamped to the node count at build).
    pub shards: usize,
    /// Halo construction policy.
    pub halo: HaloPolicy,
    /// Keep per-layer embeddings between queries. Off = every query
    /// recomputes (the "cold" mode of the latency benchmark).
    pub cache: bool,
    /// Per-shard byte budget for *retained* cache rows; 0 = unbounded.
    /// Over budget, rows are evicted lowest Monte-Carlo importance
    /// `I(v)` first (base nodes score 1.0 and effectively never go
    /// before replicas).
    pub cache_budget_bytes: u64,
    /// Restrict each layer's compute to the rows the queried nodes
    /// actually need (the L-hop cone). Off = recompute the whole shard
    /// every query — only useful as the naive baseline in benchmarks.
    pub pruned: bool,
    /// Budgeted halos only: answer exactly by gathering the rows the
    /// truncated halo lacks from their home shards (fetched bytes land
    /// in the serving traffic class) instead of approximating.
    pub gather_missing: bool,
    /// Byte budget for the cross-request gathered-row cache (gather
    /// mode only; 0 = recompute + re-bill the full dependency cone per
    /// request, the pre-cache behaviour). Cached rows are admitted and
    /// evicted by the same Monte-Carlo importance `I(v)` the embedding
    /// cache uses, and a row already replicated in a consumer's halo is
    /// never billed — cached or not.
    pub gather_cache_budget_bytes: u64,
    /// Delta application strategy (see [`DeltaMode`]).
    pub delta_mode: DeltaMode,
    /// Tune the overlay-CSR compaction threshold from the modelled
    /// splice-vs-flat read cost (deterministic arc-visit probe) instead
    /// of the static quarter-of-base-arcs default
    /// (see [`DeltaCsr::enable_adaptive_compaction`]).
    ///
    /// [`DeltaCsr::enable_adaptive_compaction`]: crate::graph::DeltaCsr::enable_adaptive_compaction
    pub adaptive_compaction: bool,
    /// Enable the online load rebalancer: after each applied delta,
    /// when the max/min base-node ratio across parts exceeds
    /// [`rebalance_ratio`](Self::rebalance_ratio), boundary nodes
    /// migrate from overloaded to underloaded parts (lowest edge-cut
    /// delta first), bytes accounted in the rebalance traffic class.
    pub rebalance: bool,
    /// Imbalance trigger/target: the rebalancer runs while
    /// `max_part/min_part > rebalance_ratio` (must be > 1.0).
    pub rebalance_ratio: f64,
    /// Migration cap per rebalance pass (bounds post-delta latency).
    pub rebalance_max_moves: usize,
    /// Serve-pool width: how many shards' micro-batches run
    /// concurrently on scoped threads inside one `query_batch` /
    /// flush wave. `1` (default) is the sequential path; `0` sizes
    /// from the process thread budget ([`crate::threads::available`]),
    /// capped at the shard count. Answers and counters are
    /// **bit-identical at any width**: shard engines are disjoint
    /// `&mut` borrows, each worker pins its GEMM panels to one thread,
    /// and per-shard outcomes merge in ascending shard order.
    pub serve_threads: usize,
    /// Partitioner / halo-sampling seed.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 4,
            halo: HaloPolicy::Exact,
            cache: true,
            cache_budget_bytes: 0,
            pruned: true,
            gather_missing: false,
            gather_cache_budget_bytes: 0,
            delta_mode: DeltaMode::Incremental,
            adaptive_compaction: false,
            rebalance: false,
            rebalance_ratio: 1.5,
            rebalance_max_moves: 32,
            serve_threads: 1,
            seed: 0,
        }
    }
}
