//! Online graph mutations and their invalidation footprint.

use crate::graph::{Csr, GraphBuilder, GraphView};
use anyhow::{anyhow, Result};
use std::collections::HashSet;

/// A node inserted online. Its id is assigned on application: the
/// `i`-th added node of a delta gets id `num_nodes + i`.
#[derive(Clone, Debug)]
pub struct NewNode {
    /// Feature row (must match the deployment's feature dim).
    pub features: Vec<f32>,
    /// Undirected edges to attach, as the *other* endpoint — an
    /// existing node id, or the prospective id of a node added earlier
    /// in the same delta.
    pub edges: Vec<u32>,
}

/// A batch of online mutations against the served graph: edge churn,
/// feature updates, and **elastic membership** — node insertion and
/// removal — applied in place through the overlay CSR; no offline
/// reshard. A removed node's incident edges are dropped implicitly and
/// its id is retired (never reused, queries against it fail).
#[derive(Clone, Debug, Default)]
pub struct GraphDelta {
    /// Undirected edges to insert (either orientation; duplicates and
    /// already-present edges are no-ops).
    pub added_edges: Vec<(u32, u32)>,
    /// Undirected edges to remove (absent edges are no-ops).
    pub removed_edges: Vec<(u32, u32)>,
    /// `(node, new feature row)` replacements.
    pub updated_features: Vec<(u32, Vec<f32>)>,
    /// Nodes to insert online (ids assigned densely at application).
    pub added_nodes: Vec<NewNode>,
    /// Nodes to remove online.
    pub removed_nodes: Vec<u32>,
}

/// The edge churn a delta *actually* applied (no-ops and implicit
/// removed-node edges resolved), plus the nodes whose degree — and
/// therefore inverse-sqrt-degree factor — changed. This is the O(Δ)
/// working set every downstream incremental update keys off.
#[derive(Clone, Debug, Default)]
pub struct EdgeChurn {
    /// Effectively inserted undirected edges.
    pub added: Vec<(u32, u32)>,
    /// Effectively removed undirected edges (including a removed
    /// node's implicit incident edges).
    pub removed: Vec<(u32, u32)>,
    /// Sorted, deduped endpoints of the effective churn.
    pub degree_changed: Vec<u32>,
}

impl EdgeChurn {
    /// Derive `degree_changed` from the effective edge lists.
    pub fn finish(&mut self) {
        let mut d: Vec<u32> = self
            .added
            .iter()
            .chain(&self.removed)
            .flat_map(|&(u, v)| [u, v])
            .collect();
        d.sort_unstable();
        d.dedup();
        self.degree_changed = d;
    }
}

impl GraphDelta {
    pub fn is_empty(&self) -> bool {
        self.added_edges.is_empty()
            && self.removed_edges.is_empty()
            && self.updated_features.is_empty()
            && self.added_nodes.is_empty()
            && self.removed_nodes.is_empty()
    }

    /// Structural checks against the deployment's dimensions. Edge and
    /// feature targets may reference prospective ids of nodes this
    /// delta itself adds (`num_nodes..num_nodes+added`); liveness of
    /// existing ids is the server's to check (it knows which are
    /// retired).
    pub fn validate(&self, num_nodes: usize, feature_dim: usize) -> Result<()> {
        let n_after = num_nodes + self.added_nodes.len();
        let removed: HashSet<u32> = self.removed_nodes.iter().copied().collect();
        if removed.len() != self.removed_nodes.len() {
            return Err(anyhow!("delta removes the same node twice"));
        }
        for &v in &self.removed_nodes {
            if v as usize >= num_nodes {
                return Err(anyhow!("removed node {v} out of range (n={num_nodes})"));
            }
        }
        for &(u, v) in self.added_edges.iter().chain(&self.removed_edges) {
            if u as usize >= n_after || v as usize >= n_after {
                return Err(anyhow!("delta edge ({u},{v}) out of range (n={n_after})"));
            }
            if u == v {
                return Err(anyhow!("delta contains self loop at {u}"));
            }
            if removed.contains(&u) || removed.contains(&v) {
                return Err(anyhow!(
                    "delta edge ({u},{v}) references a node the same delta removes"
                ));
            }
        }
        for (i, nn) in self.added_nodes.iter().enumerate() {
            if nn.features.len() != feature_dim {
                return Err(anyhow!(
                    "added node {i} has feature dim {} (expected {feature_dim})",
                    nn.features.len()
                ));
            }
            let own_id = (num_nodes + i) as u32;
            for &e in &nn.edges {
                if e as usize >= n_after {
                    return Err(anyhow!("added node {i} edge to {e} out of range (n={n_after})"));
                }
                if e == own_id {
                    return Err(anyhow!("added node {i} links to itself"));
                }
                if removed.contains(&e) {
                    return Err(anyhow!(
                        "added node {i} links to node {e}, which the same delta removes"
                    ));
                }
            }
        }
        for (v, row) in &self.updated_features {
            if *v as usize >= n_after {
                return Err(anyhow!("feature update for node {v} out of range (n={n_after})"));
            }
            if removed.contains(v) {
                return Err(anyhow!(
                    "feature update for node {v}, which the same delta removes"
                ));
            }
            if row.len() != feature_dim {
                return Err(anyhow!(
                    "feature update for node {v} has dim {} (expected {feature_dim})",
                    row.len()
                ));
            }
        }
        Ok(())
    }

    /// Nodes whose *own* row of Â or features changes — the epicentre
    /// the invalidation wave expands from. `num_nodes` is the
    /// pre-delta node count (prospective ids of added nodes resolve
    /// against it); the caller filters ids `>= num_nodes` when walking
    /// the *old* graph.
    pub fn seeds(&self, num_nodes: usize) -> Vec<u32> {
        let mut s: Vec<u32> = self
            .added_edges
            .iter()
            .chain(&self.removed_edges)
            .flat_map(|&(u, v)| [u, v])
            .chain(self.updated_features.iter().map(|(v, _)| *v))
            .chain(self.removed_nodes.iter().copied())
            .collect();
        for (i, nn) in self.added_nodes.iter().enumerate() {
            s.push((num_nodes + i) as u32);
            s.extend_from_slice(&nn.edges);
        }
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Apply everything to a flat snapshot, producing the successor
    /// graph: O(E) from-scratch rebuild. **The oracle, not the hot
    /// path** — serving applies deltas through the
    /// [`DeltaCsr`](crate::graph::DeltaCsr) overlay in O(Δ); property
    /// tests compare the two for bit-identity.
    pub fn apply_to(&self, graph: &Csr) -> Csr {
        let n_old = graph.num_nodes();
        let n_new = n_old + self.added_nodes.len();
        let canon = |(u, v): (u32, u32)| if u < v { (u, v) } else { (v, u) };
        let mut edges: HashSet<(u32, u32)> = graph.edges().collect();
        for &e in &self.removed_edges {
            edges.remove(&canon(e));
        }
        for &e in &self.added_edges {
            edges.insert(canon(e));
        }
        for (i, nn) in self.added_nodes.iter().enumerate() {
            let id = (n_old + i) as u32;
            for &e in &nn.edges {
                edges.insert(canon((id, e)));
            }
        }
        let removed: HashSet<u32> = self.removed_nodes.iter().copied().collect();
        edges.retain(|&(u, v)| !removed.contains(&u) && !removed.contains(&v));
        let mut b = GraphBuilder::new(n_new);
        for (u, v) in edges {
            b.edge(u, v);
        }
        b.build()
    }
}

/// Hop distance (≤ `max_hops`) from any seed, or `u32::MAX` beyond.
/// Taken as the *minimum over the old and new graphs* by the caller:
/// influence of a removed edge travels along old adjacency, influence
/// of an added one along new adjacency, and the layer-`l` invalidation
/// rule ("within `l` hops of a seed") must be conservative for both.
pub fn seed_distances<G: GraphView>(graph: &G, seeds: &[u32], max_hops: usize) -> Vec<u32> {
    crate::graph::bounded_bfs_distances(graph, seeds, max_hops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path5() -> Csr {
        GraphBuilder::new(5).edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]).build()
    }

    #[test]
    fn apply_adds_and_removes() {
        let g = path5();
        let d = GraphDelta {
            added_edges: vec![(0, 4), (4, 0)], // dup collapses
            removed_edges: vec![(1, 2), (2, 1)],
            ..Default::default()
        };
        let g2 = d.apply_to(&g);
        assert!(g2.has_edge(0, 4));
        assert!(!g2.has_edge(1, 2));
        assert_eq!(g2.num_edges(), 4);
        assert!(g2.validate().is_ok());
    }

    #[test]
    fn removing_absent_edge_is_noop() {
        let g = path5();
        let d = GraphDelta { removed_edges: vec![(0, 4)], ..Default::default() };
        assert_eq!(d.apply_to(&g).num_edges(), g.num_edges());
    }

    #[test]
    fn apply_handles_elastic_nodes() {
        let g = path5();
        let d = GraphDelta {
            added_nodes: vec![
                NewNode { features: vec![0.0; 3], edges: vec![0, 2] },
                NewNode { features: vec![0.0; 3], edges: vec![5] }, // prospective id
            ],
            removed_nodes: vec![4],
            ..Default::default()
        };
        assert!(d.validate(5, 3).is_ok());
        let g2 = d.apply_to(&g);
        assert_eq!(g2.num_nodes(), 7);
        assert!(g2.has_edge(5, 0) && g2.has_edge(5, 2) && g2.has_edge(5, 6));
        assert_eq!(g2.degree(4), 0, "removed node is isolated, id retired");
        assert!(!g2.has_edge(3, 4));
        assert!(g2.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_input() {
        let d = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        let d = GraphDelta { added_edges: vec![(2, 2)], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        let d = GraphDelta { updated_features: vec![(1, vec![0.0; 2])], ..Default::default() };
        assert!(d.validate(5, 3).is_err(), "wrong feature dim");
        let d = GraphDelta { updated_features: vec![(1, vec![0.0; 3])], ..Default::default() };
        assert!(d.validate(5, 3).is_ok());
    }

    #[test]
    fn validate_rejects_bad_elastic_input() {
        // wrong feature dim on the new node
        let d = GraphDelta {
            added_nodes: vec![NewNode { features: vec![0.0; 2], edges: vec![] }],
            ..Default::default()
        };
        assert!(d.validate(5, 3).is_err());
        // edge to a node removed by the same delta
        let d = GraphDelta {
            removed_nodes: vec![1],
            added_edges: vec![(0, 1)],
            ..Default::default()
        };
        assert!(d.validate(5, 3).is_err());
        // double removal
        let d = GraphDelta { removed_nodes: vec![1, 1], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        // removal out of range
        let d = GraphDelta { removed_nodes: vec![7], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        // prospective-id edge is fine, one past it is not
        let ok = GraphDelta {
            added_nodes: vec![NewNode { features: vec![0.0; 3], edges: vec![5] }],
            ..Default::default()
        };
        assert!(ok.validate(5, 3).is_err(), "node 0's own prospective id is 5");
        let ok = GraphDelta {
            added_nodes: vec![
                NewNode { features: vec![0.0; 3], edges: vec![] },
                NewNode { features: vec![0.0; 3], edges: vec![5] },
            ],
            ..Default::default()
        };
        assert!(ok.validate(5, 3).is_ok());
    }

    #[test]
    fn seeds_are_deduped_endpoints_and_feature_nodes() {
        let d = GraphDelta {
            added_edges: vec![(1, 2)],
            removed_edges: vec![(2, 3)],
            updated_features: vec![(0, vec![])],
            ..Default::default()
        };
        assert_eq!(d.seeds(5), vec![0, 1, 2, 3]);
    }

    #[test]
    fn seeds_include_elastic_nodes_and_attachment_points() {
        let d = GraphDelta {
            removed_nodes: vec![4],
            added_nodes: vec![NewNode { features: vec![], edges: vec![1] }],
            ..Default::default()
        };
        assert_eq!(d.seeds(5), vec![1, 4, 5]);
    }

    #[test]
    fn distances_bounded() {
        let g = path5();
        let dist = seed_distances(&g, &[0], 2);
        assert_eq!(dist[0], 0);
        assert_eq!(dist[1], 1);
        assert_eq!(dist[2], 2);
        assert_eq!(dist[3], u32::MAX);
    }
}
