//! Online graph mutations and their invalidation footprint.

use crate::graph::{Csr, GraphBuilder};
use anyhow::{anyhow, Result};
use std::collections::HashSet;

/// A batch of online mutations against the served graph: edge churn
/// plus feature updates. Node count is fixed (node insertion is an
/// offline reshard — see ROADMAP follow-ups).
#[derive(Clone, Debug, Default)]
pub struct GraphDelta {
    /// Undirected edges to insert (either orientation; duplicates and
    /// already-present edges are no-ops).
    pub added_edges: Vec<(u32, u32)>,
    /// Undirected edges to remove (absent edges are no-ops).
    pub removed_edges: Vec<(u32, u32)>,
    /// `(node, new feature row)` replacements.
    pub updated_features: Vec<(u32, Vec<f32>)>,
}

impl GraphDelta {
    pub fn is_empty(&self) -> bool {
        self.added_edges.is_empty()
            && self.removed_edges.is_empty()
            && self.updated_features.is_empty()
    }

    /// Structural checks against the deployment's dimensions.
    pub fn validate(&self, num_nodes: usize, feature_dim: usize) -> Result<()> {
        for &(u, v) in self.added_edges.iter().chain(&self.removed_edges) {
            if u as usize >= num_nodes || v as usize >= num_nodes {
                return Err(anyhow!("delta edge ({u},{v}) out of range (n={num_nodes})"));
            }
            if u == v {
                return Err(anyhow!("delta contains self loop at {u}"));
            }
        }
        for (v, row) in &self.updated_features {
            if *v as usize >= num_nodes {
                return Err(anyhow!("feature update for node {v} out of range (n={num_nodes})"));
            }
            if row.len() != feature_dim {
                return Err(anyhow!(
                    "feature update for node {v} has dim {} (expected {feature_dim})",
                    row.len()
                ));
            }
        }
        Ok(())
    }

    /// Nodes whose *own* row of Â or features changed — the epicentre
    /// the invalidation wave expands from.
    pub fn seeds(&self) -> Vec<u32> {
        let mut s: Vec<u32> = self
            .added_edges
            .iter()
            .chain(&self.removed_edges)
            .flat_map(|&(u, v)| [u, v])
            .chain(self.updated_features.iter().map(|(v, _)| *v))
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Apply the edge churn, producing the successor graph. O(E) — an
    /// incremental CSR is a ROADMAP follow-up; deltas are off the
    /// query hot path.
    pub fn apply_to(&self, graph: &Csr) -> Csr {
        let canon = |(u, v): (u32, u32)| if u < v { (u, v) } else { (v, u) };
        let mut edges: HashSet<(u32, u32)> = graph.edges().collect();
        for &e in &self.removed_edges {
            edges.remove(&canon(e));
        }
        for &e in &self.added_edges {
            edges.insert(canon(e));
        }
        let mut b = GraphBuilder::new(graph.num_nodes());
        for (u, v) in edges {
            b.edge(u, v);
        }
        b.build()
    }
}

/// Hop distance (≤ `max_hops`) from any seed, or `u32::MAX` beyond.
/// Taken as the *minimum over the old and new graphs* by the caller:
/// influence of a removed edge travels along old adjacency, influence
/// of an added one along new adjacency, and the layer-`l` invalidation
/// rule ("within `l` hops of a seed") must be conservative for both.
pub fn seed_distances(graph: &Csr, seeds: &[u32], max_hops: usize) -> Vec<u32> {
    crate::graph::bounded_bfs_distances(graph, seeds, max_hops)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path5() -> Csr {
        GraphBuilder::new(5).edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]).build()
    }

    #[test]
    fn apply_adds_and_removes() {
        let g = path5();
        let d = GraphDelta {
            added_edges: vec![(0, 4), (4, 0)], // dup collapses
            removed_edges: vec![(1, 2), (2, 1)],
            updated_features: vec![],
        };
        let g2 = d.apply_to(&g);
        assert!(g2.has_edge(0, 4));
        assert!(!g2.has_edge(1, 2));
        assert_eq!(g2.num_edges(), 4);
        assert!(g2.validate().is_ok());
    }

    #[test]
    fn removing_absent_edge_is_noop() {
        let g = path5();
        let d = GraphDelta { removed_edges: vec![(0, 4)], ..Default::default() };
        assert_eq!(d.apply_to(&g).num_edges(), g.num_edges());
    }

    #[test]
    fn validate_rejects_bad_input() {
        let d = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        let d = GraphDelta { added_edges: vec![(2, 2)], ..Default::default() };
        assert!(d.validate(5, 3).is_err());
        let d = GraphDelta { updated_features: vec![(1, vec![0.0; 2])], ..Default::default() };
        assert!(d.validate(5, 3).is_err(), "wrong feature dim");
        let d = GraphDelta { updated_features: vec![(1, vec![0.0; 3])], ..Default::default() };
        assert!(d.validate(5, 3).is_ok());
    }

    #[test]
    fn seeds_are_deduped_endpoints_and_feature_nodes() {
        let d = GraphDelta {
            added_edges: vec![(1, 2)],
            removed_edges: vec![(2, 3)],
            updated_features: vec![(0, vec![])],
        };
        assert_eq!(d.seeds(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn distances_bounded() {
        let g = path5();
        let dist = seed_distances(&g, &[0], 2);
        assert_eq!(dist[0], 0);
        assert_eq!(dist[1], 1);
        assert_eq!(dist[2], 2);
        assert_eq!(dist[3], u32::MAX);
    }
}
