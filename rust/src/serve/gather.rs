//! Exact answers on budgeted halos: cross-shard row gathering, with an
//! optional cross-request gathered-row cache.
//!
//! A [`HaloPolicy::Budgeted`](super::HaloPolicy::Budgeted) shard lacks
//! part of its L-hop candidate set, so its local forward approximates
//! boundary neighbourhoods. With
//! [`ServeConfig::gather_missing`](super::ServeConfig::gather_missing)
//! the server answers such queries **exactly** instead: it walks the
//! queried nodes' true dependency cone over the *global* overlay graph,
//! computes each level's rows (one GEMM per layer — per-row results are
//! independent of grouping, so this is bit-identical to the full-graph
//! forward), and accounts every row a consumer shard needs but does not
//! hold. Row *levels* are uniform here: level 0 is the feature row,
//! level `r ≥ 1` is the embedding `H_r` (the output of GEMM `r-1`).
//!
//! Billing rules, applied per `(level, row, consumer shard)` within a
//! request (deduplicated):
//!
//! * level 0 — free when the consumer's shard already replicates the
//!   node (base or sampled halo member); otherwise fetched from the
//!   node's home shard at `feature_dim × 4` bytes. **A halo-replicated
//!   row is never billed**, cached or not — replication already paid
//!   for it in the serving class.
//! * level `r ≥ 1` — computed by the node's home shard and free there;
//!   any other consumer pays `dim_r × 4` bytes.
//! * either level — free when the consumer fetched the row in an
//!   earlier request and the **gathered-row cache**
//!   ([`ServeConfig::gather_cache_budget_bytes`]) still retains that
//!   copy. Cached embedding values are also reused across requests, so
//!   a hot boundary query skips both the re-fetch *and* the recompute
//!   of its cached sub-cone.
//!
//! Cache entries model per-consumer retained copies: admission and
//! eviction order is the same Monte-Carlo importance `I(v)` the
//! embedding cache uses (the consumer shard's candidate score for the
//! row's node), budget enforced once per request. An applied
//! [`GraphDelta`](super::GraphDelta) invalidates **surgically**: since
//! gathered values are computed over the *global* graph, the same
//! L-hop cone rule the embedding caches use applies — a level-`r` row
//! of node `g` is stale iff the delta's influence cone reaches within
//! `r` hops of `g` ([`GatherRowCache::invalidate_cone`]); everything
//! outside the cone survives the delta. A rebalance migration
//! (membership-only, values unchanged) leaves the cache intact. All
//! billed bytes land in the
//! [`CommLedger`](crate::comm::CommLedger) serving class. The shards'
//! embedding caches are still bypassed on this path — mixing exact
//! gathered rows into their (approximate) local caches would poison
//! them.
//!
//! [`ServeConfig::gather_cache_budget_bytes`]: super::ServeConfig::gather_cache_budget_bytes

use super::server::{QueryResult, Server};
use crate::graph::GraphView;
use crate::tensor::{gemm, relu, softmax_rows, Matrix};
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// Cross-request gathered-row cache (see module docs).
pub(crate) struct GatherRowCache {
    budget: u64,
    bytes: u64,
    /// `(level, node, consumer shard)` → (entry bytes, admission score).
    entries: HashMap<(usize, u32, u32), (u64, f32)>,
    /// Embedding values retained for reuse (level ≥ 1 only; feature
    /// rows are globally resident and need no copy here). A value lives
    /// as long as at least one consumer entry for it does.
    values: HashMap<(usize, u32), Vec<f32>>,
    /// Embedding rows whose recompute was skipped via a cached value.
    pub rows_reused: u64,
    /// Cross-shard fetches skipped because the consumer held a copy.
    pub fetches_avoided: u64,
    /// Entries dropped by the byte budget.
    pub rows_evicted: u64,
    /// Entries dropped by surgical delta-cone invalidation.
    pub rows_invalidated: u64,
}

impl GatherRowCache {
    pub fn new(budget: u64) -> Self {
        GatherRowCache {
            budget,
            bytes: 0,
            entries: HashMap::new(),
            values: HashMap::new(),
            rows_reused: 0,
            fetches_avoided: 0,
            rows_evicted: 0,
            rows_invalidated: 0,
        }
    }

    /// Bytes currently retained.
    pub fn resident_bytes(&self) -> u64 {
        self.bytes
    }

    /// Drop every entry (counters survive). Kept as the wholesale
    /// baseline the surgical invalidation is tested against; the
    /// delta path itself uses [`invalidate_cone`](Self::invalidate_cone).
    #[cfg(test)]
    pub fn clear(&mut self) {
        self.entries.clear();
        self.values.clear();
        self.bytes = 0;
    }

    /// Surgical delta invalidation: drop exactly the rows the delta's
    /// influence cone reaches. `dist` is the sparse
    /// min-over-old-and-new-graph hop map the server already computes
    /// per delta, bounded at the layer count (a node absent from the
    /// map is farther than L hops from every seed). A level-`r` row of
    /// node `g` is stale iff `dist(g) <= r`: `H_r` depends on `g`'s
    /// r-hop neighbourhood, and a level-0 feature copy changes only
    /// when `g` itself is a seed (feature rewrite or retirement; edge
    /// churn at distance 0 invalidates it too, conservatively).
    /// Entries and values follow the same rule, so no value can
    /// outlive its consumers or vice versa. Correctness does not
    /// depend on any shard's halo membership — gathered values are
    /// global-graph quantities — which is why this survives the shard
    /// rebuilds a delta may trigger.
    pub fn invalidate_cone(&mut self, dist: &HashMap<u32, u32>) {
        let mut freed = 0u64;
        let mut dropped = 0u64;
        self.entries.retain(|&(level, node, _), &mut (bytes, _)| {
            let stale = dist.get(&node).map(|&d| d as usize <= level).unwrap_or(false);
            if stale {
                freed += bytes;
                dropped += 1;
            }
            !stale
        });
        self.bytes -= freed;
        self.rows_invalidated += dropped;
        self.values
            .retain(|&(level, node), _| dist.get(&node).map(|&d| d as usize > level).unwrap_or(true));
    }

    /// Does `consumer` hold a copy of `(level, node)`?
    fn holds(&self, level: usize, node: u32, consumer: u32) -> bool {
        self.entries.contains_key(&(level, node, consumer))
    }

    /// Retained embedding value, if any (level ≥ 1).
    fn value(&self, level: usize, node: u32) -> Option<&[f32]> {
        self.values.get(&(level, node)).map(|v| v.as_slice())
    }

    /// Record that `consumer` fetched `(level, node)`; retains the
    /// embedding value for levels ≥ 1. Budget enforcement is deferred
    /// to [`enforce_budget`](Self::enforce_budget) (once per request).
    fn admit(&mut self, level: usize, node: u32, consumer: u32, bytes: u64, score: f32, value: Option<&[f32]>) {
        if self.entries.insert((level, node, consumer), (bytes, score)).is_none() {
            self.bytes += bytes;
        }
        if level > 0 {
            if let Some(v) = value {
                self.values.entry((level, node)).or_insert_with(|| v.to_vec());
            }
        }
    }

    /// Evict lowest-score entries (ties toward higher level, then
    /// higher node/consumer id — fully deterministic) until the budget
    /// holds. A value whose last consumer entry goes is dropped too.
    pub fn enforce_budget(&mut self) {
        if self.budget == 0 || self.bytes <= self.budget {
            return;
        }
        let mut order: Vec<((usize, u32, u32), u64, f32)> =
            self.entries.iter().map(|(&k, &(b, s))| (k, b, s)).collect();
        order.sort_by(|a, b| {
            a.2.partial_cmp(&b.2)
                .expect("scores are finite")
                .then(b.0 .0.cmp(&a.0 .0))
                .then(b.0 .1.cmp(&a.0 .1))
                .then(b.0 .2.cmp(&a.0 .2))
        });
        for (key, bytes, _) in order {
            if self.bytes <= self.budget {
                break;
            }
            self.entries.remove(&key);
            self.bytes -= bytes;
            self.rows_evicted += 1;
        }
        // one pass over the survivors: a value whose every consumer
        // entry was evicted goes with them
        let live: HashSet<(usize, u32)> =
            self.entries.keys().map(|&(l, n, _)| (l, n)).collect();
        self.values.retain(|k, _| live.contains(k));
    }
}

/// One input row's contribution to the aggregation of `(v, GEMM l)`,
/// replayed in `NormAdj` row order so the result is bit-identical to
/// the full-graph forward; cross-shard fetches are tallied (and cached
/// copies recorded) as they happen. `level = l` is the consumed row's
/// level: features at 0, `H_l` otherwise.
#[allow(clippy::too_many_arguments)]
fn accumulate(
    srv: &Server,
    cache: &mut Option<GatherRowCache>,
    prev: &HashMap<u32, Vec<f32>>,
    l: usize,
    v: u32,
    t: u32,
    iv: f32,
    consumer: u32,
    orow: &mut [f32],
    bytes: &mut u64,
    fetched: &mut HashSet<(usize, u32, u32)>,
    frow_bytes: u64,
    row_bytes: u64,
) {
    let w = iv * srv.inv_sqrt[t as usize];
    let row: &[f32] = if l == 0 { srv.features.row(t as usize) } else { &prev[&t] };
    for (c, &x) in row.iter().enumerate() {
        orow[c] += w * x;
    }
    if t == v {
        return; // self loop: the consumer owns its own row
    }
    // replication first: a halo-resident feature row (or a home-shard
    // embedding) is free and never enters the fetch cache
    let missing = if l == 0 {
        srv.shards[consumer as usize].local_of(t).is_none()
    } else {
        srv.assignment[t as usize] != consumer
    };
    if !missing || !fetched.insert((l, t, consumer)) {
        return;
    }
    if let Some(c) = cache {
        if c.holds(l, t, consumer) {
            c.fetches_avoided += 1;
            return; // fetched in an earlier request; copy retained
        }
        let cost = if l == 0 { frow_bytes } else { row_bytes };
        let score = srv.shards[consumer as usize].candidate_score(t);
        let value = if l == 0 { None } else { Some(row) };
        c.admit(l, t, consumer, cost, score, value);
        *bytes += cost;
    } else {
        *bytes += if l == 0 { frow_bytes } else { row_bytes };
    }
}

/// See module docs. Caller ([`Server::query_batch`]) has validated the
/// node ids (in range, not retired).
pub(crate) fn query_batch_gather(srv: &mut Server, nodes: &[u32]) -> Result<Vec<QueryResult>> {
    let layers = srv.params.layers();
    // the cache moves out of the server for the request so the borrow
    // checker lets it mutate alongside reads of the graph/shards
    let mut cache = srv.gather_cache.take();

    // ---- the dependency cone, level by level (global ids), skipping
    //      sub-cones whose embedding value the cache retains ----------
    let mut need: Vec<Vec<u32>> = vec![Vec::new(); layers]; // per GEMM
    let mut reused: Vec<Vec<u32>> = vec![Vec::new(); layers + 1]; // per level
    let mut top: Vec<u32> = nodes.to_vec();
    top.sort_unstable();
    top.dedup();
    let mut required = top; // rows of level `l+1` required at GEMM l
    for l in (0..layers).rev() {
        let mut compute = Vec::with_capacity(required.len());
        for &u in &required {
            let cached = cache
                .as_ref()
                .map(|c| c.value(l + 1, u).is_some())
                .unwrap_or(false);
            if cached {
                reused[l + 1].push(u);
            } else {
                compute.push(u);
            }
        }
        // inputs at level l: the closed neighbourhood of what GEMM l
        // actually computes
        let mut inputs: Vec<u32> = compute.clone();
        for &u in &compute {
            inputs.extend_from_slice(srv.graph.neighbors(u as usize));
        }
        inputs.sort_unstable();
        inputs.dedup();
        need[l] = compute;
        required = inputs;
    }

    // ---- per level: aggregate over global adjacency, one GEMM -------
    let frow_bytes = (srv.features.cols * 4) as u64;
    let mut bytes = 0u64;
    let mut fetched: HashSet<(usize, u32, u32)> = HashSet::new();
    let mut prev: HashMap<u32, Vec<f32>> = HashMap::new();
    let mut rows_recomputed = 0usize;
    let mut rows_reused = 0u64;
    for l in 0..layers {
        let sel = std::mem::take(&mut need[l]);
        let in_dim = srv.params.ws[l].rows;
        let row_bytes = (in_dim * 4) as u64;
        let mut agg = Matrix::zeros(sel.len(), in_dim);
        {
            let bytes_before = bytes;
            let mut _gspan = crate::span!("serve.gather", layer = l, rows = sel.len());
            for (i, &v) in sel.iter().enumerate() {
                let vu = v as usize;
                let consumer = srv.assignment[vu];
                let iv = srv.inv_sqrt[vu];
                let orow = agg.row_mut(i);
                let mut self_done = false;
                for &t in srv.graph.neighbors(vu) {
                    if !self_done && t > v {
                        accumulate(
                            srv, &mut cache, &prev, l, v, v, iv, consumer, orow, &mut bytes,
                            &mut fetched, frow_bytes, row_bytes,
                        );
                        self_done = true;
                    }
                    accumulate(
                        srv, &mut cache, &prev, l, v, t, iv, consumer, orow, &mut bytes,
                        &mut fetched, frow_bytes, row_bytes,
                    );
                }
                if !self_done {
                    accumulate(
                        srv, &mut cache, &prev, l, v, v, iv, consumer, orow, &mut bytes,
                        &mut fetched, frow_bytes, row_bytes,
                    );
                }
            }
            // bytes this layer billed to the serving ledger class —
            // fig15's bytes column for the gather phase
            _gspan.set_arg("bytes", (bytes - bytes_before) as i64);
        }
        let mut z = {
            let _gspan = crate::span!("serve.gemm", layer = l, rows = sel.len());
            gemm(&agg, &srv.params.ws[l])
        };
        if l + 1 < layers {
            relu(&mut z);
        } else if let Some(c) = &mut cache {
            // retain the freshly computed output rows too (home-owned,
            // so no fetch is billed; score 1.0 keeps hot query outputs
            // resident) — a repeat query then skips its whole cone
            let out_bytes = (srv.params.ws[l].cols * 4) as u64;
            for (i, &v) in sel.iter().enumerate() {
                c.admit(layers, v, srv.assignment[v as usize], out_bytes, 1.0, Some(z.row(i)));
            }
        }
        let mut next: HashMap<u32, Vec<f32>> =
            sel.iter().enumerate().map(|(i, &v)| (v, z.row(i).to_vec())).collect();
        // splice in the level-(l+1) rows the cache already held
        for &u in &reused[l + 1] {
            let row = cache
                .as_ref()
                .and_then(|c| c.value(l + 1, u))
                .expect("reused rows were planned against the cache")
                .to_vec();
            next.insert(u, row);
            rows_reused += 1;
        }
        rows_recomputed += sel.len();
        prev = next;
    }

    // ---- answer ------------------------------------------------------
    let classes = srv.params.ws[layers - 1].cols;
    let mut logits = Matrix::zeros(nodes.len(), classes);
    for (i, &v) in nodes.iter().enumerate() {
        logits.row_mut(i).copy_from_slice(&prev[&v]);
    }
    let probs = softmax_rows(&logits);
    let preds = probs.argmax_rows();
    let version = srv.graph.version();
    let output_reused: HashSet<u32> = reused[layers].iter().copied().collect();

    if let Some(c) = &mut cache {
        c.rows_reused += rows_reused;
        c.enforce_budget();
    }
    srv.gather_cache = cache;
    srv.queries += nodes.len() as u64;
    srv.micro_batches += 1;
    srv.rows_recomputed += rows_recomputed as u64;
    srv.ledger.record_serving(bytes);

    Ok(nodes
        .iter()
        .enumerate()
        .map(|(i, &v)| QueryResult {
            node: v,
            pred: preds[i],
            probs: probs.row(i).to_vec(),
            shard: srv.assignment[v as usize],
            graph_version: version,
            cache_hit: output_reused.contains(&v),
            rows_recomputed,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_admits_holds_and_clears() {
        let mut c = GatherRowCache::new(1024);
        assert!(!c.holds(1, 7, 0));
        c.admit(1, 7, 0, 16, 0.5, Some(&[1.0, 2.0, 3.0, 4.0]));
        assert!(c.holds(1, 7, 0));
        assert!(!c.holds(1, 7, 1), "copies are per consumer");
        assert_eq!(c.value(1, 7), Some(&[1.0, 2.0, 3.0, 4.0][..]));
        assert_eq!(c.resident_bytes(), 16);
        // re-admitting the same key does not double count
        c.admit(1, 7, 0, 16, 0.5, Some(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(c.resident_bytes(), 16);
        // feature entries carry no value
        c.admit(0, 3, 1, 8, 0.1, None);
        assert!(c.holds(0, 3, 1));
        assert!(c.value(0, 3).is_none());
        c.clear();
        assert!(!c.holds(1, 7, 0));
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn cone_invalidation_is_surgical() {
        let mut c = GatherRowCache::new(0); // unbounded
        c.admit(0, 5, 0, 8, 0.5, None); // feature copy of node 5
        c.admit(1, 5, 0, 16, 0.5, Some(&[1.0; 4]));
        c.admit(2, 5, 0, 16, 0.5, Some(&[2.0; 4]));
        c.admit(1, 9, 1, 16, 0.9, Some(&[3.0; 4]));
        let mut dist = HashMap::new();
        dist.insert(5u32, 1u32); // node 5 is one hop from the epicentre
        c.invalidate_cone(&dist);
        // level 0 survives (a feature row only changes at distance 0);
        // levels >= 1 are inside the cone and go, values with them
        assert!(c.holds(0, 5, 0));
        assert!(!c.holds(1, 5, 0) && !c.holds(2, 5, 0));
        assert!(c.value(1, 5).is_none() && c.value(2, 5).is_none());
        // node 9 is outside the cone entirely: untouched
        assert!(c.holds(1, 9, 1) && c.value(1, 9).is_some());
        assert_eq!(c.rows_invalidated, 2);
        assert_eq!(c.resident_bytes(), 8 + 16);
        // distance 0 (a seed) takes every level including features
        let mut seed = HashMap::new();
        seed.insert(5u32, 0u32);
        c.invalidate_cone(&seed);
        assert!(!c.holds(0, 5, 0));
        assert_eq!(c.rows_invalidated, 3);
        assert_eq!(c.resident_bytes(), 16);
    }

    #[test]
    fn budget_evicts_lowest_score_and_drops_orphaned_values() {
        let mut c = GatherRowCache::new(32);
        c.admit(1, 1, 0, 16, 0.9, Some(&[1.0; 4]));
        c.admit(1, 2, 0, 16, 0.1, Some(&[2.0; 4]));
        c.enforce_budget();
        assert_eq!(c.resident_bytes(), 32, "at budget: nothing goes");
        c.admit(1, 3, 0, 16, 0.5, Some(&[3.0; 4]));
        c.enforce_budget();
        assert_eq!(c.resident_bytes(), 32);
        assert!(!c.holds(1, 2, 0), "lowest score evicted first");
        assert!(c.value(1, 2).is_none(), "orphaned value dropped");
        assert!(c.holds(1, 1, 0) && c.holds(1, 3, 0));
        assert_eq!(c.rows_evicted, 1);
        // a value with a surviving consumer stays
        c.admit(1, 1, 1, 16, 0.8, Some(&[1.0; 4]));
        c.enforce_budget();
        assert!(c.value(1, 1).is_some());
    }
}
