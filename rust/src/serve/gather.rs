//! Exact answers on budgeted halos: cross-shard row gathering.
//!
//! A [`HaloPolicy::Budgeted`](super::HaloPolicy::Budgeted) shard lacks
//! part of its L-hop candidate set, so its local forward approximates
//! boundary neighbourhoods. With
//! [`ServeConfig::gather_missing`](super::ServeConfig::gather_missing)
//! the server answers such queries **exactly** instead: it walks the
//! queried nodes' true L-hop dependency cone over the *global* overlay
//! graph, computes each layer's rows grouped by the owning home shard
//! (one GEMM per layer — per-row results are independent of grouping,
//! so this is bit-identical to the full-graph forward), and accounts
//! every row a consumer shard needs but does not hold:
//!
//! * layer 0 — a feature row is free when the consumer's shard already
//!   replicates the node (base or sampled halo member); otherwise it is
//!   fetched from the node's home shard at `feature_dim × 4` bytes.
//!   This is where a bigger sampled halo buys fewer fetches.
//! * layer `l > 0` — an embedding row is computed by its node's home
//!   shard and is free only there; any other consumer pays
//!   `dim_l × 4` bytes.
//!
//! Fetches are deduplicated per `(layer, row, consumer shard)` within a
//! request. All bytes land in the [`CommLedger`](crate::comm::CommLedger)
//! serving class. Results are transient per request — mixing exact
//! gathered rows into the shards' (approximate) local caches would
//! poison them, so the caches are bypassed entirely on this path.

use super::server::{QueryResult, Server};
use crate::graph::GraphView;
use crate::tensor::{gemm, relu, softmax_rows, Matrix};
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// One input row's contribution to the aggregation of `(v, layer l)`,
/// replayed in `NormAdj` row order so the result is bit-identical to
/// the full-graph forward; cross-shard fetches are tallied as they
/// happen.
#[allow(clippy::too_many_arguments)]
fn accumulate(
    srv: &Server,
    prev: &HashMap<u32, Vec<f32>>,
    l: usize,
    v: u32,
    t: u32,
    iv: f32,
    consumer: u32,
    orow: &mut [f32],
    bytes: &mut u64,
    fetched: &mut HashSet<(usize, u32, u32)>,
    frow_bytes: u64,
    row_bytes: u64,
) {
    let w = iv * srv.inv_sqrt[t as usize];
    let row: &[f32] = if l == 0 { srv.features.row(t as usize) } else { &prev[&t] };
    for (c, &x) in row.iter().enumerate() {
        orow[c] += w * x;
    }
    if t == v {
        return; // self loop: the consumer owns its own row
    }
    let missing = if l == 0 {
        // feature rows are replicated wherever the halo sampled them
        srv.shards[consumer as usize].local_of(t).is_none()
    } else {
        // embedding rows live only on their home shard this request
        srv.assignment[t as usize] != consumer
    };
    if missing && fetched.insert((l, t, consumer)) {
        *bytes += if l == 0 { frow_bytes } else { row_bytes };
    }
}

/// See module docs. Caller ([`Server::query_batch`]) has validated the
/// node ids (in range, not retired).
pub(crate) fn query_batch_gather(srv: &mut Server, nodes: &[u32]) -> Result<Vec<QueryResult>> {
    let layers = srv.params.layers();

    // ---- the true dependency cone, layer by layer (global ids) ------
    let mut need: Vec<Vec<u32>> = vec![Vec::new(); layers];
    let mut top: Vec<u32> = nodes.to_vec();
    top.sort_unstable();
    top.dedup();
    need[layers - 1] = top;
    for l in (0..layers.saturating_sub(1)).rev() {
        let mut s: Vec<u32> = need[l + 1].clone();
        for &v in &need[l + 1] {
            s.extend_from_slice(srv.graph.neighbors(v as usize));
        }
        s.sort_unstable();
        s.dedup();
        need[l] = s;
    }

    // ---- per-layer: aggregate over global adjacency, one GEMM -------
    let frow_bytes = (srv.features.cols * 4) as u64;
    let mut bytes = 0u64;
    let mut fetched: HashSet<(usize, u32, u32)> = HashSet::new();
    let mut prev: HashMap<u32, Vec<f32>> = HashMap::new();
    let mut rows_recomputed = 0usize;
    for l in 0..layers {
        let sel = std::mem::take(&mut need[l]);
        let in_dim = srv.params.ws[l].rows;
        let row_bytes = (in_dim * 4) as u64;
        let mut agg = Matrix::zeros(sel.len(), in_dim);
        for (i, &v) in sel.iter().enumerate() {
            let vu = v as usize;
            let consumer = srv.assignment[vu];
            let iv = srv.inv_sqrt[vu];
            let orow = agg.row_mut(i);
            let mut self_done = false;
            for &t in srv.graph.neighbors(vu) {
                if !self_done && t > v {
                    accumulate(
                        srv, &prev, l, v, v, iv, consumer, orow, &mut bytes, &mut fetched,
                        frow_bytes, row_bytes,
                    );
                    self_done = true;
                }
                accumulate(
                    srv, &prev, l, v, t, iv, consumer, orow, &mut bytes, &mut fetched,
                    frow_bytes, row_bytes,
                );
            }
            if !self_done {
                accumulate(
                    srv, &prev, l, v, v, iv, consumer, orow, &mut bytes, &mut fetched,
                    frow_bytes, row_bytes,
                );
            }
        }
        let mut z = gemm(&agg, &srv.params.ws[l]);
        if l + 1 < layers {
            relu(&mut z);
        }
        prev = sel.iter().enumerate().map(|(i, &v)| (v, z.row(i).to_vec())).collect();
        rows_recomputed += sel.len();
    }

    // ---- answer ------------------------------------------------------
    let classes = srv.params.ws[layers - 1].cols;
    let mut logits = Matrix::zeros(nodes.len(), classes);
    for (i, &v) in nodes.iter().enumerate() {
        logits.row_mut(i).copy_from_slice(&prev[&v]);
    }
    let probs = softmax_rows(&logits);
    let preds = probs.argmax_rows();
    let version = srv.graph.version();

    srv.queries += nodes.len() as u64;
    srv.micro_batches += 1;
    srv.rows_recomputed += rows_recomputed as u64;
    srv.ledger.record_serving(bytes);

    Ok(nodes
        .iter()
        .enumerate()
        .map(|(i, &v)| QueryResult {
            node: v,
            pred: preds[i],
            probs: probs.row(i).to_vec(),
            shard: srv.assignment[v as usize],
            graph_version: version,
            cache_hit: false,
            rows_recomputed,
        })
        .collect())
}
