//! Serving latency harness (Fig. 11, ours): p50/p99 request latency
//! and QPS for three deployments answering the same query stream —
//!
//! * `unsharded-pernode` — one shard covering the whole graph, no
//!   cache, full recompute per query: the naive "run the model" loop.
//! * `cold-sharded` — partition-aware shards, micro-batched, pruned to
//!   each batch's dependency cone, but nothing reused across requests.
//! * `cached-sharded` — the full subsystem: warm embedding cache plus
//!   micro-batching; steady-state serving.
//!
//! Shared by the CLI `serve-bench` command and
//! `benches/fig11_serving_latency.rs`.

use super::{HaloPolicy, ServeConfig, Server};
use crate::datasets::Dataset;
use crate::model::GcnParams;
use crate::rng::Rng;
use anyhow::Result;
use std::fmt::Write as _;
use std::time::Instant;

/// Bench dimensions.
#[derive(Clone, Debug)]
pub struct ServingBenchConfig {
    /// Shard count for the sharded modes.
    pub shards: usize,
    /// Total queries per mode (one shared random stream).
    pub queries: usize,
    /// Micro-batch (request) size for the sharded modes.
    pub batch: usize,
    /// Halo policy for the sharded modes.
    pub halo: HaloPolicy,
    pub seed: u64,
}

impl Default for ServingBenchConfig {
    fn default() -> Self {
        ServingBenchConfig {
            shards: 4,
            queries: 2000,
            batch: 32,
            halo: HaloPolicy::Exact,
            seed: 0,
        }
    }
}

/// One mode's latency/throughput row.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub mode: String,
    /// Requests issued (queries / batch, rounded up).
    pub requests: usize,
    pub queries: usize,
    pub batch: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub qps: f64,
    pub cache_hits: u64,
    pub rows_recomputed: u64,
}

/// All modes on one workload.
#[derive(Clone, Debug)]
pub struct ServingBenchReport {
    pub rows: Vec<LatencySummary>,
}

impl ServingBenchReport {
    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "| mode | batch | p50 (µs) | p99 (µs) | mean (µs) | QPS | cache hits | rows recomputed |\n\
             |---|---|---|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {:.1} | {:.1} | {:.1} | {:.0} | {} | {} |",
                r.mode, r.batch, r.p50_us, r.p99_us, r.mean_us, r.qps, r.cache_hits, r.rows_recomputed
            );
        }
        if let Some(x) = self.cached_speedup_vs_baseline() {
            let _ = writeln!(s, "\ncached-sharded vs unsharded-pernode: **{x:.1}x QPS**");
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("mode,batch,p50_us,p99_us,mean_us,qps,cache_hits,rows_recomputed\n");
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.2},{:.2},{:.2},{:.1},{},{}",
                r.mode, r.batch, r.p50_us, r.p99_us, r.mean_us, r.qps, r.cache_hits, r.rows_recomputed
            );
        }
        s
    }

    fn row(&self, mode: &str) -> Option<&LatencySummary> {
        self.rows.iter().find(|r| r.mode == mode)
    }

    /// QPS ratio of the full subsystem over the naive baseline — the
    /// number the acceptance criterion is about.
    pub fn cached_speedup_vs_baseline(&self) -> Option<f64> {
        let base = self.row("unsharded-pernode")?.qps;
        let cached = self.row("cached-sharded")?.qps;
        (base > 0.0).then(|| cached / base)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * p).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn run_mode(
    mode: &str,
    ds: &Dataset,
    params: &GcnParams,
    scfg: ServeConfig,
    stream: &[u32],
    batch: usize,
    warm: bool,
) -> Result<LatencySummary> {
    let mut srv = Server::for_dataset(ds, params.clone(), scfg)?;
    if warm {
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        for chunk in all.chunks(256) {
            srv.query_batch(chunk)?;
        }
    }
    let pre = srv.stats();
    let batch = batch.max(1);
    let mut lat_us = Vec::with_capacity(stream.len() / batch + 1);
    let t0 = Instant::now();
    for chunk in stream.chunks(batch) {
        let s = Instant::now();
        srv.query_batch(chunk)?;
        lat_us.push(s.elapsed().as_secs_f64() * 1e6);
    }
    let total_s = t0.elapsed().as_secs_f64();
    let post = srv.stats();
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let mean = lat_us.iter().sum::<f64>() / lat_us.len().max(1) as f64;
    Ok(LatencySummary {
        mode: mode.to_string(),
        requests: lat_us.len(),
        queries: stream.len(),
        batch,
        p50_us: percentile(&lat_us, 0.50),
        p99_us: percentile(&lat_us, 0.99),
        mean_us: mean,
        qps: stream.len() as f64 / total_s.max(1e-12),
        cache_hits: post.cache_hits - pre.cache_hits,
        rows_recomputed: post.rows_recomputed - pre.rows_recomputed,
    })
}

/// Run all three modes on one shared random query stream.
pub fn run_serving_bench(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &ServingBenchConfig,
) -> Result<ServingBenchReport> {
    let n = ds.num_nodes();
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x5e17e);
    let stream: Vec<u32> = (0..cfg.queries).map(|_| rng.gen_range(n) as u32).collect();

    let baseline = ServeConfig {
        shards: 1,
        halo: HaloPolicy::Exact,
        cache: false,
        pruned: false,
        seed: cfg.seed,
    };
    let cold = ServeConfig {
        shards: cfg.shards,
        halo: cfg.halo,
        cache: false,
        pruned: true,
        seed: cfg.seed,
    };
    let cached = ServeConfig { cache: true, ..cold.clone() };

    let rows = vec![
        run_mode("unsharded-pernode", ds, params, baseline, &stream, 1, false)?,
        run_mode("cold-sharded", ds, params, cold, &stream, cfg.batch, false)?,
        run_mode("cached-sharded", ds, params, cached, &stream, cfg.batch, true)?,
    ];
    Ok(ServingBenchReport { rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 3.0); // (3 * 0.5).round() = 2
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn bench_produces_all_modes() {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = crate::rng::Rng::seed_from_u64(1);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServingBenchConfig { queries: 40, batch: 8, ..Default::default() };
        let rep = run_serving_bench(&ds, &params, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 3);
        for r in &rep.rows {
            assert_eq!(r.queries, 40);
            assert!(r.qps > 0.0);
            assert!(r.p50_us <= r.p99_us);
        }
        // steady state serves straight from cache
        let cached = rep.row("cached-sharded").unwrap();
        assert_eq!(cached.cache_hits, 40);
        assert_eq!(cached.rows_recomputed, 0);
        assert!(rep.to_markdown().contains("unsharded-pernode"));
        assert!(rep.to_csv().lines().count() == 4);
        assert!(rep.cached_speedup_vs_baseline().unwrap() > 0.0);
    }
}
