//! Serving benchmark harnesses.
//!
//! **Fig. 11 (ours)** — p50/p99 request latency and QPS for three
//! deployments answering the same query stream:
//!
//! * `unsharded-pernode` — one shard covering the whole graph, no
//!   cache, full recompute per query: the naive "run the model" loop.
//! * `cold-sharded` — partition-aware shards, micro-batched, pruned to
//!   each batch's dependency cone, but nothing reused across requests.
//! * `cached-sharded` — the full subsystem: warm embedding cache plus
//!   micro-batching; steady-state serving.
//! * `parallel-sharded` (when [`ServingBenchConfig::serve_threads`]
//!   ≠ 1) — `cached-sharded` again with the per-shard fan-out on the
//!   scoped-thread serve pool: bit-identical answers and counters,
//!   wall-clock before/after for the parallel path.
//!
//! **Fig. 12 (ours)** — serving under *churn*: interleaved
//! [`GraphDelta`](super::GraphDelta) streams at increasing rates,
//! [`DeltaMode::Incremental`] (overlay splicing) vs
//! [`DeltaMode::Rebuild`] (flat-CSR rebuild per delta), reporting
//! delta throughput and query p99 side by side.
//!
//! Shared by the CLI `serve-bench` command and
//! `benches/fig11_serving_latency.rs` / `benches/fig12_churn.rs`.

use super::{DeltaMode, GraphDelta, HaloPolicy, NewNode, ServeConfig, Server};
use crate::datasets::Dataset;
use crate::model::GcnParams;
use crate::obs::hist::percentile;
use crate::rng::Rng;
use anyhow::Result;
use std::collections::HashSet;
use std::fmt::Write as _;
use std::time::Instant;

/// Bench dimensions (Fig. 11).
#[derive(Clone, Debug)]
pub struct ServingBenchConfig {
    /// Shard count for the sharded modes.
    pub shards: usize,
    /// Total queries per mode (one shared random stream).
    pub queries: usize,
    /// Micro-batch (request) size for the sharded modes.
    pub batch: usize,
    /// Halo policy for the sharded modes.
    pub halo: HaloPolicy,
    /// Per-shard retained-row cache budget (0 = unbounded).
    pub cache_budget_bytes: u64,
    /// Budgeted halos answer exactly via cross-shard row gathers.
    pub gather_missing: bool,
    /// Cross-request gathered-row cache budget (gather mode; 0 = off).
    pub gather_cache_budget_bytes: u64,
    /// Serve-pool width for the extra `parallel-sharded` row (0 =
    /// auto, 1 = skip the row; see [`ServeConfig::serve_threads`]).
    pub serve_threads: usize,
    pub seed: u64,
}

impl Default for ServingBenchConfig {
    fn default() -> Self {
        ServingBenchConfig {
            shards: 4,
            queries: 2000,
            batch: 32,
            halo: HaloPolicy::Exact,
            cache_budget_bytes: 0,
            gather_missing: false,
            gather_cache_budget_bytes: 0,
            serve_threads: 1,
            seed: 0,
        }
    }
}

/// One mode's latency/throughput row.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub mode: String,
    /// Requests issued (queries / batch, rounded up).
    pub requests: usize,
    pub queries: usize,
    pub batch: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub qps: f64,
    pub cache_hits: u64,
    pub rows_recomputed: u64,
    /// Serve-pool width this mode ran at (1 = sequential).
    pub serve_threads: usize,
}

/// All modes on one workload.
#[derive(Clone, Debug)]
pub struct ServingBenchReport {
    pub rows: Vec<LatencySummary>,
}

impl ServingBenchReport {
    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "| mode | threads | batch | p50 (µs) | p99 (µs) | mean (µs) | QPS | cache hits | rows recomputed |\n\
             |---|---|---|---|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {:.1} | {:.1} | {:.1} | {:.0} | {} | {} |",
                r.mode, r.serve_threads, r.batch, r.p50_us, r.p99_us, r.mean_us, r.qps,
                r.cache_hits, r.rows_recomputed
            );
        }
        if let Some(x) = self.cached_speedup_vs_baseline() {
            let _ = writeln!(s, "\ncached-sharded vs unsharded-pernode: **{x:.1}x QPS**");
        }
        if let Some((threads, x)) = self.parallel_speedup_vs_cached() {
            let _ = writeln!(
                s,
                "parallel-sharded ({threads} threads) vs cached-sharded: **{x:.2}x QPS** \
                 (bit-identical answers)"
            );
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "mode,serve_threads,batch,p50_us,p99_us,mean_us,qps,cache_hits,rows_recomputed\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{},{:.2},{:.2},{:.2},{:.1},{},{}",
                r.mode, r.serve_threads, r.batch, r.p50_us, r.p99_us, r.mean_us, r.qps,
                r.cache_hits, r.rows_recomputed
            );
        }
        s
    }

    /// Machine-readable form for the perf trajectory
    /// (`BENCH_fig11.json`). Hand-rolled — registry-free build, no
    /// serde.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"fig11_serving_latency\",\n");
        let _ = writeln!(
            s,
            "  \"cached_speedup_vs_baseline\": {},",
            self.cached_speedup_vs_baseline()
                .map_or_else(|| "null".to_string(), |x| format!("{x:.3}"))
        );
        let _ = writeln!(
            s,
            "  \"parallel_speedup_vs_cached\": {},",
            self.parallel_speedup_vs_cached()
                .map_or_else(|| "null".to_string(), |(_, x)| format!("{x:.3}"))
        );
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"mode\": \"{}\", \"serve_threads\": {}, \"batch\": {}, \
                 \"p50_us\": {:.2}, \"p99_us\": {:.2}, \"mean_us\": {:.2}, \"qps\": {:.1}, \
                 \"cache_hits\": {}, \"rows_recomputed\": {}}}",
                r.mode, r.serve_threads, r.batch, r.p50_us, r.p99_us, r.mean_us, r.qps,
                r.cache_hits, r.rows_recomputed
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    fn row(&self, mode: &str) -> Option<&LatencySummary> {
        self.rows.iter().find(|r| r.mode == mode)
    }

    /// QPS ratio of the full subsystem over the naive baseline — the
    /// number the acceptance criterion is about.
    pub fn cached_speedup_vs_baseline(&self) -> Option<f64> {
        let base = self.row("unsharded-pernode")?.qps;
        let cached = self.row("cached-sharded")?.qps;
        (base > 0.0).then(|| cached / base)
    }

    /// QPS ratio of the scoped-thread serve pool over the sequential
    /// cached deployment, same warm state and query stream — the
    /// parallel path's before/after. `None` when the bench ran without
    /// a `parallel-sharded` row (`serve_threads` ≤ 1).
    pub fn parallel_speedup_vs_cached(&self) -> Option<(usize, f64)> {
        let seq = self.row("cached-sharded")?.qps;
        let par = self.row("parallel-sharded")?;
        (seq > 0.0).then(|| (par.serve_threads, par.qps / seq))
    }
}

fn run_mode(
    mode: &str,
    ds: &Dataset,
    params: &GcnParams,
    scfg: ServeConfig,
    stream: &[u32],
    batch: usize,
    warm: bool,
) -> Result<LatencySummary> {
    let mut srv = Server::for_dataset(ds, params.clone(), scfg)?;
    let serve_threads = srv.serve_parallelism();
    if warm {
        let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        for chunk in all.chunks(256) {
            srv.query_batch(chunk)?;
        }
    }
    let pre = srv.stats();
    let batch = batch.max(1);
    let mut lat_us = Vec::with_capacity(stream.len() / batch + 1);
    let t0 = Instant::now();
    for chunk in stream.chunks(batch) {
        let s = Instant::now();
        srv.query_batch(chunk)?;
        lat_us.push(s.elapsed().as_secs_f64() * 1e6);
    }
    let total_s = t0.elapsed().as_secs_f64();
    let post = srv.stats();
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let mean = lat_us.iter().sum::<f64>() / lat_us.len().max(1) as f64;
    Ok(LatencySummary {
        mode: mode.to_string(),
        requests: lat_us.len(),
        queries: stream.len(),
        batch,
        p50_us: percentile(&lat_us, 0.50),
        p99_us: percentile(&lat_us, 0.99),
        mean_us: mean,
        qps: stream.len() as f64 / total_s.max(1e-12),
        cache_hits: post.cache_hits - pre.cache_hits,
        rows_recomputed: post.rows_recomputed - pre.rows_recomputed,
        serve_threads,
    })
}

/// Run all three Fig-11 modes on one shared random query stream.
pub fn run_serving_bench(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &ServingBenchConfig,
) -> Result<ServingBenchReport> {
    let n = ds.num_nodes();
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x5e17e);
    let stream: Vec<u32> = (0..cfg.queries).map(|_| rng.gen_range(n) as u32).collect();

    let baseline = ServeConfig {
        shards: 1,
        halo: HaloPolicy::Exact,
        cache: false,
        pruned: false,
        seed: cfg.seed,
        ..Default::default()
    };
    let cold = ServeConfig {
        shards: cfg.shards,
        halo: cfg.halo,
        cache: false,
        cache_budget_bytes: cfg.cache_budget_bytes,
        pruned: true,
        gather_missing: cfg.gather_missing,
        gather_cache_budget_bytes: cfg.gather_cache_budget_bytes,
        seed: cfg.seed,
        ..Default::default()
    };
    let cached = ServeConfig { cache: true, ..cold.clone() };

    let mut rows = vec![
        run_mode("unsharded-pernode", ds, params, baseline, &stream, 1, false)?,
        run_mode("cold-sharded", ds, params, cold, &stream, cfg.batch, false)?,
        run_mode("cached-sharded", ds, params, cached.clone(), &stream, cfg.batch, true)?,
    ];
    if cfg.serve_threads != 1 {
        // the cached deployment again, fanned out across the serve
        // pool: same warm state, same stream, bit-identical answers —
        // only wall-clock may move
        let parallel = ServeConfig { serve_threads: cfg.serve_threads, ..cached };
        rows.push(run_mode("parallel-sharded", ds, params, parallel, &stream, cfg.batch, true)?);
    }
    Ok(ServingBenchReport { rows })
}

// --------------------------------------------------------------------
// Fig 12 (ours): serving under churn — incremental vs rebuild
// --------------------------------------------------------------------

/// Bench dimensions (Fig. 12).
#[derive(Clone, Debug)]
pub struct ChurnBenchConfig {
    /// Serving shards (Exact halo).
    pub shards: usize,
    /// Rounds per churn rate; each round applies the rate's deltas and
    /// then answers a fixed query block.
    pub rounds: usize,
    /// Churn-rate sweep: deltas applied per round.
    pub deltas_per_round: Vec<usize>,
    /// Undirected edge mutations per delta (≈ half adds, half removes),
    /// plus one feature rewrite per delta.
    pub edges_per_delta: usize,
    /// Queries answered between delta bursts, per round.
    pub queries_per_round: usize,
    /// Micro-batch size for the query blocks.
    pub batch: usize,
    /// Tune the overlay compaction threshold from the modelled
    /// splice-vs-flat read cost (incremental mode).
    pub adaptive_compaction: bool,
    pub seed: u64,
}

impl Default for ChurnBenchConfig {
    fn default() -> Self {
        ChurnBenchConfig {
            shards: 4,
            rounds: 6,
            deltas_per_round: vec![1, 4, 16],
            edges_per_delta: 4,
            queries_per_round: 192,
            batch: 32,
            adaptive_compaction: false,
            seed: 0,
        }
    }
}

/// One `(mode, churn rate)` row.
#[derive(Clone, Debug)]
pub struct ChurnSummary {
    /// `incremental` or `rebuild`.
    pub mode: String,
    /// Deltas applied per round.
    pub deltas_per_round: usize,
    pub delta_mean_us: f64,
    pub delta_p99_us: f64,
    /// Sustained delta throughput (1e6 / mean apply µs).
    pub deltas_per_sec: f64,
    pub query_p50_us: f64,
    pub query_p99_us: f64,
    pub rows_invalidated: u64,
    pub serving_bytes: u64,
    /// Shard re-inductions (membership churn) over the run.
    pub shard_rebuilds: u64,
    /// Overlay compactions over the run.
    pub compactions: u64,
}

/// The whole sweep.
#[derive(Clone, Debug)]
pub struct ChurnBenchReport {
    pub rows: Vec<ChurnSummary>,
}

impl ChurnBenchReport {
    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "| mode | deltas/round | delta mean (µs) | delta p99 (µs) | deltas/s | query p50 (µs) | query p99 (µs) | rows invalidated | shard rebuilds | compactions |\n\
             |---|---|---|---|---|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {:.1} | {:.1} | {:.0} | {:.1} | {:.1} | {} | {} | {} |",
                r.mode,
                r.deltas_per_round,
                r.delta_mean_us,
                r.delta_p99_us,
                r.deltas_per_sec,
                r.query_p50_us,
                r.query_p99_us,
                r.rows_invalidated,
                r.shard_rebuilds,
                r.compactions
            );
        }
        if let Some(x) = self.incremental_speedup() {
            let _ = writeln!(
                s,
                "\nincremental vs rebuild delta throughput (max churn): **{x:.1}x deltas/s**"
            );
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "mode,deltas_per_round,delta_mean_us,delta_p99_us,deltas_per_sec,query_p50_us,query_p99_us,rows_invalidated,serving_bytes,shard_rebuilds,compactions\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.2},{:.2},{:.1},{:.2},{:.2},{},{},{},{}",
                r.mode,
                r.deltas_per_round,
                r.delta_mean_us,
                r.delta_p99_us,
                r.deltas_per_sec,
                r.query_p50_us,
                r.query_p99_us,
                r.rows_invalidated,
                r.serving_bytes,
                r.shard_rebuilds,
                r.compactions
            );
        }
        s
    }

    /// Machine-readable form for the perf trajectory
    /// (`BENCH_fig12.json`). Hand-rolled — registry-free build, no
    /// serde.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"fig12_churn\",\n");
        let _ = writeln!(
            s,
            "  \"incremental_speedup\": {},",
            self.incremental_speedup().map_or_else(|| "null".to_string(), |x| format!("{x:.3}"))
        );
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"mode\": \"{}\", \"deltas_per_round\": {}, \"delta_mean_us\": {:.2}, \
                 \"delta_p99_us\": {:.2}, \"deltas_per_sec\": {:.1}, \"query_p50_us\": {:.2}, \
                 \"query_p99_us\": {:.2}, \"rows_invalidated\": {}, \"serving_bytes\": {}, \
                 \"shard_rebuilds\": {}, \"compactions\": {}}}",
                r.mode,
                r.deltas_per_round,
                r.delta_mean_us,
                r.delta_p99_us,
                r.deltas_per_sec,
                r.query_p50_us,
                r.query_p99_us,
                r.rows_invalidated,
                r.serving_bytes,
                r.shard_rebuilds,
                r.compactions
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Delta-throughput ratio of incremental over rebuild at the
    /// highest churn rate — the headline number.
    pub fn incremental_speedup(&self) -> Option<f64> {
        let max_rate = self.rows.iter().map(|r| r.deltas_per_round).max()?;
        let pick = |mode: &str| {
            self.rows
                .iter()
                .find(|r| r.mode == mode && r.deltas_per_round == max_rate)
                .map(|r| r.deltas_per_sec)
        };
        let inc = pick("incremental")?;
        let reb = pick("rebuild")?;
        (reb > 0.0).then(|| inc / reb)
    }
}

/// Deterministic delta schedule for one churn rate: both modes replay
/// the exact same mutations (the rng never sees server state).
fn churn_schedule(ds: &Dataset, cfg: &ChurnBenchConfig, rate: usize) -> Vec<Vec<GraphDelta>> {
    let n = ds.num_nodes();
    let fdim = ds.feature_dim();
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xC0FFEE ^ (rate as u64).wrapping_mul(0x9E37));
    let mut edges: Vec<(u32, u32)> = ds.graph.edges().collect();
    let mut present: HashSet<(u32, u32)> = edges.iter().copied().collect();
    (0..cfg.rounds)
        .map(|_| {
            (0..rate)
                .map(|_| {
                    let mut d = GraphDelta::default();
                    for _ in 0..cfg.edges_per_delta {
                        if rng.gen_bool(0.5) && edges.len() > 1 {
                            let i = rng.gen_range(edges.len());
                            let e = edges.swap_remove(i);
                            present.remove(&e);
                            d.removed_edges.push(e);
                        } else {
                            for _attempt in 0..8 {
                                let u = rng.gen_range(n) as u32;
                                let v = rng.gen_range(n) as u32;
                                if u == v {
                                    continue;
                                }
                                let c = if u < v { (u, v) } else { (v, u) };
                                if present.insert(c) {
                                    edges.push(c);
                                    d.added_edges.push(c);
                                    break;
                                }
                            }
                        }
                    }
                    let fv = rng.gen_range(n) as u32;
                    let row: Vec<f32> = (0..fdim).map(|_| rng.gen_f32() - 0.5).collect();
                    d.updated_features.push((fv, row));
                    d
                })
                .collect()
        })
        .collect()
}

fn run_churn_mode(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &ChurnBenchConfig,
    rate: usize,
    mode: DeltaMode,
) -> Result<ChurnSummary> {
    let scfg = ServeConfig {
        shards: cfg.shards,
        delta_mode: mode,
        adaptive_compaction: cfg.adaptive_compaction && mode == DeltaMode::Incremental,
        seed: cfg.seed,
        ..Default::default()
    };
    let mut srv = Server::for_dataset(ds, params.clone(), scfg)?;
    let all: Vec<u32> = (0..ds.num_nodes() as u32).collect();
    for chunk in all.chunks(256) {
        srv.query_batch(chunk)?; // warm: churn hits a steady-state cache
    }
    let schedule = churn_schedule(ds, cfg, rate);
    let mut qrng = Rng::seed_from_u64(cfg.seed ^ 0x51AB ^ (rate as u64).wrapping_mul(0x51));
    let pre = srv.stats();
    let mut delta_us: Vec<f64> = Vec::new();
    let mut query_us: Vec<f64> = Vec::new();
    let mut rows_invalidated = 0u64;
    for round in &schedule {
        for d in round {
            let t = Instant::now();
            let rep = srv.apply_delta(d)?;
            delta_us.push(t.elapsed().as_secs_f64() * 1e6);
            rows_invalidated += rep.rows_invalidated;
        }
        let stream: Vec<u32> =
            (0..cfg.queries_per_round).map(|_| qrng.gen_range(ds.num_nodes()) as u32).collect();
        for chunk in stream.chunks(cfg.batch.max(1)) {
            let t = Instant::now();
            srv.query_batch(chunk)?;
            query_us.push(t.elapsed().as_secs_f64() * 1e6);
        }
    }
    let post = srv.stats();
    delta_us.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    query_us.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let delta_mean = delta_us.iter().sum::<f64>() / delta_us.len().max(1) as f64;
    Ok(ChurnSummary {
        mode: match mode {
            DeltaMode::Incremental => "incremental".into(),
            DeltaMode::Rebuild => "rebuild".into(),
        },
        deltas_per_round: rate,
        delta_mean_us: delta_mean,
        delta_p99_us: percentile(&delta_us, 0.99),
        deltas_per_sec: if delta_mean > 0.0 { 1e6 / delta_mean } else { 0.0 },
        query_p50_us: percentile(&query_us, 0.50),
        query_p99_us: percentile(&query_us, 0.99),
        rows_invalidated,
        serving_bytes: post.comm.serving_bytes - pre.comm.serving_bytes,
        shard_rebuilds: post.shard_rebuilds - pre.shard_rebuilds,
        compactions: post.graph_compactions - pre.graph_compactions,
    })
}

/// Sweep churn rates × delta modes on identical mutation and query
/// streams (Fig. 12).
pub fn run_churn_bench(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &ChurnBenchConfig,
) -> Result<ChurnBenchReport> {
    let mut rows = Vec::new();
    for &rate in &cfg.deltas_per_round {
        for mode in [DeltaMode::Incremental, DeltaMode::Rebuild] {
            rows.push(run_churn_mode(ds, params, cfg, rate, mode)?);
        }
    }
    Ok(ChurnBenchReport { rows })
}

// --------------------------------------------------------------------
// Fig 13 (ours): skewed elastic inserts — rebalancer on vs off
// --------------------------------------------------------------------

/// Bench dimensions (Fig. 13).
#[derive(Clone, Debug)]
pub struct RebalanceBenchConfig {
    /// Serving shards (Exact halo).
    pub shards: usize,
    /// Insert rounds; each round applies one skewed-insert delta and
    /// then answers a query block.
    pub rounds: usize,
    /// Nodes inserted per round, all attached inside one part's
    /// neighbourhood so plurality homing piles them onto one shard.
    pub inserts_per_round: usize,
    /// Attachment edges per inserted node.
    pub attach_edges: usize,
    /// Queries answered per round.
    pub queries_per_round: usize,
    /// Micro-batch size for the query blocks.
    pub batch: usize,
    /// Imbalance trigger/target for the rebalancing deployment.
    pub rebalance_ratio: f64,
    /// Per-pass migration cap.
    pub rebalance_max_moves: usize,
    pub seed: u64,
}

impl Default for RebalanceBenchConfig {
    fn default() -> Self {
        RebalanceBenchConfig {
            shards: 4,
            rounds: 8,
            inserts_per_round: 24,
            attach_edges: 2,
            queries_per_round: 128,
            batch: 32,
            rebalance_ratio: 1.5,
            rebalance_max_moves: 64,
            seed: 0,
        }
    }
}

/// One `(mode, round)` row.
#[derive(Clone, Debug)]
pub struct RebalanceRound {
    /// `rebalance-on` or `rebalance-off`.
    pub mode: String,
    pub round: usize,
    /// Max/min base-node ratio after the round (post-rebalance for the
    /// on mode).
    pub imbalance_ratio: f64,
    pub query_p50_us: f64,
    pub query_p99_us: f64,
    /// Nodes migrated this round (on mode only).
    pub moves: usize,
    /// Cumulative rebalance-class bytes so far.
    pub rebalance_bytes: u64,
}

/// The whole scenario.
#[derive(Clone, Debug)]
pub struct RebalanceBenchReport {
    pub rows: Vec<RebalanceRound>,
    /// The configured ratio the rebalancer defends.
    pub ratio_threshold: f64,
    /// Replication bill of standing the post-churn deployment up from
    /// scratch (every shard's halo feature rows shipped again) — the
    /// cost a full repartition would at minimum pay.
    pub full_repartition_bytes: u64,
}

impl RebalanceBenchReport {
    fn rows_of<'a>(&'a self, mode: &'a str) -> impl Iterator<Item = &'a RebalanceRound> + 'a {
        self.rows.iter().filter(move |r| r.mode == mode)
    }

    /// Worst post-round ratio the rebalancing deployment showed.
    pub fn max_ratio_on(&self) -> f64 {
        self.rows_of("rebalance-on").map(|r| r.imbalance_ratio).fold(0.0, f64::max)
    }

    /// Worst ratio the drifting deployment reached.
    pub fn max_ratio_off(&self) -> f64 {
        self.rows_of("rebalance-off").map(|r| r.imbalance_ratio).fold(0.0, f64::max)
    }

    /// Total bytes the rebalancer spent across the run.
    pub fn total_rebalance_bytes(&self) -> u64 {
        self.rows_of("rebalance-on").map(|r| r.rebalance_bytes).max().unwrap_or(0)
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::from(
            "| mode | round | max/min ratio | query p50 (µs) | query p99 (µs) | moves | rebalance bytes (cum.) |\n\
             |---|---|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "| {} | {} | {:.3} | {:.1} | {:.1} | {} | {} |",
                r.mode, r.round, r.imbalance_ratio, r.query_p50_us, r.query_p99_us, r.moves,
                r.rebalance_bytes
            );
        }
        let _ = writeln!(
            s,
            "\nrebalancer held max/min ≤ **{:.3}** (target {:.2}); without it the ratio drifted to **{:.3}**",
            self.max_ratio_on(),
            self.ratio_threshold,
            self.max_ratio_off()
        );
        let _ = writeln!(
            s,
            "rebalance traffic **{}** bytes vs ≥ **{}** bytes for one full repartition",
            self.total_rebalance_bytes(),
            self.full_repartition_bytes
        );
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "mode,round,imbalance_ratio,query_p50_us,query_p99_us,moves,rebalance_bytes\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.4},{:.2},{:.2},{},{}",
                r.mode, r.round, r.imbalance_ratio, r.query_p50_us, r.query_p99_us, r.moves,
                r.rebalance_bytes
            );
        }
        s
    }

    /// Machine-readable form for the perf trajectory
    /// (`BENCH_fig13.json`). Hand-rolled — registry-free build, no
    /// serde.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"fig13_rebalance\",\n");
        let _ = writeln!(s, "  \"ratio_threshold\": {:.3},", self.ratio_threshold);
        let _ = writeln!(s, "  \"max_ratio_on\": {:.4},", self.max_ratio_on());
        let _ = writeln!(s, "  \"max_ratio_off\": {:.4},", self.max_ratio_off());
        let _ = writeln!(s, "  \"total_rebalance_bytes\": {},", self.total_rebalance_bytes());
        let _ = writeln!(s, "  \"full_repartition_bytes\": {},", self.full_repartition_bytes);
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"mode\": \"{}\", \"round\": {}, \"imbalance_ratio\": {:.4}, \
                 \"query_p50_us\": {:.2}, \"query_p99_us\": {:.2}, \"moves\": {}, \
                 \"rebalance_bytes\": {}}}",
                r.mode, r.round, r.imbalance_ratio, r.query_p50_us, r.query_p99_us, r.moves,
                r.rebalance_bytes
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Deterministic skewed-insert schedule: every inserted node attaches
/// to the *initial* membership of one hot part (or to earlier inserts),
/// so plurality homing keeps piling base nodes onto that part's shard.
/// The schedule never reads live server state, so the on/off
/// deployments replay identical mutations.
fn skewed_insert_schedule(
    ds: &Dataset,
    cfg: &RebalanceBenchConfig,
    hot: &[u32],
) -> Vec<GraphDelta> {
    let fdim = ds.feature_dim();
    let n0 = ds.num_nodes() as u32;
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xF13);
    let mut inserted: Vec<u32> = Vec::new();
    (0..cfg.rounds)
        .map(|_| {
            let mut d = GraphDelta::default();
            for _ in 0..cfg.inserts_per_round {
                let mut edges: Vec<u32> = Vec::with_capacity(cfg.attach_edges);
                for _ in 0..cfg.attach_edges.max(1) {
                    // mostly the fixed hot set, occasionally an earlier
                    // insert (they live on the hot shard too)
                    let t = if !inserted.is_empty() && rng.gen_bool(0.25) {
                        inserted[rng.gen_range(inserted.len())]
                    } else {
                        hot[rng.gen_range(hot.len())]
                    };
                    if !edges.contains(&t) {
                        edges.push(t);
                    }
                }
                let features: Vec<f32> = (0..fdim).map(|_| rng.gen_f32() - 0.5).collect();
                d.added_nodes.push(NewNode { features, edges });
            }
            let base = n0 + inserted.len() as u32;
            inserted.extend((0..cfg.inserts_per_round as u32).map(|i| base + i));
            d
        })
        .collect()
}

/// Run the Fig-13 scenario: identical skewed-insert + query schedules
/// against a rebalancing deployment and a drifting one.
pub fn run_rebalance_bench(
    ds: &Dataset,
    params: &GcnParams,
    cfg: &RebalanceBenchConfig,
) -> Result<RebalanceBenchReport> {
    let scfg_off = ServeConfig {
        shards: cfg.shards,
        halo: HaloPolicy::Exact,
        rebalance: false,
        rebalance_ratio: cfg.rebalance_ratio,
        rebalance_max_moves: cfg.rebalance_max_moves,
        seed: cfg.seed,
        ..Default::default()
    };
    let scfg_on = ServeConfig { rebalance: true, ..scfg_off.clone() };
    let mut on = Server::for_dataset(ds, params.clone(), scfg_on)?;
    let mut off = Server::for_dataset(ds, params.clone(), scfg_off)?;

    // the hot part's initial membership — identical in both servers
    // (same partition seed), so the schedule is shared
    let hot: Vec<u32> =
        (0..ds.num_nodes() as u32).filter(|&v| on.shard_of(v) == 0).collect();
    if hot.is_empty() {
        return Err(anyhow::anyhow!("hot part is empty; cannot build a skewed schedule"));
    }
    let schedule = skewed_insert_schedule(ds, cfg, &hot);

    let warm: Vec<u32> = (0..ds.num_nodes() as u32).collect();
    for chunk in warm.chunks(256) {
        on.query_batch(chunk)?;
        off.query_batch(chunk)?;
    }

    let mut qrng = Rng::seed_from_u64(cfg.seed ^ 0x13F1);
    let mut rows = Vec::new();
    for (round, delta) in schedule.iter().enumerate() {
        let rep_on = on.apply_delta(delta)?;
        off.apply_delta(delta)?;
        let n_alive = on.num_nodes();
        let stream: Vec<u32> =
            (0..cfg.queries_per_round).map(|_| qrng.gen_range(n_alive) as u32).collect();
        let lat = |srv: &mut Server| -> Result<(f64, f64)> {
            let mut us = Vec::new();
            for chunk in stream.chunks(cfg.batch.max(1)) {
                let t = Instant::now();
                srv.query_batch(chunk)?;
                us.push(t.elapsed().as_secs_f64() * 1e6);
            }
            us.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
            Ok((percentile(&us, 0.50), percentile(&us, 0.99)))
        };
        let (on_p50, on_p99) = lat(&mut on)?;
        let (off_p50, off_p99) = lat(&mut off)?;
        rows.push(RebalanceRound {
            mode: "rebalance-on".into(),
            round,
            imbalance_ratio: on.imbalance_ratio(),
            query_p50_us: on_p50,
            query_p99_us: on_p99,
            moves: rep_on.rebalance_moves,
            rebalance_bytes: on.stats().comm.rebalance_bytes,
        });
        rows.push(RebalanceRound {
            mode: "rebalance-off".into(),
            round,
            imbalance_ratio: off.imbalance_ratio(),
            query_p50_us: off_p50,
            query_p99_us: off_p99,
            moves: 0,
            rebalance_bytes: 0,
        });
    }

    // a full repartition would at minimum re-ship every halo feature
    // row of the post-churn deployment
    let frow = ds.feature_dim() as u64 * 4;
    let full_repartition_bytes: u64 =
        off.shards.iter().map(|s| s.replicas.len() as u64 * frow).sum();
    Ok(RebalanceBenchReport { rows, ratio_threshold: cfg.rebalance_ratio, full_repartition_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 3.0); // (3 * 0.5).round() = 2
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn bench_produces_all_modes() {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = crate::rng::Rng::seed_from_u64(1);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ServingBenchConfig { queries: 40, batch: 8, ..Default::default() };
        let rep = run_serving_bench(&ds, &params, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 3);
        for r in &rep.rows {
            assert_eq!(r.queries, 40);
            assert!(r.qps > 0.0);
            assert!(r.p50_us <= r.p99_us);
        }
        // steady state serves straight from cache
        let cached = rep.row("cached-sharded").unwrap();
        assert_eq!(cached.cache_hits, 40);
        assert_eq!(cached.rows_recomputed, 0);
        assert!(rep.to_markdown().contains("unsharded-pernode"));
        assert!(rep.to_csv().lines().count() == 4);
        assert!(rep.cached_speedup_vs_baseline().unwrap() > 0.0);
        assert!(rep.parallel_speedup_vs_cached().is_none(), "no parallel row by default");
        assert!(rep.to_json().contains("\"bench\": \"fig11_serving_latency\""));
    }

    #[test]
    fn bench_parallel_row_rides_along_with_identical_counters() {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = crate::rng::Rng::seed_from_u64(1);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg =
            ServingBenchConfig { queries: 40, batch: 8, serve_threads: 4, ..Default::default() };
        let rep = run_serving_bench(&ds, &params, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 4, "parallel-sharded row joins the three classics");
        let cached = rep.row("cached-sharded").unwrap();
        let par = rep.row("parallel-sharded").unwrap();
        assert!(par.serve_threads > 1);
        // same warm state + stream ⇒ the fan-out may only move
        // wall-clock, never the work done
        assert_eq!(par.cache_hits, cached.cache_hits);
        assert_eq!(par.rows_recomputed, cached.rows_recomputed);
        let (threads, x) = rep.parallel_speedup_vs_cached().unwrap();
        assert_eq!(threads, par.serve_threads);
        assert!(x > 0.0);
        assert!(rep.to_json().contains("\"mode\": \"parallel-sharded\""));
    }

    #[test]
    fn rebalance_bench_holds_ratio_where_drift_breaks_it() {
        let ds = SyntheticSpec::tiny().generate(3);
        let mut rng = crate::rng::Rng::seed_from_u64(3);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = RebalanceBenchConfig {
            rounds: 4,
            inserts_per_round: 16,
            queries_per_round: 32,
            batch: 8,
            ..Default::default()
        };
        let rep = run_rebalance_bench(&ds, &params, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 2 * cfg.rounds, "one row per mode per round");
        assert!(
            rep.max_ratio_on() <= cfg.rebalance_ratio + 1e-9,
            "rebalancer must defend the ratio (got {:.3})",
            rep.max_ratio_on()
        );
        assert!(
            rep.max_ratio_off() > cfg.rebalance_ratio,
            "the skewed schedule must actually break balance without it (got {:.3})",
            rep.max_ratio_off()
        );
        assert!(rep.total_rebalance_bytes() > 0, "migrations must be accounted");
        assert!(rep.full_repartition_bytes > 0);
        let md = rep.to_markdown();
        assert!(md.contains("rebalance-on") && md.contains("rebalance-off"));
        assert_eq!(rep.to_csv().lines().count(), 1 + 2 * cfg.rounds);
        let json = rep.to_json();
        assert!(json.contains("\"bench\": \"fig13_rebalance\""));
        assert!(json.contains("\"mode\": \"rebalance-on\""));
        assert_eq!(json.matches("\"round\":").count(), 2 * cfg.rounds);
    }

    #[test]
    fn churn_bench_covers_modes_and_rates() {
        let ds = SyntheticSpec::tiny().generate(2);
        let mut rng = crate::rng::Rng::seed_from_u64(2);
        let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
        let cfg = ChurnBenchConfig {
            rounds: 2,
            deltas_per_round: vec![1, 3],
            queries_per_round: 24,
            batch: 8,
            ..Default::default()
        };
        let rep = run_churn_bench(&ds, &params, &cfg).unwrap();
        assert_eq!(rep.rows.len(), 4, "2 rates x 2 modes");
        for r in &rep.rows {
            assert!(r.deltas_per_sec > 0.0);
            assert!(r.query_p50_us <= r.query_p99_us);
        }
        assert!(rep.incremental_speedup().is_some());
        assert!(rep.to_markdown().contains("incremental"));
        assert_eq!(rep.to_csv().lines().count(), 5);
        let json = rep.to_json();
        assert!(json.contains("\"bench\": \"fig12_churn\""));
        assert!(json.contains("\"mode\": \"incremental\"") && json.contains("\"mode\": \"rebuild\""));
        assert_eq!(json.matches("\"deltas_per_round\":").count(), 4);
    }
}
