//! Online shard load rebalancing.
//!
//! PR 4's elastic membership homes inserted nodes by neighbour
//! plurality, so sustained insert skew piles base nodes onto one shard
//! — the serving-tier analogue of the partition imbalance GAD-Partition
//! avoids offline. This module restores balance *online*: when the
//! max/min base-node ratio across parts exceeds
//! [`ServeConfig::rebalance_ratio`], boundary nodes migrate from the
//! most loaded part to the least loaded one, candidates chosen by
//! **minimum edge-cut delta** (fewest new cross-part arcs), in the
//! spirit of CuSP-style streaming repartitioners.
//!
//! A migration changes *membership only* — no edge, feature or degree
//! changes — so the graph version does not move and no cached embedding
//! value becomes numerically stale. Each affected shard folds the
//! membership change through the same incremental machinery a
//! [`GraphDelta`](super::GraphDelta) uses (boundary refresh → bounded
//! BFS halo recompute → shard-local re-induction with cache-row
//! migration; never a global rebuild), and the moved nodes' feature
//! rows plus their still-valid cache rows ship donor → recipient. Every
//! migrated byte lands in the [`CommLedger`](crate::comm::CommLedger)'s
//! **rebalance** traffic class so the bench can weigh the rebalancer
//! against the replication bill of a full repartition.
//!
//! One correctness subtlety: a shard may hold cache rows for a halo
//! replica at depths its truncated neighbourhood cannot compute
//! exactly (harmless while the node stays a replica — the dependency
//! cone never reads beyond the valid envelope, which is set by the
//! node's distance to the shard's boundary). A migration moves the
//! donor's and recipient's boundaries, so envelopes near the moved
//! nodes can *grow*, making previously unreadable truncated rows
//! readable. The fold therefore invalidates every cached row within
//! the moved nodes' L-hop cone on the two affected shards (the same
//! bounded-BFS rule deltas use; third-party shards keep their boundary
//! and need nothing), and the recipient then adopts the donor's rows
//! for each moved-in node — the donor computed them while the node was
//! base there, i.e. bit-identical to the full-graph forward at every
//! depth. The property tests pin this down.

use super::delta::EdgeChurn;
use super::server::Server;
use super::shard::{ShardDeltaCtx, ShardEngine};
use super::HaloPolicy;
use crate::graph::{bounded_bfs_distances_sparse, GraphView};
use std::collections::{HashMap, HashSet};

/// What one rebalance pass did.
#[derive(Clone, Debug, Default)]
pub struct RebalanceReport {
    /// The pass moved at least one node.
    pub triggered: bool,
    /// Nodes migrated between parts.
    pub moves: usize,
    /// Bytes shipped (feature rows + cache rows + halo joins), also
    /// recorded in the ledger's rebalance class.
    pub bytes: u64,
    /// Max/min base-node ratio before the pass.
    pub ratio_before: f64,
    /// Max/min base-node ratio after the pass.
    pub ratio_after: f64,
    /// Shards that re-induced their subgraph to absorb the migrations.
    pub shards_rebuilt: usize,
}

/// One planned migration plus the pre-fold state the byte accounting
/// and cache adoption need.
struct Move {
    node: u32,
    from: u32,
    to: u32,
    /// The recipient already replicated the node's feature row in its
    /// halo — migration ships no feature bytes.
    feature_resident: bool,
    /// The donor's still-valid cache rows for the node, captured before
    /// the donor shard is rebuilt: `(layer, row)`.
    cache_rows: Vec<(usize, Vec<f32>)>,
}

/// Max/min ratio over per-part base counts; empty parts count as 1 so
/// a starved part reads as a large finite ratio instead of dividing by
/// zero.
pub(crate) fn imbalance_ratio(base_counts: &[usize]) -> f64 {
    let max = base_counts.iter().copied().max().unwrap_or(0);
    let min = base_counts.iter().copied().min().unwrap_or(0);
    max as f64 / min.max(1) as f64
}

/// Edge-cut delta of moving `node` from `from` to `to`: each neighbour
/// still in `from` becomes a new cross-part arc (+1), each neighbour
/// already in `to` stops being one (-1). Lower is better.
fn cut_delta<G: GraphView>(graph: &G, assignment: &[u32], node: u32, from: u32, to: u32) -> i64 {
    let mut d = 0i64;
    for &t in graph.neighbors(node as usize) {
        let p = assignment[t as usize];
        if p == from {
            d += 1;
        } else if p == to {
            d -= 1;
        }
    }
    d
}

/// Choose the donor node whose migration to `to` perturbs the edge cut
/// least: boundary nodes first (they already have cross-part arcs, so
/// candidates are cheap to enumerate and usually contain the winner),
/// falling back to a full scan of the donor's pre-pass membership when
/// the boundary yields nothing. Deterministic: ties break toward lower
/// degree, then lower id.
fn pick_candidate(
    srv: &Server,
    owned: &[u32],
    boundary: &[u32],
    moved: &HashSet<u32>,
    from: u32,
    to: u32,
) -> Option<u32> {
    let score_of = |v: u32| -> Option<(i64, usize, u32)> {
        if moved.contains(&v) || srv.assignment[v as usize] != from {
            return None;
        }
        let score = cut_delta(&srv.graph, &srv.assignment, v, from, to);
        Some((score, srv.graph.degree(v as usize), v))
    };
    boundary
        .iter()
        .filter_map(|&v| score_of(v))
        .min()
        .or_else(|| owned.iter().filter_map(|&v| score_of(v)).min())
        .map(|(_, _, v)| v)
}

/// Run one bounded rebalance pass over `srv` (see module docs). Caller
/// decides the trigger; the pass itself re-checks the ratio before
/// every move and stops as soon as the target holds, the move cap is
/// reached, or no move can help.
pub(crate) fn run(srv: &mut Server) -> RebalanceReport {
    let k = srv.shards.len();
    let ratio_before = imbalance_ratio(&srv.base_counts);
    let mut report = RebalanceReport {
        ratio_before,
        ratio_after: ratio_before,
        ..RebalanceReport::default()
    };
    if k < 2 {
        return report;
    }
    let layers = srv.params.layers();
    let dims: Vec<usize> = srv.params.ws.iter().map(|w| w.cols).collect();

    // shards are built one per part, but index defensively by part id
    let part_index: HashMap<u32, usize> =
        srv.shards.iter().enumerate().map(|(i, s)| (s.part, i)).collect();
    // pre-pass membership and boundary snapshots per part (the plan is
    // computed against these; assignment/base_counts update per move so
    // cut-delta scoring sees earlier moves)
    let owned: HashMap<u32, Vec<u32>> = srv
        .shards
        .iter()
        .map(|s| {
            let base: Vec<u32> = s
                .global_ids
                .iter()
                .zip(&s.is_replica)
                .filter(|&(_, &r)| !r)
                .map(|(&g, _)| g)
                .collect();
            (s.part, base)
        })
        .collect();

    // ---- plan: greedy max->min moves by minimum edge-cut delta ------
    let mut moves: Vec<Move> = Vec::new();
    let mut moved: HashSet<u32> = HashSet::new();
    while moves.len() < srv.cfg.rebalance_max_moves {
        let (max_p, &max_c) = srv
            .base_counts
            .iter()
            .enumerate()
            .max_by_key(|&(p, &c)| (c, std::cmp::Reverse(p)))
            .expect("k >= 2");
        let (min_p, &min_c) = srv
            .base_counts
            .iter()
            .enumerate()
            .min_by_key(|&(p, &c)| (c, p))
            .expect("k >= 2");
        if imbalance_ratio(&srv.base_counts) <= srv.cfg.rebalance_ratio || max_c - min_c < 2 {
            break;
        }
        let (from, to) = (max_p as u32, min_p as u32);
        let Some(v) = pick_candidate(
            srv,
            owned.get(&from).map(|o| o.as_slice()).unwrap_or(&[]),
            srv.shards[part_index[&from]].boundary_set(),
            &moved,
            from,
            to,
        ) else {
            break;
        };
        // pre-fold state the accounting needs
        let feature_resident = srv.shards[part_index[&to]].local_of(v).is_some();
        let donor = &srv.shards[part_index[&from]];
        let mut cache_rows = Vec::new();
        if donor.cache.is_allocated(layers) {
            let local = donor.local_of(v).expect("donor owns its base node") as usize;
            for l in 0..dims.len() {
                if donor.cache.is_valid(l, local) {
                    cache_rows.push((l, donor.cache.row(l, local).to_vec()));
                }
            }
        }
        srv.assignment[v as usize] = to;
        srv.base_counts[from as usize] -= 1;
        srv.base_counts[to as usize] += 1;
        moved.insert(v);
        moves.push(Move { node: v, from, to, feature_resident, cache_rows });
    }
    if moves.is_empty() {
        return report;
    }

    // ---- fold: only donor/recipient shards change membership (a
    //      third part's boundary, and therefore halo, cannot move) ----
    let mut degree_changed: Vec<u32> = Vec::new();
    for m in &moves {
        degree_changed.push(m.node);
        degree_changed.extend_from_slice(srv.graph.neighbors(m.node as usize));
    }
    degree_changed.sort_unstable();
    degree_changed.dedup();
    // membership-only churn: no edges moved, but these nodes' boundary
    // status must be re-derived from the new assignment
    let churn = EdgeChurn { added: Vec::new(), removed: Vec::new(), degree_changed };
    // boundary movement can grow replica envelopes near the moved
    // nodes, so the affected shards drop every cached row within the
    // moves' L-hop cone (see module docs) — values elsewhere survive
    let moved_ids: Vec<u32> = moves.iter().map(|m| m.node).collect();
    let dist = bounded_bfs_distances_sparse(&srv.graph, &moved_ids, layers);
    let affected: Vec<u32> = {
        let mut p: Vec<u32> = moves.iter().flat_map(|m| [m.from, m.to]).collect();
        p.sort_unstable();
        p.dedup();
        p
    };
    let mut bytes = 0u64;
    let frow = (srv.features.cols * 4) as u64;
    for &part in &affected {
        let si = part_index[&part];
        let base_added: Vec<u32> =
            moves.iter().filter(|m| m.to == part).map(|m| m.node).collect();
        let base_removed: Vec<u32> =
            moves.iter().filter(|m| m.from == part).map(|m| m.node).collect();
        match srv.cfg.halo {
            HaloPolicy::Exact => {
                // membership-only deltas splice through the same
                // incremental path graph deltas use, in either
                // DeltaMode — nothing structural changed, so the
                // rebuild-mode oracle semantics are unaffected
                let ctx = ShardDeltaCtx {
                    graph: &srv.graph,
                    global_features: &srv.features,
                    inv_sqrt: &srv.inv_sqrt,
                    assignment: &srv.assignment,
                    churn: &churn,
                    updated_features: &[],
                    base_added: &base_added,
                    base_removed: &base_removed,
                    dist: &dist,
                    layers,
                    dims: &dims,
                    multi_shard: k > 1,
                };
                let out = srv.shards[si].apply_delta(&srv.cfg, &ctx);
                bytes += out.bytes;
                if out.rebuilt {
                    report.shards_rebuilt += 1;
                }
            }
            HaloPolicy::Budgeted { .. } => {
                // budgeted halos are re-sampled on the new membership
                // and restart cold, matching their delta semantics
                let mut fresh = ShardEngine::build(
                    &srv.graph,
                    &srv.features,
                    &srv.inv_sqrt,
                    &srv.assignment,
                    part,
                    layers,
                    &srv.cfg,
                );
                fresh.cache.carry_counters_discarding(&srv.shards[si].cache);
                if k > 1 {
                    bytes += fresh.halo_join_bytes(&srv.shards[si], frow);
                }
                srv.shards[si] = fresh;
                report.shards_rebuilt += 1;
            }
        }
    }

    // ---- migration payload: feature rows + donor cache rows ---------
    for m in &moves {
        if !m.feature_resident {
            bytes += frow;
        }
        if !matches!(srv.cfg.halo, HaloPolicy::Exact) {
            continue; // budgeted recipients start cold
        }
        let rsh = &mut srv.shards[part_index[&m.to]];
        if !rsh.cache.is_allocated(layers) {
            continue; // never queried — rows will recompute lazily
        }
        let local = rsh.local_of(m.node).expect("recipient owns the moved node") as usize;
        // drop the recipient's own (possibly fringe-truncated) rows for
        // the newly based node, then adopt the donor's exact ones
        for l in 0..layers {
            rsh.cache.invalidate(l, local);
        }
        for (l, row) in &m.cache_rows {
            rsh.cache.adopt(*l, local, row);
            bytes += (row.len() * 4) as u64;
        }
    }

    srv.ledger.record_rebalance(bytes);
    srv.rebalances += 1;
    srv.nodes_migrated += moves.len() as u64;
    report.triggered = true;
    report.moves = moves.len();
    report.bytes = bytes;
    report.ratio_after = imbalance_ratio(&srv.base_counts);
    report
}
