//! Per-shard layered embedding cache.
//!
//! One matrix per GCN layer (`n_local x dim_l`) plus a validity bit
//! per row — the bit is what gates serving: when a
//! [`GraphDelta`](super::GraphDelta) lands, the server clears the bits
//! of invalidated rows, so a stale row can never be served and is
//! recomputed lazily by the next query whose dependency cone touches
//! it. The `version` field is the graph version the surviving rows are
//! valid for — a stamp the server sets after each delta, carried into
//! query provenance; it is not consulted on the read path.

use crate::tensor::Matrix;

/// See module docs.
#[derive(Clone, Debug)]
pub struct EmbeddingCache {
    enabled: bool,
    version: u64,
    /// `layers[l]` holds the layer-`l+1` activations (hidden layers
    /// post-ReLU, output layer raw logits).
    layers: Vec<Matrix>,
    valid: Vec<Vec<bool>>,
    /// Rows computed over the cache's lifetime.
    pub rows_recomputed: u64,
    /// Rows dropped by delta invalidation (including membership churn).
    pub rows_invalidated: u64,
    /// Rows dropped by the byte-budget admission policy (lowest
    /// Monte-Carlo importance first) — distinct from invalidation:
    /// evicted rows were still *correct*, just not worth their bytes.
    pub rows_evicted: u64,
}

impl EmbeddingCache {
    /// Empty cache; `enabled = false` clears validity after every
    /// query batch so nothing is reused across calls.
    pub fn new(enabled: bool) -> Self {
        EmbeddingCache {
            enabled,
            version: 0,
            layers: Vec::new(),
            valid: Vec::new(),
            rows_recomputed: 0,
            rows_invalidated: 0,
            rows_evicted: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// (Re)allocate storage for `n` local nodes with the given
    /// per-layer widths. All rows start invalid.
    pub fn allocate(&mut self, n: usize, dims: &[usize]) {
        self.layers = dims.iter().map(|&d| Matrix::zeros(n, d)).collect();
        self.valid = dims.iter().map(|_| vec![false; n]).collect();
    }

    /// True once [`allocate`](Self::allocate) ran for `layers` layers.
    pub fn is_allocated(&self, layers: usize) -> bool {
        self.layers.len() == layers
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.valid.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Is row `node` of layer `l` servable?
    #[inline]
    pub fn is_valid(&self, l: usize, node: usize) -> bool {
        self.valid[l][node]
    }

    /// Read a cached row (caller must have checked validity).
    #[inline]
    pub fn row(&self, l: usize, node: usize) -> &[f32] {
        self.layers[l].row(node)
    }

    /// The whole layer matrix (valid rows only are meaningful).
    #[inline]
    pub fn layer(&self, l: usize) -> &Matrix {
        &self.layers[l]
    }

    /// Store a freshly computed row and mark it valid.
    pub fn store(&mut self, l: usize, node: usize, row: &[f32]) {
        self.layers[l].row_mut(node).copy_from_slice(row);
        self.valid[l][node] = true;
        self.rows_recomputed += 1;
    }

    /// Carry a still-valid row over from a pre-delta cache (no
    /// recompute counted — nothing was computed).
    pub fn adopt(&mut self, l: usize, node: usize, row: &[f32]) {
        self.layers[l].row_mut(node).copy_from_slice(row);
        self.valid[l][node] = true;
    }

    /// Stamp the graph version the surviving rows are valid for (the
    /// server calls this after applying a delta).
    pub fn set_version(&mut self, v: u64) {
        self.version = v;
    }

    /// Carry lifetime counters from a predecessor cache whose rows are
    /// all being discarded (budgeted-halo rebuilds start cold — the
    /// re-sampled halo changes the local structure everywhere, so no
    /// old row is trustworthy). The dropped rows count as invalidated.
    pub fn carry_counters_discarding(&mut self, old: &EmbeddingCache) {
        self.rows_recomputed += old.rows_recomputed;
        self.rows_invalidated += old.rows_invalidated + old.valid_rows() as u64;
        self.rows_evicted += old.rows_evicted;
    }

    /// Drop one row.
    pub fn invalidate(&mut self, l: usize, node: usize) {
        if self.valid[l][node] {
            self.valid[l][node] = false;
            self.rows_invalidated += 1;
        }
    }

    /// Forget everything (cache-disabled mode calls this after each
    /// query batch; the scratch values were still needed *within* the
    /// batch so upper layers could read lower ones).
    pub fn clear_validity(&mut self) {
        for v in &mut self.valid {
            v.iter_mut().for_each(|b| *b = false);
        }
    }

    /// Bytes resident in the embedding matrices.
    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(|m| m.nbytes()).sum()
    }

    /// Bytes of *retained* (valid) rows — what the admission budget
    /// governs. The dense layer matrices double as per-batch compute
    /// scratch, so the budget caps what survives between queries, not
    /// the transient working set.
    pub fn cached_bytes(&self) -> u64 {
        self.layers
            .iter()
            .zip(&self.valid)
            .map(|(m, v)| (v.iter().filter(|&&b| b).count() * m.cols * 4) as u64)
            .sum()
    }

    /// Enforce a byte budget over retained rows: evict valid rows in
    /// ascending admission-score order (`scores[node]`, the shard's
    /// Monte-Carlo importance `I(v)` for halo replicas, 1.0 for base
    /// nodes) until `cached_bytes() <= budget`. Ties break toward
    /// evicting lower layers (cheapest to recompute — their inputs sit
    /// closer to the features) first, then higher node ids — fully
    /// deterministic. Returns rows evicted.
    pub fn enforce_budget(&mut self, budget: u64, scores: &[f32]) -> u64 {
        let mut resident = self.cached_bytes();
        if resident <= budget {
            return 0;
        }
        // candidate rows: (score, layer, node)
        let mut cand: Vec<(f32, usize, usize)> = Vec::new();
        for (l, valid) in self.valid.iter().enumerate() {
            for (node, &b) in valid.iter().enumerate() {
                if b {
                    cand.push((scores.get(node).copied().unwrap_or(0.0), l, node));
                }
            }
        }
        let cmp = |a: &(f32, usize, usize), b: &(f32, usize, usize)| {
            a.0.partial_cmp(&b.0)
                .expect("scores are finite")
                .then(a.1.cmp(&b.1))
                .then(b.2.cmp(&a.2))
        };
        // steady state sits at the cap and only a few rows must go per
        // batch: quickselect an upper bound on the eviction count and
        // sort just that prefix instead of every valid row
        let min_row_bytes =
            self.layers.iter().map(|m| (m.cols * 4).max(4)).min().unwrap_or(4) as u64;
        let excess = resident - budget;
        let k = (excess.div_ceil(min_row_bytes) as usize).min(cand.len());
        if k > 0 && k < cand.len() {
            cand.select_nth_unstable_by(k - 1, cmp);
            cand.truncate(k);
        }
        cand.sort_by(cmp);
        let mut evicted = 0u64;
        for (_, l, node) in cand {
            if resident <= budget {
                break;
            }
            self.valid[l][node] = false;
            resident -= (self.layers[l].cols * 4) as u64;
            evicted += 1;
        }
        self.rows_evicted += evicted;
        evicted
    }

    /// Count of currently valid rows (diagnostics / tests).
    pub fn valid_rows(&self) -> usize {
        self.valid.iter().map(|v| v.iter().filter(|&&b| b).count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_flags() {
        let mut c = EmbeddingCache::new(true);
        c.allocate(3, &[4, 2]);
        assert!(c.is_allocated(2));
        assert!(!c.is_valid(0, 1));
        c.store(0, 1, &[1.0, 2.0, 3.0, 4.0]);
        assert!(c.is_valid(0, 1));
        assert_eq!(c.row(0, 1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.valid_rows(), 1);
        c.invalidate(0, 1);
        assert!(!c.is_valid(0, 1));
        assert_eq!(c.rows_invalidated, 1);
        // invalidating an already-invalid row is not double counted
        c.invalidate(0, 1);
        assert_eq!(c.rows_invalidated, 1);
    }

    #[test]
    fn clear_validity_keeps_storage() {
        let mut c = EmbeddingCache::new(false);
        c.allocate(2, &[3]);
        c.store(0, 0, &[1.0, 1.0, 1.0]);
        c.clear_validity();
        assert_eq!(c.valid_rows(), 0);
        assert!(c.is_allocated(1));
    }

    #[test]
    fn version_stamp() {
        let mut c = EmbeddingCache::new(true);
        assert_eq!(c.version(), 0);
        c.set_version(3);
        assert_eq!(c.version(), 3);
    }

    #[test]
    fn budget_evicts_lowest_importance_first() {
        let mut c = EmbeddingCache::new(true);
        c.allocate(3, &[2]); // 8 bytes per row
        for node in 0..3 {
            c.store(0, node, &[node as f32, 0.0]);
        }
        assert_eq!(c.cached_bytes(), 24);
        // scores: node 1 is the unimportant one
        let scores = [1.0, 0.05, 0.9];
        let evicted = c.enforce_budget(16, &scores);
        assert_eq!(evicted, 1);
        assert!(!c.is_valid(0, 1), "lowest-I(v) row goes first");
        assert!(c.is_valid(0, 0) && c.is_valid(0, 2));
        assert_eq!(c.cached_bytes(), 16);
        assert_eq!(c.rows_evicted, 1);
        // already under budget: no-op
        assert_eq!(c.enforce_budget(16, &scores), 0);
        // budget 0 clears everything
        assert_eq!(c.enforce_budget(0, &scores), 2);
        assert_eq!(c.cached_bytes(), 0);
    }
}
