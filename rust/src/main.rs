//! `gad` binary entrypoint — see `gad help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = gad::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
