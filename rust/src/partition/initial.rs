//! Initial partition phase: seeded region growing (paper §3.2.1
//! step 2). Choose k random seeds; repeatedly expand the lightest part
//! by absorbing the frontier node attached through the heaviest edge;
//! sweep leftover nodes into the nearest part.

use super::wgraph::WGraph;
use crate::rng::Rng;
use std::collections::BinaryHeap;

/// Grow `k` regions on `g`; returns a part id per node. The balance
/// constraint of Eq. 2 is enforced on node *weights* (which equal node
/// counts of the original graph after projection).
pub fn region_grow(g: &WGraph, k: usize, epsilon: f64, rng: &mut Rng) -> Vec<u32> {
    let n = g.num_nodes();
    const FREE: u32 = u32::MAX;
    let mut assignment = vec![FREE; n];
    let total_w = g.total_nweight();
    let cap = ((1.0 + epsilon) * (total_w as f64 / k as f64).ceil()).ceil() as u64;

    // distinct random seeds
    let seeds = rng.sample_indices(n, k);
    let mut part_weight = vec![0u64; k];
    // per-part max-heap of (edge weight, node) frontier candidates
    let mut frontiers: Vec<BinaryHeap<(u64, u32)>> = vec![BinaryHeap::new(); k];
    for (p, &s) in seeds.iter().enumerate() {
        assignment[s] = p as u32;
        part_weight[p] += g.nweights[s];
        let (ts, ws) = g.neighbors(s);
        for (&t, &w) in ts.iter().zip(ws) {
            frontiers[p].push((w, t));
        }
    }

    // round-robin over parts, always trying the lightest unfinished part
    let mut active: Vec<usize> = (0..k).collect();
    while !active.is_empty() {
        // pick the active part with the least weight (keeps balance)
        let (ai, &p) = active
            .iter()
            .enumerate()
            .min_by_key(|(_, &p)| part_weight[p])
            .unwrap();
        let mut grew = false;
        while let Some((_, v)) = frontiers[p].pop() {
            let v = v as usize;
            if assignment[v] != FREE {
                continue;
            }
            if part_weight[p] + g.nweights[v] > cap {
                break;
            }
            assignment[v] = p as u32;
            part_weight[p] += g.nweights[v];
            let (ts, ws) = g.neighbors(v);
            for (&t, &w) in ts.iter().zip(ws) {
                if assignment[t as usize] == FREE {
                    frontiers[p].push((w, t));
                }
            }
            grew = true;
            break;
        }
        if !grew || frontiers[p].is_empty() && part_weight[p] >= cap {
            // frontier exhausted or at capacity
            if !grew {
                active.remove(ai);
            }
        }
    }

    // leftover sweep: BFS from assigned nodes, attach to the nearest
    // part that still has capacity, else the lightest part (paper:
    // "pick up each node and add it into the nearest partition")
    let mut queue: std::collections::VecDeque<u32> = (0..n as u32)
        .filter(|&v| assignment[v as usize] != FREE)
        .collect();
    while let Some(v) = queue.pop_front() {
        let p = assignment[v as usize] as usize;
        let (ts, _) = g.neighbors(v as usize);
        for &t in ts {
            if assignment[t as usize] == FREE {
                let w = g.nweights[t as usize];
                let dest = if part_weight[p] + w <= cap {
                    p
                } else {
                    (0..k).min_by_key(|&q| part_weight[q]).unwrap()
                };
                assignment[t as usize] = dest as u32;
                part_weight[dest] += w;
                queue.push_back(t);
            }
        }
    }
    // disconnected leftovers -> lightest part
    for v in 0..n {
        if assignment[v] == FREE {
            let p = (0..k).min_by_key(|&p| part_weight[p]).unwrap();
            assignment[v] = p as u32;
            part_weight[p] += g.nweights[v];
        }
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn all_nodes_assigned() {
        let g = GraphBuilder::new(20)
            .edges(&(0..19).map(|i| (i as u32, i as u32 + 1)).collect::<Vec<_>>())
            .build();
        let w = WGraph::from_csr(&g);
        let mut rng = Rng::seed_from_u64(4);
        let a = region_grow(&w, 4, 0.1, &mut rng);
        assert!(a.iter().all(|&p| p < 4));
        let mut sizes = [0usize; 4];
        for &p in &a {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s > 0));
    }

    #[test]
    fn handles_disconnected_components() {
        let g = GraphBuilder::new(6).edges(&[(0, 1), (2, 3), (4, 5)]).build();
        let w = WGraph::from_csr(&g);
        let mut rng = Rng::seed_from_u64(5);
        let a = region_grow(&w, 2, 0.2, &mut rng);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&p| p < 2));
    }
}
