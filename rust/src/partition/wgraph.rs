//! Weighted graph used inside the multilevel partitioner: node weights
//! accumulate merged fine nodes, edge weights accumulate merged fine
//! edges (paper §3.2.1 coarsening phase).

use crate::graph::Csr;

/// CSR graph with u64 node and edge weights.
#[derive(Clone, Debug)]
pub struct WGraph {
    pub offsets: Vec<usize>,
    pub targets: Vec<u32>,
    pub eweights: Vec<u64>,
    pub nweights: Vec<u64>,
}

impl WGraph {
    /// Lift an unweighted [`Csr`] (all weights 1).
    pub fn from_csr(g: &Csr) -> WGraph {
        WGraph {
            offsets: g.offsets().to_vec(),
            targets: g.targets().to_vec(),
            eweights: vec![1; g.targets().len()],
            nweights: vec![1; g.num_nodes()],
        }
    }

    /// Build from a weighted (undirected, canonical `u<v`) edge list.
    pub fn from_weighted_edges(
        n: usize,
        edges: &[(u32, u32, u64)],
        nweights: Vec<u64>,
    ) -> WGraph {
        assert_eq!(nweights.len(), n);
        let mut deg = vec![0usize; n];
        for &(u, v, _) in edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0u32; edges.len() * 2];
        let mut eweights = vec![0u64; edges.len() * 2];
        for &(u, v, w) in edges {
            targets[cursor[u as usize]] = v;
            eweights[cursor[u as usize]] = w;
            cursor[u as usize] += 1;
            targets[cursor[v as usize]] = u;
            eweights[cursor[v as usize]] = w;
            cursor[v as usize] += 1;
        }
        WGraph { offsets, targets, eweights, nweights }
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.nweights.len()
    }

    #[inline]
    pub fn neighbors(&self, v: usize) -> (&[u32], &[u64]) {
        let r = self.offsets[v]..self.offsets[v + 1];
        (&self.targets[r.clone()], &self.eweights[r])
    }

    /// Total node weight.
    pub fn total_nweight(&self) -> u64 {
        self.nweights.iter().sum()
    }

    /// Sum of edge weights crossing parts (each undirected edge once).
    pub fn weighted_cut(&self, assignment: &[u32]) -> u64 {
        let mut cut = 0u64;
        for v in 0..self.num_nodes() {
            let (ts, ws) = self.neighbors(v);
            for (&t, &w) in ts.iter().zip(ws) {
                if (v as u32) < t && assignment[v] != assignment[t as usize] {
                    cut += w;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn from_csr_unit_weights() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let w = WGraph::from_csr(&g);
        assert_eq!(w.total_nweight(), 3);
        assert_eq!(w.weighted_cut(&[0, 0, 1]), 1);
        assert_eq!(w.weighted_cut(&[0, 1, 0]), 2);
    }

    #[test]
    fn weighted_edges_roundtrip() {
        let w = WGraph::from_weighted_edges(3, &[(0, 1, 5), (1, 2, 2)], vec![1, 2, 1]);
        let (ts, ws) = w.neighbors(1);
        assert_eq!(ts.len(), 2);
        assert_eq!(ws.iter().sum::<u64>(), 7);
        assert_eq!(w.weighted_cut(&[0, 1, 1]), 5);
    }
}
