//! Partition quality metrics beyond raw edge cut: conductance,
//! modularity and the replication factor — what `gad partition` prints
//! and the comparison yardstick between the multilevel and random
//! partitioners.

use super::Partitioning;
use crate::graph::Csr;

/// Conductance of one part: cut(S) / min(vol(S), vol(V\S)).
pub fn conductance(g: &Csr, assignment: &[u32], part: u32) -> f64 {
    let total_vol = g.num_arcs() as f64;
    let mut vol = 0.0f64;
    let mut cut = 0.0f64;
    for v in 0..g.num_nodes() {
        if assignment[v] != part {
            continue;
        }
        vol += g.degree(v) as f64;
        cut += g
            .neighbors(v)
            .iter()
            .filter(|&&t| assignment[t as usize] != part)
            .count() as f64;
    }
    let denom = vol.min(total_vol - vol);
    if denom == 0.0 {
        0.0
    } else {
        cut / denom
    }
}

/// Mean conductance over parts (lower = better-separated parts).
pub fn avg_conductance(g: &Csr, p: &Partitioning) -> f64 {
    (0..p.k as u32).map(|i| conductance(g, &p.assignment, i)).sum::<f64>() / p.k as f64
}

/// Newman modularity of the partition (higher = more community-like).
pub fn modularity(g: &Csr, assignment: &[u32]) -> f64 {
    let m2 = g.num_arcs() as f64; // 2m
    if m2 == 0.0 {
        return 0.0;
    }
    let k = assignment.iter().copied().max().map(|x| x as usize + 1).unwrap_or(1);
    // per part: internal arc count and total degree
    let mut internal = vec![0.0f64; k];
    let mut degree = vec![0.0f64; k];
    for v in 0..g.num_nodes() {
        let p = assignment[v] as usize;
        degree[p] += g.degree(v) as f64;
        internal[p] += g
            .neighbors(v)
            .iter()
            .filter(|&&t| assignment[t as usize] as usize == p)
            .count() as f64;
    }
    (0..k)
        .map(|p| internal[p] / m2 - (degree[p] / m2) * (degree[p] / m2))
        .sum()
}

/// Replication factor of an augmented partitioning: total stored nodes
/// (base + replicas) over original nodes — 1.0 means no redundancy.
pub fn replication_factor(num_nodes: usize, replicas_total: usize) -> f64 {
    if num_nodes == 0 {
        return 1.0;
    }
    (num_nodes + replicas_total) as f64 / num_nodes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::{partition, random, PartitionConfig};
    use crate::datasets::SyntheticSpec;

    fn two_triangles() -> Csr {
        GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (2, 3)])
            .build()
    }

    #[test]
    fn conductance_of_clean_split_is_low() {
        let g = two_triangles();
        let a = vec![0, 0, 0, 1, 1, 1];
        let c = conductance(&g, &a, 0);
        // one cut edge over volume 7
        assert!((c - 1.0 / 7.0).abs() < 1e-12, "c={c}");
    }

    #[test]
    fn modularity_prefers_communities() {
        let g = two_triangles();
        let good = modularity(&g, &[0, 0, 0, 1, 1, 1]);
        let bad = modularity(&g, &[0, 1, 0, 1, 0, 1]);
        assert!(good > bad, "good {good} bad {bad}");
        assert!(good > 0.3);
    }

    #[test]
    fn multilevel_beats_random_on_modularity() {
        let ds = SyntheticSpec::tiny().generate(6);
        let p = partition(&ds.graph, &PartitionConfig { k: 4, seed: 6, ..Default::default() });
        let r = random::random_partition(ds.graph.num_nodes(), 4, 6);
        assert!(
            modularity(&ds.graph, &p.assignment) > modularity(&ds.graph, &r),
            "multilevel should find more modular parts"
        );
    }

    #[test]
    fn replication_factor_identity() {
        assert_eq!(replication_factor(100, 0), 1.0);
        assert!((replication_factor(100, 10) - 1.1).abs() < 1e-12);
    }
}
