//! Coarsening phase: randomized heavy-edge matching (paper §3.2.1
//! step 1). Visit nodes in random order; match each unmatched node with
//! its unmatched neighbour of maximum edge weight (ties broken
//! uniformly); merge matched pairs, summing node weights and collapsing
//! parallel edges by summing their weights.

use super::wgraph::WGraph;
use crate::rng::Rng;

/// One coarsening level: the fine graph, the coarse graph, and the
/// fine-node -> coarse-node map.
pub struct Level {
    pub fine: WGraph,
    pub coarse: WGraph,
    pub map: Vec<u32>,
}

/// Perform one round of heavy-edge matching + contraction.
pub fn coarsen_once(g: &WGraph, rng: &mut Rng) -> Level {
    let n = g.num_nodes();
    const UNMATCHED: u32 = u32::MAX;
    let mut mate = vec![UNMATCHED; n];

    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    let mut tied: Vec<u32> = Vec::new();
    for &v in &order {
        let v = v as usize;
        if mate[v] != UNMATCHED {
            continue;
        }
        // heaviest unmatched neighbour; random tie-break
        let (ts, ws) = g.neighbors(v);
        let mut best_w = 0u64;
        tied.clear();
        for (&t, &w) in ts.iter().zip(ws) {
            if mate[t as usize] != UNMATCHED || t as usize == v {
                continue;
            }
            if w > best_w {
                best_w = w;
                tied.clear();
                tied.push(t);
            } else if w == best_w && best_w > 0 {
                tied.push(t);
            }
        }
        if let Some(&u) = (!tied.is_empty()).then(|| rng.choose(&tied)) {
            mate[v] = u;
            mate[u as usize] = v as u32;
        } else {
            mate[v] = v as u32; // matched with itself (stays single)
        }
    }

    // assign coarse ids (pair -> one id)
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = next;
        let m = mate[v] as usize;
        if m != v && m < n {
            map[m] = next;
        }
        next += 1;
    }
    let cn = next as usize;

    // coarse node weights
    let mut nweights = vec![0u64; cn];
    for v in 0..n {
        nweights[map[v] as usize] += g.nweights[v];
    }

    // coarse edges: collapse parallel edges by summing weights
    use std::collections::HashMap;
    let mut emap: HashMap<(u32, u32), u64> = HashMap::new();
    for v in 0..n {
        let cv = map[v];
        let (ts, ws) = g.neighbors(v);
        for (&t, &w) in ts.iter().zip(ws) {
            let ct = map[t as usize];
            if cv < ct {
                *emap.entry((cv, ct)).or_insert(0) += w;
            }
        }
    }
    let mut edges: Vec<(u32, u32, u64)> = emap.into_iter().map(|((u, v), w)| (u, v, w)).collect();
    // HashMap iteration order is seeded per-process: sort so the whole
    // pipeline is deterministic for a given PartitionConfig::seed
    edges.sort_unstable();
    let coarse = WGraph::from_weighted_edges(cn, &edges, nweights);

    Level { fine: g.clone(), coarse, map }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn coarsen_preserves_total_node_weight() {
        let g = GraphBuilder::new(8)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 0)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut rng = Rng::seed_from_u64(1);
        let lvl = coarsen_once(&w, &mut rng);
        assert_eq!(lvl.coarse.total_nweight(), 8);
        assert!(lvl.coarse.num_nodes() <= 8);
        assert!(lvl.coarse.num_nodes() >= 4); // perfect matching halves
    }

    #[test]
    fn map_is_total_and_in_range() {
        let g = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (3, 4), (4, 5), (2, 3)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut rng = Rng::seed_from_u64(2);
        let lvl = coarsen_once(&w, &mut rng);
        let cn = lvl.coarse.num_nodes() as u32;
        assert!(lvl.map.iter().all(|&c| c < cn));
        // every coarse id hit
        let mut seen = vec![false; cn as usize];
        for &c in &lvl.map {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cut_preserved_under_projection() {
        // a cut measured on the coarse graph equals the fine cut of the
        // projected assignment
        let g = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut rng = Rng::seed_from_u64(3);
        let lvl = coarsen_once(&w, &mut rng);
        let cn = lvl.coarse.num_nodes();
        let coarse_assign: Vec<u32> = (0..cn).map(|c| (c % 2) as u32).collect();
        let fine_assign: Vec<u32> =
            lvl.map.iter().map(|&c| coarse_assign[c as usize]).collect();
        assert_eq!(lvl.coarse.weighted_cut(&coarse_assign), lvl.fine.weighted_cut(&fine_assign));
    }
}
