//! Multilevel k-way graph partitioner (paper §3.2.1).
//!
//! Implements the three phases the paper describes (its re-statement of
//! METIS): **coarsening** by heavy-edge matching, a multi-restart
//! **initial partition** by seeded region growing that keeps the
//! minimum-edge-cut candidate, and **uncoarsening** with greedy
//! boundary (FM-style) refinement at every level.
//!
//! Objective: `min (|E| - Σ|E_i|)` (Eq. 1) subject to the balance
//! constraint `|V_i| <= (1+ε) ceil(|V|/k)` (Eq. 2).

mod coarsen;
mod initial;
mod refine;
mod wgraph;

pub mod quality;
pub mod random;

pub use quality::{avg_conductance, modularity, replication_factor};
pub use wgraph::WGraph;

use crate::graph::Csr;
use crate::rng::Rng;

/// Tunables for [`partition`].
#[derive(Clone, Debug)]
pub struct PartitionConfig {
    /// Number of parts `k`.
    pub k: usize,
    /// Imbalance tolerance ε of Eq. 2.
    pub epsilon: f64,
    /// Restarts of the initial-partition phase (paper: "run the above
    /// procedure for many times ... take the result with the minimum
    /// edge cut").
    pub restarts: usize,
    /// Coarsening stops once the graph has at most
    /// `max(coarsen_ratio * n, min_coarse_nodes)` nodes.
    pub coarsen_ratio: f64,
    /// Floor for the coarsest graph (also never below `4 * k`).
    pub min_coarse_nodes: usize,
    /// Refinement passes per uncoarsening level.
    pub refine_passes: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig {
            k: 4,
            epsilon: 0.1,
            restarts: 8,
            coarsen_ratio: 0.2, // paper: "e.g., 20% number of nodes"
            min_coarse_nodes: 64,
            refine_passes: 4,
            seed: 0,
        }
    }
}

/// Result of a partition run.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// Part id per node.
    pub assignment: Vec<u32>,
    /// Number of parts.
    pub k: usize,
    /// Edges crossing parts: `|E| - Σ|E_i|` (Eq. 1).
    pub edge_cut: usize,
    /// `max_i |V_i| / ceil(|V|/k)` — must be `<= 1+ε` on success.
    pub balance: f64,
}

impl Partitioning {
    /// Node lists per part.
    pub fn part_nodes(&self) -> Vec<Vec<u32>> {
        let mut parts = vec![Vec::new(); self.k];
        for (v, &p) in self.assignment.iter().enumerate() {
            parts[p as usize].push(v as u32);
        }
        parts
    }

    /// Sizes per part.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.assignment {
            sizes[p as usize] += 1;
        }
        sizes
    }
}

/// Count edges of `g` whose endpoints live in different parts.
pub fn edge_cut(g: &Csr, assignment: &[u32]) -> usize {
    g.edges()
        .filter(|&(u, v)| assignment[u as usize] != assignment[v as usize])
        .count()
}

/// Balance ratio `max_i |V_i| / ceil(n/k)`.
pub fn balance_ratio(assignment: &[u32], k: usize) -> f64 {
    let n = assignment.len();
    let mut sizes = vec![0usize; k];
    for &p in assignment {
        sizes[p as usize] += 1;
    }
    let cap = n.div_ceil(k).max(1);
    *sizes.iter().max().unwrap_or(&0) as f64 / cap as f64
}

/// Multilevel k-way partition of `g`.
pub fn partition(g: &Csr, cfg: &PartitionConfig) -> Partitioning {
    assert!(cfg.k >= 1, "k must be >= 1");
    let n = g.num_nodes();
    if cfg.k == 1 || n <= cfg.k {
        // trivial cases: everything in one part / one node per part
        let assignment: Vec<u32> = if cfg.k == 1 {
            vec![0; n]
        } else {
            (0..n).map(|v| (v % cfg.k) as u32).collect()
        };
        let cut = edge_cut(g, &assignment);
        return Partitioning {
            k: cfg.k,
            balance: balance_ratio(&assignment, cfg.k),
            edge_cut: cut,
            assignment,
        };
    }

    let mut rng = Rng::seed_from_u64(cfg.seed);

    // --- coarsening phase -------------------------------------------------
    let base = WGraph::from_csr(g);
    let stop_at = ((n as f64 * cfg.coarsen_ratio) as usize)
        .max(cfg.min_coarse_nodes)
        .max(4 * cfg.k);
    let mut levels: Vec<coarsen::Level> = Vec::new();
    let mut current = base;
    while current.num_nodes() > stop_at {
        let level = coarsen::coarsen_once(&current, &mut rng);
        // no progress -> matching saturated (e.g. star graphs); stop
        if level.coarse.num_nodes() as f64 > 0.97 * current.num_nodes() as f64 {
            break;
        }
        let coarse = level.coarse.clone();
        levels.push(coarsen::Level { fine: current, ..level });
        current = coarse;
    }

    // --- initial partition phase (multi-restart, keep min cut) ------------
    let mut best: Option<Vec<u32>> = None;
    let mut best_cut = u64::MAX;
    for _ in 0..cfg.restarts.max(1) {
        let cand = initial::region_grow(&current, cfg.k, cfg.epsilon, &mut rng);
        let cut = current.weighted_cut(&cand);
        if cut < best_cut {
            best_cut = cut;
            best = Some(cand);
        }
    }
    let mut assignment = best.expect("at least one restart");
    refine::refine(&current, &mut assignment, cfg.k, cfg.epsilon, cfg.refine_passes);

    // --- uncoarsening phase ------------------------------------------------
    for level in levels.iter().rev() {
        // project coarse assignment onto the finer graph
        let mut fine_assignment = vec![0u32; level.fine.num_nodes()];
        for (v, &c) in level.map.iter().enumerate() {
            fine_assignment[v] = assignment[c as usize];
        }
        refine::refine(&level.fine, &mut fine_assignment, cfg.k, cfg.epsilon, cfg.refine_passes);
        assignment = fine_assignment;
    }

    // Eq. 2 is a hard constraint: force balance at the finest level,
    // then give refinement one more pass to recover any cut damage.
    let base_fine = WGraph::from_csr(g);
    refine::rebalance(&base_fine, &mut assignment, cfg.k, cfg.epsilon);
    refine::refine(&base_fine, &mut assignment, cfg.k, cfg.epsilon, 1);
    refine::rebalance(&base_fine, &mut assignment, cfg.k, cfg.epsilon);

    let cut = edge_cut(g, &assignment);
    Partitioning {
        k: cfg.k,
        balance: balance_ratio(&assignment, cfg.k),
        edge_cut: cut,
        assignment,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticSpec;
    use crate::graph::GraphBuilder;

    fn two_cliques_bridge() -> Csr {
        // two K5s joined by one edge: the optimal 2-cut is 1
        let mut b = GraphBuilder::new(10);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                b.edge(u, v);
                b.edge(u + 5, v + 5);
            }
        }
        b.edge(0, 5);
        b.build()
    }

    #[test]
    fn two_cliques_find_the_bridge() {
        let g = two_cliques_bridge();
        let p = partition(&g, &PartitionConfig { k: 2, restarts: 16, seed: 1, ..Default::default() });
        assert_eq!(p.edge_cut, 1, "should cut exactly the bridge");
        assert!(p.balance <= 1.1 + 1e-9);
    }

    #[test]
    fn assignment_is_total_and_in_range() {
        let g = SyntheticSpec::tiny().generate(3).graph;
        for k in [2, 3, 5] {
            let p = partition(&g, &PartitionConfig { k, seed: 7, ..Default::default() });
            assert_eq!(p.assignment.len(), g.num_nodes());
            assert!(p.assignment.iter().all(|&a| (a as usize) < k));
            // every part non-empty
            assert!(p.part_sizes().iter().all(|&s| s > 0), "empty part for k={k}");
        }
    }

    #[test]
    fn beats_random_partition_on_clustered_graph() {
        let ds = SyntheticSpec::tiny().generate(5);
        let cfg = PartitionConfig { k: 4, seed: 9, ..Default::default() };
        let ml = partition(&ds.graph, &cfg);
        let rnd = random::random_partition(ds.graph.num_nodes(), 4, 9);
        let rnd_cut = edge_cut(&ds.graph, &rnd);
        assert!(
            ml.edge_cut < rnd_cut,
            "multilevel ({}) should beat random ({})",
            ml.edge_cut,
            rnd_cut
        );
    }

    #[test]
    fn k_one_is_trivial() {
        let g = two_cliques_bridge();
        let p = partition(&g, &PartitionConfig { k: 1, ..Default::default() });
        assert_eq!(p.edge_cut, 0);
        assert!(p.assignment.iter().all(|&a| a == 0));
    }

    #[test]
    fn respects_balance_constraint() {
        let ds = SyntheticSpec::tiny().generate(11);
        let cfg = PartitionConfig { k: 3, epsilon: 0.1, seed: 2, ..Default::default() };
        let p = partition(&ds.graph, &cfg);
        // allow a little slack beyond epsilon for the leftover-node pass
        assert!(p.balance <= 1.0 + cfg.epsilon + 0.15, "balance {}", p.balance);
    }
}
