//! Random (hash) partitioner — the DistDGL-style baseline and the
//! control arm for partition-quality comparisons.

use crate::rng::Rng;

/// Uniform random balanced partition: a shuffled round-robin, so part
/// sizes differ by at most one.
pub fn random_partition(n: usize, k: usize, seed: u64) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut order);
    let mut assignment = vec![0u32; n];
    for (i, &v) in order.iter().enumerate() {
        assignment[v as usize] = (i % k) as u32;
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_to_within_one() {
        let a = random_partition(103, 4, 7);
        let mut sizes = [0usize; 4];
        for &p in &a {
            sizes[p as usize] += 1;
        }
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(random_partition(50, 3, 1), random_partition(50, 3, 1));
        assert_ne!(random_partition(50, 3, 1), random_partition(50, 3, 2));
    }
}
