//! Uncoarsening refinement: greedy boundary moves (FM-style gain,
//! paper §3.2.1 step 3). Each pass scans boundary nodes and moves a
//! node to the neighbouring part with the largest positive cut gain,
//! respecting the Eq. 2 balance constraint.

use super::wgraph::WGraph;

/// In-place refinement of `assignment`; `passes` full sweeps or until a
/// sweep makes no move.
pub fn refine(g: &WGraph, assignment: &mut [u32], k: usize, epsilon: f64, passes: usize) {
    let n = g.num_nodes();
    let total_w = g.total_nweight();
    let cap = ((1.0 + epsilon) * (total_w as f64 / k as f64).ceil()).ceil() as u64;

    let mut part_weight = vec![0u64; k];
    for v in 0..n {
        part_weight[assignment[v] as usize] += g.nweights[v];
    }

    // connectivity weight of v to each part (scratch, reset per node)
    let mut conn = vec![0u64; k];
    let mut touched: Vec<u32> = Vec::with_capacity(16);

    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n {
            let home = assignment[v] as usize;
            let (ts, ws) = g.neighbors(v);
            // skip interior nodes fast
            if ts.iter().all(|&t| assignment[t as usize] as usize == home) {
                continue;
            }
            touched.clear();
            for (&t, &w) in ts.iter().zip(ws) {
                let p = assignment[t as usize];
                if conn[p as usize] == 0 {
                    touched.push(p);
                }
                conn[p as usize] += w;
            }
            let home_conn = conn[home];
            let mut best_part = home;
            let mut best_gain = 0i64;
            for &p in &touched {
                let p = p as usize;
                if p == home {
                    continue;
                }
                let gain = conn[p] as i64 - home_conn as i64;
                let fits = part_weight[p] + g.nweights[v] <= cap;
                // don't empty a part entirely
                let keeps_home = part_weight[home] > g.nweights[v];
                if gain > best_gain && fits && keeps_home {
                    best_gain = gain;
                    best_part = p;
                }
            }
            for &p in &touched {
                conn[p as usize] = 0;
            }
            if best_part != home {
                assignment[v] = best_part as u32;
                part_weight[home] -= g.nweights[v];
                part_weight[best_part] += g.nweights[v];
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Force the Eq. 2 balance constraint: while a part exceeds the
/// capacity, evict its least-connected boundary node to the lightest
/// part (cut may grow; balance is a hard constraint, cut is the
/// objective). Runs after the final refinement level.
pub fn rebalance(g: &WGraph, assignment: &mut [u32], k: usize, epsilon: f64) {
    let n = g.num_nodes();
    let total_w = g.total_nweight();
    let cap = ((1.0 + epsilon) * (total_w as f64 / k as f64).ceil()).ceil() as u64;
    let mut part_weight = vec![0u64; k];
    for v in 0..n {
        part_weight[assignment[v] as usize] += g.nweights[v];
    }
    // bounded loop: each iteration moves one node out of an over-cap part
    for _ in 0..n {
        let Some(over) = (0..k).find(|&p| part_weight[p] > cap) else {
            return;
        };
        // candidate: node of `over` with the smallest internal edge weight
        let mut best: Option<(u64, usize)> = None;
        for v in 0..n {
            if assignment[v] as usize != over {
                continue;
            }
            let (ts, ws) = g.neighbors(v);
            let internal: u64 = ts
                .iter()
                .zip(ws)
                .filter(|(&t, _)| assignment[t as usize] as usize == over)
                .map(|(_, &w)| w)
                .sum();
            if best.map_or(true, |(bi, _)| internal < bi) {
                best = Some((internal, v));
            }
        }
        let Some((_, v)) = best else { return };
        let dest = (0..k).filter(|&p| p != over).min_by_key(|&p| part_weight[p]).unwrap();
        part_weight[over] -= g.nweights[v];
        part_weight[dest] += g.nweights[v];
        assignment[v] = dest as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn rebalance_enforces_capacity() {
        // path of 8, everything dumped in part 0
        let g = GraphBuilder::new(8)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut a = vec![0u32; 8];
        rebalance(&w, &mut a, 2, 0.1);
        let c1 = a.iter().filter(|&&p| p == 0).count();
        let cap = ((1.1f64) * 4.0).ceil() as usize;
        assert!(c1 <= cap, "part 0 still has {c1} > cap {cap}");
    }

    #[test]
    fn refine_fixes_obviously_bad_assignment() {
        // two triangles joined by one edge; node 2 starts on the wrong
        // side (cut=2), greedy gain moves it home (cut=1). Note greedy
        // FM is not global: a fully interleaved start can be a local
        // optimum — the multilevel pipeline avoids those via coarsening.
        let g = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (2, 3)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut a = vec![0, 0, 1, 1, 1, 1];
        refine(&w, &mut a, 2, 0.4, 8);
        assert_eq!(w.weighted_cut(&a), 1, "assignment {a:?}");
        assert_eq!(a, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn refine_never_violates_capacity_much() {
        let g = GraphBuilder::new(8)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7)])
            .build();
        let w = WGraph::from_csr(&g);
        let mut a = vec![0, 0, 0, 0, 1, 1, 1, 1];
        refine(&w, &mut a, 2, 0.1, 4);
        let mut sizes = [0u64; 2];
        for (v, &p) in a.iter().enumerate() {
            sizes[p as usize] += w.nweights[v];
        }
        let cap = ((1.1f64) * 4.0).ceil() as u64;
        assert!(sizes.iter().all(|&s| s <= cap));
    }

    #[test]
    fn refine_no_moves_on_optimal() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (2, 3)]).build();
        let w = WGraph::from_csr(&g);
        let mut a = vec![0, 0, 1, 1];
        let before = a.clone();
        refine(&w, &mut a, 2, 0.1, 4);
        assert_eq!(a, before);
    }
}
