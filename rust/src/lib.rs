//! # GAD — Graph-Augmentation-based Distributed GCN training
//!
//! Reproduction of *"Distributed Optimization of Graph Convolutional
//! Network using Subgraph Variance"* (Zhao et al., 2021) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the distributed coordinator: graph store,
//!   multilevel partitioner, Monte-Carlo subgraph augmentation,
//!   variance-weighted global consensus, worker/leader training loop,
//!   communication accounting, and the six baselines of the paper's
//!   evaluation.
//! * **L2** — the GCN forward/backward as a JAX program
//!   (`python/compile/model.py`), AOT-lowered to HLO text once at build
//!   time (`make artifacts`).
//! * **L1** — the fused GCN-layer Pallas kernel
//!   (`python/compile/kernels/`), called from L2 so it lowers into the
//!   same HLO module.
//!
//! Python never runs on the training path: [`runtime`] loads the AOT
//! artifacts through the PJRT C API (`xla` crate) and [`backend::XlaBackend`]
//! executes them from the rust hot loop. [`backend::NativeBackend`] is a
//! pure-rust oracle/fallback for shapes with no compiled bucket.
//!
//! Beyond training, [`serve`] turns a checkpoint into a partition-aware
//! inference tier: halo-complete shards answer node-classification
//! queries shard-locally through a versioned embedding cache with
//! L-hop delta invalidation and per-shard micro-batching. The served
//! graph is a **versioned delta-friendly core**
//! ([`graph::DeltaCsr`] behind the [`graph::GraphView`] trait):
//! online edge churn and elastic node insertion/removal splice through
//! a per-node overlay in O(Δ) with batched compaction — no O(E)
//! rebuild, no offline reshard. [`loadgen`] closes the loop on the
//! serving story: a deterministic open-loop workload generator drives
//! the server through a virtual-time event loop (Poisson arrivals,
//! Zipfian popularity, interleaved churn) under pluggable schedulers,
//! measuring the goodput knee that closed-loop benches cannot see.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gad::prelude::*;
//!
//! let dataset = SyntheticSpec::cora_like().generate(42);
//! let cfg = TrainConfig {
//!     partitions: 8,
//!     workers: 4,
//!     layers: 2,
//!     hidden: 64,
//!     epochs: 30,
//!     ..TrainConfig::default()
//! };
//! let report = gad::coordinator::train_gad(&dataset, &cfg).unwrap();
//! println!("test accuracy = {:.4}", report.test_accuracy);
//! ```

pub mod augment;
pub mod backend;
pub mod baselines;
pub mod bench_util;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod datasets;
pub mod graph;
pub mod loadgen;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod partition;
pub mod proptest_util;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod threads;
pub mod variance;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::augment::{AugmentConfig, AugmentedSubgraph};
    pub use crate::backend::{Backend, BackendKind, NativeBackend};
    pub use crate::baselines::Method;
    pub use crate::coordinator::{AsyncConfig, ConsensusMode, TrainConfig, TrainReport};
    pub use crate::datasets::{Dataset, SyntheticSpec};
    pub use crate::graph::{Csr, DeltaCsr, GraphView, Subgraph};
    pub use crate::loadgen::{
        FifoScheduler, Scheduler, SloBatchScheduler, WorkloadConfig,
    };
    pub use crate::model::GcnParams;
    pub use crate::obs::{LogHistogram, MetricsRegistry, ProfileReport};
    pub use crate::partition::{PartitionConfig, Partitioning};
    pub use crate::rng::Rng;
    pub use crate::serve::{DeltaMode, GraphDelta, HaloPolicy, NewNode, ServeConfig, Server};
    pub use crate::tensor::Matrix;
}
