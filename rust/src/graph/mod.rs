//! Graph substrate: CSR storage, builders, subgraph extraction,
//! boundary / candidate-replication sets (paper Def. 2), and the
//! degree/density statistics the augmentation budget uses (Def. 3).

mod boundary;
mod builder;
mod csr;
mod stats;
mod subgraph;

pub use boundary::{bounded_bfs_distances, boundary_nodes, candidate_replication_nodes};
pub use builder::GraphBuilder;
pub use csr::Csr;
pub use stats::{avg_degree, degree_histogram, density};
pub use subgraph::Subgraph;
