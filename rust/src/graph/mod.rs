//! Graph substrate: CSR storage, builders, subgraph extraction,
//! boundary / candidate-replication sets (paper Def. 2), and the
//! degree/density statistics the augmentation budget uses (Def. 3).
//!
//! Two adjacency representations sit behind one read surface
//! ([`GraphView`]): the flat [`Csr`] snapshot (training, builds) and
//! the versioned [`DeltaCsr`] overlay (serving under churn — O(Δ)
//! edge/node mutations with batched compaction). Every algorithm in
//! this module is generic over the trait, so BFS, induction and
//! statistics run on either without flattening.

mod boundary;
mod builder;
mod csr;
mod delta_csr;
mod stats;
mod subgraph;
mod view;

pub use boundary::{
    bounded_bfs_distances, bounded_bfs_distances_sparse, boundary_nodes,
    candidate_replication_from_boundary, candidate_replication_nodes,
};
pub use builder::GraphBuilder;
pub use csr::Csr;
pub use delta_csr::DeltaCsr;
pub use stats::{avg_degree, degree_histogram, density};
pub use subgraph::Subgraph;
pub use view::GraphView;
