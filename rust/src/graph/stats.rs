//! Degree / density statistics (paper Definition 3).

use super::GraphView;

/// Graph density `2|E| / (|V| (|V|-1))` — Definition 3. Zero for
/// graphs with fewer than two nodes.
pub fn density<G: GraphView>(g: &G) -> f64 {
    let n = g.num_nodes();
    if n < 2 {
        return 0.0;
    }
    2.0 * g.num_edges() as f64 / (n as f64 * (n - 1) as f64)
}

/// Mean degree over a node subset (used for Algorithm 1's pilot
/// walk count `d * |B(g)|`).
pub fn avg_degree<G: GraphView>(g: &G, nodes: &[u32]) -> f64 {
    if nodes.is_empty() {
        return 0.0;
    }
    nodes.iter().map(|&v| g.degree(v as usize) as f64).sum::<f64>() / nodes.len() as f64
}

/// Histogram of degrees (index = degree).
pub fn degree_histogram<G: GraphView>(g: &G) -> Vec<usize> {
    let max_deg = (0..g.num_nodes()).map(|v| g.degree(v)).max().unwrap_or(0);
    let mut h = vec![0usize; max_deg + 1];
    for v in 0..g.num_nodes() {
        h[g.degree(v)] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn complete_graph_density_one() {
        let g = GraphBuilder::new(4)
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
            .build();
        assert!((density(&g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn path_density() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        assert!((density(&g) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn avg_degree_subset() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        assert_eq!(avg_degree(&g, &[0, 3]), 1.0);
        assert_eq!(avg_degree(&g, &[1, 2]), 2.0);
    }

    #[test]
    fn degree_histogram_counts() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        assert_eq!(degree_histogram(&g), vec![0, 2, 2]);
    }
}
