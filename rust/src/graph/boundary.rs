//! Boundary nodes and candidate replication nodes (paper Definition 2).
//!
//! Given a partition assignment, the boundary of part `i` is the set of
//! its nodes with at least one edge leaving the part; the *candidate
//! replication nodes* `C(g_i)` are the x-hop neighbourhood (x = number
//! of GCN layers) of those boundary nodes, restricted to nodes outside
//! the part — exactly the remote nodes a distributed GCN would have to
//! fetch during training.

use super::GraphView;
use std::collections::HashMap;

/// Nodes of part `part` that have at least one cross-part edge.
pub fn boundary_nodes<G: GraphView>(graph: &G, assignment: &[u32], part: u32) -> Vec<u32> {
    let mut out = Vec::new();
    for v in 0..graph.num_nodes() {
        if assignment[v] != part {
            continue;
        }
        if graph
            .neighbors(v)
            .iter()
            .any(|&t| assignment[t as usize] != part)
        {
            out.push(v as u32);
        }
    }
    out
}

/// Bounded multi-source BFS: hop distance (≤ `max_hops`) from the
/// nearest seed, `u32::MAX` beyond. Shared by candidate-replication
/// discovery and the serving tier's delta-invalidation footprint.
pub fn bounded_bfs_distances<G: GraphView>(graph: &G, seeds: &[u32], max_hops: usize) -> Vec<u32> {
    let n = graph.num_nodes();
    let mut dist = vec![u32::MAX; n];
    let mut frontier: Vec<u32> = Vec::new();
    for &s in seeds {
        if dist[s as usize] == u32::MAX {
            dist[s as usize] = 0;
            frontier.push(s);
        }
    }
    for d in 1..=max_hops as u32 {
        let mut next = Vec::new();
        for &v in &frontier {
            for &t in graph.neighbors(v as usize) {
                if dist[t as usize] == u32::MAX {
                    dist[t as usize] = d;
                    next.push(t);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    dist
}

/// Sparse bounded multi-source BFS: hop distance (≤ `max_hops`) from
/// the nearest seed for every *reached* node only. Memory and time are
/// proportional to the visited region, not the graph — the form the
/// serving tier's delta path uses so a small delta never allocates
/// O(V) state. Unreached nodes are simply absent.
pub fn bounded_bfs_distances_sparse<G: GraphView>(
    graph: &G,
    seeds: &[u32],
    max_hops: usize,
) -> HashMap<u32, u32> {
    let mut dist: HashMap<u32, u32> = HashMap::new();
    let mut frontier: Vec<u32> = Vec::new();
    for &s in seeds {
        if !dist.contains_key(&s) {
            dist.insert(s, 0);
            frontier.push(s);
        }
    }
    for d in 1..=max_hops as u32 {
        let mut next = Vec::new();
        for &v in &frontier {
            for &t in graph.neighbors(v as usize) {
                if !dist.contains_key(&t) {
                    dist.insert(t, d);
                    next.push(t);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    dist
}

/// `C(g_part)`: all nodes outside `part` reachable within `hops` edges
/// from the part's boundary nodes (paths may pass through any node).
/// Returned sorted.
pub fn candidate_replication_nodes<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    part: u32,
    hops: usize,
) -> Vec<u32> {
    let seeds = boundary_nodes(graph, assignment, part);
    candidate_replication_from_boundary(graph, assignment, &seeds, part, hops)
}

/// [`candidate_replication_nodes`] with a caller-supplied boundary set —
/// the serving tier maintains per-shard boundaries incrementally under
/// churn, so halo recomputation after a [`GraphDelta`] needs no
/// full-part rescan, only the bounded BFS from the (updated) boundary.
///
/// [`GraphDelta`]: crate::serve::GraphDelta
pub fn candidate_replication_from_boundary<G: GraphView>(
    graph: &G,
    assignment: &[u32],
    boundary: &[u32],
    part: u32,
    hops: usize,
) -> Vec<u32> {
    let dist = bounded_bfs_distances_sparse(graph, boundary, hops);
    let mut out: Vec<u32> =
        dist.into_keys().filter(|&v| assignment[v as usize] != part).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// path graph 0-1-2-3-4-5, parts [0,0,0,1,1,1]
    fn path6() -> (Csr, Vec<u32>) {
        let g = GraphBuilder::new(6)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
            .build();
        (g, vec![0, 0, 0, 1, 1, 1])
    }

    #[test]
    fn boundary_is_cut_endpoints() {
        let (g, a) = path6();
        assert_eq!(boundary_nodes(&g, &a, 0), vec![2]);
        assert_eq!(boundary_nodes(&g, &a, 1), vec![3]);
    }

    #[test]
    fn candidates_respect_hops() {
        let (g, a) = path6();
        assert_eq!(candidate_replication_nodes(&g, &a, 0, 1), vec![3]);
        assert_eq!(candidate_replication_nodes(&g, &a, 0, 2), vec![3, 4]);
        assert_eq!(candidate_replication_nodes(&g, &a, 0, 10), vec![3, 4, 5]);
    }

    #[test]
    fn no_candidates_when_isolated_part() {
        let g = GraphBuilder::new(4).edges(&[(0, 1), (2, 3)]).build();
        let a = vec![0, 0, 1, 1];
        assert!(boundary_nodes(&g, &a, 0).is_empty());
        assert!(candidate_replication_nodes(&g, &a, 0, 3).is_empty());
    }

    #[test]
    fn sparse_bfs_matches_dense() {
        let (g, _) = path6();
        let dense = bounded_bfs_distances(&g, &[0, 3], 2);
        let sparse = bounded_bfs_distances_sparse(&g, &[0, 3], 2);
        for (v, &d) in dense.iter().enumerate() {
            assert_eq!(
                sparse.get(&(v as u32)).copied().unwrap_or(u32::MAX),
                d,
                "node {v}"
            );
        }
        assert_eq!(sparse.len(), dense.iter().filter(|&&d| d != u32::MAX).count());
    }

    #[test]
    fn bounded_bfs_distances_respect_bound() {
        let (g, _) = path6();
        let dist = bounded_bfs_distances(&g, &[0], 2);
        assert_eq!(&dist[..4], &[0, 1, 2, u32::MAX]);
        // duplicate seeds are harmless; multi-source takes the min
        let dist = bounded_bfs_distances(&g, &[0, 0, 3], 1);
        assert_eq!(dist, vec![0, 1, 1, 0, 1, u32::MAX]);
    }
}
