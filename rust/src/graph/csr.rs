//! Compressed-sparse-row undirected graph.

use super::GraphView;

/// An undirected graph in CSR form. Every edge `{u,v}` is stored in both
/// adjacency lists; `num_edges()` reports undirected edge count.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    offsets: Vec<usize>,
    targets: Vec<u32>,
}

impl Csr {
    /// Build directly from CSR arrays (must be a valid symmetric CSR).
    pub fn from_raw(offsets: Vec<usize>, targets: Vec<u32>) -> Self {
        assert!(!offsets.is_empty());
        assert_eq!(*offsets.last().unwrap(), targets.len());
        Csr { offsets, targets }
    }

    /// Empty graph with `n` isolated nodes.
    pub fn empty(n: usize) -> Self {
        Csr { offsets: vec![0; n + 1], targets: Vec::new() }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *undirected* edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Number of stored directed arcs (2x undirected edges).
    #[inline]
    pub fn num_arcs(&self) -> usize {
        self.targets.len()
    }

    /// Neighbours of `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.targets[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Raw offsets array (`num_nodes()+1` entries).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// Raw targets array.
    #[inline]
    pub fn targets(&self) -> &[u32] {
        &self.targets
    }

    /// True if `{u,v}` is an edge (binary search; lists are sorted).
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Iterate undirected edges as `(u, v)` with `u < v`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.num_nodes()).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .filter(move |&&v| (u as u32) < v)
                .map(move |&v| (u as u32, v))
        })
    }

    /// Bytes held by the adjacency structure (memory accounting).
    pub fn nbytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.targets.len() * std::mem::size_of::<u32>()
    }

    /// Validate structural invariants (tests / debug builds).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        for v in 0..n {
            if self.offsets[v] > self.offsets[v + 1] {
                return Err(format!("offsets not monotone at {v}"));
            }
            let mut prev: Option<u32> = None;
            for &t in self.neighbors(v) {
                if t as usize >= n {
                    return Err(format!("target {t} out of range at node {v}"));
                }
                if t as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if let Some(p) = prev {
                    if t <= p {
                        return Err(format!("neighbors of {v} not strictly sorted"));
                    }
                }
                prev = Some(t);
            }
        }
        // symmetry
        for v in 0..n {
            for &t in self.neighbors(v) {
                if !self.has_edge(t as usize, v) {
                    return Err(format!("asymmetric edge {v}->{t}"));
                }
            }
        }
        Ok(())
    }
}

/// The flat snapshot trivially implements the shared read surface
/// (delegating to the inherent methods, which stay the fast path).
impl GraphView for Csr {
    fn num_nodes(&self) -> usize {
        Csr::num_nodes(self)
    }

    fn degree(&self, v: usize) -> usize {
        Csr::degree(self, v)
    }

    fn neighbors(&self, v: usize) -> &[u32] {
        Csr::neighbors(self, v)
    }

    fn num_edges(&self) -> usize {
        Csr::num_edges(self)
    }

    fn has_edge(&self, u: usize, v: usize) -> bool {
        Csr::has_edge(self, u, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn triangle_plus_tail() -> Csr {
        // 0-1, 1-2, 2-0, 2-3
        GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 0), (2, 3)]).build()
    }

    #[test]
    fn counts() {
        let g = triangle_plus_tail();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.num_arcs(), 8);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn has_edge_symmetric() {
        let g = triangle_plus_tail();
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn edge_iterator_each_once() {
        let g = triangle_plus_tail();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 4);
        assert!(es.contains(&(0, 1)) && es.contains(&(2, 3)));
    }

    #[test]
    fn validate_ok() {
        assert!(triangle_plus_tail().validate().is_ok());
    }
}
