//! Edge-list -> CSR builder with dedup, self-loop removal and
//! symmetrization.

use super::Csr;

/// Accumulates an edge list and finalises it into a canonical [`Csr`].
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    /// Builder for a graph on `n` nodes.
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Add one undirected edge (either orientation; self-loops dropped
    /// at build time).
    pub fn edge(&mut self, u: u32, v: u32) -> &mut Self {
        self.edges.push((u, v));
        self
    }

    /// Add many edges (chainable, consuming style used in tests).
    pub fn edges(mut self, es: &[(u32, u32)]) -> Self {
        self.edges.extend_from_slice(es);
        self
    }

    /// Number of raw (pre-dedup) edges added so far.
    pub fn raw_len(&self) -> usize {
        self.edges.len()
    }

    /// Finalise: dedup, drop self loops, symmetrize, sort adjacency.
    pub fn build(self) -> Csr {
        let n = self.n;
        // canonical orientation + dedup
        let mut canon: Vec<(u32, u32)> = self
            .edges
            .into_iter()
            .filter(|&(u, v)| u != v)
            .map(|(u, v)| if u < v { (u, v) } else { (v, u) })
            .collect();
        for &(u, v) in &canon {
            assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range");
        }
        canon.sort_unstable();
        canon.dedup();

        // counting sort into CSR, both directions
        let mut deg = vec![0usize; n];
        for &(u, v) in &canon {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0u32; canon.len() * 2];
        for &(u, v) in &canon {
            targets[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            targets[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // adjacency lists sorted (canon is sorted by (u,v) so the u-side
        // is already in order, but the v-side is not — sort each list)
        for v in 0..n {
            targets[offsets[v]..offsets[v + 1]].sort_unstable();
        }
        Csr::from_raw(offsets, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_self_loops() {
        let g = GraphBuilder::new(3)
            .edges(&[(0, 1), (1, 0), (0, 1), (2, 2), (1, 2)])
            .build();
        assert_eq!(g.num_edges(), 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn isolated_nodes_allowed() {
        let g = GraphBuilder::new(5).edges(&[(0, 1)]).build();
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.degree(4), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    #[should_panic]
    fn out_of_range_edge_panics() {
        GraphBuilder::new(2).edges(&[(0, 5)]).build();
    }
}
