//! Read-only adjacency access shared by flat and overlay graphs.

/// The read surface every graph-consuming algorithm in this crate
/// (BFS, subgraph induction, statistics, augmentation walks, serving)
/// actually needs. Implemented by the flat [`Csr`](super::Csr)
/// snapshot and by the versioned [`DeltaCsr`](super::DeltaCsr)
/// overlay, so the same call sites run on either representation —
/// the key to applying [`GraphDelta`](crate::serve::GraphDelta)s
/// without rebuilding a flat CSR first.
pub trait GraphView {
    /// Number of nodes.
    fn num_nodes(&self) -> usize;

    /// Degree of `v`.
    fn degree(&self, v: usize) -> usize;

    /// Neighbours of `v`, strictly sorted ascending.
    fn neighbors(&self, v: usize) -> &[u32];

    /// Number of *undirected* edges.
    fn num_edges(&self) -> usize;

    /// True if `{u,v}` is an edge (binary search; lists are sorted).
    fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DeltaCsr, GraphBuilder};

    /// The same algorithm must run on both representations.
    fn sum_two_hop<G: GraphView>(g: &G, v: usize) -> usize {
        g.neighbors(v).iter().map(|&t| g.degree(t as usize)).sum()
    }

    #[test]
    fn trait_object_agnostic_algorithms() {
        let flat = GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (2, 3)]).build();
        let overlay = DeltaCsr::new(flat.clone());
        assert_eq!(sum_two_hop(&flat, 1), sum_two_hop(&overlay, 1));
        assert!(flat.has_edge(1, 2) && GraphView::has_edge(&overlay, 1, 2));
    }
}
