//! Versioned delta-friendly adjacency: a flat [`Csr`] base plus
//! per-node overlay rows, with batched compaction.
//!
//! The serving tier mutates the graph continuously (edge churn, online
//! node insertion/removal). Rebuilding a flat CSR per
//! [`GraphDelta`](crate::serve::GraphDelta) costs O(E); `DeltaCsr`
//! instead keeps the last compacted snapshot as the *base* and stores a
//! full merged neighbour row only for nodes that have diverged — so a
//! delta costs O(Δ · deg) and reads stay `&[u32]` slices either way.
//! Once the overlay grows past a threshold the whole thing is folded
//! back into a fresh flat base (O(V+E), amortised over the many deltas
//! that grew the overlay).
//!
//! Node ids are stable for the lifetime of the structure: an inserted
//! node takes the next id (`num_nodes()` grows), a removed node is
//! isolated (all incident edges dropped) and its id is never reused —
//! exactly what the serving tier needs so caches, shard membership and
//! query routing never have to renumber.

use super::{Csr, GraphView};
use std::collections::HashMap;

/// Self-tuning compaction state: the threshold chases a modelled
/// splice-vs-flat read-cost ratio instead of staying at the static
/// quarter-of-base-arcs default. The flat cost is probed right after
/// each compaction (the freshest flat snapshot), the overlay cost
/// right before each compaction decision; when overlay reads cost more
/// than `target_slowdown` times the flat baseline the threshold halves
/// (compact sooner), and when they stay within budget it grows
/// (compact less often, amortising the O(V+E) fold over more deltas).
/// Costs come from [`DeltaCsr::probe_cost_per_arc`] — a deterministic
/// arc-visit-count model, not wall-clock timing — so the threshold
/// trajectory is bit-reproducible and immune to shared-box noise.
#[derive(Clone, Debug)]
struct AdaptiveCompaction {
    /// Tolerated overlay/flat read-cost ratio (> 1.0).
    target_slowdown: f64,
    /// EWMA cost-per-arc probed on the flat base after compactions
    /// (0.0 until the first probe).
    flat_cost_per_arc: f64,
    /// EWMA cost-per-arc probed through the overlay before compaction
    /// decisions (0.0 until the first probe).
    overlay_cost_per_arc: f64,
    /// Threshold bounds the tuner may move within.
    min_threshold: usize,
    max_threshold: usize,
}

/// EWMA blend factor for cost observations: recent probes dominate but
/// one unrepresentative sample (the strided probe sees different rows
/// as the graph grows) cannot whipsaw the threshold.
const ADAPTIVE_EWMA: f64 = 0.5;

/// Modelled extra cost of reading a row through the overlay, in
/// arc-equivalents per diverged row: the `HashMap` lookup plus the
/// pointer chase to a separately allocated `Vec` row, versus the flat
/// base's contiguous slice. The constant only has to get the *order*
/// right — the retune rule compares the resulting ratio against
/// `target_slowdown`, so moderate inaccuracy shifts when the threshold
/// moves, never correctness.
const OVERLAY_ROW_SURCHARGE: f64 = 8.0;

/// Pure retuning rule, factored out so tests can drive it with
/// synthetic costs instead of probe output. Returns the new threshold
/// given the current one and the observed cost-per-arc pair.
fn retune_threshold(
    threshold: usize,
    overlay_cost_per_arc: f64,
    flat_cost_per_arc: f64,
    target_slowdown: f64,
    min_threshold: usize,
    max_threshold: usize,
) -> usize {
    if flat_cost_per_arc <= 0.0 || overlay_cost_per_arc <= 0.0 {
        return threshold.clamp(min_threshold, max_threshold);
    }
    let ratio = overlay_cost_per_arc / flat_cost_per_arc;
    let next = if ratio > target_slowdown {
        // overlay reads have become too slow: compact sooner
        threshold / 2
    } else if ratio < 0.5 * target_slowdown + 0.5 {
        // comfortably within budget: let the overlay grow longer
        threshold.saturating_mul(2)
    } else {
        threshold
    };
    next.clamp(min_threshold, max_threshold)
}

/// See module docs.
#[derive(Clone, Debug)]
pub struct DeltaCsr {
    /// Last compacted flat snapshot.
    base: Csr,
    /// Full merged (sorted) neighbour row per diverged node.
    overlay: HashMap<u32, Vec<u32>>,
    /// Nodes appended after the base snapshot (ids `base.num_nodes()..`).
    extra_nodes: usize,
    /// Directed arc count of the *current* graph (base ± overlay).
    arcs: usize,
    /// Sum of overlay row lengths — the compaction trigger metric.
    overlay_arcs: usize,
    /// Overlay arcs above which [`maybe_compact`](Self::maybe_compact)
    /// folds into a fresh base.
    threshold: usize,
    /// Monotonic graph version, bumped once per applied delta batch.
    version: u64,
    /// Lifetime compaction count (diagnostics / benches).
    compactions: u64,
    /// Self-tuning threshold state; `None` keeps the static policy.
    adaptive: Option<AdaptiveCompaction>,
}

impl DeltaCsr {
    /// Wrap a flat snapshot with the default compaction threshold
    /// (a quarter of the base arcs, at least 1024).
    pub fn new(base: Csr) -> Self {
        let t = (base.num_arcs() / 4).max(1024);
        Self::with_threshold(base, t)
    }

    /// Wrap with an explicit overlay-arc compaction threshold (tests
    /// use tiny thresholds to force compactions mid-sequence).
    pub fn with_threshold(base: Csr, threshold: usize) -> Self {
        let arcs = base.num_arcs();
        DeltaCsr {
            base,
            overlay: HashMap::new(),
            extra_nodes: 0,
            arcs,
            overlay_arcs: 0,
            threshold: threshold.max(1),
            version: 0,
            compactions: 0,
            adaptive: None,
        }
    }

    /// Switch [`maybe_compact`](Self::maybe_compact) to the self-tuning
    /// policy: before each compaction decision the overlay read cost is
    /// probed (deterministic arc-visit model, see
    /// [`probe_cost_per_arc`](Self::probe_cost_per_arc)) and the
    /// threshold retuned against the flat baseline probed after the
    /// last compaction. `target_slowdown` is the tolerated overlay/flat
    /// ratio (values ≤ 1.0 are clamped to 1.1).
    pub fn enable_adaptive_compaction(&mut self, target_slowdown: f64) {
        let max = (self.base.num_arcs() / 2).max(4096);
        self.adaptive = Some(AdaptiveCompaction {
            target_slowdown: target_slowdown.max(1.1),
            flat_cost_per_arc: 0.0,
            overlay_cost_per_arc: 0.0,
            min_threshold: 64,
            max_threshold: max,
        });
    }

    /// Current compaction threshold (diagnostics; moves under the
    /// adaptive policy).
    pub fn compaction_threshold(&self) -> usize {
        self.threshold
    }

    /// Last observed `(overlay, flat)` cost-per-arc pair, when adaptive
    /// compaction is enabled and both sides have been probed.
    pub fn adaptive_costs(&self) -> Option<(f64, f64)> {
        self.adaptive
            .as_ref()
            .filter(|a| a.flat_cost_per_arc > 0.0 && a.overlay_cost_per_arc > 0.0)
            .map(|a| (a.overlay_cost_per_arc, a.flat_cost_per_arc))
    }

    /// Modelled read cost per traversed arc over a deterministic
    /// strided row sample: an arc read through the flat base costs 1
    /// unit, and each sampled row resident in the overlay adds
    /// [`OVERLAY_ROW_SURCHARGE`] units on top. A freshly compacted
    /// graph therefore probes at exactly 1.0 and the value rises with
    /// overlay density. This replaces an earlier wall-clock ns-per-arc
    /// probe: arc-visit counts depend only on the structure, so the
    /// adaptive threshold now moves identically on every machine and
    /// every run — no shared-box timing noise, no black-box read walk
    /// on the delta path.
    fn probe_cost_per_arc(&self, sample_rows: usize) -> f64 {
        let n = self.num_nodes();
        if n == 0 {
            return 0.0;
        }
        let sample = sample_rows.clamp(1, n);
        let stride = (n / sample).max(1);
        let mut arcs = 0usize;
        let mut overlay_rows = 0usize;
        let mut v = 0usize;
        while v < n {
            arcs += GraphView::degree(self, v);
            if self.overlay.contains_key(&(v as u32)) {
                overlay_rows += 1;
            }
            v += stride;
        }
        if arcs == 0 {
            return 0.0;
        }
        (arcs as f64 + overlay_rows as f64 * OVERLAY_ROW_SURCHARGE) / arcs as f64
    }

    /// Current graph version (bumped by [`bump_version`](Self::bump_version)).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Advance the version — the server calls this once per applied
    /// delta batch; caches key their validity stamp off it.
    pub fn bump_version(&mut self) {
        self.version += 1;
    }

    /// Lifetime compaction count.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Diverged-row count (diagnostics).
    pub fn overlay_rows(&self) -> usize {
        self.overlay.len()
    }

    /// Sum of overlay row lengths (the compaction trigger metric).
    pub fn overlay_arcs(&self) -> usize {
        self.overlay_arcs
    }

    /// Number of stored directed arcs (2x undirected edges).
    pub fn num_arcs(&self) -> usize {
        self.arcs
    }

    /// Append a fresh isolated node; returns its id. Ids are assigned
    /// densely and never reused.
    pub fn add_node(&mut self) -> u32 {
        let id = self.num_nodes() as u32;
        self.extra_nodes += 1;
        id
    }

    /// Insert (`insert = true`) or remove `b` in `a`'s row, copying the
    /// base row into the overlay on first touch and maintaining the
    /// overlay-arc counter. Caller guarantees the operation applies.
    fn splice(&mut self, a: u32, b: u32, insert: bool) {
        let base = &self.base;
        let mut materialised = 0usize;
        let row = self.overlay.entry(a).or_insert_with(|| {
            let r: Vec<u32> = if (a as usize) < base.num_nodes() {
                base.neighbors(a as usize).to_vec()
            } else {
                Vec::new()
            };
            materialised = r.len();
            r
        });
        if insert {
            let pos = row.binary_search(&b).unwrap_err();
            row.insert(pos, b);
            self.overlay_arcs += materialised + 1;
        } else {
            let pos = row.binary_search(&b).expect("edge present");
            row.remove(pos);
            self.overlay_arcs += materialised;
            self.overlay_arcs -= 1;
        }
    }

    /// Insert undirected edge `{u,v}`. Returns `false` (no-op) when the
    /// edge already exists or `u == v`. O(deg(u) + deg(v)).
    pub fn add_edge(&mut self, u: u32, v: u32) -> bool {
        let n = self.num_nodes();
        assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range (n={n})");
        if u == v || GraphView::has_edge(self, u as usize, v as usize) {
            return false;
        }
        self.splice(u, v, true);
        self.splice(v, u, true);
        self.arcs += 2;
        true
    }

    /// Remove undirected edge `{u,v}`. Returns `false` (no-op) when the
    /// edge is absent. O(deg(u) + deg(v)).
    pub fn remove_edge(&mut self, u: u32, v: u32) -> bool {
        let n = self.num_nodes();
        assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range (n={n})");
        if u == v || !GraphView::has_edge(self, u as usize, v as usize) {
            return false;
        }
        self.splice(u, v, false);
        self.splice(v, u, false);
        self.arcs -= 2;
        true
    }

    /// Drop every edge incident to `v` (online node removal keeps the
    /// id, isolated). Returns the former neighbours.
    pub fn isolate(&mut self, v: u32) -> Vec<u32> {
        let nbrs = GraphView::neighbors(self, v as usize).to_vec();
        for &t in &nbrs {
            self.remove_edge(v, t);
        }
        nbrs
    }

    /// Fold the overlay into a fresh flat base when it has outgrown the
    /// threshold (appended isolated nodes alone never trigger — they
    /// carry no arcs). Under the adaptive policy the threshold is
    /// retuned first from a fresh overlay-cost probe. Returns whether a
    /// compaction ran.
    pub fn maybe_compact(&mut self) -> bool {
        // probe only when a compaction decision is actually near (the
        // overlay past half the threshold) — even the cheap counting
        // walk on every delta would tax the hot path more than splicing
        // costs
        if self.adaptive.is_some() && !self.overlay.is_empty() && self.overlay_arcs * 2 > self.threshold
        {
            // observe the overlay before deciding; the flat side of the
            // ratio was captured right after the last compaction
            let sample = (self.overlay.len() * 4).max(64);
            let probe = self.probe_cost_per_arc(sample);
            let a = self.adaptive.as_mut().expect("checked above");
            if probe > 0.0 {
                a.overlay_cost_per_arc = if a.overlay_cost_per_arc > 0.0 {
                    ADAPTIVE_EWMA * probe + (1.0 - ADAPTIVE_EWMA) * a.overlay_cost_per_arc
                } else {
                    probe
                };
            }
            self.threshold = retune_threshold(
                self.threshold,
                a.overlay_cost_per_arc,
                a.flat_cost_per_arc,
                a.target_slowdown,
                a.min_threshold,
                a.max_threshold,
            );
        }
        if self.overlay_arcs <= self.threshold {
            return false;
        }
        self.compact();
        true
    }

    /// Unconditionally fold the overlay into a fresh flat base. O(V+E).
    pub fn compact(&mut self) {
        if self.overlay.is_empty() && self.extra_nodes == 0 {
            return;
        }
        self.base = self.to_csr();
        self.overlay.clear();
        self.extra_nodes = 0;
        self.overlay_arcs = 0;
        self.compactions += 1;
        debug_assert_eq!(self.base.num_arcs(), self.arcs);
        if self.adaptive.is_some() {
            // freshly flat: (re)probe the baseline the tuner compares
            // overlay probes against (always exactly 1.0 under the
            // arc-visit model, kept as a probe so the model can evolve)
            let probe = self.probe_cost_per_arc(256);
            let a = self.adaptive.as_mut().expect("checked above");
            if probe > 0.0 {
                a.flat_cost_per_arc = if a.flat_cost_per_arc > 0.0 {
                    ADAPTIVE_EWMA * probe + (1.0 - ADAPTIVE_EWMA) * a.flat_cost_per_arc
                } else {
                    probe
                };
            }
        }
    }

    /// Flatten into a standalone [`Csr`] (does not mutate; the oracle
    /// path for property tests and the compaction workhorse).
    pub fn to_csr(&self) -> Csr {
        let n = self.num_nodes();
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + GraphView::degree(self, v);
        }
        let mut targets = vec![0u32; offsets[n]];
        for v in 0..n {
            let row = GraphView::neighbors(self, v);
            targets[offsets[v]..offsets[v] + row.len()].copy_from_slice(row);
        }
        Csr::from_raw(offsets, targets)
    }

    /// Bytes held by base + overlay (memory accounting).
    pub fn nbytes(&self) -> usize {
        self.base.nbytes()
            + self
                .overlay
                .values()
                .map(|r| r.capacity() * std::mem::size_of::<u32>() + std::mem::size_of::<(u32, Vec<u32>)>())
                .sum::<usize>()
    }

    /// Structural invariants across base and overlay (tests).
    pub fn validate(&self) -> Result<(), String> {
        let flat = self.to_csr();
        flat.validate()?;
        if flat.num_arcs() != self.arcs {
            return Err(format!("arc counter {} != materialised {}", self.arcs, flat.num_arcs()));
        }
        let tracked: usize = self.overlay.values().map(|r| r.len()).sum();
        if tracked != self.overlay_arcs {
            return Err(format!("overlay_arcs {} != tracked {}", self.overlay_arcs, tracked));
        }
        Ok(())
    }
}

impl GraphView for DeltaCsr {
    fn num_nodes(&self) -> usize {
        self.base.num_nodes() + self.extra_nodes
    }

    fn degree(&self, v: usize) -> usize {
        match self.overlay.get(&(v as u32)) {
            Some(row) => row.len(),
            None if v < self.base.num_nodes() => self.base.degree(v),
            None => 0,
        }
    }

    fn neighbors(&self, v: usize) -> &[u32] {
        match self.overlay.get(&(v as u32)) {
            Some(row) => row,
            None if v < self.base.num_nodes() => self.base.neighbors(v),
            None => &[],
        }
    }

    fn num_edges(&self) -> usize {
        self.arcs / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path5() -> Csr {
        GraphBuilder::new(5).edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]).build()
    }

    #[test]
    fn reads_passthrough_before_any_delta() {
        let base = path5();
        let d = DeltaCsr::new(base.clone());
        assert_eq!(GraphView::num_nodes(&d), 5);
        assert_eq!(GraphView::num_edges(&d), 4);
        for v in 0..5 {
            assert_eq!(GraphView::neighbors(&d, v), base.neighbors(v));
        }
        assert_eq!(d.overlay_rows(), 0);
    }

    #[test]
    fn add_remove_edge_roundtrip() {
        let mut d = DeltaCsr::new(path5());
        assert!(d.add_edge(0, 4));
        assert!(!d.add_edge(4, 0), "duplicate (either orientation) is a no-op");
        assert!(GraphView::has_edge(&d, 0, 4) && GraphView::has_edge(&d, 4, 0));
        assert_eq!(GraphView::num_edges(&d), 5);
        assert!(d.remove_edge(4, 0));
        assert!(!d.remove_edge(0, 4), "absent edge is a no-op");
        assert_eq!(GraphView::num_edges(&d), 4);
        assert!(!d.add_edge(2, 2), "self loop rejected");
        assert!(d.validate().is_ok());
    }

    #[test]
    fn overlay_rows_stay_sorted() {
        let mut d = DeltaCsr::new(path5());
        d.add_edge(2, 0);
        d.add_edge(2, 4);
        let row = GraphView::neighbors(&d, 2);
        assert_eq!(row, &[0, 1, 3, 4]);
    }

    #[test]
    fn added_nodes_and_isolation() {
        let mut d = DeltaCsr::new(path5());
        let v = d.add_node();
        assert_eq!(v, 5);
        assert_eq!(GraphView::degree(&d, 5), 0);
        assert!(d.add_edge(5, 0));
        assert!(d.add_edge(5, 3));
        assert_eq!(GraphView::neighbors(&d, 5), &[0, 3]);
        let dropped = d.isolate(5);
        assert_eq!(dropped, vec![0, 3]);
        assert_eq!(GraphView::degree(&d, 5), 0);
        assert!(!GraphView::has_edge(&d, 0, 5));
        assert!(d.validate().is_ok());
    }

    #[test]
    fn compaction_preserves_graph_and_counts() {
        let mut d = DeltaCsr::with_threshold(path5(), 2);
        d.add_edge(0, 3);
        d.add_edge(1, 4);
        d.remove_edge(2, 3);
        let before = d.to_csr();
        assert!(d.maybe_compact(), "tiny threshold must trigger");
        assert_eq!(d.compactions(), 1);
        assert_eq!(d.overlay_rows(), 0);
        assert_eq!(d.to_csr(), before);
        assert!(d.validate().is_ok());
    }

    #[test]
    fn to_csr_matches_builder_rebuild() {
        let mut d = DeltaCsr::new(path5());
        d.add_edge(0, 2);
        d.remove_edge(0, 1);
        let want = GraphBuilder::new(5).edges(&[(1, 2), (2, 3), (3, 4), (0, 2)]).build();
        assert_eq!(d.to_csr(), want);
    }

    #[test]
    fn retune_rule_moves_threshold_both_ways() {
        // overlay 3x slower than flat with a 1.5x budget: compact sooner
        assert_eq!(retune_threshold(1000, 30.0, 10.0, 1.5, 64, 4096), 500);
        // overlay as fast as flat: let the overlay grow
        assert_eq!(retune_threshold(1000, 10.0, 10.0, 1.5, 64, 4096), 2000);
        // in the comfort band: hold steady
        assert_eq!(retune_threshold(1000, 13.0, 10.0, 1.5, 64, 4096), 1000);
        // clamped at both ends
        assert_eq!(retune_threshold(100, 30.0, 10.0, 1.5, 64, 4096), 64);
        assert_eq!(retune_threshold(4000, 10.0, 10.0, 1.5, 64, 4096), 4096);
        // no measurements yet: threshold only clamps
        assert_eq!(retune_threshold(1000, 0.0, 0.0, 1.5, 64, 4096), 1000);
    }

    #[test]
    fn adaptive_compaction_preserves_graph_and_stays_bounded() {
        let mut d = DeltaCsr::new(path5());
        d.enable_adaptive_compaction(1.5);
        let (min_t, max_t) = {
            let a = d.adaptive.as_ref().unwrap();
            (a.min_threshold, a.max_threshold)
        };
        for i in 0..4u32 {
            d.add_edge(i, (i + 2) % 5);
            d.maybe_compact();
            assert!(d.threshold >= min_t && d.threshold <= max_t);
        }
        d.compact();
        // flat baseline probed after an adaptive compaction: exactly
        // 1.0 under the arc-visit model (no overlay rows remain)
        assert_eq!(d.adaptive.as_ref().unwrap().flat_cost_per_arc, 1.0);
        assert!(d.validate().is_ok());
        let want = {
            let mut m = DeltaCsr::new(path5());
            for i in 0..4u32 {
                m.add_edge(i, (i + 2) % 5);
            }
            m.to_csr()
        };
        assert_eq!(d.to_csr(), want, "adaptive policy must not change the graph");
    }

    #[test]
    fn adaptive_probe_is_deterministic() {
        // the whole point of the arc-visit cost model: two identical
        // edit sequences leave identical tuner state, bit for bit
        let run = || {
            let mut d = DeltaCsr::new(path5());
            d.enable_adaptive_compaction(1.5);
            for i in 0..4u32 {
                d.add_edge(i, (i + 2) % 5);
                d.maybe_compact();
            }
            d.compact();
            let a = d.adaptive.as_ref().unwrap();
            (d.threshold, a.overlay_cost_per_arc.to_bits(), a.flat_cost_per_arc.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn cost_probe_charges_overlay_surcharge() {
        let mut d = DeltaCsr::new(path5());
        // fully flat: every arc costs exactly one unit
        assert_eq!(d.probe_cost_per_arc(64), 1.0);
        d.add_edge(0, 3);
        let spliced = d.probe_cost_per_arc(64);
        assert!(spliced > 1.0, "overlay rows must carry a surcharge, got {spliced}");
        d.compact();
        assert_eq!(d.probe_cost_per_arc(64), 1.0, "compaction restores the flat cost");
    }

    #[test]
    fn version_is_explicit() {
        let mut d = DeltaCsr::new(path5());
        assert_eq!(d.version(), 0);
        d.add_edge(0, 2);
        assert_eq!(d.version(), 0, "edits alone don't advance the version");
        d.bump_version();
        assert_eq!(d.version(), 1);
    }
}
