//! Induced subgraphs with global<->local id maps.

use super::{Csr, GraphView};
use std::collections::HashMap;

/// A node-induced subgraph of a parent graph. Local ids are dense
/// `0..len()`; `global_ids[local]` maps back to the parent.
#[derive(Clone, Debug)]
pub struct Subgraph {
    /// Parent-graph node id per local id (sorted ascending).
    pub global_ids: Vec<u32>,
    /// Local CSR over the induced edges.
    pub csr: Csr,
}

impl Subgraph {
    /// Induce the subgraph of `parent` on `nodes` (dedup + sorted).
    /// Generic over [`GraphView`] so shards can re-induce straight off
    /// the serving tier's overlay graph without flattening it first.
    pub fn induce<G: GraphView>(parent: &G, nodes: &[u32]) -> Subgraph {
        let mut global_ids = nodes.to_vec();
        global_ids.sort_unstable();
        global_ids.dedup();
        let local: HashMap<u32, u32> = global_ids
            .iter()
            .enumerate()
            .map(|(l, &g)| (g, l as u32))
            .collect();

        let n = global_ids.len();
        let mut offsets = vec![0usize; n + 1];
        // first pass: degrees
        for (l, &g) in global_ids.iter().enumerate() {
            let d = parent
                .neighbors(g as usize)
                .iter()
                .filter(|t| local.contains_key(t))
                .count();
            offsets[l + 1] = offsets[l] + d;
        }
        let mut targets = vec![0u32; offsets[n]];
        let mut cursor = 0usize;
        for &g in &global_ids {
            for t in parent.neighbors(g as usize) {
                if let Some(&lt) = local.get(t) {
                    targets[cursor] = lt;
                    cursor += 1;
                }
            }
        }
        // parent adjacency is sorted by global id and global_ids is
        // sorted, so local targets are already sorted per node.
        Subgraph { global_ids, csr: Csr::from_raw(offsets, targets) }
    }

    /// Node count.
    #[inline]
    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    /// True if empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }

    /// Local id of a global node, if present (binary search).
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.global_ids.binary_search(&global).ok().map(|i| i as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn induce_keeps_internal_edges_only() {
        // square 0-1-2-3-0 plus diagonal 0-2
        let g = GraphBuilder::new(4)
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
            .build();
        let s = Subgraph::induce(&g, &[0, 1, 2]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.csr.num_edges(), 3); // 0-1, 1-2, 0-2
        assert!(s.csr.validate().is_ok());
        assert_eq!(s.local_of(2), Some(2));
        assert_eq!(s.local_of(3), None);
    }

    #[test]
    fn induce_dedups_input() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 2)]).build();
        let s = Subgraph::induce(&g, &[1, 1, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.csr.num_edges(), 1);
    }
}
