//! Load-generator correctness: schedules must be deterministic per
//! seed, and answers delivered under open-loop load — queueing,
//! micro-batched flushes, delta barriers and all — must be
//! bit-identical to a sequential replay of the same schedule against a
//! fresh server. The scheduler comparison at the end is the fig14
//! headline in miniature: past the knee the SLO batcher amortises the
//! backlog while FIFO drowns in it.

use gad::datasets::{Dataset, SyntheticSpec};
use gad::loadgen::{
    generate_schedule, run_open_loop, Arrival, ArrivalKind, FifoScheduler, Scheduler,
    SimOptions, SloBatchScheduler, WorkloadConfig,
};
use gad::model::GcnParams;
use gad::rng::Rng;
use gad::serve::{ServeConfig, Server};

fn fixture(seed: u64) -> (Dataset, GcnParams) {
    let ds = SyntheticSpec::tiny().generate(seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
    let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
    (ds, params)
}

fn server(ds: &Dataset, params: &GcnParams) -> Server {
    server_at(ds, params, 1)
}

fn server_at(ds: &Dataset, params: &GcnParams, serve_threads: usize) -> Server {
    let cfg = ServeConfig { shards: 4, seed: 7, serve_threads, ..Default::default() };
    Server::for_dataset(ds, params.clone(), cfg).expect("server")
}

#[test]
fn same_seed_byte_identical_schedule() {
    let (ds, _) = fixture(7);
    let cfg = WorkloadConfig {
        rate_qps: 5_000.0,
        events: 400,
        churn_frac: 0.05,
        seed: 11,
        ..Default::default()
    };
    let a = generate_schedule(&ds.graph, ds.feature_dim(), &cfg);
    let b = generate_schedule(&ds.graph, ds.feature_dim(), &cfg);
    // GraphDelta carries f32 features; Debug is total over every field,
    // so equal renderings mean equal schedules
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed must replay identically");

    let c = generate_schedule(
        &ds.graph,
        ds.feature_dim(),
        &WorkloadConfig { seed: 12, ..cfg.clone() },
    );
    assert_ne!(format!("{a:?}"), format!("{c:?}"), "a different seed must differ");

    assert!(a.windows(2).all(|w| w[0].at_us <= w[1].at_us), "arrivals are time-ordered");
    let queries = a.iter().filter(|x| matches!(x.kind, ArrivalKind::Query { .. })).count();
    let deltas = a.len() - queries;
    assert!(queries > 0 && deltas > 0, "mixed traffic: {queries} queries, {deltas} deltas");
}

/// Sequential oracle: the same arrivals, one at a time, no queue.
fn replay_sequentially(
    srv: &mut Server,
    schedule: &[Arrival],
) -> (Vec<(u64, u32, u64, Vec<u32>)>, usize) {
    let mut answers = Vec::new();
    let mut deltas = 0usize;
    for (id, arrival) in schedule.iter().enumerate() {
        match &arrival.kind {
            ArrivalKind::Query { node } => {
                let r = srv.query(*node).expect("oracle query");
                let bits: Vec<u32> = r.probs.iter().map(|p| p.to_bits()).collect();
                answers.push((id as u64, r.pred, r.graph_version, bits));
            }
            ArrivalKind::Delta(d) => {
                srv.apply_delta(d).expect("oracle delta");
                deltas += 1;
            }
        }
    }
    (answers, deltas)
}

#[test]
fn answers_under_load_bit_identical_to_direct_queries() {
    let (ds, params) = fixture(7);
    let wcfg = WorkloadConfig {
        rate_qps: 20_000.0,
        events: 250,
        zipf_s: 1.1,
        churn_frac: 0.08,
        seed: 5,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let (oracle, oracle_deltas) = replay_sequentially(&mut server(&ds, &params), &schedule);

    let opts = SimOptions { slo_us: 2_000, record_probs: true };
    for threads in [1usize, 4] {
        for mode in ["fifo", "slo-batch"] {
            let mut srv = server_at(&ds, &params, threads);
            let mut fifo = FifoScheduler::new();
            let mut batch = SloBatchScheduler::new(srv.num_shards(), 8, opts.slo_us / 4);
            let sched: &mut dyn Scheduler = if mode == "fifo" { &mut fifo } else { &mut batch };
            let sim = run_open_loop(&mut srv, &schedule, sched, &opts).expect("open loop");

            assert_eq!(sim.deltas_applied, oracle_deltas, "[{mode}/{threads}] every delta applied");
            assert_eq!(sim.outcomes.len(), oracle.len(), "[{mode}/{threads}] every query answered");
            for (o, (id, pred, version, bits)) in sim.outcomes.iter().zip(&oracle) {
                assert_eq!(o.id, *id, "[{mode}/{threads}] outcomes align with the schedule");
                assert_eq!(o.pred, *pred, "[{mode}/{threads}] query {id}: class flipped under load");
                assert_eq!(
                    o.graph_version, *version,
                    "[{mode}/{threads}] query {id}: saw a different graph version than sequential \
                     replay"
                );
                let got: Vec<u32> =
                    o.probs.as_ref().expect("record_probs").iter().map(|p| p.to_bits()).collect();
                assert_eq!(&got, bits, "[{mode}/{threads}] query {id}: probs not bit-identical");
            }
        }
    }
}

/// The tentpole contract on the direct path: `query_batch` across a
/// parallel serve pool returns the same bytes and the same counters as
/// the sequential pool, before and after churn.
#[test]
fn parallel_query_batch_bit_identical_with_equal_counters() {
    let (ds, params) = fixture(13);
    let n = ds.graph.num_nodes() as u32;
    // a batch that lands on every shard, twice over, in scrambled order
    let nodes: Vec<u32> = (0..48u32).map(|i| (i * 29) % n).collect();

    let mut seq = server_at(&ds, &params, 1);
    let mut par = server_at(&ds, &params, 4);
    assert_eq!(seq.serve_parallelism(), 1);
    assert!(par.serve_parallelism() > 1, "pool must actually be parallel");

    let check = |seq: &mut Server, par: &mut Server, tag: &str| {
        let a = seq.query_batch(&nodes).expect("sequential batch");
        let b = par.query_batch(&nodes).expect("parallel batch");
        assert_eq!(a.len(), b.len(), "[{tag}] answer count");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pred, y.pred, "[{tag}] pred for node {}", x.node);
            assert_eq!(x.graph_version, y.graph_version, "[{tag}] version for node {}", x.node);
            let xb: Vec<u32> = x.probs.iter().map(|p| p.to_bits()).collect();
            let yb: Vec<u32> = y.probs.iter().map(|p| p.to_bits()).collect();
            assert_eq!(xb, yb, "[{tag}] probs for node {} not bit-identical", x.node);
        }
        let (s, p) = (seq.stats(), par.stats());
        assert_eq!(s.queries, p.queries, "[{tag}] query counter");
        assert_eq!(s.micro_batches, p.micro_batches, "[{tag}] micro-batch counter");
        assert_eq!(s.cache_hits, p.cache_hits, "[{tag}] cache-hit counter");
        assert_eq!(s.rows_recomputed, p.rows_recomputed, "[{tag}] recompute counter");
    };
    check(&mut seq, &mut par, "warm-up");
    check(&mut seq, &mut par, "cached");

    // churn, then re-check: the pools must agree on the new version too
    let wcfg = WorkloadConfig {
        rate_qps: 1_000.0,
        events: 60,
        churn_frac: 1.0,
        seed: 3,
        ..Default::default()
    };
    for arrival in generate_schedule(&ds.graph, ds.feature_dim(), &wcfg) {
        if let ArrivalKind::Delta(d) = &arrival.kind {
            seq.apply_delta(d).expect("seq delta");
            par.apply_delta(d).expect("par delta");
        }
    }
    check(&mut seq, &mut par, "post-churn");
}

/// Overlap must actually happen: at a rate far past one shard's
/// service time, a 4-slot pool keeps ≥ 2 flushes in flight — while the
/// answers still match the sequential oracle byte for byte.
#[test]
fn concurrent_flushes_overlap_and_stay_bit_identical() {
    let (ds, params) = fixture(21);
    let wcfg = WorkloadConfig {
        rate_qps: 50_000_000.0,
        events: 200,
        zipf_s: 0.0, // uniform popularity → all shards stay busy
        churn_frac: 0.0,
        seed: 17,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let (oracle, _) = replay_sequentially(&mut server(&ds, &params), &schedule);

    let opts = SimOptions { slo_us: u64::MAX / 2, record_probs: true };
    let mut srv = server_at(&ds, &params, 4);
    let mut fifo = FifoScheduler::new();
    let sim = run_open_loop(&mut srv, &schedule, &mut fifo, &opts).expect("open loop");

    assert!(
        sim.peak_inflight >= 2,
        "a saturated 4-slot pool must overlap flushes (peak {})",
        sim.peak_inflight
    );
    assert_eq!(sim.outcomes.len(), oracle.len());
    for (o, (id, pred, version, bits)) in sim.outcomes.iter().zip(&oracle) {
        assert_eq!(o.id, *id);
        assert_eq!(o.pred, *pred, "query {id}: class flipped under concurrent flushes");
        assert_eq!(o.graph_version, *version, "query {id}: version drift");
        let got: Vec<u32> =
            o.probs.as_ref().expect("record_probs").iter().map(|p| p.to_bits()).collect();
        assert_eq!(&got, bits, "query {id}: probs not bit-identical under overlap");
    }
}

#[test]
fn slo_batcher_outperforms_fifo_past_the_knee() {
    let (ds, params) = fixture(7);
    // far past any knee: arrivals land ~every 0.02 virtual µs while a
    // flush costs at least 1, so the backlog is structural
    let wcfg = WorkloadConfig {
        rate_qps: 50_000_000.0,
        events: 320,
        churn_frac: 0.0,
        seed: 9,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    // a deadline no run can miss: queueing comparisons stay post-hoc
    let opts = SimOptions { slo_us: u64::MAX / 2, record_probs: false };

    let mut fifo_srv = server(&ds, &params);
    let mut fifo = FifoScheduler::new();
    let fifo_sim = run_open_loop(&mut fifo_srv, &schedule, &mut fifo, &opts).expect("fifo");

    let mut batch_srv = server(&ds, &params);
    let mut batch = SloBatchScheduler::new(batch_srv.num_shards(), 32, 0);
    let batch_sim = run_open_loop(&mut batch_srv, &schedule, &mut batch, &opts).expect("batch");

    assert!(
        batch_sim.flushes < fifo_sim.flushes,
        "batcher must amortise: {} flushes vs fifo's {}",
        batch_sim.flushes,
        fifo_sim.flushes
    );
    let mean = |sim: &gad::loadgen::SimResult| {
        sim.outcomes.iter().map(|o| o.latency_us() as f64).sum::<f64>()
            / sim.outcomes.len().max(1) as f64
    };
    let (fifo_mean, batch_mean) = (mean(&fifo_sim), mean(&batch_sim));
    assert!(
        batch_mean < fifo_mean,
        "batched mean latency {batch_mean:.0}µs must beat fifo's {fifo_mean:.0}µs under overload"
    );
    // goodput at an SLO set to fifo's own mean: the batcher answers
    // strictly more within it on the identical schedule
    let slo = fifo_mean as u64;
    let good = |sim: &gad::loadgen::SimResult| {
        sim.outcomes.iter().filter(|o| o.latency_us() <= slo).count()
    };
    assert!(
        good(&batch_sim) > good(&fifo_sim),
        "past the knee the batcher must deliver more answers within {slo}µs ({} vs {})",
        good(&batch_sim),
        good(&fifo_sim)
    );
}
