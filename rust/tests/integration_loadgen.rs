//! Load-generator correctness: schedules must be deterministic per
//! seed, and answers delivered under open-loop load — queueing,
//! micro-batched flushes, delta barriers and all — must be
//! bit-identical to a sequential replay of the same schedule against a
//! fresh server. The scheduler comparison at the end is the fig14
//! headline in miniature: past the knee the SLO batcher amortises the
//! backlog while FIFO drowns in it.

use gad::datasets::{Dataset, SyntheticSpec};
use gad::loadgen::{
    generate_schedule, run_open_loop, Arrival, ArrivalKind, FifoScheduler, Scheduler,
    SimOptions, SloBatchScheduler, WorkloadConfig,
};
use gad::model::GcnParams;
use gad::rng::Rng;
use gad::serve::{ServeConfig, Server};

fn fixture(seed: u64) -> (Dataset, GcnParams) {
    let ds = SyntheticSpec::tiny().generate(seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
    let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
    (ds, params)
}

fn server(ds: &Dataset, params: &GcnParams) -> Server {
    let cfg = ServeConfig { shards: 4, seed: 7, ..Default::default() };
    Server::for_dataset(ds, params.clone(), cfg).expect("server")
}

#[test]
fn same_seed_byte_identical_schedule() {
    let (ds, _) = fixture(7);
    let cfg = WorkloadConfig {
        rate_qps: 5_000.0,
        events: 400,
        churn_frac: 0.05,
        seed: 11,
        ..Default::default()
    };
    let a = generate_schedule(&ds.graph, ds.feature_dim(), &cfg);
    let b = generate_schedule(&ds.graph, ds.feature_dim(), &cfg);
    // GraphDelta carries f32 features; Debug is total over every field,
    // so equal renderings mean equal schedules
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed must replay identically");

    let c = generate_schedule(
        &ds.graph,
        ds.feature_dim(),
        &WorkloadConfig { seed: 12, ..cfg.clone() },
    );
    assert_ne!(format!("{a:?}"), format!("{c:?}"), "a different seed must differ");

    assert!(a.windows(2).all(|w| w[0].at_us <= w[1].at_us), "arrivals are time-ordered");
    let queries = a.iter().filter(|x| matches!(x.kind, ArrivalKind::Query { .. })).count();
    let deltas = a.len() - queries;
    assert!(queries > 0 && deltas > 0, "mixed traffic: {queries} queries, {deltas} deltas");
}

/// Sequential oracle: the same arrivals, one at a time, no queue.
fn replay_sequentially(
    srv: &mut Server,
    schedule: &[Arrival],
) -> (Vec<(u64, u32, u64, Vec<u32>)>, usize) {
    let mut answers = Vec::new();
    let mut deltas = 0usize;
    for (id, arrival) in schedule.iter().enumerate() {
        match &arrival.kind {
            ArrivalKind::Query { node } => {
                let r = srv.query(*node).expect("oracle query");
                let bits: Vec<u32> = r.probs.iter().map(|p| p.to_bits()).collect();
                answers.push((id as u64, r.pred, r.graph_version, bits));
            }
            ArrivalKind::Delta(d) => {
                srv.apply_delta(d).expect("oracle delta");
                deltas += 1;
            }
        }
    }
    (answers, deltas)
}

#[test]
fn answers_under_load_bit_identical_to_direct_queries() {
    let (ds, params) = fixture(7);
    let wcfg = WorkloadConfig {
        rate_qps: 20_000.0,
        events: 250,
        zipf_s: 1.1,
        churn_frac: 0.08,
        seed: 5,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let (oracle, oracle_deltas) = replay_sequentially(&mut server(&ds, &params), &schedule);

    let opts = SimOptions { slo_us: 2_000, record_probs: true };
    for mode in ["fifo", "slo-batch"] {
        let mut srv = server(&ds, &params);
        let mut fifo = FifoScheduler::new();
        let mut batch = SloBatchScheduler::new(srv.num_shards(), 8, opts.slo_us / 4);
        let sched: &mut dyn Scheduler = if mode == "fifo" { &mut fifo } else { &mut batch };
        let sim = run_open_loop(&mut srv, &schedule, sched, &opts).expect("open loop");

        assert_eq!(sim.deltas_applied, oracle_deltas, "[{mode}] every delta applied");
        assert_eq!(sim.outcomes.len(), oracle.len(), "[{mode}] every query answered");
        for (o, (id, pred, version, bits)) in sim.outcomes.iter().zip(&oracle) {
            assert_eq!(o.id, *id, "[{mode}] outcomes align with the schedule");
            assert_eq!(o.pred, *pred, "[{mode}] query {id}: class flipped under load");
            assert_eq!(
                o.graph_version, *version,
                "[{mode}] query {id}: saw a different graph version than sequential replay"
            );
            let got: Vec<u32> =
                o.probs.as_ref().expect("record_probs").iter().map(|p| p.to_bits()).collect();
            assert_eq!(&got, bits, "[{mode}] query {id}: probabilities not bit-identical");
        }
    }
}

#[test]
fn slo_batcher_outperforms_fifo_past_the_knee() {
    let (ds, params) = fixture(7);
    // far past any knee: arrivals land ~every 0.02 virtual µs while a
    // flush costs at least 1, so the backlog is structural
    let wcfg = WorkloadConfig {
        rate_qps: 50_000_000.0,
        events: 320,
        churn_frac: 0.0,
        seed: 9,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    // a deadline no run can miss: queueing comparisons stay post-hoc
    let opts = SimOptions { slo_us: u64::MAX / 2, record_probs: false };

    let mut fifo_srv = server(&ds, &params);
    let mut fifo = FifoScheduler::new();
    let fifo_sim = run_open_loop(&mut fifo_srv, &schedule, &mut fifo, &opts).expect("fifo");

    let mut batch_srv = server(&ds, &params);
    let mut batch = SloBatchScheduler::new(batch_srv.num_shards(), 32, 0);
    let batch_sim = run_open_loop(&mut batch_srv, &schedule, &mut batch, &opts).expect("batch");

    assert!(
        batch_sim.flushes < fifo_sim.flushes,
        "batcher must amortise: {} flushes vs fifo's {}",
        batch_sim.flushes,
        fifo_sim.flushes
    );
    let mean = |sim: &gad::loadgen::SimResult| {
        sim.outcomes.iter().map(|o| o.latency_us() as f64).sum::<f64>()
            / sim.outcomes.len().max(1) as f64
    };
    let (fifo_mean, batch_mean) = (mean(&fifo_sim), mean(&batch_sim));
    assert!(
        batch_mean < fifo_mean,
        "batched mean latency {batch_mean:.0}µs must beat fifo's {fifo_mean:.0}µs under overload"
    );
    // goodput at an SLO set to fifo's own mean: the batcher answers
    // strictly more within it on the identical schedule
    let slo = fifo_mean as u64;
    let good = |sim: &gad::loadgen::SimResult| {
        sim.outcomes.iter().filter(|o| o.latency_us() <= slo).count()
    };
    assert!(
        good(&batch_sim) > good(&fifo_sim),
        "past the knee the batcher must deliver more answers within {slo}µs ({} vs {})",
        good(&batch_sim),
        good(&fifo_sim)
    );
}
