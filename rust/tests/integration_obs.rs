//! Observability contract tests. The tracer is annotation only: with
//! spans recording or not, the serving tier must hand back the same
//! bytes, the same graph versions, and the same counters — at serve
//! width 1 and across the parallel pool — and a traced run must
//! actually contain the nested three-tier timeline the `--trace` flag
//! promises (train rounds, serve flushes with gather/GEMM phases
//! under them, loadgen virtual-time lanes).
//!
//! The tracer is process-global, so every test here serialises on
//! `trace::exclusive()` and drains before releasing it.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::{Dataset, SyntheticSpec};
use gad::loadgen::{
    generate_schedule, run_open_loop, SimOptions, SloBatchScheduler, WorkloadConfig,
};
use gad::model::GcnParams;
use gad::obs::trace;
use gad::rng::Rng;
use gad::serve::{ServeConfig, ServeStats, Server};

fn fixture(seed: u64) -> (Dataset, GcnParams) {
    let ds = SyntheticSpec::tiny().generate(seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
    let params = GcnParams::init(ds.feature_dim(), 8, ds.num_classes, 2, &mut rng);
    (ds, params)
}

fn server_at(ds: &Dataset, params: &GcnParams, serve_threads: usize) -> Server {
    let cfg = ServeConfig { shards: 4, seed: 7, serve_threads, ..Default::default() };
    Server::for_dataset(ds, params.clone(), cfg).expect("server")
}

/// Everything a run can answer, reduced to exact bits.
#[derive(PartialEq, Debug)]
struct RunFingerprint {
    batch_answers: Vec<(u32, u32, u64, Vec<u32>)>,
    outcomes: Vec<(u64, u32, u64, Vec<u32>)>,
    deltas_applied: usize,
    stats: ServeStats,
}

/// One full direct-burst + open-loop pass at `serve_threads`, with the
/// tracer on or off. Caller holds `trace::exclusive()`.
fn run_once(ds: &Dataset, params: &GcnParams, serve_threads: usize, traced: bool) -> RunFingerprint {
    if traced {
        trace::enable();
    }
    let mut srv = server_at(ds, params, serve_threads);

    let n = ds.graph.num_nodes() as u32;
    let nodes: Vec<u32> = (0..48u32).map(|i| (i * 29) % n).collect();
    let batch_answers = srv
        .query_batch(&nodes)
        .expect("direct batch")
        .iter()
        .map(|r| {
            (r.node, r.pred, r.graph_version, r.probs.iter().map(|p| p.to_bits()).collect())
        })
        .collect();

    let wcfg = WorkloadConfig {
        rate_qps: 20_000.0,
        events: 200,
        zipf_s: 1.1,
        churn_frac: 0.08,
        seed: 5,
        ..Default::default()
    };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let opts = SimOptions { slo_us: 2_000, record_probs: true };
    let mut sched = SloBatchScheduler::new(srv.num_shards(), 8, opts.slo_us / 4);
    let sim = run_open_loop(&mut srv, &schedule, &mut sched, &opts).expect("open loop");

    if traced {
        trace::disable();
        let t = trace::drain();
        assert!(!t.events.is_empty(), "traced run must have recorded spans");
    }
    RunFingerprint {
        batch_answers,
        outcomes: sim
            .outcomes
            .iter()
            .map(|o| {
                let bits = o.probs.as_ref().expect("record_probs");
                (o.id, o.pred, o.graph_version, bits.iter().map(|p| p.to_bits()).collect())
            })
            .collect(),
        deltas_applied: sim.deltas_applied,
        stats: srv.stats(),
    }
}

/// The PR-7 determinism contract, extended to the tracer: tracing on
/// vs off is bit-identical — answers, versions, probabilities, and
/// every `ServeStats` counter — at width 1 and across the pool.
#[test]
fn tracing_on_vs_off_bit_identical_at_widths_1_and_4() {
    let _g = trace::exclusive();
    trace::drain(); // start from a clean global buffer
    let (ds, params) = fixture(7);
    for threads in [1usize, 4] {
        let off = run_once(&ds, &params, threads, false);
        let on = run_once(&ds, &params, threads, true);
        assert_eq!(
            off, on,
            "[width {threads}] tracing changed an answer or a counter"
        );
    }
    // and the off-runs really were off: nothing accumulated
    assert!(trace::drain().events.is_empty(), "untraced runs must record nothing");
}

/// A traced train → serve → replay pass carries nested spans from all
/// three tiers, and the Chrome export is structurally sound.
#[test]
fn traced_run_spans_all_three_tiers_with_nesting() {
    let _g = trace::exclusive();
    trace::drain();
    let (ds, params) = fixture(11);

    trace::enable();
    // train tier: a tiny run is enough to emit epoch/round spans
    let cfg = TrainConfig {
        partitions: 4,
        workers: 2,
        layers: 2,
        hidden: 16,
        epochs: 3,
        seed: 42,
        ..Default::default()
    };
    train_gad(&ds, &cfg).expect("tiny training run");
    // serve + loadgen tiers
    let mut srv = server_at(&ds, &params, 4);
    let wcfg =
        WorkloadConfig { rate_qps: 20_000.0, events: 150, churn_frac: 0.05, seed: 5, ..Default::default() };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let mut sched = SloBatchScheduler::new(srv.num_shards(), 8, 500);
    run_open_loop(&mut srv, &schedule, &mut sched, &SimOptions::default()).expect("open loop");
    trace::disable();
    let t = trace::drain();

    assert_eq!(t.tiers(), vec!["loadgen", "serve", "train"], "all three tiers present");
    assert_eq!(t.count_named("loadgen.run_open_loop"), 1, "one sim event loop");
    assert!(t.count_named("train.epoch") >= 3, "an epoch span per epoch");
    assert!(t.count_named("serve.shard_flush") > 0, "server flushes recorded");
    assert!(t.count_named("serve.gather") > 0 && t.count_named("serve.gemm") > 0);
    assert!(t.count_named("loadgen.service") > 0, "virtual service lanes");
    assert!(t.count_named("loadgen.queueing") > 0, "virtual queueing lanes");

    // nesting: flushes hang off a wave/batch span, phases off a flush
    let id_of = |name: &str| -> Vec<u64> {
        t.events.iter().filter(|e| e.name == name).map(|e| e.id).collect()
    };
    let parents_of = |name: &str| -> Vec<u64> {
        t.events.iter().filter(|e| e.name == name).filter_map(|e| e.parent).collect()
    };
    let flush_ids = id_of("serve.shard_flush");
    let wave_ids: Vec<u64> =
        [id_of("serve.flush_wave"), id_of("serve.query_batch")].concat();
    assert!(
        parents_of("serve.shard_flush").iter().any(|p| wave_ids.contains(p)),
        "a shard flush must link to its dispatching wave"
    );
    assert!(
        parents_of("serve.gemm").iter().any(|p| flush_ids.contains(p)),
        "a GEMM phase must nest under a shard flush"
    );

    // Chrome export: one complete-event object per span, metadata rows
    // for thread labels, balanced top-level JSON
    let json = t.to_chrome_json();
    assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n"));
    assert!(json.trim_end().ends_with("]}"));
    assert_eq!(json.matches("\"ph\":\"X\"").count(), t.events.len());
    assert!(json.matches("\"ph\":\"M\"").count() >= 1, "thread-name metadata present");
    assert_eq!(json.matches('{').count(), json.matches('}').count(), "balanced braces");
}
