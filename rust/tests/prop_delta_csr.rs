//! Property tests for the versioned graph core: any random sequence of
//! online mutations applied through the `DeltaCsr` overlay — including
//! across compactions — must yield a graph, and serve answers,
//! bit-identical to a from-scratch rebuild.

use gad::datasets::SyntheticSpec;
use gad::graph::{DeltaCsr, GraphBuilder};
use gad::model::GcnParams;
use gad::proptest_util::{arb_graph, forall};
use gad::rng::Rng;
use gad::serve::{DeltaMode, GraphDelta, NewNode, ServeConfig, Server};
use std::collections::HashSet;

fn canon(u: u32, v: u32) -> (u32, u32) {
    if u < v {
        (u, v)
    } else {
        (v, u)
    }
}

/// Graph level: DeltaCsr under random add/remove-edge, add-node and
/// isolate ops, with a tiny compaction threshold so sequences cross
/// multiple compactions, always equals the GraphBuilder rebuild of the
/// mirrored edge set.
#[test]
fn delta_csr_sequences_match_builder_rebuild() {
    forall("delta-csr == rebuild", 40, |rng| {
        let (n0, edges) = arb_graph(rng, 4, 20, 0.25);
        let base = GraphBuilder::new(n0).edges(&edges).build();
        let mut dc = DeltaCsr::with_threshold(base.clone(), 6);
        let mut mirror: HashSet<(u32, u32)> = base.edges().collect();
        let mut n = n0;
        for step in 0..15 {
            match rng.gen_range(4) {
                0 => {
                    let u = rng.gen_range(n) as u32;
                    let v = rng.gen_range(n) as u32;
                    if u != v {
                        let applied = dc.add_edge(u, v);
                        let fresh = mirror.insert(canon(u, v));
                        if applied != fresh {
                            return Err(format!(
                                "step {step}: add_edge({u},{v}) applied={applied} mirror={fresh}"
                            ));
                        }
                    }
                }
                1 => {
                    let mut es: Vec<(u32, u32)> = mirror.iter().copied().collect();
                    es.sort_unstable();
                    if !es.is_empty() {
                        let e = es[rng.gen_range(es.len())];
                        if !dc.remove_edge(e.0, e.1) {
                            return Err(format!("step {step}: remove of present edge no-opped"));
                        }
                        mirror.remove(&e);
                    }
                }
                2 => {
                    let id = dc.add_node();
                    if id as usize != n {
                        return Err(format!("step {step}: new id {id}, expected {n}"));
                    }
                    n += 1;
                    let t = rng.gen_range(n - 1) as u32;
                    if dc.add_edge(id, t) {
                        mirror.insert(canon(id, t));
                    }
                }
                _ => {
                    let v = rng.gen_range(n) as u32;
                    for t in dc.isolate(v) {
                        mirror.remove(&canon(v, t));
                    }
                }
            }
            if rng.gen_bool(0.3) {
                dc.maybe_compact();
            }
            let mut es: Vec<(u32, u32)> = mirror.iter().copied().collect();
            es.sort_unstable();
            let want = GraphBuilder::new(n).edges(&es).build();
            let got = dc.to_csr();
            if got != want {
                return Err(format!(
                    "step {step}: overlay diverged from rebuild ({} vs {} edges, {} compactions)",
                    got.num_edges(),
                    want.num_edges(),
                    dc.compactions()
                ));
            }
            dc.validate().map_err(|e| format!("step {step}: invariants: {e}"))?;
        }
        Ok(())
    });
}

/// Serving level: a random sequence of deltas — edge churn, feature
/// rewrites, **elastic node insert/remove** — applied to (a) the
/// incremental overlay server, (b) the rebuild-mode server, (c) an
/// incremental server with the online rebalancer forced aggressive
/// (every delta triggers migrations, plus an explicit pass per round)
/// and (e) an incremental server flushing through a 4-wide scoped
/// serve pool must answer bit-identically to (d) a fresh server that
/// never saw the old graph, on every alive node, after every delta.
/// (c) is the migration-sequence property the rebalancer's bit-identity
/// contract rests on; (e) is the same property for the parallel serve
/// path, counters included.
#[test]
fn serve_answers_match_across_delta_modes_and_fresh_rebuild() {
    forall("incremental == rebuild == rebalanced == parallel == fresh", 4, |rng| {
        let seed = rng.next_u64() % 1_000;
        let ds = SyntheticSpec::tiny().generate(seed);
        let fdim = ds.feature_dim();
        let mut prng = Rng::seed_from_u64(seed ^ 0xD2);
        let params = GcnParams::init(fdim, 10, ds.num_classes, 2, &mut prng);
        let cfg = ServeConfig { shards: 3, seed: 7, ..Default::default() };
        let rcfg = ServeConfig { delta_mode: DeltaMode::Rebuild, ..cfg.clone() };
        let bcfg = ServeConfig {
            rebalance: true,
            rebalance_ratio: 1.05,
            rebalance_max_moves: 128,
            ..cfg.clone()
        };
        let pcfg = ServeConfig { serve_threads: 4, ..cfg.clone() };
        let mut inc = Server::for_dataset(&ds, params.clone(), cfg.clone())
            .map_err(|e| format!("build inc: {e:#}"))?;
        let mut reb = Server::for_dataset(&ds, params.clone(), rcfg)
            .map_err(|e| format!("build reb: {e:#}"))?;
        let mut bal = Server::for_dataset(&ds, params.clone(), bcfg)
            .map_err(|e| format!("build bal: {e:#}"))?;
        let mut par = Server::for_dataset(&ds, params.clone(), pcfg)
            .map_err(|e| format!("build par: {e:#}"))?;
        let warm: Vec<u32> = (0..ds.num_nodes() as u32).collect();
        inc.query_batch(&warm).map_err(|e| format!("warm inc: {e:#}"))?;
        reb.query_batch(&warm).map_err(|e| format!("warm reb: {e:#}"))?;
        bal.query_batch(&warm).map_err(|e| format!("warm bal: {e:#}"))?;
        par.query_batch(&warm).map_err(|e| format!("warm par: {e:#}"))?;

        // mirror of the evolving deployment, for the fresh oracle
        let mut graph = ds.graph.clone();
        let mut features = ds.features.clone();
        let mut dead: HashSet<u32> = HashSet::new();

        for round in 0..3 {
            let n = graph.num_nodes();
            let alive: Vec<u32> = (0..n as u32).filter(|v| !dead.contains(v)).collect();
            let mut d = GraphDelta::default();
            for _ in 0..1 + rng.gen_range(3) {
                let u = *rng.choose(&alive);
                let v = *rng.choose(&alive);
                if u != v {
                    d.added_edges.push((u, v));
                }
            }
            let live_edges: Vec<(u32, u32)> = graph.edges().collect();
            if !live_edges.is_empty() {
                for _ in 0..rng.gen_range(3) {
                    d.removed_edges.push(*rng.choose(&live_edges));
                }
            }
            if rng.gen_bool(0.7) {
                let v = *rng.choose(&alive);
                let row: Vec<f32> = (0..fdim).map(|_| (rng.gen_f32() - 0.5) * 2.0).collect();
                d.updated_features.push((v, row));
            }
            if rng.gen_bool(0.8) {
                let mut attach = vec![*rng.choose(&alive)];
                if rng.gen_bool(0.5) {
                    let other = *rng.choose(&alive);
                    if other != attach[0] {
                        attach.push(other);
                    }
                }
                let row: Vec<f32> = (0..fdim).map(|_| (rng.gen_f32() - 0.5) * 2.0).collect();
                d.added_nodes.push(NewNode { features: row, edges: attach });
            }
            if rng.gen_bool(0.5) && alive.len() > 4 {
                let v = *rng.choose(&alive);
                // a delta may not touch the node it removes
                d.added_edges.retain(|&(a, b)| a != v && b != v);
                d.removed_edges.retain(|&(a, b)| a != v && b != v);
                d.updated_features.retain(|(a, _)| *a != v);
                for nn in &mut d.added_nodes {
                    nn.edges.retain(|&e| e != v);
                }
                d.removed_nodes.push(v);
            }

            let ri = inc.apply_delta(&d).map_err(|e| format!("round {round} inc: {e:#}"))?;
            let rr = reb.apply_delta(&d).map_err(|e| format!("round {round} reb: {e:#}"))?;
            if ri.graph_version != rr.graph_version {
                return Err("modes disagree on version".into());
            }
            bal.apply_delta(&d).map_err(|e| format!("round {round} bal: {e:#}"))?;
            // force an extra migration pass beyond the automatic
            // trigger: rebalancing must never move an answer
            bal.rebalance();
            par.apply_delta(&d).map_err(|e| format!("round {round} par: {e:#}"))?;

            // evolve the mirror through the O(E) oracle
            graph = d.apply_to(&graph);
            for (v, row) in &d.updated_features {
                features.row_mut(*v as usize).copy_from_slice(row);
            }
            for nn in &d.added_nodes {
                features.push_row(&nn.features);
            }
            for &v in &d.removed_nodes {
                dead.insert(v);
            }

            let mut ds2 = ds.clone();
            ds2.graph = graph.clone();
            ds2.features = features.clone();
            let mut fresh = Server::for_dataset(&ds2, params.clone(), cfg.clone())
                .map_err(|e| format!("round {round} fresh: {e:#}"))?;

            let q: Vec<u32> =
                (0..graph.num_nodes() as u32).filter(|v| !dead.contains(v)).collect();
            let a = inc.query_batch(&q).map_err(|e| format!("round {round} q inc: {e:#}"))?;
            let b = reb.query_batch(&q).map_err(|e| format!("round {round} q reb: {e:#}"))?;
            let m = bal.query_batch(&q).map_err(|e| format!("round {round} q bal: {e:#}"))?;
            let p = par.query_batch(&q).map_err(|e| format!("round {round} q par: {e:#}"))?;
            let c = fresh.query_batch(&q).map_err(|e| format!("round {round} q fresh: {e:#}"))?;
            for ((((x, y), w), v), z) in a.iter().zip(&b).zip(&m).zip(&p).zip(&c) {
                let bits =
                    |r: &gad::serve::QueryResult| -> Vec<u32> { r.probs.iter().map(|p| p.to_bits()).collect() };
                if x.pred != z.pred || bits(x) != bits(z) {
                    return Err(format!(
                        "round {round}: incremental diverged from fresh at node {} \
                         ({} rebuilt, {} invalidated)",
                        x.node, ri.shards_rebuilt, ri.rows_invalidated
                    ));
                }
                if y.pred != z.pred || bits(y) != bits(z) {
                    return Err(format!(
                        "round {round}: rebuild-mode diverged from fresh at node {}",
                        y.node
                    ));
                }
                if w.pred != z.pred || bits(w) != bits(z) {
                    return Err(format!(
                        "round {round}: rebalanced server diverged from fresh at node {} \
                         ({} nodes migrated so far)",
                        w.node,
                        bal.stats().nodes_migrated
                    ));
                }
                if v.pred != z.pred || bits(v) != bits(z) {
                    return Err(format!(
                        "round {round}: parallel serve pool diverged from fresh at node {}",
                        v.node
                    ));
                }
            }
            // the parallel pool must also keep the *counters* of the
            // sequential incremental server, exactly — same graph, same
            // batches, same caches, just overlapped
            let (si, sp) = (inc.stats(), par.stats());
            if (si.queries, si.micro_batches, si.cache_hits, si.rows_recomputed)
                != (sp.queries, sp.micro_batches, sp.cache_hits, sp.rows_recomputed)
            {
                return Err(format!(
                    "round {round}: parallel counters drifted from sequential \
                     (q {}/{}, mb {}/{}, hits {}/{}, rows {}/{})",
                    si.queries,
                    sp.queries,
                    si.micro_batches,
                    sp.micro_batches,
                    si.cache_hits,
                    sp.cache_hits,
                    si.rows_recomputed,
                    sp.rows_recomputed
                ));
            }
            // retired ids must reject queries in every mode
            if let Some(&v) = d.removed_nodes.first() {
                if inc.query(v).is_ok()
                    || reb.query(v).is_ok()
                    || bal.query(v).is_ok()
                    || par.query(v).is_ok()
                {
                    return Err(format!("round {round}: retired node {v} still answers"));
                }
            }
        }
        Ok(())
    });
}
