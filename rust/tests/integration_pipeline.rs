//! Integration: the full GAD pipeline (dataset → partition → augment →
//! load → distributed train → eval) on the native backend, plus the
//! paper's qualitative claims at miniature scale.

use gad::coordinator::{train_gad, ConsensusMode, TrainConfig};
use gad::datasets::SyntheticSpec;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        partitions: 6,
        workers: 3,
        layers: 2,
        hidden: 32,
        lr: 0.02,
        epochs: 40,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn pipeline_reaches_reasonable_accuracy() {
    let ds = SyntheticSpec::tiny().generate(21);
    let r = train_gad(&ds, &base_cfg()).unwrap();
    assert!(r.test_accuracy > 0.6, "accuracy {}", r.test_accuracy);
    // loss decreased substantially
    let first = r.curve.first().unwrap().loss;
    let last = r.curve.last().unwrap().loss;
    assert!(last < 0.7 * first, "loss {first} -> {last}");
}

#[test]
fn table4_shape_augmentation_recovers_accuracy_and_cuts_comm() {
    // the paper's Table 4 structure: distributed w/o augmentation loses
    // accuracy vs augmented; augmentation halves feature traffic and
    // costs a little memory
    let ds = SyntheticSpec::tiny().generate(22);
    let mut cfg = base_cfg();
    cfg.epochs = 40;
    cfg.alpha = 0.05;

    cfg.augment = true;
    let aug = train_gad(&ds, &cfg).unwrap();
    cfg.augment = false;
    let plain = train_gad(&ds, &cfg).unwrap();

    assert!(
        aug.comm.feature_bytes < plain.comm.feature_bytes,
        "feature comm should drop: {} vs {}",
        aug.comm.feature_bytes,
        plain.comm.feature_bytes
    );
    let aug_mem: usize = aug.memory_per_worker.iter().sum();
    let plain_mem: usize = plain.memory_per_worker.iter().sum();
    assert!(aug_mem >= plain_mem, "replicas cost memory");
    // accuracy with augmentation should not be (much) worse
    assert!(
        aug.test_accuracy >= plain.test_accuracy - 0.05,
        "aug {} plain {}",
        aug.test_accuracy,
        plain.test_accuracy
    );
}

#[test]
fn table3_shape_accuracy_stable_across_workers() {
    // paper Table 3: accuracy fluctuation < ~0.01-0.05 as workers vary
    let ds = SyntheticSpec::tiny().generate(23);
    let mut accs = Vec::new();
    for workers in [1usize, 2, 4] {
        let cfg = TrainConfig { workers, partitions: 4.max(workers), ..base_cfg() };
        let r = train_gad(&ds, &cfg).unwrap();
        accs.push(r.test_accuracy);
    }
    let max = accs.iter().cloned().fold(f32::MIN, f32::max);
    let min = accs.iter().cloned().fold(f32::MAX, f32::min);
    assert!(max - min < 0.12, "accuracy spread too wide: {accs:?}");
}

#[test]
fn fig9_shape_weighted_consensus_not_worse() {
    // weighted consensus should converge at least as low as plain
    let ds = SyntheticSpec::tiny().generate(24);
    let mut cfg = base_cfg();
    cfg.partitions = 8;
    cfg.epochs = 30;

    cfg.consensus = ConsensusMode::Weighted;
    let weighted = train_gad(&ds, &cfg).unwrap();
    cfg.consensus = ConsensusMode::Plain;
    let plain = train_gad(&ds, &cfg).unwrap();

    let wl = weighted.curve.last().unwrap().loss;
    let pl = plain.curve.last().unwrap().loss;
    assert!(wl <= pl * 1.15, "weighted {wl} vs plain {pl}");
}

#[test]
fn gradient_comm_scales_with_workers() {
    let ds = SyntheticSpec::tiny().generate(25);
    let mut cfg = base_cfg();
    cfg.epochs = 5;
    cfg.workers = 1;
    cfg.partitions = 4;
    let one = train_gad(&ds, &cfg).unwrap();
    cfg.workers = 4;
    let four = train_gad(&ds, &cfg).unwrap();
    // a single co-located worker syncs nothing; 4 workers pay the
    // up+down gradient exchange every round
    assert_eq!(one.comm.gradient_bytes, 0);
    assert!(four.comm.gradient_bytes > 0);
}

#[test]
fn training_survives_worker_crash() {
    use gad::coordinator::{Fault, FaultPlan};
    let ds = SyntheticSpec::tiny().generate(27);
    let mut cfg = base_cfg();
    cfg.workers = 3;
    cfg.partitions = 6;
    cfg.epochs = 20;
    cfg.faults = FaultPlan { faults: vec![Fault::Crash { worker: 1, epoch: 5 }] };
    let r = train_gad(&ds, &cfg).unwrap();
    // run completes and still learns from the surviving workers
    assert_eq!(r.epochs_run, 20);
    assert!(r.test_accuracy > 0.4, "accuracy after crash {}", r.test_accuracy);
    // healthy baseline sees strictly more test nodes than the degraded run
    cfg.faults = FaultPlan::none();
    let healthy = train_gad(&ds, &cfg).unwrap();
    assert!(healthy.test_accuracy >= r.test_accuracy - 0.15);
}

#[test]
fn straggler_slows_rounds_but_preserves_result() {
    use gad::coordinator::{Fault, FaultPlan};
    let ds = SyntheticSpec::tiny().generate(28);
    let mut cfg = base_cfg();
    cfg.epochs = 6;
    let fast = train_gad(&ds, &cfg).unwrap();
    cfg.faults = FaultPlan {
        faults: vec![Fault::Straggle { worker: 0, epoch: 0, millis: 30 }],
    };
    let slow = train_gad(&ds, &cfg).unwrap();
    assert!(
        slow.wall_seconds > fast.wall_seconds,
        "straggler should stretch synchronous rounds ({} vs {})",
        slow.wall_seconds,
        fast.wall_seconds
    );
    // determinism unaffected: same consensus sequence, same accuracy
    assert_eq!(slow.test_accuracy, fast.test_accuracy);
}

#[test]
fn lr_schedules_train() {
    use gad::model::LrSchedule;
    let ds = SyntheticSpec::tiny().generate(29);
    for schedule in [
        LrSchedule::Constant,
        LrSchedule::Warmup { epochs: 3 },
        LrSchedule::Cosine { total: 15, floor: 0.1 },
    ] {
        let mut cfg = base_cfg();
        cfg.epochs = 15;
        cfg.schedule = schedule;
        let r = train_gad(&ds, &cfg).unwrap();
        assert!(r.test_accuracy > 0.4, "{schedule:?}: {}", r.test_accuracy);
    }
}

#[test]
fn network_estimate_reflects_topology() {
    use gad::comm::Topology;
    let ds = SyntheticSpec::tiny().generate(30);
    let mut cfg = base_cfg();
    cfg.epochs = 5;
    cfg.workers = 4;
    cfg.topology = Topology::Star;
    let star = train_gad(&ds, &cfg).unwrap();
    cfg.topology = Topology::FullMesh;
    let mesh = train_gad(&ds, &cfg).unwrap();
    assert!(star.network_time_est_sec > mesh.network_time_est_sec);
}

#[test]
fn curve_is_monotone_in_epochs_field() {
    let ds = SyntheticSpec::tiny().generate(26);
    let mut cfg = base_cfg();
    cfg.epochs = 10;
    let r = train_gad(&ds, &cfg).unwrap();
    for (i, p) in r.curve.iter().enumerate() {
        assert_eq!(p.epoch, i);
    }
    assert!(r.wall_seconds > 0.0);
}
