//! Serving correctness: the degenerate deployment must reproduce the
//! training-time forward bit-for-bit, exact-halo sharding must not
//! change a single answer, and the delta-invalidation path must be
//! indistinguishable from a from-scratch recompute.

use gad::augment::plain_part;
use gad::backend::{Backend, NativeBackend};
use gad::coordinator::{batch_from_subgraph, train_gad, TrainConfig};
use gad::datasets::{Dataset, SyntheticSpec};
use gad::graph::GraphBuilder;
use gad::model::{checkpoint, GcnParams};
use gad::proptest_util::forall;
use gad::rng::Rng;
use gad::serve::{
    run_serving_bench, GraphDelta, HaloPolicy, NewNode, ServeConfig, Server, ServingBenchConfig,
};
use gad::tensor::Matrix;

/// The training-time full-graph forward — the oracle every serving
/// configuration is measured against.
fn native_preds(ds: &Dataset, params: &GcnParams) -> Vec<u32> {
    let assignment = vec![0u32; ds.num_nodes()];
    let aug = plain_part(&ds.graph, &assignment, 0);
    let batch = batch_from_subgraph(ds, &aug, 0);
    NativeBackend::new().predict(&batch, params).expect("native forward")
}

fn fixture(seed: u64, layers: usize) -> (Dataset, GcnParams) {
    let ds = SyntheticSpec::tiny().generate(seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
    let params = GcnParams::init(ds.feature_dim(), 16, ds.num_classes, layers, &mut rng);
    (ds, params)
}

fn all_nodes(ds: &Dataset) -> Vec<u32> {
    (0..ds.num_nodes() as u32).collect()
}

/// Extend a dataset mirror for nodes inserted online. The serving tier
/// never sees labels or splits, but the training-forward oracle
/// (`native_preds` → `batch_from_subgraph`) indexes both per node, so
/// the mirror must stay rectangular. (PR 4's elastic round-trip test
/// grew only graph+features — a latent out-of-bounds panic this sweep
/// fixed.)
fn extend_mirror(ds: &mut Dataset, added: usize) {
    for _ in 0..added {
        ds.labels.push(0);
        ds.split.train.push(false);
        ds.split.val.push(false);
        ds.split.test.push(true);
    }
}

#[test]
fn degenerate_config_is_bit_identical_to_training_forward() {
    // single shard, no cache, no pruning: the serving pipeline reduced
    // to "run the model" — must agree with the trainer's forward on
    // every node, bit for bit
    let (ds, params) = fixture(1, 2);
    let oracle = native_preds(&ds, &params);
    let cfg = ServeConfig { shards: 1, cache: false, pruned: false, ..Default::default() };
    let mut srv = Server::for_dataset(&ds, params.clone(), cfg).unwrap();
    let res = srv.query_batch(&all_nodes(&ds)).unwrap();
    let preds: Vec<u32> = res.iter().map(|r| r.pred).collect();
    assert_eq!(preds, oracle);
    // the full feature set on (cache + pruning) must not change a bit
    let mut srv2 = Server::for_dataset(&ds, params, ServeConfig { shards: 1, ..Default::default() })
        .unwrap();
    let res2 = srv2.query_batch(&all_nodes(&ds)).unwrap();
    for (a, b) in res.iter().zip(&res2) {
        assert_eq!(a.pred, b.pred);
        assert_eq!(
            a.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn exact_halo_sharding_matches_full_graph_forward() {
    // the tentpole claim: with complete L-hop halos and global-degree
    // normalization, every shard-local answer equals the full-graph
    // forward exactly — zero cross-shard fetches, zero approximation
    for layers in [1usize, 2, 3] {
        let (ds, params) = fixture(2 + layers as u64, layers);
        let oracle = native_preds(&ds, &params);
        for shards in [2usize, 4, 7] {
            let cfg = ServeConfig { shards, halo: HaloPolicy::Exact, ..Default::default() };
            let mut srv = Server::for_dataset(&ds, params.clone(), cfg).unwrap();
            let preds: Vec<u32> =
                srv.query_batch(&all_nodes(&ds)).unwrap().iter().map(|r| r.pred).collect();
            assert_eq!(preds, oracle, "layers={layers} shards={shards}");
        }
    }
}

#[test]
fn budgeted_halo_is_approximate_but_mostly_agrees() {
    let (ds, params) = fixture(9, 2);
    let oracle = native_preds(&ds, &params);
    let cfg = ServeConfig {
        shards: 4,
        halo: HaloPolicy::Budgeted { alpha: 0.05 },
        ..Default::default()
    };
    let mut srv = Server::for_dataset(&ds, params, cfg).unwrap();
    let preds: Vec<u32> =
        srv.query_batch(&all_nodes(&ds)).unwrap().iter().map(|r| r.pred).collect();
    let agree = preds.iter().zip(&oracle).filter(|(a, b)| a == b).count();
    // the truncated halo only perturbs boundary neighbourhoods
    assert!(
        agree as f64 >= 0.7 * oracle.len() as f64,
        "budgeted halo agreement {agree}/{}",
        oracle.len()
    );
}

#[test]
fn batching_cannot_change_answers() {
    let (ds, params) = fixture(4, 2);
    let cfg = ServeConfig::default();
    let mut batched = Server::for_dataset(&ds, params.clone(), cfg.clone()).unwrap();
    let mut single = Server::for_dataset(&ds, params, cfg).unwrap();
    let nodes: Vec<u32> = (0..60).map(|i| (i * 7) % ds.num_nodes() as u32).collect();
    let res = batched.query_batch(&nodes).unwrap();
    for (r, &v) in res.iter().zip(&nodes) {
        let s = single.query(v).unwrap();
        assert_eq!(r.pred, s.pred);
        assert_eq!(
            r.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            s.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "node {v}: micro-batching changed the numerics"
        );
    }
}

#[test]
fn warm_cache_serves_identical_results() {
    let (ds, params) = fixture(5, 3);
    let mut srv = Server::for_dataset(&ds, params, ServeConfig::default()).unwrap();
    let nodes = all_nodes(&ds);
    let cold = srv.query_batch(&nodes).unwrap();
    let warm = srv.query_batch(&nodes).unwrap();
    let mut hits = 0usize;
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.pred, w.pred);
        assert_eq!(
            c.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            w.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        hits += w.cache_hit as usize;
    }
    assert_eq!(hits, nodes.len(), "second pass must be all cache hits");
    assert_eq!(srv.stats().cache_hits as usize, nodes.len());
}

/// Random online mutations: the cached server's post-delta answers must
/// be bit-identical to a server built from scratch on the mutated
/// graph — delta invalidation may never save a stale row.
#[test]
fn delta_invalidation_matches_from_scratch_recompute() {
    forall("delta == fresh rebuild", 6, |rng| {
        let seed = rng.next_u64() % 1_000;
        let ds = SyntheticSpec::tiny().generate(seed);
        let mut prng = Rng::seed_from_u64(seed ^ 0xD1);
        let params = GcnParams::init(ds.feature_dim(), 12, ds.num_classes, 2, &mut prng);
        let n = ds.num_nodes();

        // random delta: a few adds, removes and feature rewrites
        let edges: Vec<(u32, u32)> = ds.graph.edges().collect();
        let added: Vec<(u32, u32)> = (0..1 + rng.gen_range(3))
            .filter_map(|_| {
                let u = rng.gen_range(n) as u32;
                let v = rng.gen_range(n) as u32;
                (u != v).then_some((u, v))
            })
            .collect();
        let removed: Vec<(u32, u32)> =
            (0..1 + rng.gen_range(3)).map(|_| *rng.choose(&edges)).collect();
        let updated: Vec<(u32, Vec<f32>)> = (0..rng.gen_range(3))
            .map(|_| {
                let v = rng.gen_range(n) as u32;
                let row: Vec<f32> =
                    (0..ds.feature_dim()).map(|_| (rng.gen_f32() - 0.5) * 2.0).collect();
                (v, row)
            })
            .collect();
        let delta = GraphDelta {
            added_edges: added,
            removed_edges: removed,
            updated_features: updated,
            ..Default::default()
        };

        // cached server: warm on the old graph, then mutate
        let cfg = ServeConfig { shards: 3, seed: 7, ..Default::default() };
        let mut cached = Server::for_dataset(&ds, params.clone(), cfg.clone())
            .map_err(|e| format!("build: {e:#}"))?;
        let nodes: Vec<u32> = (0..n as u32).collect();
        cached.query_batch(&nodes).map_err(|e| format!("warm: {e:#}"))?;
        let rep = cached.apply_delta(&delta).map_err(|e| format!("delta: {e:#}"))?;
        let after = cached.query_batch(&nodes).map_err(|e| format!("requery: {e:#}"))?;

        // oracle 1: a server that never saw the old graph
        let mut ds2 = ds.clone();
        ds2.graph = delta.apply_to(&ds.graph);
        for (v, row) in &delta.updated_features {
            ds2.features.row_mut(*v as usize).copy_from_slice(row);
        }
        let mut fresh = Server::for_dataset(&ds2, params.clone(), cfg)
            .map_err(|e| format!("fresh build: {e:#}"))?;
        let scratch = fresh.query_batch(&nodes).map_err(|e| format!("fresh query: {e:#}"))?;

        for (a, b) in after.iter().zip(&scratch) {
            if a.pred != b.pred
                || a.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                    != b.probs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            {
                return Err(format!(
                    "node {}: cached-after-delta != from-scratch (v{}, {} rows invalidated)",
                    a.node, rep.graph_version, rep.rows_invalidated
                ));
            }
        }

        // oracle 2: the full-graph training forward on the mutated data
        let oracle = native_preds(&ds2, &params);
        for (a, want) in after.iter().zip(&oracle) {
            if a.pred != *want {
                return Err(format!("node {}: delta'd server diverged from oracle", a.node));
            }
        }
        Ok(())
    });
}

/// Elastic membership round-trip: insert a node online, serve it
/// bit-identically to the full-graph oracle on the extended graph,
/// then remove it and get the original graph's answers back — shard,
/// halo and cache state updated incrementally, replication bytes
/// visible in the serving ledger, no offline reshard anywhere.
#[test]
fn elastic_add_remove_node_round_trip() {
    let (ds, params) = fixture(14, 2);
    let fdim = ds.feature_dim();
    let mut srv = Server::for_dataset(&ds, params.clone(), ServeConfig::default()).unwrap();
    srv.query_batch(&all_nodes(&ds)).unwrap(); // warm
    let bytes_before = srv.stats().comm.serving_bytes;
    let version_before = srv.graph_version();

    // ---- insert, attached to two existing nodes ---------------------
    let new_id = ds.num_nodes() as u32;
    let new_row: Vec<f32> = (0..fdim).map(|i| 0.1 * (i as f32 + 1.0)).collect();
    let rep = srv
        .apply_delta(&GraphDelta {
            added_nodes: vec![NewNode { features: new_row.clone(), edges: vec![0, 5] }],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(rep.nodes_added, 1);
    assert!(rep.graph_version > version_before);

    // oracle: the training-time forward on the extended graph
    let mut ds2 = ds.clone();
    ds2.graph = GraphDelta {
        added_nodes: vec![NewNode { features: new_row.clone(), edges: vec![0, 5] }],
        ..Default::default()
    }
    .apply_to(&ds.graph);
    ds2.features.push_row(&new_row);
    extend_mirror(&mut ds2, 1);
    let oracle2 = native_preds(&ds2, &params);
    let q2: Vec<u32> = (0..ds2.num_nodes() as u32).collect();
    let res2 = srv.query_batch(&q2).unwrap();
    for (r, want) in res2.iter().zip(&oracle2) {
        assert_eq!(r.pred, *want, "node {} after elastic insert", r.node);
    }
    assert_eq!(srv.shard_of(new_id), srv.query(new_id).unwrap().shard);
    let bytes_mid = srv.stats().comm.serving_bytes;
    assert!(bytes_mid > bytes_before, "membership churn must cost visible bytes");

    // ---- remove the node again --------------------------------------
    let rep = srv
        .apply_delta(&GraphDelta { removed_nodes: vec![new_id], ..Default::default() })
        .unwrap();
    assert_eq!(rep.nodes_removed, 1);
    assert!(srv.query(new_id).is_err(), "retired id must reject queries");
    // surviving nodes answer exactly as on the original graph
    let oracle = native_preds(&ds, &params);
    let res = srv.query_batch(&all_nodes(&ds)).unwrap();
    for (r, want) in res.iter().zip(&oracle) {
        assert_eq!(r.pred, *want, "node {} after elastic remove", r.node);
    }
    let st = srv.stats();
    assert_eq!(st.nodes_added, 1);
    assert_eq!(st.nodes_removed, 1);
}

/// Budgeted halos + gather: answers become bit-identical to the
/// full-graph forward — the halo's missing rows are fetched from their
/// home shards instead of approximated, and every fetch lands in the
/// serving traffic class.
#[test]
fn budgeted_gather_is_exact_and_accounted() {
    let (ds, params) = fixture(16, 2);
    let oracle = native_preds(&ds, &params);
    let cfg = ServeConfig {
        shards: 4,
        halo: HaloPolicy::Budgeted { alpha: 0.02 },
        gather_missing: true,
        ..Default::default()
    };
    let mut srv = Server::for_dataset(&ds, params.clone(), cfg).unwrap();
    let build_bytes = srv.stats().comm.serving_bytes;
    let res = srv.query_batch(&all_nodes(&ds)).unwrap();
    let preds: Vec<u32> = res.iter().map(|r| r.pred).collect();
    assert_eq!(preds, oracle, "gather mode must erase the budgeted approximation");
    let st = srv.stats();
    assert!(
        st.comm.serving_bytes > build_bytes,
        "missing-row fetches must be accounted"
    );
    assert_eq!(st.queries as usize, ds.num_nodes());

    // a single-shard deployment holds everything: gather fetches nothing
    let cfg1 = ServeConfig {
        shards: 1,
        halo: HaloPolicy::Budgeted { alpha: 0.02 },
        gather_missing: true,
        ..Default::default()
    };
    let mut one = Server::for_dataset(&ds, params, cfg1).unwrap();
    let before = one.stats().comm.serving_bytes;
    let res1 = one.query_batch(&all_nodes(&ds)).unwrap();
    assert_eq!(res1.iter().map(|r| r.pred).collect::<Vec<_>>(), oracle);
    assert_eq!(
        one.stats().comm.serving_bytes,
        before,
        "one shard owns every row — zero gather bytes"
    );
}

/// Satellite regression: gather-mode byte accounting on a hand-built
/// two-clique graph, asserted EXACTLY against the documented rule —
/// a row already replicated in the consumer's halo is never billed,
/// every other input row of the cone is billed once per consumer, and
/// with the cross-request gathered-row cache a repeat query bills zero.
#[test]
fn gather_bytes_are_exact_and_halo_replicas_are_never_billed() {
    // two 6-cliques bridged by (3,6),(4,7),(5,8): the 2-partition
    // splits the cliques, and the tiny replication budget (alpha 0.01
    // -> one replica per part) cannot cover the three bridge
    // candidates, so cross-shard fetches must happen
    let mut edges = vec![(3u32, 6u32), (4, 7), (5, 8)];
    for base in [0u32, 6] {
        for i in 0..6 {
            for j in (i + 1)..6 {
                edges.push((base + i, base + j));
            }
        }
    }
    let graph = GraphBuilder::new(12).edges(&edges).build();
    let fdim = 5usize;
    let mut features = Matrix::zeros(12, fdim);
    for v in 0..12 {
        for c in 0..fdim {
            features[(v, c)] = (v * fdim + c) as f32 * 0.1;
        }
    }
    let mut rng = Rng::seed_from_u64(77);
    // one layer: the cone of a query is exactly its closed neighbourhood
    let params = GcnParams::init(fdim, 8, 3, 1, &mut rng);
    let cfg = ServeConfig {
        shards: 2,
        halo: HaloPolicy::Budgeted { alpha: 0.01 },
        gather_missing: true,
        gather_cache_budget_bytes: 1 << 20,
        ..Default::default()
    };
    let mut srv =
        Server::build(graph.clone(), features.clone(), params.clone(), cfg.clone()).unwrap();
    assert_ne!(srv.shard_of(0), srv.shard_of(11), "partitioner must split the cliques");

    // the documented rule, recomputed independently: one feature row
    // per distinct (neighbour, consumer shard) pair the consumer does
    // not already replicate; replicated rows (base or sampled halo
    // member) are free
    let frow = (fdim * 4) as u64;
    let batch = vec![3u32, 4, 5];
    let mut billed: std::collections::HashSet<(u32, u32)> = std::collections::HashSet::new();
    for &q in &batch {
        let consumer = srv.shard_of(q);
        for &t in graph.neighbors(q as usize) {
            if t != q && srv.shard(consumer as usize).local_of(t).is_none() {
                billed.insert((t, consumer));
            }
        }
    }
    let expected = billed.len() as u64 * frow;
    assert!(expected > 0, "the fixture must force at least one fetch");

    let before = srv.stats().comm.serving_bytes;
    srv.query_batch(&batch).unwrap();
    let first = srv.stats().comm.serving_bytes - before;
    assert_eq!(first, expected, "gather must bill exactly the non-replicated cone rows");

    // repeat request: the retained output rows short-circuit the whole
    // cone (and any re-walked row is covered by the fetched copies), so
    // the bill is zero
    let mid = srv.stats().comm.serving_bytes;
    let repeat = srv.query_batch(&batch).unwrap();
    assert_eq!(srv.stats().comm.serving_bytes - mid, 0, "cached copies must not re-bill");
    assert!(srv.stats().gather_rows_reused > 0, "repeat request must reuse cached rows");
    assert!(repeat.iter().all(|r| r.cache_hit), "reused outputs must show in provenance");

    // without the cache the same request re-bills the same exact amount
    let cfg_nc = ServeConfig { gather_cache_budget_bytes: 0, ..cfg };
    let mut nc = Server::build(graph.clone(), features, params, cfg_nc).unwrap();
    let b0 = nc.stats().comm.serving_bytes;
    nc.query_batch(&batch).unwrap();
    let n1 = nc.stats().comm.serving_bytes - b0;
    nc.query_batch(&batch).unwrap();
    let n2 = nc.stats().comm.serving_bytes - b0 - n1;
    assert_eq!(n1, expected);
    assert_eq!(n2, expected, "per-request accounting is stateless without the cache");
}

/// The gathered-row cache may change bytes and latency, never answers:
/// cached and uncached gather deployments must agree bit-for-bit with
/// each other and with the full-graph oracle, across repeat queries and
/// a delta (which clears the cache).
#[test]
fn gather_cache_never_changes_answers() {
    let (ds, params) = fixture(23, 2);
    let oracle = native_preds(&ds, &params);
    let base = ServeConfig {
        shards: 4,
        halo: HaloPolicy::Budgeted { alpha: 0.02 },
        gather_missing: true,
        ..Default::default()
    };
    let cached_cfg = ServeConfig { gather_cache_budget_bytes: 1 << 20, ..base.clone() };
    let mut plain = Server::for_dataset(&ds, params.clone(), base).unwrap();
    let mut cached = Server::for_dataset(&ds, params.clone(), cached_cfg).unwrap();
    let nodes = all_nodes(&ds);
    for pass in 0..2 {
        let a = plain.query_batch(&nodes).unwrap();
        let b = cached.query_batch(&nodes).unwrap();
        for ((x, y), want) in a.iter().zip(&b).zip(&oracle) {
            assert_eq!(x.pred, *want, "pass {pass} node {}", x.node);
            assert_eq!(y.pred, *want, "pass {pass} node {}", y.node);
            assert_eq!(
                x.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "pass {pass} node {}: cache changed the numerics",
                x.node
            );
        }
    }
    let st = cached.stats();
    assert!(st.gather_rows_reused > 0, "second pass must reuse cached rows");
    assert!(
        cached.stats().comm.serving_bytes < plain.stats().comm.serving_bytes,
        "the cache must save bytes across requests"
    );
    // a delta clears the cache; answers track the mutated oracle
    let delta = GraphDelta { added_edges: vec![(0, 9)], ..Default::default() };
    plain.apply_delta(&delta).unwrap();
    cached.apply_delta(&delta).unwrap();
    let a = plain.query_batch(&nodes).unwrap();
    let b = cached.query_batch(&nodes).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.pred, y.pred);
        assert_eq!(
            x.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "post-delta node {}: stale cached row served",
            x.node
        );
    }
}

/// Skewed elastic inserts drift the base counts; the rebalancer must
/// pull the ratio back under the threshold while every answer stays
/// bit-identical to the full-graph forward on the evolved graph.
#[test]
fn rebalancer_converges_and_preserves_answers_under_skewed_inserts() {
    let (ds, params) = fixture(31, 2);
    let fdim = ds.feature_dim();
    let ratio = 1.5f64;
    let cfg = ServeConfig {
        shards: 4,
        rebalance: true,
        rebalance_ratio: ratio,
        rebalance_max_moves: 64,
        ..Default::default()
    };
    let cfg_off = ServeConfig { rebalance: false, ..cfg.clone() };
    let mut on = Server::for_dataset(&ds, params.clone(), cfg).unwrap();
    let mut off = Server::for_dataset(&ds, params.clone(), cfg_off).unwrap();
    on.query_batch(&all_nodes(&ds)).unwrap(); // warm caches before churn
    let hot: Vec<u32> = (0..ds.num_nodes() as u32).filter(|&v| on.shard_of(v) == 0).collect();
    assert!(!hot.is_empty());

    // evolving mirror for the oracle
    let mut ds2 = ds.clone();
    let mut migrated_total = 0u64;
    for round in 0..6 {
        let delta = GraphDelta {
            added_nodes: (0..12)
                .map(|i| NewNode {
                    features: vec![0.05 * (i as f32 + 1.0); fdim],
                    edges: vec![hot[(round * 12 + i) % hot.len()]],
                })
                .collect(),
            ..Default::default()
        };
        let rep_on = on.apply_delta(&delta).unwrap();
        off.apply_delta(&delta).unwrap();
        migrated_total += rep_on.rebalance_moves as u64;
        assert!(
            on.imbalance_ratio() <= ratio + 1e-9,
            "round {round}: rebalancer left ratio {:.3}",
            on.imbalance_ratio()
        );
        ds2.graph = delta.apply_to(&ds2.graph);
        let added = delta.added_nodes.len();
        for nn in &delta.added_nodes {
            ds2.features.push_row(&nn.features);
        }
        extend_mirror(&mut ds2, added);
    }
    assert!(
        off.imbalance_ratio() > ratio,
        "the skew must actually break balance without the rebalancer (got {:.3})",
        off.imbalance_ratio()
    );
    assert!(migrated_total > 0, "convergence must come from real migrations");
    let st = on.stats();
    assert!(st.rebalances > 0);
    assert_eq!(st.nodes_migrated, migrated_total);
    assert!(st.comm.rebalance_bytes > 0, "migrated bytes must be accounted");
    assert_eq!(off.stats().comm.rebalance_bytes, 0);

    // bit-identity after all that migration
    let oracle = native_preds(&ds2, &params);
    let q: Vec<u32> = (0..ds2.num_nodes() as u32).collect();
    let res_on = on.query_batch(&q).unwrap();
    let res_off = off.query_batch(&q).unwrap();
    for ((a, b), want) in res_on.iter().zip(&res_off).zip(&oracle) {
        assert_eq!(a.pred, *want, "node {} diverged after migrations", a.node);
        assert_eq!(
            a.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "node {}: rebalanced and drifting deployments must agree bit-for-bit",
            a.node
        );
    }
}

#[test]
fn checkpoint_to_serving_pipeline() {
    // the end-to-end path the CLI and example walk: train briefly,
    // checkpoint, reload validated, serve
    let ds = SyntheticSpec::tiny().generate(21);
    let cfg = TrainConfig {
        partitions: 4,
        workers: 2,
        layers: 2,
        hidden: 24,
        epochs: 4,
        seed: 21,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).unwrap();
    let params = report.final_params.expect("harvested params");
    let path = std::env::temp_dir().join("gad_serve_pipeline_test.ckpt");
    checkpoint::save(&params, &path).unwrap();
    let loaded = checkpoint::load_validated(&path, ds.feature_dim(), ds.num_classes).unwrap();
    // wrong deployment dims must fail cleanly, not serve garbage
    assert!(checkpoint::load_validated(&path, ds.feature_dim() + 1, ds.num_classes).is_err());
    std::fs::remove_file(&path).ok();

    let mut srv = Server::for_dataset(&ds, loaded, ServeConfig::default()).unwrap();
    let res = srv.query(0).unwrap();
    assert!((res.probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    // and the served answers are still the training forward's answers
    let oracle = native_preds(&ds, srv.params());
    let preds: Vec<u32> =
        srv.query_batch(&all_nodes(&ds)).unwrap().iter().map(|r| r.pred).collect();
    assert_eq!(preds, oracle);
}

/// Tentpole (PR 9): the gather→GEMM pipeline must actually overlap —
/// a warm partial recompute whose next layer has safe rows emits a
/// `serve.pipeline` span with a `serve.gather_prefetch` child on the
/// worker thread — and the overlapped answers must be bit-identical
/// to the degenerate (never-pipelined) full forward.
#[test]
fn gather_gemm_pipeline_overlaps_and_preserves_answers() {
    use gad::obs::trace;
    // Path graph 0-1-2-3-4-5. After warming the cones of 0 and 2,
    // querying {1, 4} leaves layer-0 work {4, 5}, while node 1's
    // layer-1 gather depends only on already-valid rows {0, 1, 2} —
    // exactly one safe prefetch row, deterministically.
    let n = 6usize;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
    let graph = GraphBuilder::new(n).edges(&edges).build();
    let fdim = 5usize;
    let mut features = Matrix::zeros(n, fdim);
    for v in 0..n {
        for c in 0..fdim {
            features[(v, c)] = ((v * fdim + c) as f32).sin();
        }
    }
    let mut rng = Rng::seed_from_u64(93);
    let params = GcnParams::init(fdim, 8, 3, 2, &mut rng);

    // degenerate control: full recompute, no cache — every next-layer
    // row has its own input in flight, so this path never pipelines
    let ctl_cfg = ServeConfig { shards: 1, cache: false, pruned: false, ..Default::default() };
    let mut ctl = Server::build(graph.clone(), features.clone(), params.clone(), ctl_cfg).unwrap();
    let ctl_res = ctl.query_batch(&[1, 4]).unwrap();

    let _g = trace::exclusive();
    trace::drain();
    trace::enable();
    let cfg = ServeConfig { shards: 1, ..Default::default() };
    let mut srv = Server::build(graph, features, params, cfg).unwrap();
    srv.query_batch(&[0]).unwrap(); // warm cone of 0: layer 0 {0,1}, layer 1 {0}
    srv.query_batch(&[2]).unwrap(); // warm cone of 2: layer 0 +{2,3}, layer 1 +{2}
    let res = srv.query_batch(&[1, 4]).unwrap();
    trace::disable();
    let t = trace::drain();

    assert!(t.count_named("serve.pipeline") >= 1, "overlap window must be spanned");
    assert!(t.count_named("serve.gather_prefetch") >= 1, "prefetch worker must be spanned");
    let pipeline_ids: Vec<u64> =
        t.events.iter().filter(|e| e.name == "serve.pipeline").map(|e| e.id).collect();
    for e in t.events.iter().filter(|e| e.name == "serve.gather_prefetch") {
        assert!(
            e.parent.map(|p| pipeline_ids.contains(&p)).unwrap_or(false),
            "prefetch must nest under its pipeline window"
        );
        assert!(e.args.iter().any(|&(k, v)| k == "rows" && v >= 1));
    }
    // and the next layer's gather actually consumed prefetched rows
    assert!(
        t.events.iter().any(|e| e.name == "serve.gather"
            && e.args.iter().any(|&(k, v)| k == "prefetched" && v >= 1)),
        "a gather must report prefetched rows"
    );

    // not one bit moved relative to the unpipelined control
    for (a, b) in res.iter().zip(&ctl_res) {
        assert_eq!(a.pred, b.pred);
        assert_eq!(
            a.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.probs.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}

/// PR 9 acceptance: a staged warm-then-mixed query sequence — the
/// shape that exercises gather→GEMM pipelining — answers
/// bit-identically at serve widths 1 and 4, and the full sweep still
/// agrees with the training forward.
#[test]
fn pipelined_serving_is_bit_identical_at_widths_1_and_4() {
    let (ds, params) = fixture(27, 3);
    let oracle = native_preds(&ds, &params);
    let n = ds.num_nodes() as u32;
    // two disjoint warm-ups, then a batch mixing warm and cold nodes
    // (partial recomputes with prefetchable rows), then the whole graph
    let warm_a: Vec<u32> = (0..n).step_by(5).collect();
    let warm_b: Vec<u32> = (2..n).step_by(7).collect();
    let mixed: Vec<u32> = (0..n).filter(|v| v % 3 != 1).collect();
    let mut fingerprints: Vec<Vec<(u32, u32, Vec<u32>)>> = Vec::new();
    for threads in [1usize, 4] {
        let cfg =
            ServeConfig { shards: 4, serve_threads: threads, seed: 11, ..Default::default() };
        let mut srv = Server::for_dataset(&ds, params.clone(), cfg).unwrap();
        srv.query_batch(&warm_a).unwrap();
        srv.query_batch(&warm_b).unwrap();
        let mixed_res = srv.query_batch(&mixed).unwrap();
        let full_res = srv.query_batch(&all_nodes(&ds)).unwrap();
        let full_preds: Vec<u32> = full_res.iter().map(|r| r.pred).collect();
        assert_eq!(full_preds, oracle, "width {threads} vs training forward");
        fingerprints.push(
            mixed_res
                .iter()
                .chain(&full_res)
                .map(|r| (r.node, r.pred, r.probs.iter().map(|v| v.to_bits()).collect()))
                .collect(),
        );
    }
    assert_eq!(fingerprints[0], fingerprints[1], "serve width changed an answer bit");
}

#[test]
fn cached_microbatched_serving_beats_unsharded_pernode() {
    // the Fig-11 acceptance criterion, at test scale: steady-state
    // cached serving must out-QPS the naive per-node full forward by a
    // wide margin (cache hit = row gather + softmax; baseline = full
    // L-layer forward over the whole graph, per query)
    let (ds, params) = fixture(30, 2);
    let cfg = ServingBenchConfig { shards: 4, queries: 120, batch: 16, ..Default::default() };
    let rep = run_serving_bench(&ds, &params, &cfg).unwrap();
    let speedup = rep.cached_speedup_vs_baseline().expect("both modes ran");
    assert!(speedup > 1.0, "cached QPS must beat the baseline (got {speedup:.2}x)");
    let md = rep.to_markdown();
    assert!(md.contains("cached-sharded") && md.contains("cold-sharded"));
}
