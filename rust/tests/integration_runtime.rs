//! Integration: the XLA backend (PJRT + AOT artifacts) against the
//! native oracle. Requires `make artifacts`; every test skips cleanly
//! when the artifact directory is absent (e.g. plain `cargo test`
//! before the first `make artifacts`).

use gad::backend::{Backend, NativeBackend, XlaBackend};
use gad::coordinator::{batch_from_subgraph, train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::model::GcnParams;
use gad::rng::Rng;

const ARTIFACTS: &str = "artifacts";

fn artifacts_ready() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.txt").exists()
}

/// Build one whole-graph batch of the tiny dataset (fits the f=32/c=4
/// default buckets).
fn tiny_batch() -> (gad::model::Batch, GcnParams) {
    let ds = SyntheticSpec::tiny().generate(77);
    let assignment = vec![0u32; ds.num_nodes()];
    let part = gad::augment::plain_part(&ds.graph, &assignment, 0);
    let batch = batch_from_subgraph(&ds, &part, 0);
    let mut rng = Rng::seed_from_u64(7);
    let params = GcnParams::init(ds.feature_dim(), 32, ds.num_classes, 2, &mut rng);
    (batch, params)
}

#[test]
fn xla_loss_and_grads_match_native() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (batch, params) = tiny_batch();
    let mut native = NativeBackend::new();
    let mut xla = XlaBackend::new(ARTIFACTS).unwrap();

    let a = native.train_step(&batch, &params).unwrap();
    let b = xla.train_step(&batch, &params).unwrap();

    assert!(
        (a.loss - b.loss).abs() < 1e-3 + 0.01 * a.loss.abs(),
        "loss native {} vs xla {}",
        a.loss,
        b.loss
    );
    for (l, (ga, gb)) in a.grads.iter().zip(&b.grads).enumerate() {
        assert!(
            ga.allclose(gb, 1e-3),
            "layer {l} grad mismatch, max diff {}",
            ga.max_abs_diff(gb)
        );
    }
}

#[test]
fn xla_predictions_match_native() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (batch, params) = tiny_batch();
    let mut native = NativeBackend::new();
    let mut xla = XlaBackend::new(ARTIFACTS).unwrap();
    let pa = native.predict(&batch, &params).unwrap();
    let pb = xla.predict(&batch, &params).unwrap();
    let agree = pa.iter().zip(&pb).filter(|(x, y)| x == y).count();
    // argmax can flip on near-ties; demand near-total agreement
    assert!(
        agree as f64 / pa.len() as f64 > 0.99,
        "only {agree}/{} predictions agree",
        pa.len()
    );
}

#[test]
fn xla_backend_trains_end_to_end() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds = SyntheticSpec::tiny().generate(78);
    let cfg = TrainConfig {
        partitions: 4,
        workers: 2,
        layers: 2,
        hidden: 32,
        lr: 0.02,
        epochs: 10,
        backend: gad::backend::BackendKind::Xla,
        artifact_dir: ARTIFACTS.to_string(),
        seed: 3,
        ..Default::default()
    };
    let r = train_gad(&ds, &cfg).unwrap();
    assert!(r.test_accuracy > 0.4, "xla e2e accuracy {}", r.test_accuracy);
}

#[test]
fn missing_bucket_is_a_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (batch, _) = tiny_batch();
    let mut rng = Rng::seed_from_u64(9);
    // hidden=77 has no compiled bucket
    let params = GcnParams::init(batch.features.cols, 77, batch.num_classes, 2, &mut rng);
    let mut xla = XlaBackend::new(ARTIFACTS).unwrap();
    let err = xla.train_step(&batch, &params).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("artifact"), "unexpected error: {msg}");
}
