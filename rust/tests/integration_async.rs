//! Integration: the bounded-staleness async engine against the
//! synchronous oracle.
//!
//! The load-bearing test is the bit-equivalence one: with
//! `staleness: 0, quorum: 0 (= all alive), lambda: 1.0` the async
//! engine must reproduce the synchronous trainer exactly — same
//! accuracies to the bit, same communication bytes. That equivalence is
//! the safety argument for the engine refactor.

use gad::coordinator::{
    train_gad, AsyncConfig, ConsensusMode, Fault, FaultPlan, TrainConfig,
};
use gad::datasets::SyntheticSpec;
use gad::proptest_util::forall;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        partitions: 4,
        workers: 2,
        layers: 2,
        hidden: 24,
        lr: 0.02,
        epochs: 6,
        seed: 7,
        ..Default::default()
    }
}

/// The degenerate async config that must equal the sync engine.
fn sync_equivalent(zeta_weighted: bool) -> AsyncConfig {
    AsyncConfig { staleness: 0, quorum: 0, lambda: 1.0, zeta_weighted }
}

fn assert_bitwise_equal(sync: &gad::coordinator::TrainReport, asy: &gad::coordinator::TrainReport) {
    assert_eq!(
        sync.test_accuracy.to_bits(),
        asy.test_accuracy.to_bits(),
        "test accuracy diverged: sync {} vs async {}",
        sync.test_accuracy,
        asy.test_accuracy
    );
    assert_eq!(sync.val_accuracy.to_bits(), asy.val_accuracy.to_bits());
    assert_eq!(sync.train_accuracy.to_bits(), asy.train_accuracy.to_bits());
    assert_eq!(sync.epochs_run, asy.epochs_run);
    assert_eq!(sync.comm.gradient_bytes, asy.comm.gradient_bytes, "gradient traffic diverged");
    assert_eq!(sync.comm.feature_bytes, asy.comm.feature_bytes, "feature traffic diverged");
    assert_eq!(asy.comm.resync_bytes, 0, "degenerate async must never re-sync");
    assert_eq!(asy.max_staleness_applied, 0);
    assert_eq!(asy.resyncs, 0);
    // per-epoch loss curves must agree bit-for-bit too (same summation order)
    assert_eq!(sync.curve.len(), asy.curve.len());
    for (a, b) in sync.curve.iter().zip(&asy.curve) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at epoch {}", a.epoch);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
}

#[test]
fn degenerate_async_is_bitwise_equal_to_weighted_sync() {
    let ds = SyntheticSpec::tiny().generate(31);
    let mut s = base_cfg();
    s.consensus = ConsensusMode::Weighted;
    let sync = train_gad(&ds, &s).unwrap();

    let mut a = base_cfg();
    a.consensus = ConsensusMode::Async(sync_equivalent(true));
    let asy = train_gad(&ds, &a).unwrap();

    assert_bitwise_equal(&sync, &asy);
}

#[test]
fn degenerate_async_is_bitwise_equal_to_plain_sync() {
    let ds = SyntheticSpec::tiny().generate(32);
    let mut s = base_cfg();
    s.consensus = ConsensusMode::Plain;
    let sync = train_gad(&ds, &s).unwrap();

    let mut a = base_cfg();
    a.consensus = ConsensusMode::Async(sync_equivalent(false));
    let asy = train_gad(&ds, &a).unwrap();

    assert_bitwise_equal(&sync, &asy);
}

#[test]
fn prop_applied_staleness_never_exceeds_bound() {
    // random staleness bounds / quorums / decay, with an injected
    // straggler so real staleness occurs; the engine's own report is
    // the observable: no applied gradient may exceed the bound
    forall("staleness bound holds", 5, |rng| {
        let staleness = rng.gen_range(4); // 0..=3
        let quorum = 1 + rng.gen_range(2); // 1 or 2
        let lambda = 0.25 + 0.5 * rng.gen_f64();
        let seed = 100 + rng.gen_range(1000) as u64;
        let ds = SyntheticSpec::tiny().generate(seed);
        let mut cfg = base_cfg();
        cfg.epochs = 3;
        cfg.hidden = 16;
        cfg.seed = seed;
        cfg.consensus = ConsensusMode::Async(AsyncConfig {
            staleness,
            quorum,
            lambda,
            zeta_weighted: true,
        });
        cfg.faults = FaultPlan {
            faults: vec![Fault::Straggle { worker: 0, epoch: 0, millis: 30 }],
        };
        let r = train_gad(&ds, &cfg).map_err(|e| format!("train failed: {e:#}"))?;
        if r.max_staleness_applied > staleness {
            return Err(format!(
                "applied staleness {} exceeds bound {staleness} (quorum {quorum})",
                r.max_staleness_applied
            ));
        }
        Ok(())
    });
}

#[test]
fn async_beats_sync_wall_clock_under_straggler() {
    // a 250ms straggler stretches every synchronous round; the async
    // engine routes around it via quorum-1 updates and only waits for
    // the laggard's single in-flight step at each epoch edge
    let ds = SyntheticSpec::tiny().generate(33);
    let straggler = FaultPlan {
        faults: vec![Fault::Straggle { worker: 0, epoch: 0, millis: 250 }],
    };

    let mut s = base_cfg();
    s.epochs = 4;
    s.consensus = ConsensusMode::Weighted;
    s.faults = straggler.clone();
    let sync = train_gad(&ds, &s).unwrap();

    let mut a = base_cfg();
    a.epochs = 4;
    a.consensus = ConsensusMode::Async(AsyncConfig {
        staleness: 3,
        quorum: 1,
        lambda: 0.5,
        zeta_weighted: true,
    });
    a.faults = straggler;
    let asy = train_gad(&ds, &a).unwrap();

    assert!(
        asy.wall_seconds < sync.wall_seconds,
        "async {:.2}s should beat sync {:.2}s under a 250ms straggler",
        asy.wall_seconds,
        sync.wall_seconds
    );
    // and it still learns: the model is driven by the healthy worker
    // with discounted straggler contributions folded in
    assert!(asy.test_accuracy > 0.25, "async accuracy {}", asy.test_accuracy);
}

#[test]
fn elastic_membership_crash_and_rejoin() {
    // a crash mid-run removes the worker from the quorum; a recovery
    // rejoins it through a fresh replica pull (re-sync traffic), and
    // the run survives end to end
    let ds = SyntheticSpec::tiny().generate(34);
    let mut cfg = base_cfg();
    cfg.epochs = 6;
    cfg.consensus = ConsensusMode::Async(AsyncConfig {
        staleness: 2,
        quorum: 1,
        lambda: 0.5,
        zeta_weighted: true,
    });
    cfg.faults = FaultPlan {
        faults: vec![
            Fault::Crash { worker: 1, epoch: 2 },
            Fault::Recover { worker: 1, epoch: 4 },
        ],
    };
    let r = train_gad(&ds, &cfg).unwrap();
    assert_eq!(r.epochs_run, 6, "run must survive the crash/rejoin cycle");
    assert!(r.resyncs >= 1, "rejoin must pull a fresh replica");
    assert!(r.comm.resync_bytes > 0, "re-sync traffic must be accounted");
    assert!(r.test_accuracy > 0.25, "accuracy {}", r.test_accuracy);
}

#[test]
fn async_mode_parses_from_cli_string() {
    let mode: ConsensusMode = "async".parse().unwrap();
    match mode {
        ConsensusMode::Async(a) => {
            assert_eq!(a.staleness, 2);
            assert_eq!(a.quorum, 0);
            assert!(a.zeta_weighted);
        }
        other => panic!("expected async, got {other:?}"),
    }
}
