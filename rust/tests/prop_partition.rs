//! Property tests: partitioner invariants over random graphs.

use gad::graph::GraphBuilder;
use gad::partition::{balance_ratio, edge_cut, partition, random, PartitionConfig};
use gad::proptest_util::{arb_graph, forall};

#[test]
fn prop_assignment_total_and_in_range() {
    forall("assignment total & in range", 40, |rng| {
        let (n, edges) = arb_graph(rng, 8, 60, 0.15);
        let g = GraphBuilder::new(n).edges(&edges).build();
        let k = 2 + rng.gen_range(4);
        let cfg = PartitionConfig { k, seed: rng.next_u64(), ..Default::default() };
        let p = partition(&g, &cfg);
        if p.assignment.len() != n {
            return Err(format!("len {} != {n}", p.assignment.len()));
        }
        if !p.assignment.iter().all(|&a| (a as usize) < k) {
            return Err("part id out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_no_empty_parts_when_k_le_n() {
    forall("no empty parts", 30, |rng| {
        let (n, edges) = arb_graph(rng, 12, 50, 0.2);
        let g = GraphBuilder::new(n).edges(&edges).build();
        let k = 2 + rng.gen_range(3);
        let p = partition(&g, &PartitionConfig { k, seed: rng.next_u64(), ..Default::default() });
        let sizes = p.part_sizes();
        if sizes.iter().any(|&s| s == 0) {
            return Err(format!("empty part: {sizes:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_reported_cut_matches_recount() {
    forall("edge cut consistency", 30, |rng| {
        let (n, edges) = arb_graph(rng, 8, 40, 0.25);
        let g = GraphBuilder::new(n).edges(&edges).build();
        let k = 2 + rng.gen_range(3);
        let p = partition(&g, &PartitionConfig { k, seed: rng.next_u64(), ..Default::default() });
        let recount = edge_cut(&g, &p.assignment);
        if recount != p.edge_cut {
            return Err(format!("reported {} recount {recount}", p.edge_cut));
        }
        Ok(())
    });
}

#[test]
fn prop_balance_within_tolerance() {
    forall("balance", 30, |rng| {
        let (n, edges) = arb_graph(rng, 20, 80, 0.1);
        let g = GraphBuilder::new(n).edges(&edges).build();
        let k = 2 + rng.gen_range(3);
        let cfg = PartitionConfig { k, epsilon: 0.15, seed: rng.next_u64(), ..Default::default() };
        let p = partition(&g, &cfg);
        // leftover-sweep slack documented in partition::tests
        let limit = 1.0 + cfg.epsilon + 0.35;
        if p.balance > limit {
            return Err(format!("balance {} > {limit}", p.balance));
        }
        Ok(())
    });
}

#[test]
fn prop_partition_deterministic_per_seed() {
    forall("determinism", 20, |rng| {
        let (n, edges) = arb_graph(rng, 8, 40, 0.2);
        let g = GraphBuilder::new(n).edges(&edges).build();
        let seed = rng.next_u64();
        let cfg = PartitionConfig { k: 3, seed, ..Default::default() };
        let a = partition(&g, &cfg);
        let b = partition(&g, &cfg);
        if a.assignment != b.assignment {
            return Err("same seed, different assignment".into());
        }
        Ok(())
    });
}

#[test]
fn prop_random_partition_balanced() {
    forall("random partition balance", 30, |rng| {
        let n = 10 + rng.gen_range(200);
        let k = 2 + rng.gen_range(6);
        let a = random::random_partition(n, k, rng.next_u64());
        let _ = balance_ratio(&a, k);
        let mut sizes = vec![0usize; k];
        for &p in &a {
            sizes[p as usize] += 1;
        }
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        if mx - mn > 1 {
            return Err(format!("sizes {sizes:?}"));
        }
        Ok(())
    });
}
