//! Integration: all seven methods train end-to-end and the paper's
//! qualitative ordering holds at miniature scale.

use gad::baselines::{train_method, Method};
use gad::coordinator::TrainConfig;
use gad::datasets::SyntheticSpec;

fn cfg() -> TrainConfig {
    TrainConfig {
        partitions: 6,
        workers: 2,
        layers: 2,
        hidden: 32,
        lr: 0.02,
        epochs: 30,
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn all_methods_learn_something() {
    let ds = SyntheticSpec::tiny().generate(31);
    for m in Method::ALL {
        let r = train_method(&ds, m, &cfg(), 120).unwrap();
        assert!(
            r.test_accuracy > 0.3,
            "{}: accuracy {}",
            m.label(),
            r.test_accuracy
        );
        assert!(r.curve.len() >= 5, "{}: no curve", m.label());
    }
}

#[test]
fn gad_at_least_matches_full_gcn_baseline() {
    // Table 2's headline: GAD >= the plain distributed GCN
    let ds = SyntheticSpec::tiny().generate(32);
    let gad = train_method(&ds, Method::Gad, &cfg(), 120).unwrap();
    let gcn = train_method(&ds, Method::Gcn, &cfg(), 120).unwrap();
    assert!(
        gad.test_accuracy >= gcn.test_accuracy - 0.03,
        "gad {} vs gcn {}",
        gad.test_accuracy,
        gcn.test_accuracy
    );
}

#[test]
fn cluster_and_gad_report_partition_cut() {
    let ds = SyntheticSpec::tiny().generate(33);
    let gad = train_method(&ds, Method::Gad, &cfg(), 120).unwrap();
    // multilevel partitioning should beat random hashing on edge cut
    let gcn = train_method(&ds, Method::Gcn, &cfg(), 120).unwrap();
    assert!(
        gad.edge_cut < gcn.edge_cut,
        "multilevel cut {} vs random cut {}",
        gad.edge_cut,
        gcn.edge_cut
    );
}

#[test]
fn sampling_methods_touch_fewer_nodes_per_round() {
    // samplers train on strict subsets; fixed full-shard batches don't
    let ds = SyntheticSpec::tiny().generate(34);
    let mut c = cfg();
    c.epochs = 3;
    let saint = train_method(&ds, Method::SaintNode, &c, 50).unwrap();
    assert!(saint.test_accuracy > 0.0);
}
