//! Property tests: augmentation invariants (Algorithm 1) over random
//! graphs and partitions.

use gad::augment::{augment_part, AugmentConfig};
use gad::graph::{candidate_replication_nodes, GraphBuilder};
use gad::partition::random::random_partition;
use gad::proptest_util::{arb_graph, forall};

fn random_setup(rng: &mut gad::rng::Rng) -> (gad::graph::Csr, Vec<u32>, usize) {
    let (n, edges) = arb_graph(rng, 10, 60, 0.15);
    let g = GraphBuilder::new(n).edges(&edges).build();
    let k = 2 + rng.gen_range(3);
    let a = random_partition(n, k, rng.next_u64());
    (g, a, k)
}

#[test]
fn prop_replicas_are_candidates() {
    forall("replicas are candidates", 30, |rng| {
        let (g, a, k) = random_setup(rng);
        let part = rng.gen_range(k) as u32;
        let cfg = AugmentConfig {
            alpha: 0.2,
            walk_length: 1 + rng.gen_range(3),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let aug = augment_part(&g, &a, part, &cfg);
        let cands = candidate_replication_nodes(&g, &a, part, cfg.walk_length);
        for r in &aug.replicas {
            if !cands.contains(r) {
                return Err(format!("replica {r} not a candidate"));
            }
            if a[*r as usize] == part {
                return Err(format!("replica {r} is local"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_base_nodes_preserved() {
    forall("base nodes preserved", 30, |rng| {
        let (g, a, k) = random_setup(rng);
        let part = rng.gen_range(k) as u32;
        let cfg = AugmentConfig { seed: rng.next_u64(), ..Default::default() };
        let aug = augment_part(&g, &a, part, &cfg);
        let expected: Vec<u32> = (0..g.num_nodes() as u32)
            .filter(|&v| a[v as usize] == part)
            .collect();
        let got: Vec<u32> = aug
            .sub
            .global_ids
            .iter()
            .zip(&aug.is_replica)
            .filter(|(_, &r)| !r)
            .map(|(&gid, _)| gid)
            .collect();
        if got != expected {
            return Err(format!("base {got:?} != expected {expected:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_no_dangling_replicas() {
    forall("no dangling replicas", 30, |rng| {
        let (g, a, k) = random_setup(rng);
        let part = rng.gen_range(k) as u32;
        let cfg = AugmentConfig {
            alpha: 0.3,
            walk_length: 2,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let aug = augment_part(&g, &a, part, &cfg);
        // BFS inside the augmented subgraph from base nodes
        let n = aug.sub.len();
        let mut seen: Vec<bool> = aug.is_replica.iter().map(|&r| !r).collect();
        let mut queue: std::collections::VecDeque<usize> = (0..n).filter(|&i| seen[i]).collect();
        while let Some(v) = queue.pop_front() {
            for &t in aug.sub.csr.neighbors(v) {
                if !seen[t as usize] {
                    seen[t as usize] = true;
                    queue.push_back(t as usize);
                }
            }
        }
        if let Some(i) = (0..n).find(|&i| aug.is_replica[i] && !seen[i]) {
            return Err(format!(
                "dangling replica local={i} global={}",
                aug.sub.global_ids[i]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_importance_in_unit_interval() {
    forall("importance in [0,1]", 30, |rng| {
        let (g, a, k) = random_setup(rng);
        let part = rng.gen_range(k) as u32;
        let cfg = AugmentConfig { seed: rng.next_u64(), ..Default::default() };
        let aug = augment_part(&g, &a, part, &cfg);
        for &(v, i) in &aug.candidate_importance {
            if !(0.0..=1.0).contains(&i) {
                return Err(format!("I({v}) = {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_replica_count_monotone_in_alpha() {
    forall("replicas monotone in alpha", 15, |rng| {
        let (g, a, k) = random_setup(rng);
        let part = rng.gen_range(k) as u32;
        let seed = rng.next_u64();
        let lo = augment_part(&g, &a, part, &AugmentConfig { alpha: 0.02, seed, ..Default::default() });
        let hi = augment_part(&g, &a, part, &AugmentConfig { alpha: 0.4, seed, ..Default::default() });
        if lo.replicas.len() > hi.replicas.len() {
            return Err(format!(
                "alpha 0.02 -> {}, alpha 0.4 -> {}",
                lo.replicas.len(),
                hi.replicas.len()
            ));
        }
        Ok(())
    });
}
