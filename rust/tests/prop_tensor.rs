//! Property tests: tensor-op algebra over random shapes/values.

use gad::proptest_util::forall;
use gad::rng::Rng;
use gad::tensor::{
    add_assign, cross_entropy_masked, gemm, gemm_into, gemm_reference, gemm_reference_into,
    gemm_ta, gemm_ta_reference, gemm_tb, gemm_tb_reference, relu, scale, set_intra_threads,
    softmax_rows, spmm_csr, spmm_csr_reference, Matrix,
};

fn rand_m(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::rand_uniform(r, c, rng)
}

/// Sparse-ish random matrix: exercises the kernels' `a == 0.0` skip,
/// which must fire for the same elements on both sides of a
/// bit-identity pair.
fn rand_sparse(rng: &mut Rng, r: usize, c: usize, p_zero: f64) -> Matrix {
    let mut m = Matrix::rand_uniform(r, c, rng);
    for v in m.data_mut() {
        if rng.gen_bool(p_zero) {
            *v = 0.0;
        }
    }
    m
}

/// Bit-for-bit equality — the determinism contract, stronger than
/// `allclose`.
fn bits_equal(a: &Matrix, b: &Matrix) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_gemm_associates_with_identity() {
    forall("A*I == A", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(20), 1 + rng.gen_range(20));
        let a = rand_m(rng, m, n);
        let prod = gemm(&a, &Matrix::eye(n));
        if !prod.allclose(&a, 1e-5) {
            return Err("A*I != A".into());
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_distributes_over_addition() {
    forall("A(B+C) == AB + AC", 25, |rng| {
        let (m, k, n) = (1 + rng.gen_range(12), 1 + rng.gen_range(12), 1 + rng.gen_range(12));
        let a = rand_m(rng, m, k);
        let b = rand_m(rng, k, n);
        let c = rand_m(rng, k, n);
        let mut bc = b.clone();
        add_assign(&mut bc, &c);
        let left = gemm(&a, &bc);
        let mut right = gemm(&a, &b);
        add_assign(&mut right, &gemm(&a, &c));
        if !left.allclose(&right, 1e-4) {
            return Err(format!("max diff {}", left.max_abs_diff(&right)));
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_variants_consistent() {
    forall("gemm_ta/tb == explicit transpose", 25, |rng| {
        let (m, k, n) = (1 + rng.gen_range(10), 1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let a = rand_m(rng, k, m);
        let b = rand_m(rng, k, n);
        if !gemm_ta(&a, &b).allclose(&gemm(&a.transpose(), &b), 1e-4) {
            return Err("gemm_ta mismatch".into());
        }
        let c = rand_m(rng, m, k);
        let d = rand_m(rng, n, k);
        if !gemm_tb(&c, &d).allclose(&gemm(&c, &d.transpose()), 1e-4) {
            return Err("gemm_tb mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_rows_are_distributions() {
    forall("softmax rows sum to 1", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(15), 2 + rng.gen_range(10));
        let mut a = rand_m(rng, m, n);
        scale(&mut a, 10.0);
        let s = softmax_rows(&a);
        for i in 0..m {
            let sum: f32 = s.row(i).iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("row {i} sums to {sum}"));
            }
            if s.row(i).iter().any(|&p| !(0.0..=1.0).contains(&p)) {
                return Err(format!("row {i} out of range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ce_gradient_rows_sum_to_zero() {
    // softmax-CE gradient (p - y) has zero row-sum on masked rows
    forall("CE grad row-sums", 25, |rng| {
        let (m, c) = (1 + rng.gen_range(12), 2 + rng.gen_range(6));
        let logits = rand_m(rng, m, c);
        let probs = softmax_rows(&logits);
        let labels: Vec<u32> = (0..m).map(|_| rng.gen_range(c) as u32).collect();
        let mask: Vec<bool> = (0..m).map(|_| rng.gen_bool(0.7)).collect();
        let (_, grad) = cross_entropy_masked(&probs, &labels, &mask);
        for i in 0..m {
            let sum: f32 = grad.row(i).iter().sum();
            if mask[i] && sum.abs() > 1e-5 {
                return Err(format!("masked row {i} sums {sum}"));
            }
            if !mask[i] && grad.row(i).iter().any(|&g| g != 0.0) {
                return Err(format!("unmasked row {i} nonzero"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_relu_idempotent_and_nonneg() {
    forall("relu", 25, |rng| {
        let (r, c) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let mut a = rand_m(rng, r, c);
        scale(&mut a, 4.0);
        relu(&mut a);
        if a.data().iter().any(|&v| v < 0.0) {
            return Err("negative after relu".into());
        }
        let mut b = a.clone();
        relu(&mut b);
        if b != a {
            return Err("relu not idempotent".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packed_gemm_bitidentical_to_reference() {
    // random ragged (m, k, n) — deliberately not multiples of the
    // MR=4 / NR=8 register blocks, so the masked tail kernel runs on
    // most iterations
    forall("packed gemm == unpacked oracle, bit-for-bit", 20, |rng| {
        let (m, k, n) = (1 + rng.gen_range(70), 1 + rng.gen_range(70), 1 + rng.gen_range(70));
        let a = rand_sparse(rng, m, k, 0.3);
        let b = rand_m(rng, k, n);
        if !bits_equal(&gemm(&a, &b), &gemm_reference(&a, &b)) {
            return Err(format!("gemm bits diverged at ({m},{k},{n})"));
        }
        // the accumulate form: C starts non-zero
        let c0 = rand_m(rng, m, n);
        let mut c_new = c0.clone();
        let mut c_ref = c0;
        gemm_into(&a, &b, &mut c_new);
        gemm_reference_into(&a, &b, &mut c_ref);
        if !bits_equal(&c_new, &c_ref) {
            return Err(format!("gemm_into bits diverged at ({m},{k},{n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_grad_kernels_bitidentical_to_sequential() {
    forall("gemm_ta/tb panels == sequential oracles, bit-for-bit", 20, |rng| {
        let (m, k, n) = (1 + rng.gen_range(40), 1 + rng.gen_range(40), 1 + rng.gen_range(40));
        let a = rand_sparse(rng, k, m, 0.3); // gemm_ta takes A as k x m
        let b = rand_m(rng, k, n);
        if !bits_equal(&gemm_ta(&a, &b), &gemm_ta_reference(&a, &b)) {
            return Err(format!("gemm_ta bits diverged at ({m},{k},{n})"));
        }
        let c = rand_m(rng, m, k);
        let d = rand_m(rng, n, k); // gemm_tb takes B as n x k
        if !bits_equal(&gemm_tb(&c, &d), &gemm_tb_reference(&c, &d)) {
            return Err(format!("gemm_tb bits diverged at ({m},{k},{n})"));
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_nnz_split_bitidentical_to_row_split() {
    forall("nnz-balanced spmm == row-count split, bit-for-bit", 20, |rng| {
        let rows = 1 + rng.gen_range(60);
        let cols = 1 + rng.gen_range(60);
        let n = 1 + rng.gen_range(24);
        // skewed degrees: a few hub rows carry most of the nnz — the
        // case the nnz split exists for
        let mut offsets = vec![0usize];
        let mut targets: Vec<u32> = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        for r in 0..rows {
            let deg = if r % 7 == 0 { rng.gen_range(40) } else { rng.gen_range(4) };
            for _ in 0..deg {
                targets.push(rng.gen_range(cols) as u32);
                values.push(0.1 + rng.gen_f32());
            }
            offsets.push(targets.len());
        }
        let dense = rand_m(rng, cols, n);
        let new = spmm_csr(&offsets, &targets, &values, &dense, rows);
        let old = spmm_csr_reference(&offsets, &targets, &values, &dense, rows);
        if !bits_equal(&new, &old) {
            return Err(format!("spmm bits diverged at rows={rows} nnz={}", targets.len()));
        }
        Ok(())
    });
}

/// Fixed shapes that cross every blocking boundary (MR=4, NR=8, MC=64,
/// KC=256) plus one large enough to clear the parallelism threshold,
/// where the new kernels genuinely run multi-threaded. Each shape is
/// also recomputed under intra-thread budgets 1 and 4 — any width must
/// produce the same bits.
#[test]
fn kernel_bitidentity_across_blocking_and_thread_widths() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (4, 8, 8),       // exact register blocks
        (5, 9, 11),      // ragged everywhere
        (64, 256, 8),    // exact MC / KC / NR
        (65, 257, 17),   // one past every block edge
        (136, 132, 128), // > PAR_THRESHOLD MACs: threaded path
    ] {
        let a = rand_sparse(&mut rng, m, k, 0.25);
        let b = rand_m(&mut rng, k, n);
        let reference = gemm_reference(&a, &b);
        let at = rand_sparse(&mut rng, k, m, 0.25);
        let ta_reference = gemm_ta_reference(&at, &b);
        let bt = rand_m(&mut rng, n, k);
        let tb_reference = gemm_tb_reference(&a, &bt);
        for budget in [1usize, 4] {
            set_intra_threads(budget);
            assert!(
                bits_equal(&gemm(&a, &b), &reference),
                "gemm ({m},{k},{n}) diverged at budget {budget}"
            );
            assert!(
                bits_equal(&gemm_ta(&at, &b), &ta_reference),
                "gemm_ta ({m},{k},{n}) diverged at budget {budget}"
            );
            assert!(
                bits_equal(&gemm_tb(&a, &bt), &tb_reference),
                "gemm_tb ({m},{k},{n}) diverged at budget {budget}"
            );
        }
        set_intra_threads(0);
    }
}

/// A hub graph big enough to force the threaded spmm path: row 0 holds
/// half the edges, so the row-count split serialises behind thread 0
/// while the nnz split rebalances — and the bits must not move.
#[test]
fn spmm_hub_graph_bitidentical_under_thread_widths() {
    let mut rng = Rng::seed_from_u64(0xBEEF);
    let (rows, cols, n) = (512usize, 512usize, 64usize);
    let hub_deg = 8_192usize;
    let mut offsets = vec![0usize];
    let mut targets: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    for r in 0..rows {
        let deg = if r == 0 { hub_deg } else { 1 + rng.gen_range(16) };
        for _ in 0..deg {
            targets.push(rng.gen_range(cols) as u32);
            values.push(0.1 + rng.gen_f32());
        }
        offsets.push(targets.len());
    }
    let dense = Matrix::rand_uniform(cols, n, &mut rng);
    let reference = spmm_csr_reference(&offsets, &targets, &values, &dense, rows);
    for budget in [1usize, 4] {
        set_intra_threads(budget);
        assert!(
            bits_equal(&spmm_csr(&offsets, &targets, &values, &dense, rows), &reference),
            "spmm hub graph diverged at budget {budget}"
        );
    }
    set_intra_threads(0);
}

#[test]
fn prop_pad_crop_roundtrip() {
    forall("pad->crop identity", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let a = rand_m(rng, m, n);
        let padded = a.pad_to(m + rng.gen_range(8), n + rng.gen_range(8));
        if padded.crop(m, n) != a {
            return Err("roundtrip broke values".into());
        }
        Ok(())
    });
}
