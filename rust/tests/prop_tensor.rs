//! Property tests: tensor-op algebra over random shapes/values.

use gad::proptest_util::forall;
use gad::rng::Rng;
use gad::tensor::{
    add_assign, cross_entropy_masked, gemm, gemm_ta, gemm_tb, relu, scale, softmax_rows, Matrix,
};

fn rand_m(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::rand_uniform(r, c, rng)
}

#[test]
fn prop_gemm_associates_with_identity() {
    forall("A*I == A", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(20), 1 + rng.gen_range(20));
        let a = rand_m(rng, m, n);
        let prod = gemm(&a, &Matrix::eye(n));
        if !prod.allclose(&a, 1e-5) {
            return Err("A*I != A".into());
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_distributes_over_addition() {
    forall("A(B+C) == AB + AC", 25, |rng| {
        let (m, k, n) = (1 + rng.gen_range(12), 1 + rng.gen_range(12), 1 + rng.gen_range(12));
        let a = rand_m(rng, m, k);
        let b = rand_m(rng, k, n);
        let c = rand_m(rng, k, n);
        let mut bc = b.clone();
        add_assign(&mut bc, &c);
        let left = gemm(&a, &bc);
        let mut right = gemm(&a, &b);
        add_assign(&mut right, &gemm(&a, &c));
        if !left.allclose(&right, 1e-4) {
            return Err(format!("max diff {}", left.max_abs_diff(&right)));
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_variants_consistent() {
    forall("gemm_ta/tb == explicit transpose", 25, |rng| {
        let (m, k, n) = (1 + rng.gen_range(10), 1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let a = rand_m(rng, k, m);
        let b = rand_m(rng, k, n);
        if !gemm_ta(&a, &b).allclose(&gemm(&a.transpose(), &b), 1e-4) {
            return Err("gemm_ta mismatch".into());
        }
        let c = rand_m(rng, m, k);
        let d = rand_m(rng, n, k);
        if !gemm_tb(&c, &d).allclose(&gemm(&c, &d.transpose()), 1e-4) {
            return Err("gemm_tb mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_rows_are_distributions() {
    forall("softmax rows sum to 1", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(15), 2 + rng.gen_range(10));
        let mut a = rand_m(rng, m, n);
        scale(&mut a, 10.0);
        let s = softmax_rows(&a);
        for i in 0..m {
            let sum: f32 = s.row(i).iter().sum();
            if (sum - 1.0).abs() > 1e-4 {
                return Err(format!("row {i} sums to {sum}"));
            }
            if s.row(i).iter().any(|&p| !(0.0..=1.0).contains(&p)) {
                return Err(format!("row {i} out of range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ce_gradient_rows_sum_to_zero() {
    // softmax-CE gradient (p - y) has zero row-sum on masked rows
    forall("CE grad row-sums", 25, |rng| {
        let (m, c) = (1 + rng.gen_range(12), 2 + rng.gen_range(6));
        let logits = rand_m(rng, m, c);
        let probs = softmax_rows(&logits);
        let labels: Vec<u32> = (0..m).map(|_| rng.gen_range(c) as u32).collect();
        let mask: Vec<bool> = (0..m).map(|_| rng.gen_bool(0.7)).collect();
        let (_, grad) = cross_entropy_masked(&probs, &labels, &mask);
        for i in 0..m {
            let sum: f32 = grad.row(i).iter().sum();
            if mask[i] && sum.abs() > 1e-5 {
                return Err(format!("masked row {i} sums {sum}"));
            }
            if !mask[i] && grad.row(i).iter().any(|&g| g != 0.0) {
                return Err(format!("unmasked row {i} nonzero"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_relu_idempotent_and_nonneg() {
    forall("relu", 25, |rng| {
        let (r, c) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let mut a = rand_m(rng, r, c);
        scale(&mut a, 4.0);
        relu(&mut a);
        if a.data().iter().any(|&v| v < 0.0) {
            return Err("negative after relu".into());
        }
        let mut b = a.clone();
        relu(&mut b);
        if b != a {
            return Err("relu not idempotent".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pad_crop_roundtrip() {
    forall("pad->crop identity", 25, |rng| {
        let (m, n) = (1 + rng.gen_range(10), 1 + rng.gen_range(10));
        let a = rand_m(rng, m, n);
        let padded = a.pad_to(m + rng.gen_range(8), n + rng.gen_range(8));
        if padded.crop(m, n) != a {
            return Err("roundtrip broke values".into());
        }
        Ok(())
    });
}
