//! Persistence properties: GADB dataset files and GADCKPT checkpoints
//! must round-trip losslessly — including split masks, sparse feature
//! encoding, and exact f32 bit patterns.

use gad::datasets::{io, Dataset, Split};
use gad::graph::GraphBuilder;
use gad::model::{checkpoint, GcnParams};
use gad::proptest_util::{arb_graph, forall};
use gad::tensor::Matrix;

#[test]
fn gadb_roundtrip_is_identity() {
    forall("to_gadb -> from_gadb is the identity", 40, |rng| {
        let (n, edges) = arb_graph(rng, 2, 40, 0.15);
        let classes = 1 + rng.gen_range(5);
        let f = 1 + rng.gen_range(12);
        // sparse-ish features with negative / fractional values so the
        // index:value encoding and float formatting are both exercised
        let mut features = Matrix::zeros(n, f);
        for i in 0..n {
            for j in 0..f {
                if rng.gen_bool(0.3) {
                    features[(i, j)] = (rng.gen_f32() - 0.5) * 100.0;
                }
            }
        }
        let labels: Vec<u32> = (0..n).map(|_| rng.gen_range(classes) as u32).collect();
        let split = Split::random(n, 0.5, 0.2, rng);
        let ds = Dataset {
            name: format!("prop {n}"),
            graph: GraphBuilder::new(n).edges(&edges).build(),
            features,
            labels,
            num_classes: classes,
            split,
        };

        let back = io::from_gadb(&io::to_gadb(&ds)).map_err(|e| format!("parse: {e:#}"))?;
        back.validate().map_err(|e| format!("validate: {e}"))?;
        if back.name != ds.name {
            return Err(format!("name: '{}' != '{}'", back.name, ds.name));
        }
        if back.graph != ds.graph {
            return Err("graph differs".into());
        }
        if back.labels != ds.labels || back.num_classes != ds.num_classes {
            return Err("labels differ".into());
        }
        let bits = |m: &Matrix| m.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        if bits(&back.features) != bits(&ds.features) {
            return Err("features not bit-identical".into());
        }
        if back.split.train != ds.split.train
            || back.split.val != ds.split.val
            || back.split.test != ds.split.test
        {
            return Err("split masks differ".into());
        }
        Ok(())
    });
}

#[test]
fn checkpoint_roundtrip_is_identity() {
    forall("to_text -> from_text is the identity", 30, |rng| {
        let f = 1 + rng.gen_range(20);
        let h = 1 + rng.gen_range(16);
        let c = 2 + rng.gen_range(6);
        let layers = 1 + rng.gen_range(4);
        let params = GcnParams::init(f, h, c, layers, rng);
        let back = checkpoint::from_text(&checkpoint::to_text(&params))
            .map_err(|e| format!("parse: {e:#}"))?;
        if back.layers() != params.layers() {
            return Err("layer count differs".into());
        }
        for (a, b) in params.ws.iter().zip(&back.ws) {
            if (a.rows, a.cols) != (b.rows, b.cols) {
                return Err("shape differs".into());
            }
            let ab: Vec<u32> = a.data().iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
            if ab != bb {
                return Err("weights not bit-identical".into());
            }
        }
        Ok(())
    });
}
