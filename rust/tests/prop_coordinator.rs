//! Property tests: coordinator invariants — consensus arithmetic,
//! subgraph loading, ζ weighting.

use gad::coordinator::{aggregate_gradients, allocate_subgraphs};
use gad::proptest_util::forall;
use gad::rng::Rng;
use gad::tensor::Matrix;
use gad::variance::zeta_weights;

fn rand_grads(rng: &mut Rng, workers: usize, shape: (usize, usize)) -> Vec<Vec<Matrix>> {
    (0..workers)
        .map(|_| vec![Matrix::rand_uniform(shape.0, shape.1, rng)])
        .collect()
}

#[test]
fn prop_consensus_bounded_by_extremes() {
    // every entry of the aggregate lies within [min, max] over workers
    forall("consensus convexity", 30, |rng| {
        let w = 2 + rng.gen_range(4);
        let shape = (1 + rng.gen_range(4), 1 + rng.gen_range(4));
        let grads = rand_grads(rng, w, shape);
        let weights: Vec<f64> = (0..w).map(|_| 0.1 + rng.gen_f64()).collect();
        let agg = aggregate_gradients(&grads, &weights);
        for idx in 0..shape.0 * shape.1 {
            let vals: Vec<f32> = grads.iter().map(|g| g[0].data()[idx]).collect();
            let (mn, mx) = vals
                .iter()
                .fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)));
            let got = agg[0].data()[idx];
            if got < mn - 1e-5 || got > mx + 1e-5 {
                return Err(format!("agg {got} outside [{mn}, {mx}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_consensus_with_equal_weights_is_mean() {
    forall("equal weights == mean", 30, |rng| {
        let w = 2 + rng.gen_range(4);
        let grads = rand_grads(rng, w, (3, 2));
        let agg = aggregate_gradients(&grads, &vec![7.0; w]);
        for idx in 0..6 {
            let mean: f32 =
                grads.iter().map(|g| g[0].data()[idx]).sum::<f32>() / w as f32;
            if (agg[0].data()[idx] - mean).abs() > 1e-5 {
                return Err("not the mean".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_partitions_the_index_set() {
    forall("allocation is a partition", 40, |rng| {
        let n = 1 + rng.gen_range(40);
        let workers = 1 + rng.gen_range(8);
        let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.gen_range(500)).collect();
        let alloc = allocate_subgraphs(&sizes, workers);
        if alloc.len() != workers {
            return Err("wrong worker count".into());
        }
        let mut all: Vec<usize> = alloc.iter().flatten().copied().collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..n).collect();
        if all != expect {
            return Err(format!("not a partition: {all:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_allocation_near_balanced() {
    // LPT guarantee: makespan <= (4/3 - 1/3m) * OPT; with OPT >= total/m
    // we check load_max <= 4/3 * total/m + max_item
    forall("allocation balance", 30, |rng| {
        let n = 2 + rng.gen_range(40);
        let workers = 1 + rng.gen_range(6);
        let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.gen_range(300)).collect();
        let alloc = allocate_subgraphs(&sizes, workers);
        let total: usize = sizes.iter().sum();
        let max_item = *sizes.iter().max().unwrap();
        let max_load = alloc
            .iter()
            .map(|w| w.iter().map(|&i| sizes[i]).sum::<usize>())
            .max()
            .unwrap();
        let bound = (4 * total).div_ceil(3 * workers) + max_item;
        if max_load > bound {
            return Err(format!("load {max_load} > bound {bound}"));
        }
        Ok(())
    });
}

#[test]
fn prop_zeta_weights_mean_one() {
    forall("zeta weights normalised", 30, |rng| {
        let n = 1 + rng.gen_range(12);
        let zs: Vec<f64> = (0..n).map(|_| rng.gen_f64() * 5.0).collect();
        let w = zeta_weights(&zs);
        let sum: f64 = w.iter().sum();
        if (sum - n as f64).abs() > 1e-9 {
            return Err(format!("sum {sum} != {n}"));
        }
        // order preserved
        for i in 0..n {
            for j in 0..n {
                if zs[i] > zs[j] && w[i] < w[j] - 1e-12 {
                    return Err("ordering broken".into());
                }
            }
        }
        Ok(())
    });
}
