//! Bench: Fig 13 (ours) — serving under skewed elastic inserts. Trains
//! a small model, stands up two identical Exact-halo deployments, then
//! replays the same hot-part insert schedule against both: one with the
//! online rebalancer defending a max/min part-size ratio, one drifting.
//! Reports per-round imbalance ratio and query p50/p99, the migration
//! byte bill, and the replication cost a full repartition would pay.
//!
//! Output: CSV `mode,round,imbalance_ratio,query_p50_us,query_p99_us,
//! moves,rebalance_bytes`.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::serve::{run_rebalance_bench, RebalanceBenchConfig};

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 12,
        seed: 42,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).expect("training run");
    let params = report.final_params.expect("trained parameters");
    eprintln!("trained: acc {:.4}; skewed-insert sweep...", report.test_accuracy);

    let bcfg = RebalanceBenchConfig {
        shards: 4,
        rounds: 10,
        inserts_per_round: 32,
        queries_per_round: 256,
        batch: 32,
        rebalance_ratio: 1.5,
        seed: 42,
        ..Default::default()
    };
    let rep = run_rebalance_bench(&ds, &params, &bcfg).expect("rebalance bench");
    print!("{}", rep.to_csv());
    eprintln!(
        "rebalancer held max/min <= {:.3} (drift reached {:.3}); {} rebalance bytes vs >= {} for a full repartition",
        rep.max_ratio_on(),
        rep.max_ratio_off(),
        rep.total_rebalance_bytes(),
        rep.full_repartition_bytes
    );
}
