//! Bench: hot-path microbenchmarks — the §Perf working set.
//! GEMM / SpMM / train_step (native + xla) / partition / augmentation /
//! ζ / consensus. Before/after numbers for EXPERIMENTS.md §Perf come
//! from here.

use gad::augment::{augment_all, AugmentConfig};
use gad::backend::{Backend, NativeBackend, XlaBackend};
use gad::bench_util::Bencher;
use gad::coordinator::{aggregate_gradients, batch_from_subgraph};
use gad::datasets::SyntheticSpec;
use gad::model::GcnParams;
use gad::partition::{partition, PartitionConfig};
use gad::rng::Rng;
use gad::tensor::{gemm, Matrix};
use gad::variance::{zeta, ZetaConfig};

fn main() {
    let mut b = Bencher::new(1, 5);
    let mut rng = Rng::seed_from_u64(1);

    // --- L3 tensor kernels ------------------------------------------------
    println!("== tensor kernels ==");
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 1433, 128), (1024, 512, 256)] {
        let a = Matrix::rand_uniform(m, k, &mut rng);
        let w = Matrix::rand_uniform(k, n, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let s = b.bench(&format!("gemm {m}x{k}x{n}"), || gemm(&a, &w));
        println!(
            "    -> {:.2} GFLOP/s",
            flops / s.mean.as_secs_f64() / 1e9
        );
    }

    // --- dataset fixture ----------------------------------------------------
    let ds = SyntheticSpec::cora_like().generate(42);
    let cfg = PartitionConfig { k: 16, seed: 42, ..Default::default() };

    println!("\n== partition / augmentation ==");
    b.bench("multilevel partition cora-like k=16", || partition(&ds.graph, &cfg));
    let part = partition(&ds.graph, &cfg);
    let acfg = AugmentConfig { alpha: 0.01, walk_length: 2, seed: 42, ..Default::default() };
    b.bench("augment_all cora-like k=16", || {
        augment_all(&ds.graph, &part.assignment, 16, &acfg)
    });
    let augs = augment_all(&ds.graph, &part.assignment, 16, &acfg);

    println!("\n== batch build / zeta / consensus ==");
    b.bench("batch_from_subgraph (one part)", || {
        batch_from_subgraph(&ds, &augs[0], 0)
    });
    let batch = batch_from_subgraph(&ds, &augs[0], 0);
    b.bench("zeta (one part, features)", || {
        zeta(&augs[0].sub.csr, Some(&batch.features), &ZetaConfig::default())
    });
    let mut prng = Rng::seed_from_u64(2);
    let params = GcnParams::init(ds.feature_dim(), 128, ds.num_classes, 2, &mut prng);
    let grads: Vec<Vec<Matrix>> = (0..4).map(|_| params.ws.clone()).collect();
    b.bench("aggregate_gradients 4 workers (f1433 h128)", || {
        aggregate_gradients(&grads, &[1.0, 2.0, 3.0, 4.0])
    });

    println!("\n== serve query_batch (4 shards, cache off, 64-node mixed batch) ==");
    {
        use gad::serve::{ServeConfig, Server};
        // cache off so every flush recomputes — the parallel pool has
        // real per-shard work to overlap, not cache lookups
        let scfg = ServeConfig { shards: 4, cache: false, seed: 42, ..Default::default() };
        let batch_nodes: Vec<u32> =
            (0..64u32).map(|i| (i * 37) % ds.graph.num_nodes() as u32).collect();
        let mut seq = Server::for_dataset(&ds, params.clone(), scfg.clone()).unwrap();
        b.bench("query_batch serve_threads=1", || seq.query_batch(&batch_nodes).unwrap());
        let par_cfg = ServeConfig { serve_threads: 4, ..scfg };
        let mut par = Server::for_dataset(&ds, params.clone(), par_cfg).unwrap();
        b.bench("query_batch serve_threads=4", || par.query_batch(&batch_nodes).unwrap());
    }

    println!("\n== train_step (one augmented cora subgraph) ==");
    let mut native = NativeBackend::new();
    b.bench("native train_step", || native.train_step(&batch, &params).unwrap());
    b.bench("native predict", || native.predict(&batch, &params).unwrap());

    if std::path::Path::new("artifacts/manifest.txt").exists() {
        match XlaBackend::new("artifacts") {
            Ok(mut xla) => {
                // first call compiles; bench steady-state after warmup
                let _ = xla.train_step(&batch, &params);
                b.bench("xla train_step (AOT pallas artifact)", || {
                    xla.train_step(&batch, &params).unwrap()
                });
                b.bench("xla predict", || xla.predict(&batch, &params).unwrap());
            }
            Err(e) => eprintln!("xla backend unavailable: {e:#}"),
        }
    } else {
        eprintln!("artifacts/ missing — skipping xla benches (run `make artifacts`)");
    }

    println!("\n== summary ==\n{}", b.markdown());
}
