//! Bench: Fig 10 (ours) — synchronous vs bounded-staleness async
//! consensus under an injected straggler. The sync engine's epoch time
//! stretches to the slowest worker; the async engine routes around it
//! and pays only a bounded accuracy discount.
//!
//! Output: CSV `engine,staleness,quorum,wall_seconds,test_accuracy,resyncs`.

use gad::coordinator::{
    train_gad, AsyncConfig, ConsensusMode, Fault, FaultPlan, TrainConfig,
};
use gad::datasets::SyntheticSpec;

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    let straggle_ms = 100u64;
    let base = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 10,
        seed: 42,
        ..Default::default()
    };
    let faults = FaultPlan {
        faults: vec![Fault::Straggle { worker: 0, epoch: 0, millis: straggle_ms }],
    };

    println!("engine,staleness,quorum,wall_seconds,test_accuracy,resyncs");

    let mut sync = base.clone();
    sync.consensus = ConsensusMode::Weighted;
    sync.faults = faults.clone();
    let r = train_gad(&ds, &sync).expect("sync run");
    println!("sync,-,-,{:.3},{:.4},{}", r.wall_seconds, r.test_accuracy, r.resyncs);

    for (staleness, quorum) in [(1usize, 3usize), (2, 3), (2, 1), (4, 1)] {
        let mut cfg = base.clone();
        cfg.consensus = ConsensusMode::Async(AsyncConfig {
            staleness,
            quorum,
            lambda: 0.5,
            zeta_weighted: true,
        });
        cfg.faults = faults.clone();
        let r = train_gad(&ds, &cfg).expect("async run");
        println!(
            "async,{staleness},{quorum},{:.3},{:.4},{}",
            r.wall_seconds, r.test_accuracy, r.resyncs
        );
    }
}
