//! Bench: Fig 5 — accuracy-vs-epoch curves for all methods on one
//! scaled dataset (full version: `gad fig5`). Prints a compact curve
//! every 5 epochs per method.

use gad::baselines::{train_method, Method};
use gad::coordinator::TrainConfig;
use gad::datasets::Dataset;

fn main() {
    let ds = Dataset::by_name_scaled("cora", 42, 0.25).unwrap();
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.01,
        epochs: 30,
        seed: 42,
        ..Default::default()
    };
    println!("== Fig 5 (cora 1/4-scale): test accuracy by epoch ==");
    println!("method,epoch,accuracy");
    for m in Method::ALL {
        let r = train_method(&ds, m, &cfg, 150).unwrap();
        for p in r.curve.iter().filter(|p| p.epoch % 5 == 0) {
            println!("{},{},{:.4}", m.label(), p.epoch, p.accuracy);
        }
    }
}
