//! Bench: Fig 15 (ours) — where the time actually goes. Runs one
//! small train → serve-burst → open-loop-replay pass with the global
//! tracer on the whole time, then folds the drained spans into the
//! per-phase profile: count, total time, tier share, p50/p99 from the
//! deterministic log-bucketed histogram, bytes where spans carry them.
//! Wall rows come from RAII scopes; virtual rows are the load
//! generator's virtual-time annotations (queueing vs service vs
//! delta-barrier drains).
//!
//! Output: CSV `tier,phase,clock,count,total_ms,share,mean_us,p50_us,
//! p99_us,max_us,bytes`.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::loadgen::{
    generate_schedule, run_open_loop, SimOptions, SloBatchScheduler, WorkloadConfig,
};
use gad::obs::{trace, MetricsRegistry, ProfileReport};
use gad::serve::{ServeConfig, Server};

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    trace::enable();

    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 12,
        seed: 42,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).expect("training run");
    let params = report.final_params.clone().expect("trained parameters");
    eprintln!("trained: acc {:.4}; serve burst + replay...", report.test_accuracy);

    let scfg = ServeConfig { shards: 4, seed: 42, ..Default::default() };
    let mut srv = Server::for_dataset(&ds, params, scfg).expect("server build");
    let nodes: Vec<u32> = (0..256u32).map(|i| i % ds.num_nodes().max(1) as u32).collect();
    for chunk in nodes.chunks(32) {
        srv.query_batch(chunk).expect("query burst");
    }

    let wcfg = WorkloadConfig { events: 600, seed: 42, ..Default::default() };
    let schedule = generate_schedule(&ds.graph, ds.feature_dim(), &wcfg);
    let mut sched = SloBatchScheduler::new(srv.num_shards(), 16, 1_250);
    let sim = run_open_loop(&mut srv, &schedule, &mut sched, &SimOptions::default())
        .expect("open-loop replay");

    trace::disable();
    let t = trace::drain();
    let mut reg = MetricsRegistry::new();
    reg.record_train_report("train", &report);
    reg.record_serve_stats("serve", &srv.stats());
    reg.record_sim_result("loadgen", &sim);
    let prof = ProfileReport::from_trace("tiny", &t, reg);

    print!("{}", prof.to_csv());
    let tiers = t.tiers();
    eprintln!(
        "{} spans across tiers {:?}; {} phase rows, {} metrics",
        prof.span_count,
        tiers,
        prof.rows.len(),
        prof.registry.len(),
    );
}
