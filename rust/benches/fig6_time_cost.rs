//! Bench: Fig 6 — time-to-convergence per method (scaled datasets);
//! prints the GAD speedup column the paper reports as 1.7-3.1x.

use gad::baselines::{train_method, Method};
use gad::coordinator::TrainConfig;
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() {
    let datasets: Vec<Dataset> = ["cora", "pubmed"]
        .iter()
        .map(|&n| Dataset::by_name_scaled(n, 42, 0.125).unwrap())
        .collect();
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 64,
        lr: 0.01,
        epochs: 40,
        stop_on_converge: true,
        seed: 42,
        ..Default::default()
    };
    let mut times = Vec::new();
    for m in Method::ALL {
        let mut total = 0.0;
        for ds in &datasets {
            let r = train_method(ds, m, &cfg, 200).unwrap();
            total += r.time_to_converge;
        }
        times.push((m, total / datasets.len() as f64));
        eprintln!("{:28} {:.2}s", m.label(), times.last().unwrap().1);
    }
    let gad = times.iter().find(|(m, _)| *m == Method::Gad).unwrap().1;
    let mut t = MarkdownTable::new(&["Method", "avg convergence (s)", "GAD speedup"]);
    for (m, s) in &times {
        t.row(vec![
            m.label().to_string(),
            format!("{s:.2}"),
            format!("{:.1}x", s / gad.max(1e-9)),
        ]);
    }
    println!("\n== Fig 6 (1/8-scale) ==\n{}", t.render());
}
