//! Bench: Fig 9 — weighted vs plain global consensus (flickr, scaled).
//! The paper's claim: ζ-weighting reaches lower loss sooner.

use gad::coordinator::{train_gad, ConsensusMode, TrainConfig};
use gad::datasets::Dataset;

fn main() {
    let ds = Dataset::by_name_scaled("flickr", 42, 0.125).unwrap();
    println!("consensus,partitions,epoch,loss");
    for k in [10usize, 20] {
        for mode in [ConsensusMode::Weighted, ConsensusMode::Plain] {
            let cfg = TrainConfig {
                partitions: k,
                workers: 4,
                layers: 3,
                hidden: 64,
                lr: 0.01,
                epochs: 25,
                consensus: mode,
                seed: 42,
                ..Default::default()
            };
            let r = train_gad(&ds, &cfg).unwrap();
            let label = if mode == ConsensusMode::Weighted { "weighted" } else { "plain" };
            for p in r.curve.iter().filter(|p| p.epoch % 5 == 0 || p.epoch == 24) {
                println!("{label},{k},{},{:.4}", p.epoch, p.loss);
            }
        }
    }
}
