//! Bench: Table 4 — augmentation impact on accuracy / memory / comm
//! (cora + pubmed, 1 vs 4 workers, scaled).

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::Dataset;
use gad::metrics::MarkdownTable;

fn main() {
    let mut table = MarkdownTable::new(&[
        "Dataset", "Workers", "Augmentation", "Accuracy", "Memory/worker (MB)", "Comm (MB)",
    ]);
    for name in ["cora", "pubmed"] {
        let ds = Dataset::by_name_scaled(name, 42, 0.25).unwrap();
        for workers in [1usize, 4] {
            for augment in [false, true] {
                let cfg = TrainConfig {
                    partitions: if workers == 1 { 1 } else { 8 },
                    workers,
                    layers: 2,
                    hidden: 64,
                    lr: 0.01,
                    epochs: 30,
                    augment,
                    alpha: 0.01,
                    seed: 42,
                    ..Default::default()
                };
                let r = train_gad(&ds, &cfg).unwrap();
                eprintln!(
                    "{name} w={workers} aug={augment}: acc {:.4} mem {:.2}MB comm {:.4}MB",
                    r.test_accuracy,
                    r.memory_mb_per_worker(),
                    r.comm.feature_mb()
                );
                table.row(vec![
                    name.into(),
                    workers.to_string(),
                    if augment { "Yes" } else { "No" }.into(),
                    format!("{:.4}", r.test_accuracy),
                    format!("{:.2}", r.memory_mb_per_worker()),
                    format!("{:.4}", r.comm.feature_mb()),
                ]);
            }
        }
    }
    println!("\n== Table 4 (1/4-scale) ==\n{}", table.render());
}
