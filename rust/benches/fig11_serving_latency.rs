//! Bench: Fig 11 (ours) — inference serving latency. Trains a small
//! model, checkpoints it, reloads it, and measures p50/p99 request
//! latency plus QPS for three deployments answering the same random
//! query stream: the naive unsharded per-node forward, cold sharded
//! micro-batched serving, and the full cached subsystem.
//!
//! Output: CSV `mode,batch,p50_us,p99_us,mean_us,qps,cache_hits,rows_recomputed`.

use gad::coordinator::{train_gad, TrainConfig};
use gad::datasets::SyntheticSpec;
use gad::model::checkpoint;
use gad::serve::{run_serving_bench, ServingBenchConfig};

fn main() {
    let ds = SyntheticSpec::tiny().generate(42);
    let cfg = TrainConfig {
        partitions: 8,
        workers: 4,
        layers: 2,
        hidden: 48,
        lr: 0.02,
        epochs: 15,
        seed: 42,
        ..Default::default()
    };
    let report = train_gad(&ds, &cfg).expect("training run");
    let params = report.final_params.expect("trained parameters");
    eprintln!(
        "trained: acc {:.4} ({} params); checkpoint round-trip...",
        report.test_accuracy,
        params.num_params()
    );
    let params = checkpoint::from_text(&checkpoint::to_text(&params)).expect("checkpoint");

    let bcfg = ServingBenchConfig { shards: 4, queries: 1500, batch: 32, ..Default::default() };
    let rep = run_serving_bench(&ds, &params, &bcfg).expect("serving bench");
    print!("{}", rep.to_csv());
    if let Some(x) = rep.cached_speedup_vs_baseline() {
        eprintln!("cached-sharded vs unsharded-pernode: {x:.1}x QPS");
    }
}
